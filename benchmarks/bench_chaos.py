"""ISSUE 10: latency-fault chaos suite — BENCH_chaos.json.

The paper's "online graph database" claim is exercised where it actually
breaks: under PARTIAL slowness and overload, not under clean load. A
`FrontDesk` (admission control + same-kind coalescing) fronts a 2-shard
`ShardRouter` (deadline-propagating RPCs, backoff retries, hedged
broadcasts, per-shard breakers); an unsharded ServiceDB fed the same
edges is the bitwise oracle. Three measured phases, one fixed op mix
(point out/in lookups, friends-of-friends, and writes into a reserved
id range that never intersects the read sample):

  1. `baseline` — fault-free closed loop: the capacity estimate and the
     fault-free latency distribution every other gate is relative to.
  2. `stall`   — one shard's worker stalls `delay:50` with probability
     0.05 per op (seeded, armed over the per-shard failpoint RPC). Gates:
     aggregate p99 within 3x the fault-free p99 (hedged reads mop up the
     stalls), ZERO requests completing past their deadline without a
     typed error, and every admitted answer bitwise-equal to the oracle.
  3. `overload` — 2x the measured capacity offered open-loop. Gates:
     shed requests fail typed (`OverloadError`) in < 10ms at p99,
     admitted goodput >= 70% of fault-free capacity, zero untyped-late,
     answers bitwise-equal, and the store's edge count grows by EXACTLY
     the number of acknowledged inserts (shed writes never applied).

`--smoke` shrinks the store and durations and exits non-zero on any gate
failure — the CI step. The full run commits BENCH_chaos.json.
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import threading
import time

import numpy as np

from .common import percentiles, power_law_graph, save

# fault shape (the ISSUE acceptance scenario)
STALL_MS = 50
STALL_PROB = 0.05
STALL_SEED = 20260809

# gates
P99_DEGRADE_X = 3.0        # stalled p99 vs fault-free p99
SHED_P99_MS = 10.0         # typed shed latency at p99
GOODPUT_FRAC = 0.70        # admitted goodput vs fault-free capacity
OVERLOAD_X = 2.0           # offered load vs measured capacity

# client-side tolerance when checking "completed past deadline without a
# typed error": the front desk enforces the deadline at delivery; the
# extra scheduling hop before result() returns is measurement noise, not
# a lifecycle violation
LATE_TOL_S = 0.025

READ_DEADLINE_S = 0.25
INSERT_DEADLINE_S = 1.0    # writes are never hedged/retried; a generous
# budget keeps "applied but reported late" out of the write-count oracle

MIX = (("out", 0.60), ("in", 0.25), ("fof", 0.10), ("insert", 0.05))


def _db_kw():
    return dict(n_partitions=8, n_levels=2, branching=8,
                buffer_cap=50_000, max_partition_edges=16_000_000,
                persist_min_edges=4096, checkpoint_interval_ops=10 ** 9,
                wal_tail_budget_bytes=1 << 40)


def _pick_op(rng):
    x = rng.random()
    acc = 0.0
    for op, w in MIX:
        acc += w
        if x < acc:
            return op
    return MIX[0][0]


class _Oracle:
    """Precomputed fault-free answers (canonical sorted order) for the
    read sample, from the unsharded reference store."""

    def __init__(self, ref, sample):
        from repro.core import two_hop_counts
        self.sample = sample
        self.out = {}
        self.inn = {}
        self.fof = {}
        with ref.read_view() as view:
            eng = view.storage_engine()
            vals, offs = eng._neighbors_batch(sample, "out")
            for i, v in enumerate(sample):
                self.out[int(v)] = np.sort(vals[offs[i]:offs[i + 1]])
            vals, offs = eng._neighbors_batch(sample, "in")
            for i, v in enumerate(sample):
                self.inn[int(v)] = np.sort(vals[offs[i]:offs[i + 1]])
            res = two_hop_counts(eng, sample)
            for i, v in enumerate(sample):
                self.fof[int(v)] = res.ids[res.slice_of(i)]

    def check(self, op, v, got):
        want = {"out": self.out, "in": self.inn, "fof": self.fof}[op][v]
        return np.array_equal(np.asarray(got), want)


class _Tally:
    """One phase's request accounting (merged across client threads)."""

    def __init__(self):
        self.lock = threading.Lock()
        self.lat_ms = []          # completed requests (ok or typed-late)
        self.shed_ms = []         # admission sheds (typed OverloadError)
        self.ok = 0
        self.typed_deadline = 0   # DeadlineExceeded anywhere in the path
        self.typed_overload = 0
        self.other_errors = 0
        self.late_untyped = 0     # ok but past budget + tolerance: gate=0
        self.mismatches = 0       # answers != oracle: gate=0
        self.inserts_ok = 0

    def merge(self, other):
        with self.lock:
            self.lat_ms += other.lat_ms
            self.shed_ms += other.shed_ms
            for k in ("ok", "typed_deadline", "typed_overload",
                      "other_errors", "late_untyped", "mismatches",
                      "inserts_ok"):
                setattr(self, k, getattr(self, k) + getattr(other, k))

    def doc(self, duration_s):
        return {
            "requests": self.ok + self.typed_deadline
            + self.typed_overload + self.other_errors + len(self.shed_ms),
            "ok": self.ok,
            "ok_per_s": self.ok / duration_s,
            "sheds": len(self.shed_ms),
            "typed_deadline": self.typed_deadline,
            "typed_overload": self.typed_overload,
            "other_errors": self.other_errors,
            "late_untyped": self.late_untyped,
            "oracle_mismatches": self.mismatches,
            "inserts_ok": self.inserts_ok,
            "latency_ms": percentiles(self.lat_ms),
            "shed_latency_ms": percentiles(self.shed_ms),
        }


def _one_request(fd, oracle, op, v, ins, tally):
    """Issue one request through the front desk, classify the outcome."""
    from repro.core import Deadline, DeadlineExceeded, OverloadError

    budget = INSERT_DEADLINE_S if op == "insert" else READ_DEADLINE_S
    dl = Deadline.after(budget)
    t0 = time.perf_counter()
    try:
        if op == "insert":
            src, dst = ins()
            fut = fd.submit("insert", deadline=dl, src=src, dst=dst)
        else:
            kind = "out_neighbors" if op == "out" else (
                "in_neighbors" if op == "in" else "fof")
            fut = fd.submit(kind, deadline=dl, v=v)
    except OverloadError:
        tally.shed_ms.append((time.perf_counter() - t0) * 1e3)
        return
    except DeadlineExceeded:
        tally.typed_deadline += 1
        return
    try:
        res = fut.result(timeout=60.0)
    except DeadlineExceeded:
        tally.typed_deadline += 1
        tally.lat_ms.append((time.perf_counter() - t0) * 1e3)
        return
    except OverloadError:
        tally.typed_overload += 1
        return
    except Exception:  # noqa: BLE001 — counted, gated via other_errors
        tally.other_errors += 1
        return
    elapsed = time.perf_counter() - t0
    tally.lat_ms.append(elapsed * 1e3)
    tally.ok += 1
    if elapsed > budget + LATE_TOL_S:
        tally.late_untyped += 1
    if op == "insert":
        tally.inserts_ok += 1
    elif not oracle.check(op, v, res):
        tally.mismatches += 1


def _closed_loop(fd, oracle, n_threads, duration_s, reserve, seed0):
    """Fixed offered load: n_threads clients, each submitting the op mix
    back to back. Returns the merged tally (per-thread seeded => the mix
    is identical across phases)."""
    total = _Tally()
    barrier = threading.Barrier(n_threads)

    def client(idx):
        rng = np.random.default_rng(seed0 + idx)
        local = _Tally()
        ctr = [0]

        def ins():
            d = reserve["dst0"] + (ctr[0] % reserve["n_dst"])
            ctr[0] += 1
            return (np.asarray([reserve["src0"] + idx], np.int64),
                    np.asarray([d], np.int64))

        barrier.wait()
        t_end = time.perf_counter() + duration_s
        while time.perf_counter() < t_end:
            op = _pick_op(rng)
            v = int(oracle.sample[rng.integers(0, len(oracle.sample))])
            _one_request(fd, oracle, op, v, ins, local)
        total.merge(local)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return total


def _open_loop(fd, oracle, rate_rps, duration_s, reserve, seed0,
               src_off=63):
    """Offered load decoupled from completion: one pacer submits at
    `rate_rps` regardless of how fast the store answers (the overload
    phase), worker threads resolve the futures so the pacer never blocks
    on a result."""
    total = _Tally()
    rng = np.random.default_rng(seed0)
    pending = []
    plock = threading.Lock()
    done = threading.Event()
    ctr = [0]

    def ins():
        d = reserve["dst0"] + (ctr[0] % reserve["n_dst"])
        ctr[0] += 1
        return (np.asarray([reserve["src0"] + src_off], np.int64),
                np.asarray([d], np.int64))

    def resolver():
        from repro.core import DeadlineExceeded, OverloadError
        while True:
            with plock:
                batch, pending[:] = pending[:], []
            if not batch and done.is_set():
                return
            for op, v, budget, t0, fut in batch:
                try:
                    res = fut.result(timeout=60.0)
                except DeadlineExceeded:
                    total.typed_deadline += 1
                    total.lat_ms.append((time.perf_counter() - t0) * 1e3)
                    continue
                except OverloadError:
                    total.typed_overload += 1
                    continue
                except Exception:  # noqa: BLE001
                    total.other_errors += 1
                    continue
                elapsed = time.perf_counter() - t0
                total.lat_ms.append(elapsed * 1e3)
                total.ok += 1
                if elapsed > budget + LATE_TOL_S:
                    total.late_untyped += 1
                if op == "insert":
                    total.inserts_ok += 1
                elif not oracle.check(op, v, res):
                    total.mismatches += 1
            time.sleep(0.002)

    res_threads = [threading.Thread(target=resolver) for _ in range(2)]
    for t in res_threads:
        t.start()

    from repro.core import Deadline, DeadlineExceeded, OverloadError
    t_start = time.perf_counter()
    t_end = t_start + duration_s
    offered = 0
    tick = 0.005
    while True:
        now = time.perf_counter()
        if now >= t_end:
            break
        should_have = int((now - t_start) * rate_rps)
        for _ in range(max(0, should_have - offered)):
            offered += 1
            op = _pick_op(rng)
            v = int(oracle.sample[rng.integers(0, len(oracle.sample))])
            budget = INSERT_DEADLINE_S if op == "insert" else READ_DEADLINE_S
            t0 = time.perf_counter()
            try:
                if op == "insert":
                    src, dst = ins()
                    fut = fd.submit("insert",
                                    deadline=Deadline.after(budget),
                                    src=src, dst=dst)
                else:
                    kind = "out_neighbors" if op == "out" else (
                        "in_neighbors" if op == "in" else "fof")
                    fut = fd.submit(kind, deadline=Deadline.after(budget),
                                    v=v)
            except OverloadError:
                total.shed_ms.append((time.perf_counter() - t0) * 1e3)
                continue
            except DeadlineExceeded:
                total.typed_deadline += 1
                continue
            with plock:
                pending.append((op, v, budget, t0, fut))
        time.sleep(tick)
    done.set()
    for t in res_threads:
        t.join(timeout=120.0)
    total.offered = offered
    return total


def run(scale: float = 1.0, smoke: bool = False) -> dict:
    from repro.core import ServiceDB, ShardRouter, FrontDesk, telemetry

    if smoke:
        n_vertices, n_edges = 4_000, 50_000
        n_threads, base_s, stall_s, over_s = 2, 2.0, 3.0, 3.0
        sample_n = 128
    else:
        n_vertices = max(4_000, int(50_000 * scale))
        n_edges = max(50_000, int(600_000 * scale))
        n_threads, base_s, stall_s, over_s = 4, 5.0, 8.0, 6.0
        sample_n = 400
    n_dst_reserve = 20_000
    reserve = {"src0": n_vertices, "dst0": n_vertices + 64,
               "n_dst": n_dst_reserve}
    max_id = n_vertices + 64 + n_dst_reserve

    payload = {
        "scale": scale, "smoke": smoke, "cpu_count": os.cpu_count(),
        "n_vertices": n_vertices, "n_edges": n_edges,
        "n_client_threads": n_threads,
        "op_mix": dict(MIX),
        "fault": {"stall_ms": STALL_MS, "stall_prob": STALL_PROB,
                  "seed": STALL_SEED, "shard": 1,
                  "site": "shard.worker.op"},
        "deadlines_s": {"read": READ_DEADLINE_S,
                        "insert": INSERT_DEADLINE_S},
        "gate_spec": {"p99_degrade_x": P99_DEGRADE_X,
                      "shed_p99_ms": SHED_P99_MS,
                      "goodput_frac": GOODPUT_FRAC,
                      "overload_x": OVERLOAD_X},
    }

    src, dst = power_law_graph(n_vertices, n_edges, seed=10)
    rng = np.random.default_rng(5)
    sample = np.unique(rng.integers(0, n_vertices, sample_n)
                       .astype(np.int64))

    workdir = tempfile.mkdtemp(prefix="bench_chaos_")
    failures = []
    try:
        print(f"  stores: unsharded oracle + 2-shard router, "
              f"{n_edges} edges ...")
        ref = ServiceDB.create(os.path.join(workdir, "ref"),
                               max_id=max_id, **_db_kw())
        ref.insert_edges(src, dst)
        ref.checkpoint()
        oracle = _Oracle(ref, sample)
        ref.close()

        router = ShardRouter.create(os.path.join(workdir, "sharded"),
                                    max_id=max_id, n_shards=2, **_db_kw())
        router.insert_edges(src, dst)
        router.checkpoint_all()
        fd = FrontDesk(router, queue_cap=256, max_batch=128, dispatchers=2)
        try:
            # ---- phase 1: fault-free baseline / capacity ---------------
            print(f"  baseline: {n_threads} closed-loop clients x "
                  f"{base_s}s ...")
            n0 = router.n_edges
            base = _closed_loop(fd, oracle, n_threads, base_s, reserve,
                                seed0=100)
            base_doc = base.doc(base_s)
            base_doc["write_count_exact"] = bool(
                router.n_edges - n0 == base.inserts_ok)
            payload["baseline"] = base_doc
            capacity = base_doc["ok_per_s"]
            base_p99 = base_doc["latency_ms"]["p99"]
            print(f"    capacity {capacity:,.0f} req/s  "
                  f"p99={base_p99:.2f}ms  ok={base.ok}")

            # ---- phase 2: one shard stalling -------------------------
            print(f"  stall: shard 1 delay:{STALL_MS} "
                  f"prob={STALL_PROB} x {stall_s}s ...")
            router.arm_failpoint(1, "shard.worker.op",
                                 f"delay:{STALL_MS}", count=None,
                                 prob=STALL_PROB, seed=STALL_SEED)
            n0 = router.n_edges
            try:
                stall = _closed_loop(fd, oracle, n_threads, stall_s,
                                     reserve, seed0=200)
            finally:
                router.arm_failpoint(1, "shard.worker.op", clear=True)
            stall_doc = stall.doc(stall_s)
            stall_doc["write_count_exact"] = bool(
                router.n_edges - n0 == stall.inserts_ok)
            payload["stall"] = stall_doc
            s_p99 = stall_doc["latency_ms"]["p99"]
            print(f"    p99={s_p99:.2f}ms ({s_p99 / base_p99:.2f}x "
                  f"baseline)  ok={stall.ok}  "
                  f"late_untyped={stall.late_untyped}  "
                  f"mismatches={stall.mismatches}")

            # ---- capacity probe: find SATURATION throughput ----------
            # the closed-loop estimate underestimates a coalescing front
            # end badly (each client waits for its answer; the desk could
            # batch far more). Escalate an open-loop rate until admission
            # actually sheds — the admitted goodput at that point is the
            # real capacity the overload gate is relative to.
            probe_rate = max(500.0, 4.0 * capacity)
            probe_s = 1.5 if smoke else 2.5
            probes = []
            probe_extra = _Tally()
            for it in range(5):
                print(f"  capacity probe: {probe_rate:,.0f} req/s "
                      f"open-loop x {probe_s}s ...")
                probe = _open_loop(fd, oracle, probe_rate, probe_s,
                                   reserve, seed0=400 + it,
                                   src_off=40 + it)
                pdoc = probe.doc(probe_s)
                pdoc["offered_per_s"] = probe.offered / probe_s
                pdoc["rate_target"] = probe_rate
                probes.append(pdoc)
                probe_extra.merge(probe)
                print(f"    admitted {pdoc['ok_per_s']:,.0f}/s  "
                      f"sheds={pdoc['sheds']}")
                if pdoc["sheds"] > 0:
                    capacity = pdoc["ok_per_s"]
                    break
                capacity = max(capacity, pdoc["ok_per_s"])
                probe_rate *= 3.0
            payload["capacity_probes"] = probes
            payload["capacity_req_per_s"] = capacity

            # ---- phase 3: 2x overload --------------------------------
            rate = OVERLOAD_X * capacity
            print(f"  overload: {rate:,.0f} req/s offered open-loop x "
                  f"{over_s}s ...")
            n0 = router.n_edges
            over = _open_loop(fd, oracle, rate, over_s, reserve,
                              seed0=300)
            over_doc = over.doc(over_s)
            over_doc["offered"] = over.offered
            over_doc["offered_per_s"] = over.offered / over_s
            over_doc["goodput_frac_of_capacity"] = (
                over_doc["ok_per_s"] / capacity if capacity else 0.0)
            over_doc["write_count_exact"] = bool(
                router.n_edges - n0 == over.inserts_ok)
            payload["overload"] = over_doc
            print(f"    goodput {over_doc['ok_per_s']:,.0f}/s "
                  f"({over_doc['goodput_frac_of_capacity']:.2f}x "
                  f"capacity)  sheds={over_doc['sheds']} "
                  f"shed_p99={over_doc['shed_latency_ms']['p99']}ms  "
                  f"late_untyped={over.late_untyped}")
        finally:
            fd.close()
            router.close()
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    snap = telemetry.snapshot()

    def ctr(name):
        v = snap["counters"].get(name, 0)
        return sum(v.values()) if isinstance(v, dict) else v

    payload["lifecycle_counters"] = {
        n: ctr(n) for n in
        ("shard.hedges.sent", "shard.hedges.won", "shard.rpc.retries",
         "shard.breaker.trips", "shard.breaker.fastfail",
         "frontdesk.sheds", "frontdesk.batches", "frontdesk.batched_ops",
         "request.deadline_exceeded")
    }

    # ---- gates -----------------------------------------------------------
    gates = {}
    gates["stall_p99_within_3x"] = bool(
        s_p99 is not None and base_p99 is not None
        and s_p99 <= P99_DEGRADE_X * base_p99)
    gates["zero_late_untyped"] = bool(
        base.late_untyped == 0 and stall.late_untyped == 0
        and over.late_untyped == 0 and probe_extra.late_untyped == 0)
    gates["bitwise_vs_oracle"] = bool(
        base.mismatches == 0 and stall.mismatches == 0
        and over.mismatches == 0 and probe_extra.mismatches == 0
        and base.other_errors == 0 and stall.other_errors == 0)
    shed_p99 = over_doc["shed_latency_ms"]["p99"]
    gates["overload_sheds_typed_fast"] = bool(
        over_doc["sheds"] > 0 and shed_p99 is not None
        and shed_p99 <= SHED_P99_MS)
    gates["overload_goodput"] = bool(
        over_doc["goodput_frac_of_capacity"] >= GOODPUT_FRAC)
    gates["write_counts_exact"] = bool(
        payload["baseline"]["write_count_exact"]
        and payload["stall"]["write_count_exact"]
        and payload["overload"]["write_count_exact"])
    gates["hedging_active"] = bool(
        payload["lifecycle_counters"]["shard.hedges.sent"] > 0)
    payload["gates"] = gates
    for name, ok in gates.items():
        if not ok:
            failures.append(f"gate '{name}' failed")
        print(f"  gate {name}: {'OK' if ok else 'FAIL'}")
    payload["gate_failures"] = failures

    save("BENCH_chaos", payload)
    if failures and smoke:
        sys.exit(1)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny store, short phases, enforce the gates")
    args = ap.parse_args()
    run(scale=args.scale, smoke=args.smoke)


if __name__ == "__main__":
    main()
