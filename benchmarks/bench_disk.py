"""Out-of-core benchmark (ISSUE 3): serve a graph ≥4x the memory budget.

Builds a `GraphDB` whose on-disk edge data is at least 4x a configured
data-memory budget, then runs the full workload out of core — point
queries, friends-of-friends, and a streaming PSW PageRank sweep — while
tracking peak RSS. The budget applies to the DELTA over the post-import
baseline (the Python + numpy + jax footprint is recorded separately and is
not the paper's claim); the run FAILS (exit 1) if the peak delta exceeds
the budget, which CI uses as a smoke gate.

Also reproduces the paper's Figure 8c index comparison with REAL I/O:
  * raw pointer array on disk      — block-granular binary search, every
    probe a counted `os.pread`;
  * sparse index                   — resident stride keys + ONE pread;
  * Elias-Gamma chunked, resident  — compressed blobs pinned in RAM,
    one chunk decoded per lookup, zero disk reads.

Emits `experiments/bench/BENCH_disk.json`.
"""
from __future__ import annotations

import ctypes
import os
import resource
import shutil
import sys
import tempfile
import time

import numpy as np


def _pin_mmap_threshold() -> bool:
    """glibc's dynamic M_MMAP_THRESHOLD retains freed multi-MB merge
    scratch in the heap (RSS creep of tens of MB that has nothing to do
    with the storage tier). Pin the threshold so large temporaries always
    come from (and return to) mmap."""
    try:
        libc = ctypes.CDLL("libc.so.6", use_errno=True)
        return libc.mallopt(-3, 256 * 1024) == 1  # M_MMAP_THRESHOLD
    except OSError:
        return False

from repro.core import GraphDB, GammaChunkedIndex
from repro.core.disk import RawDiskIndex, SparseDiskIndex
from repro.core.psw import pagerank_out_of_core
from repro.core.query import friends_of_friends

from .common import save, timer


def rss_bytes() -> int:
    """Peak RSS so far (ru_maxrss is KB on Linux)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024


def current_rss_bytes() -> int:
    with open("/proc/self/status") as f:
        for line in f:
            if line.startswith("VmRSS:"):
                return int(line.split()[1]) * 1024
    return rss_bytes()


def run(scale: float = 1.0, budget_mb: float = None, keep_dir: str = None):
    # data budget: 96 MB at scale 1.0, floored at 96 MB — below that the
    # fixed cost of the process itself (allocator retention from merge
    # scratch, ~40 MB measured) would dominate and the 4x claim would be
    # about numpy temporaries, not the storage tier
    budget = int(max(96.0, (budget_mb if budget_mb is not None
                            else 96.0 * scale)) * 1e6)
    pinned = _pin_mmap_threshold()
    baseline = rss_bytes()

    workdir = keep_dir or tempfile.mkdtemp(prefix="bench_disk_")
    dbdir = os.path.join(workdir, "db")
    results = {"budget_bytes": budget, "baseline_rss_bytes": baseline,
               "mmap_threshold_pinned": pinned}

    # -- out-of-core build ----------------------------------------------------
    # a partition file costs ~41 B/edge (src+dst+perm int64, etype, raw +
    # gamma pointer copies); pick n_edges so the on-disk store is >=4x budget
    n_edges = int(4.2 * budget / 41)
    # twitter-like density (~30 edges/vertex) keeps the O(V) PageRank state
    # a small fraction of the budget, as in the paper's §6.1.1 model
    max_id = max(100_000, n_edges // 30)
    chunk = 100_000
    # merge transients hold ~10 array copies of one partition — cap
    # partition size so the largest merge fits comfortably in the budget
    max_part = max(50_000, int(budget / (30 * 41)))
    db = GraphDB.create(
        dbdir, max_id=max_id - 1, n_partitions=64, n_levels=3, branching=4,
        buffer_cap=min(chunk, max_part // 2), max_partition_edges=max_part,
        persist_min_edges=4096, resident_budget_bytes=budget // 8)

    rng = np.random.default_rng(7)
    probes = []  # (src, dst) pairs re-verified at every stage
    t_build = time.perf_counter()
    inserted = 0
    while inserted < n_edges:
        m = min(chunk, n_edges - inserted)
        src = rng.integers(0, max_id, m)
        dst = rng.integers(0, max_id, m)
        db.insert_edges(src, dst)
        if len(probes) < 500:
            probes.extend(zip(src[:25].tolist(), dst[:25].tolist()))
        inserted += m
        if inserted % (chunk * 10) == 0:
            db.checkpoint()  # bounds store garbage + WAL-covered RAM state
    db.checkpoint()
    results["build"] = {
        "n_edges": inserted,
        "seconds": time.perf_counter() - t_build,
        "disk_partitions": len(db._disk_partitions()),
        "on_disk_bytes": sum(p.nbytes() for p in db._disk_partitions()),
        "resident": db.resident_nbytes(),
        "peak_rss_delta_bytes": rss_bytes() - baseline,
    }
    on_disk = results["build"]["on_disk_bytes"]
    print(f"  built {inserted} edges, {on_disk/1e6:.0f} MB on disk "
          f"({on_disk/max(budget,1):.1f}x budget), peak RSS delta "
          f"{results['build']['peak_rss_delta_bytes']/1e6:.0f} MB")

    def verify_probes(tag):
        """Every recorded (s, d) edge must appear in s's out-neighbors AND
        d's in-neighbors — checked through the engine's batched path (the
        scalar per-partition path is exercised by the tests; per-probe
        scalar loops over 80+ slabs would dominate the bench)."""
        eng_v = db.storage_engine()
        ps = np.asarray([s for s, _ in probes], np.int64)
        pd = np.asarray([d for _, d in probes], np.int64)
        ok = 0
        vals, offs = eng_v.out_neighbors_batch(ps)
        ok_out = [pd[i] in vals[offs[i]:offs[i + 1]] for i in range(len(ps))]
        vals, offs = eng_v.in_neighbors_batch(pd)
        ok_in = [ps[i] in vals[offs[i]:offs[i + 1]] for i in range(len(pd))]
        ok = int(np.sum(np.asarray(ok_out) & np.asarray(ok_in)))
        assert ok == len(probes), f"{tag}: {len(probes)-ok} probes missing"
        return ok

    # -- point queries --------------------------------------------------------
    db.evict()
    db.io.block_reads = db.io.bytes_read = db.io.gathers = 0
    eng = db.storage_engine()
    qs = rng.integers(0, max_id, 2000)
    times = []
    with timer(times):
        vals, offsets = eng.out_neighbors_batch(qs)
    out_t = times[-1]
    with timer(times):
        vals_in, off_in = eng.in_neighbors_batch(qs)
    results["queries"] = {
        "n_queries": int(qs.shape[0]),
        "out_batch_seconds": out_t,
        "in_batch_seconds": times[-1],
        "io": db.io.snapshot(),
        "probes_verified": verify_probes("queries"),
    }
    db.evict()

    # -- friends of friends ---------------------------------------------------
    t0 = time.perf_counter()
    fof_sizes = []
    n_fof = 50
    for v in qs[:n_fof]:
        fof = friends_of_friends(eng, int(v))
        fof_sizes.append(len(fof))
    results["fof"] = {
        "n_queries": n_fof,
        "seconds": time.perf_counter() - t0,
        "mean_fof_size": float(np.mean(fof_sizes)),
    }
    db.evict()

    # -- streaming PSW sweep --------------------------------------------------
    t0 = time.perf_counter()
    ranks = pagerank_out_of_core(db, n_iters=2, evict_each=True)
    results["psw_sweep"] = {
        "n_iters": 2,
        "seconds": time.perf_counter() - t0,
        "rank_sum": float(ranks.sum()),
        "peak_rss_delta_bytes": rss_bytes() - baseline,
    }

    # -- Figure 8c: index variants with real block reads ----------------------
    big = max(db._disk_partitions(), key=lambda p: p.n_edges)
    off, dt, n_keys = big._section_spec("src_vertices_raw")
    keys = np.array(big.src_vertices)
    lookups = rng.choice(keys, size=min(2000, keys.shape[0]), replace=True)
    fig8 = {}
    raw = RawDiskIndex(big.path, off, n_keys)
    sparse = SparseDiskIndex(big.path, off, n_keys, stride=512)
    gamma = GammaChunkedIndex(keys, chunk=1024)
    for name, idx in (("raw_on_disk", raw), ("sparse_index", sparse),
                      ("elias_gamma_resident", gamma)):
        t0 = time.perf_counter()
        for k in lookups:
            assert idx.lookup(int(k)) >= 0
        dt_s = time.perf_counter() - t0
        fig8[name] = {
            "n_keys": int(n_keys),
            "lookups": int(lookups.shape[0]),
            "seconds": dt_s,
            "us_per_lookup": dt_s / lookups.shape[0] * 1e6,
            "resident_bytes": int(idx.nbytes()),
            "block_reads": int(getattr(idx, "block_reads", 0)),
        }
    fig8["raw_resident_bytes_for_reference"] = int(keys.nbytes)
    results["figure8c"] = fig8
    raw.close()
    sparse.close()
    del keys, big
    db.evict()

    # -- close → reopen must be bitwise-identical ----------------------------
    sample = np.asarray(qs[:200], np.int64)
    pre = db.storage_engine().out_neighbors_batch(sample)
    db.close()
    db = GraphDB.open(dbdir)
    post = db.storage_engine().out_neighbors_batch(sample)
    assert np.array_equal(pre[0], post[0]) and np.array_equal(pre[1], post[1]), \
        "reopen changed query results"
    verify_probes("reopen")
    # crash: insert without checkpoint, copy dir, recover from WAL tail.
    # The live db is closed BEFORE the copy is opened — one store resident
    # at a time, and the copy must recover from the files alone anyway.
    s2 = rng.integers(0, max_id, 20_000)
    d2 = rng.integers(0, max_id, 20_000)
    db.insert_edges(s2, d2)
    pre_n = db.n_edges
    expect_nbrs = np.sort(db.out_neighbors(int(s2[0]))).tolist()
    db.tree.wal_flush()
    crash_dir = os.path.join(workdir, "crash")
    shutil.copytree(dbdir, crash_dir)
    db.close()
    db = GraphDB.open(crash_dir)
    assert db.n_edges == pre_n, "crash recovery lost edges"
    assert np.sort(db.out_neighbors(int(s2[0]))).tolist() == expect_nbrs
    results["recovery"] = {"reopen_bitwise": True, "crash_edges": int(pre_n)}
    print("  reopen + crash recovery verified")

    # -- verdict --------------------------------------------------------------
    peak_delta = rss_bytes() - baseline
    results["peak_rss_delta_bytes"] = peak_delta
    results["peak_rss_bytes"] = rss_bytes()
    results["under_budget"] = bool(peak_delta <= budget)
    results["disk_to_budget_ratio"] = on_disk / max(budget, 1)
    save("BENCH_disk", results)

    print("— BENCH_disk —")
    print(f"  on-disk {on_disk/1e6:.0f} MB vs budget {budget/1e6:.0f} MB "
          f"({results['disk_to_budget_ratio']:.1f}x)")
    print(f"  peak RSS delta {peak_delta/1e6:.0f} MB "
          f"({'UNDER' if results['under_budget'] else 'OVER'} budget)")
    for name, row in fig8.items():
        if isinstance(row, dict):
            print(f"  {name}: {row['us_per_lookup']:.1f} us/lookup, "
                  f"{row['resident_bytes']/1e3:.0f} KB resident, "
                  f"{row['block_reads']} block reads")
    db.close()
    if keep_dir is None:
        shutil.rmtree(workdir)
    if not results["under_budget"]:
        print("FAIL: peak RSS delta exceeded the memory budget", file=sys.stderr)
        raise SystemExit(1)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--budget-mb", type=float, default=None)
    args = ap.parse_args()
    run(scale=args.scale, budget_mb=args.budget_mb)
