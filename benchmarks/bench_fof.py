"""Paper Table 3 + Fig 8b: friends-of-friends latency percentiles, with and
without concurrent analytics (PageRank), plus depth-limited shortest path
(paper §8.4)."""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import (GraphPAL, IntervalMap, LSMTree, friends_of_friends,
                        pagerank_host, shortest_path)

from .common import percentiles, power_law_graph, save


def run(scale: float = 1.0):
    n_vertices = int(100_000 * scale)
    n_edges = int(1_000_000 * scale)
    src, dst = power_law_graph(n_vertices, n_edges, seed=4)
    g = GraphPAL.from_edges(src, dst, n_partitions=16, max_id=n_vertices - 1)

    rng = np.random.default_rng(1)
    queries = rng.integers(0, n_vertices, int(400 * max(scale, 0.25)))

    lat = []
    sizes = []
    for v in queries:
        t0 = time.perf_counter()
        fof = friends_of_friends(g, int(v), max_friends=200)
        lat.append((time.perf_counter() - t0) * 1e3)
        sizes.append(int(fof.size))

    # concurrent analytics: PageRank sweeps on a background thread while the
    # same FoF mix runs (paper's 'GraphChi-DB + Pagerank' rows)
    stop = threading.Event()

    def pr_loop():
        while not stop.is_set():
            pagerank_host(g, n_iters=1)

    th = threading.Thread(target=pr_loop, daemon=True)
    th.start()
    lat_pr = []
    for v in queries:
        t0 = time.perf_counter()
        friends_of_friends(g, int(v), max_friends=200)
        lat_pr.append((time.perf_counter() - t0) * 1e3)
    stop.set()
    th.join(timeout=10)

    # shortest paths (depth <= 5, two-sided)
    sp_lat = []
    found = 0
    for _ in range(50):
        a, b = rng.integers(0, n_vertices, 2)
        t0 = time.perf_counter()
        d = shortest_path(g, int(a), int(b), max_depth=5)
        sp_lat.append((time.perf_counter() - t0) * 1e3)
        found += d is not None

    results = {
        "fof_ms": percentiles(lat),
        "fof_with_pagerank_ms": percentiles(lat_pr),
        "fof_result_size": percentiles(sizes),
        "shortest_path_ms": percentiles(sp_lat),
        "shortest_path_found_frac": found / 50,
        "n_queries": len(lat),
    }
    save("fof", results)
    print("— Table 3 (FoF latency, ms) —")
    print(f"  plain      : {results['fof_ms']}")
    print(f"  + pagerank : {results['fof_with_pagerank_ms']}")
    print(f"  shortest-path: {results['shortest_path_ms']} "
          f"(found {found}/50)")
    return results


if __name__ == "__main__":
    run()
