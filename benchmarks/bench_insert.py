"""Paper Fig 7a: online insert throughput — plus the ISSUE 2 acceptance
harness: old (pre-PR, per-edge Python) write path vs the new columnar +
linear-merge write path, best-of-3, emitted to BENCH_insert.json.

The legacy classes below reproduce the pre-PR write path faithfully:
Python-list buffers with per-element int() conversion, an O(#buffers)
`total_buffered()` sum on every insert, a full `np.lexsort` re-sort of the
merged partition on every flush, and an unbuffered per-record WAL. They
exist only as the benchmark baseline.
"""
from __future__ import annotations

import struct
import time

import numpy as np

from repro.core import IntervalMap, LSMTree, pagerank_host
from repro.core.lsm import BufferStaging
from repro.core.pal import build_partition

from .common import power_law_graph, save


# ---------------------------------------------------------------------------
# Legacy (pre-PR) reference write path
# ---------------------------------------------------------------------------
class _LegacyEdgeBuffer:
    """Pre-PR buffer: Python lists, list→array staging conversion."""

    def __init__(self, column_dtypes):
        self.src, self.dst, self.etype = [], [], []
        self.column_dtypes = dict(column_dtypes)
        self.columns = {k: [] for k in column_dtypes}
        self._staging = None

    def __len__(self):
        return len(self.src)

    def staging(self):
        if self._staging is None:
            self._staging = BufferStaging(
                src=np.asarray(self.src, dtype=np.int64),
                dst=np.asarray(self.dst, dtype=np.int64),
                etype=np.asarray(self.etype, dtype=np.int8),
                columns={k: np.asarray(v, dtype=self.column_dtypes[k])
                         for k, v in self.columns.items()},
            )
        return self._staging

    def append(self, src, dst, etype, cols):
        self.src.append(src)
        self.dst.append(dst)
        self.etype.append(etype)
        for k in self.columns:
            self.columns[k].append(cols.get(k, 0))
        self._staging = None

    def extend(self, src, dst, etype, cols):
        self.src.extend(int(x) for x in src)
        self.dst.extend(int(x) for x in dst)
        self.etype.extend(int(x) for x in etype)
        n = len(src)
        for k in self.columns:
            v = cols.get(k)
            self.columns[k].extend([0] * n if v is None else v)
        self._staging = None

    def drain(self):
        st = self.staging()
        out = (st.src, st.dst, st.etype, st.columns)
        self.src, self.dst, self.etype = [], [], []
        self.columns = {k: [] for k in self.columns}
        self._staging = None
        return out


class _LegacyLSMTree(LSMTree):
    """Pre-PR write path on top of the current read path."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.buffers = [_LegacyEdgeBuffer(self.column_dtypes)
                        for _ in self.levels[0]]
        if self._wal is not None:  # unbuffered, per-record writes
            path = self._wal.name
            self._wal.close()
            self._wal = open(path, "ab", buffering=0)

    def total_buffered(self):
        return sum(len(b) for b in self.buffers)

    def insert_edge(self, src, dst, etype=0, **cols):
        isrc = int(self.intervals.to_internal(src))
        idst = int(self.intervals.to_internal(dst))
        if self._wal is not None:
            self._wal.write(struct.pack("<qqb", isrc, idst, etype))
        self.buffers[self._top_index_of(idst)].append(isrc, idst, etype, cols)
        self.stats.inserts += 1
        if self.total_buffered() > self.buffer_cap:
            self.flush_fullest_buffer()

    def insert_edges(self, src, dst, etype=None, columns=None):
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        etype = np.zeros(src.shape[0], np.int8) if etype is None else np.asarray(etype)
        columns = columns or {}
        isrc = self.intervals.to_internal(src)
        idst = self.intervals.to_internal(dst)
        if self._wal is not None:
            rec = np.rec.fromarrays([isrc, idst, etype.astype(np.int8)],
                                    names="s,d,t")
            self._wal.write(rec.tobytes())
        span = self.intervals.max_vertices // len(self.levels[0])
        top = idst // span
        for i in np.unique(top):
            m = top == i
            self.buffers[int(i)].extend(
                isrc[m], idst[m], etype[m],
                {k: np.asarray(v)[m] for k, v in columns.items()})
        self.stats.inserts += int(src.shape[0])
        while self.total_buffered() > self.buffer_cap:
            self.flush_fullest_buffer()

    def flush_fullest_buffer(self):
        j = int(np.argmax([len(b) for b in self.buffers]))
        if len(self.buffers[j]) == 0:
            return
        bsrc, bdst, btype, bcols = self.buffers[j].drain()
        self.levels[0][j] = self._merge_into(
            self.levels[0][j], bsrc, bdst, btype, bcols)
        self.stats.buffer_flushes += 1
        self._maybe_pushdown(0, j)

    def _merge_into(self, part, src, dst, etype, cols, presorted=False,
                    run=None):
        # full O(n log n) re-sort of the entire merged partition
        live = np.ones(part.n_edges, bool) if part.dead is None else ~part.dead
        self.stats.purged_tombstones += int(part.n_edges - live.sum())
        msrc = np.concatenate([part.src[live], src])
        mdst = np.concatenate([part.dst[live], dst])
        mtyp = np.concatenate([part.etype[live], etype])
        mcols = {}
        for k, dt in self.column_dtypes.items():
            old = part.columns.get(k, np.zeros(part.n_edges, dt))[live]
            new = cols.get(k, np.zeros(src.shape[0], dt))
            mcols[k] = np.concatenate([old, new])
        self.stats.edges_rewritten += int(msrc.shape[0])
        return build_partition(part.interval, msrc, mdst, mtyp, mcols)

    def _maybe_pushdown(self, level, j):
        # pre-PR push-down: materialize live masks, re-sort in child merges
        part = self.levels[level][j]
        if part.n_edges <= self.max_partition_edges:
            return
        if level == self.n_levels - 1:
            self.stats.splits += 1
            return
        child_span = self.intervals.max_vertices // len(self.levels[level + 1])
        live = np.ones(part.n_edges, bool) if part.dead is None else ~part.dead
        csrc, cdst, ctyp = part.src[live], part.dst[live], part.etype[live]
        ccols = {k: part.columns.get(k, np.zeros(part.n_edges, dt))[live]
                 for k, dt in self.column_dtypes.items()}
        child_of = cdst // child_span
        for c in np.unique(child_of):
            m = child_of == c
            self.levels[level + 1][int(c)] = self._merge_into(
                self.levels[level + 1][int(c)], csrc[m], cdst[m], ctyp[m],
                {k: v[m] for k, v in ccols.items()})
        self.levels[level][j] = build_partition(
            part.interval, np.empty(0, np.int64), np.empty(0, np.int64),
            columns={k: np.empty(0, dt) for k, dt in self.column_dtypes.items()})
        self.stats.pushdown_merges += 1
        for c in np.unique(child_of):
            self._maybe_pushdown(level + 1, int(c))


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------
def _make(cls, n_vertices, p=16, levels=3, f=4, buffer_cap=50_000,
          max_partition_edges=150_000, **kw):
    iv = IntervalMap.for_capacity(n_vertices - 1, p)
    return cls(iv, n_levels=levels, branching=f, buffer_cap=buffer_cap,
               max_partition_edges=max_partition_edges, **kw)


def _bulk(tree, src, dst, batch=20_000):
    t0 = time.perf_counter()
    for k in range(0, src.shape[0], batch):
        tree.insert_edges(src[k:k + batch], dst[k:k + batch])
    return time.perf_counter() - t0


def _single(tree, src, dst):
    ie = tree.insert_edge
    t0 = time.perf_counter()
    for s, d in zip(src.tolist(), dst.tolist()):
        ie(s, d)
    return time.perf_counter() - t0


def _mix_op_count(n_edges, batch, queries_per_batch=64):
    """Total ops _mix performs — the single source of truth for the
    ops/sec denominator."""
    return n_edges + queries_per_batch * ((n_edges + batch - 1) // batch)


def _mix(tree, src, dst, batch=20_000, queries_per_batch=64):
    """LinkBench-style sustained mix: bulk insert batches interleaved with
    batched out-neighbor frontier queries against the live store. Op
    accounting lives in `_mix_op_count` only."""
    rng = np.random.default_rng(7)
    eng = tree.storage_engine()
    t0 = time.perf_counter()
    for k in range(0, src.shape[0], batch):
        tree.insert_edges(src[k:k + batch], dst[k:k + batch])
        vs = rng.choice(src[: k + batch], size=queries_per_batch)
        eng.out_neighbors_batch(vs)
    return time.perf_counter() - t0


def _best_of(fn, repeats):
    import gc
    times = []
    for _ in range(repeats):
        gc.collect()  # identical allocator/GC state for every rep
        times.append(fn())
    return min(times), times


def run(scale: float = 1.0, repeats: int = 3):
    n_vertices = int(100_000 * scale)
    n_edges = int(1_000_000 * scale)
    src, dst = power_law_graph(n_vertices, n_edges, seed=2)
    n_single = max(1, n_edges // 5)  # single-edge stream (per-call Python cost)
    # keep caps proportional so reduced scales still exercise flushes and
    # push-down merges (CI smoke runs at tiny --scale)
    caps = dict(buffer_cap=max(1000, int(50_000 * scale)),
                max_partition_edges=max(3000, int(150_000 * scale)))
    batch = max(250, int(20_000 * scale))

    results = {"n_vertices": n_vertices, "n_edges": n_edges,
               "repeats": repeats, **caps}

    def compare(name, workload, n_items, **tree_kw):
        entry = {}
        for label, cls in (("legacy", _LegacyLSMTree), ("new", LSMTree)):
            def once():
                t = _make(cls, n_vertices, **caps, **tree_kw)
                out = workload(t)
                t.close()
                return out
            best, times = _best_of(once, repeats)
            entry[label] = {"best_s": best, "times_s": times,
                            "per_s": n_items / best}
        entry["speedup"] = entry["legacy"]["best_s"] / entry["new"]["best_s"]
        results[name] = entry
        print(f"  {name}: legacy {entry['legacy']['per_s']:,.0f}/s, "
              f"new {entry['new']['per_s']:,.0f}/s "
              f"→ {entry['speedup']:.1f}x")

    print("— BENCH_insert (old vs new write path, best-of-%d) —" % repeats)
    compare("bulk", lambda t: _bulk(t, src, dst, batch=batch), n_edges)
    compare("single_edge",
            lambda t: _single(t, src[:n_single], dst[:n_single]), n_single)
    compare("bulk_durable",
            lambda t: _bulk(t, src, dst, batch=batch), n_edges,
            durable=True, wal_path="/tmp/bench_insert.wal")
    mix_ops = _mix_op_count(n_edges, batch)
    compare("mix", lambda t: _mix(t, src, dst, batch=batch), mix_ops)

    # paper Fig 7a invariants on the new path: LSM vs no-LSM rewrite
    # amplification, and inserts with concurrent PageRank (§6.1.2)
    lsm = _make(LSMTree, n_vertices, **caps)
    _bulk(lsm, src, dst, batch=batch)
    flat = _make(LSMTree, n_vertices, levels=1, f=1, **caps)
    _bulk(flat, src, dst, batch=batch)
    results["rewrite_amplification"] = {
        "lsm": lsm.stats.edges_rewritten / n_edges,
        "no_lsm": flat.stats.edges_rewritten / n_edges,
    }
    assert results["rewrite_amplification"]["lsm"] < \
        results["rewrite_amplification"]["no_lsm"], "LSM must reduce rewrites"

    t = _make(LSMTree, n_vertices, **caps)
    t0 = time.perf_counter()
    for k in range(0, n_edges, batch):
        t.insert_edges(src[k:k + batch], dst[k:k + batch])
        if (k // batch + 1) % 10 == 0:
            pagerank_host(t, n_iters=1)
    results["lsm_with_pagerank"] = {
        "edges_per_s": n_edges / (time.perf_counter() - t0)}

    save("BENCH_insert", results)
    print(f"  rewrite amplification: lsm x"
          f"{results['rewrite_amplification']['lsm']:.1f} vs no-lsm x"
          f"{results['rewrite_amplification']['no_lsm']:.1f}")
    return results


if __name__ == "__main__":
    run()
