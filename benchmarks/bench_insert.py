"""Paper Fig 7a: online insert throughput over time — LSM vs no-LSM vs
durable buffers, plus inserts with concurrent PageRank (incremental
computation, paper §6.1.2)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import IntervalMap, LSMTree, pagerank_host

from .common import power_law_graph, save


def _stream_insert(tree: LSMTree, src, dst, batch: int = 20_000,
                   pagerank_every: int = 0):
    t0 = time.perf_counter()
    progress = []
    for k in range(0, src.shape[0], batch):
        tree.insert_edges(src[k:k + batch], dst[k:k + batch])
        if pagerank_every and (k // batch + 1) % pagerank_every == 0:
            pagerank_host(tree, n_iters=1)
        progress.append({"edges": k + min(batch, src.shape[0] - k),
                         "elapsed_s": time.perf_counter() - t0})
    total = time.perf_counter() - t0
    return progress, total


def run(scale: float = 1.0):
    n_vertices = int(100_000 * scale)
    n_edges = int(1_000_000 * scale)
    src, dst = power_law_graph(n_vertices, n_edges, seed=2)
    iv_args = dict(max_id=n_vertices - 1)

    results = {}

    def make(p, levels, f, **kw):
        iv = IntervalMap.for_capacity(n_vertices - 1, p)
        return LSMTree(iv, n_levels=levels, branching=f,
                       buffer_cap=50_000, max_partition_edges=150_000, **kw)

    # (1) LSM, memory-only buffers
    t = make(16, 3, 4)
    prog, total = _stream_insert(t, src, dst)
    results["lsm"] = {
        "total_s": total, "edges_per_s": n_edges / total,
        "edges_rewritten": t.stats.edges_rewritten,
        "rewrite_amplification": t.stats.edges_rewritten / n_edges,
        "progress": prog[::5],
    }

    # (2) no LSM (single level — the paper's 'basic edge buffer' baseline)
    t = make(16, 1, 1)
    prog, total = _stream_insert(t, src, dst)
    results["no_lsm"] = {
        "total_s": total, "edges_per_s": n_edges / total,
        "edges_rewritten": t.stats.edges_rewritten,
        "rewrite_amplification": t.stats.edges_rewritten / n_edges,
    }

    # (3) LSM + durable buffers (WAL fsync'd per batch)
    t = make(16, 3, 4, durable=True, wal_path="/tmp/bench_insert.wal")
    prog, total = _stream_insert(t, src, dst)
    t.close()
    results["lsm_durable"] = {"total_s": total, "edges_per_s": n_edges / total}

    # (4) LSM + concurrent PageRank (incremental analytics, §6.1.2)
    t = make(16, 3, 4)
    prog, total = _stream_insert(t, src, dst, pagerank_every=10)
    results["lsm_with_pagerank"] = {"total_s": total,
                                    "edges_per_s": n_edges / total}

    save("insert", results)
    print("— Fig 7a (insert throughput) —")
    for k, v in results.items():
        print(f"  {k}: {v['edges_per_s']:.0f} edges/s"
              + (f", rewrite x{v['rewrite_amplification']:.1f}"
                 if "rewrite_amplification" in v else ""))
    assert results["lsm"]["rewrite_amplification"] < \
        results["no_lsm"]["rewrite_amplification"], "LSM must reduce rewrites"
    return results


if __name__ == "__main__":
    run()
