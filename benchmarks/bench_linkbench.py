"""Paper Table 2 + Fig 8a: LinkBench-style online workload over LSM-PAL —
per-operation latency percentiles, total throughput, and throughput vs
graph size."""
from __future__ import annotations

import time
from collections import defaultdict

import numpy as np

from repro.core import IntervalMap, LSMTree
from repro.data import LinkBenchConfig, LinkBenchWorkload

from .common import percentiles, save


def _build(cfg: LinkBenchConfig):
    wl = LinkBenchWorkload(cfg)
    src, dst, ts = wl.initial_graph()
    iv = IntervalMap.for_capacity(cfg.n_vertices - 1, 16)
    tree = LSMTree(iv, n_levels=3, branching=4, buffer_cap=50_000,
                   max_partition_edges=200_000,
                   column_dtypes={"ts": np.int64, "payload": np.float64})
    tree.insert_edges(src, dst, columns={"ts": ts,
                                         "payload": np.zeros(len(src))})
    # vertex store: payload column via a host dict (node ops are O(1))
    nodes = np.zeros(cfg.n_vertices, np.float64)
    return wl, tree, nodes


def _serve(wl, tree, nodes, n_requests: int):
    lat = defaultdict(list)
    t0 = time.perf_counter()
    for req in wl.requests(n_requests):
        op = req["op"]
        t1 = time.perf_counter()
        if op == "node_get":
            _ = nodes[req["u"]]
        elif op == "node_insert" or op == "node_update":
            nodes[req["u"]] = req["payload"]
        elif op == "edge_insert_or_update":
            if not tree.update_edge_column(req["u"], req["v"], "payload",
                                           req["payload"]):
                tree.insert_edge(req["u"], req["v"],
                                 ts=req["ts"], payload=req["payload"])
        elif op == "edge_update":
            tree.update_edge_column(req["u"], req["v"], "payload",
                                    req["payload"])
        elif op == "edge_delete":
            tree.delete_edge(req["u"], req["v"])
        elif op == "edge_getrange":
            # hits now include buffered edges (level -1); one vectorized
            # gather per slab replaces the per-hit Python loop, which also
            # silently skipped every buffered edge's timestamp (ISSUE 5)
            hits = tree.out_edge_hits(req["u"])
            tss = tree.columns_for_hits(hits, "ts")
            # timestamp-range filter + sort (paper notes the sort cost)
            order = np.argsort(tss)[-10:]
        elif op == "edge_outnbrs":
            _ = tree.out_neighbors(req["u"])
        lat[op].append((time.perf_counter() - t1) * 1e3)
    wall = time.perf_counter() - t0
    return lat, n_requests / wall


def run(scale: float = 1.0):
    results = {"ops": {}, "scaling": []}
    cfg = LinkBenchConfig(n_vertices=int(50_000 * scale), edges_per_vertex=5)
    wl, tree, nodes = _build(cfg)
    lat, throughput = _serve(wl, tree, nodes, int(20_000 * scale))
    for op, xs in lat.items():
        results["ops"][op] = {"n": len(xs), **percentiles(xs)}
    results["throughput_req_s"] = throughput

    # Fig 8a: throughput vs graph size
    for nv in [10_000, 30_000, 100_000]:
        nv = int(nv * scale)
        cfg = LinkBenchConfig(n_vertices=nv, edges_per_vertex=5, seed=7)
        wl, tree, nodes = _build(cfg)
        _, thr = _serve(wl, tree, nodes, 5_000)
        results["scaling"].append({"vertices": nv, "edges": nv * 5,
                                   "throughput_req_s": thr})

    save("linkbench", results)
    print("— Table 2 (LinkBench latencies, ms) —")
    for op, p in results["ops"].items():
        print(f"  {op:24} p50={p['p50']:.3f} p95={p['p95']:.3f}")
    print(f"  throughput: {results['throughput_req_s']:.0f} req/s")
    print("— Fig 8a (throughput vs size) —")
    for row in results["scaling"]:
        print(f"  |V|={row['vertices']:>8}: {row['throughput_req_s']:.0f} req/s")
    return results


if __name__ == "__main__":
    run()
