"""ISSUE 6: vectorized multi-hop operators — BENCH_multihop.json.

Three sections, each verified bitwise in-run before anything is timed:

  1. `two_hop`: a seed batch answered by the per-hop baseline (a Python
     loop of `friends_of_friends_perhop`, the PR-1-era strategy) vs ONE
     columnar `two_hop_counts` call — measured on the LIVE ServiceDB
     epoch view (`read_view()`, buffers + tombstones visible) AND on the
     same store reopened cold via `GraphDB.open`.
  2. `triangle`: directed closed wedges over a sampled middle set, per-hop
     baseline (per-vertex neighbor calls + chunked vectorized membership)
     vs `triangle_count`; the columnar operator is also timed over the
     FULL middle set (headline number — the baseline loop would take
     minutes there, which is the point).
  3. `kernel`: the dense `dense="kernel"` 2-hop on a seed panel vs the
     sparse columnar path on the same seeds — bitwise-equal, with the
     plan build (memoized in the engine plan cache) reported separately.
     Off-TPU this routes through the jit'd ref K-loop (see
     kernels/frontier_expand/ops.py), so the number is an XLA-CPU figure,
     not a Mosaic one; the section records which path ran.

Gates are in-run relative (same store, same process, seconds apart):
columnar two-hop and triangle must beat the per-hop baseline by GATE_X
on BOTH the live view and the reopened store. `--smoke` shrinks the
store and relaxes the gate; it exits non-zero on any gate or equality
failure (the CI step). Timings are best-of-3.
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import time

import numpy as np

from .common import power_law_graph, save

GATE_X = 10.0        # full-size: columnar must be >= 10x the per-hop loop
GATE_X_SMOKE = 3.0   # CI smoke runs a tiny store where fixed costs loom


def _best_of(fn, n=3):
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _db_opts(n_vertices):
    return dict(max_id=n_vertices - 1, n_partitions=16, n_levels=3,
                branching=4, buffer_cap=50_000, max_partition_edges=400_000,
                persist_min_edges=4096, wal_segment_bytes=4 << 20)


def _two_hop_section(g, seeds, failures, tag) -> dict:
    """Per-hop loop vs one columnar call on the same engine-like `g`."""
    from repro.core import two_hop_counts
    from repro.core.query import friends_of_friends_perhop

    def perhop():
        return [friends_of_friends_perhop(g, int(v)) for v in seeds]

    def columnar():
        return two_hop_counts(g, seeds)

    # bitwise equality first: every seed's slice vs the per-hop answer
    res = columnar()
    base = perhop()
    for i, ref in enumerate(base):
        got = res.ids[res.offsets[i]:res.offsets[i + 1]]
        if not np.array_equal(np.sort(got), np.sort(ref)):
            failures.append(f"two_hop[{tag}]: seed {seeds[i]} mismatch "
                            f"({got.shape[0]} vs {ref.shape[0]} ids)")
            break
    t_perhop = _best_of(perhop)
    t_col = _best_of(columnar)
    out = {
        "n_seeds": int(seeds.shape[0]),
        "result_ids": int(res.ids.shape[0]),
        "perhop_s": t_perhop,
        "columnar_s": t_col,
        "speedup_x": t_perhop / t_col,
    }
    print(f"    two_hop[{tag}]: perhop {t_perhop:.3f}s  columnar "
          f"{t_col:.4f}s  speedup {out['speedup_x']:.1f}x")
    return out


def _triangle_baseline(g, mids, max_id) -> int:
    """Per-vertex loop with chunked vectorized membership — the per-hop
    strategy: two neighbor calls per middle, then the wedge cross-product
    probed against the global distinct edge-key set."""
    from repro.core import as_engine

    eng = as_engine(g)
    so, do = eng.to_coo()
    N = np.int64(max_id + 1)
    keys = np.unique(so.astype(np.int64) * N + do.astype(np.int64))
    total = 0
    for v in mids:
        one = np.asarray([v], np.int64)
        inn = np.unique(eng.in_neighbors_batch(one)[0])
        out = np.unique(eng.out_neighbors_batch(one)[0])
        if inn.size == 0 or out.size == 0:
            continue
        for a in range(0, inn.size, 256):   # bound resident wedges
            pairs = (inn[a:a + 256, None] * N + out[None, :]).ravel()
            pos = np.searchsorted(keys, pairs)
            pos[pos >= keys.size] = 0
            total += int((keys[pos] == pairs).sum())
    return total


def _triangle_section(g, n_vertices, failures, tag, n_mids=1000,
                      full_headline=False) -> dict:
    from repro.core import as_engine, triangle_count
    from repro.core.multihop import _edge_keys_internal

    eng = as_engine(g)
    M = np.int64(eng.n_internal_vertices)
    ek = _edge_keys_internal(eng)
    mids_all = np.intersect1d(np.unique(ek // M), np.unique(ek % M),
                              assume_unique=True)
    mids_all = np.sort(np.asarray(eng.intervals.to_original(mids_all),
                                  np.int64))
    rng = np.random.default_rng(11)
    mids = np.sort(rng.choice(mids_all, min(n_mids, mids_all.size),
                              replace=False))

    base = _triangle_baseline(g, mids, n_vertices - 1)
    col = triangle_count(g, middles=mids)
    if base != col:
        failures.append(f"triangle[{tag}]: baseline {base} != columnar {col}")
    t_base = _best_of(lambda: _triangle_baseline(g, mids, n_vertices - 1))
    t_col = _best_of(lambda: triangle_count(g, middles=mids))
    out = {
        "n_middles": int(mids.size),
        "n_middles_total": int(mids_all.size),
        "triangles": int(col),
        "perhop_s": t_base,
        "columnar_s": t_col,
        "speedup_x": t_base / t_col,
    }
    print(f"    triangle[{tag}]: {col} wedges over {mids.size} middles  "
          f"perhop {t_base:.3f}s  columnar {t_col:.4f}s  "
          f"speedup {out['speedup_x']:.1f}x")
    if full_headline:
        t0 = time.perf_counter()
        full = triangle_count(g)
        out["full_triangles"] = int(full)
        out["full_columnar_s"] = time.perf_counter() - t0
        print(f"    triangle[{tag}]: FULL store {full} wedges in "
              f"{out['full_columnar_s']:.2f}s (columnar only)")
    return out


def _kernel_section(g, seeds, failures) -> dict:
    from repro.core import two_hop_counts
    from repro.kernels.frontier_expand import HAVE_PALLAS

    try:
        import jax
        backend = jax.default_backend()
    except Exception:
        backend = "none"

    sparse = two_hop_counts(g, seeds, dense="never")
    dense = two_hop_counts(g, seeds, dense="kernel")  # builds + caches plan
    ok = (np.array_equal(sparse.ids, dense.ids)
          and np.array_equal(sparse.counts, dense.counts)
          and np.array_equal(sparse.offsets, dense.offsets))
    if not ok:
        failures.append("kernel: dense 2-hop not bitwise-equal to sparse")
    t_sparse = _best_of(lambda: two_hop_counts(g, seeds, dense="never"))
    t_dense = _best_of(lambda: two_hop_counts(g, seeds, dense="kernel"))
    out = {
        "n_seeds": int(seeds.shape[0]),
        "backend": backend,
        "mosaic_kernel": bool(HAVE_PALLAS and backend == "tpu"),
        "bitwise_equal": ok,
        "sparse_s": t_sparse,
        "dense_s": t_dense,  # plan memoized in the engine cache by now
    }
    print(f"    kernel[{backend}]: sparse {t_sparse:.4f}s  dense "
          f"{t_dense:.4f}s  (mosaic={out['mosaic_kernel']}, "
          f"equal={ok})")
    return out


def run(scale: float = 1.0, smoke: bool = False) -> dict:
    from repro.core import GraphDB, ServiceDB

    n_vertices = max(4000, int(100_000 * scale))
    n_edges = max(30_000, int(1_000_000 * scale))
    n_seeds = 64 if smoke else 512
    n_mids = 200 if smoke else 1000
    gate = GATE_X_SMOKE if smoke else GATE_X
    src, dst = power_law_graph(n_vertices, n_edges, seed=0)
    rng = np.random.default_rng(3)
    seeds = np.unique(rng.integers(0, n_vertices, n_seeds * 2))[:n_seeds]
    panel = seeds[:min(128, n_seeds)]

    failures: list = []
    payload = {
        "scale": scale,
        "smoke": smoke,
        "n_vertices": n_vertices,
        "n_edges": n_edges,
        "gate_x": gate,
    }
    workdir = tempfile.mkdtemp(prefix="bench_multihop_")
    d = os.path.join(workdir, "db")
    try:
        svc = ServiceDB.create(d, checkpoint_interval_ops=10 ** 9,
                               **_db_opts(n_vertices))
        svc.insert_edges(src, dst)
        svc.checkpoint()
        # leave a buffered tail so the live view exercises buffer slabs
        tail_s, tail_d = power_law_graph(n_vertices, max(2000, n_edges // 50),
                                         seed=9)
        svc.insert_edges(tail_s, tail_d)

        print("  live epoch view (read_view): 2-hop + triangle + kernel ...")
        with svc.read_view() as view:
            payload["two_hop_live"] = _two_hop_section(
                view, seeds, failures, "live")
            payload["triangle_live"] = _triangle_section(
                view, n_vertices, failures, "live", n_mids=n_mids)
            payload["kernel"] = _kernel_section(view, panel, failures)
        svc.checkpoint()
        svc.close()

        print("  reopened GraphDB (cold): 2-hop + triangle ...")
        db = GraphDB.open(d)
        try:
            payload["two_hop_reopened"] = _two_hop_section(
                db, seeds, failures, "reopened")
            payload["triangle_reopened"] = _triangle_section(
                db, n_vertices, failures, "reopened", n_mids=n_mids,
                full_headline=not smoke)
        finally:
            db.close()
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    for key in ("two_hop_live", "two_hop_reopened",
                "triangle_live", "triangle_reopened"):
        sp = payload[key]["speedup_x"]
        if sp < gate:
            failures.append(f"{key}: speedup {sp:.1f}x < gate {gate}x")
    payload["failures"] = failures
    save("BENCH_multihop", payload)
    if failures:
        print("  GATE FAILURES:")
        for f in failures:
            print(f"    - {f}")
        if smoke:
            sys.exit(1)
    else:
        print("  all gates passed")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny store, relaxed gate, non-zero exit on failure")
    args = ap.parse_args()
    run(scale=args.scale if not args.smoke else min(args.scale, 0.03),
        smoke=args.smoke)


if __name__ == "__main__":
    main()
