"""Paper §6 PSW cost + the TPU adaptation: host PSW seek-count vs the Θ(P²)
bound, PageRank convergence, and device-PSW window-exchange vs dense-gather
equivalence + bytes accounting."""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.core import (GraphPAL, build_device_graph, edge_centric_sweep,
                        pagerank_device, pagerank_host, psw_sweep_host)

from .common import power_law_graph, save


def run(scale: float = 1.0):
    n_vertices = int(20_000 * scale)
    n_edges = int(200_000 * scale)
    src, dst = power_law_graph(n_vertices, n_edges, seed=5)
    P = 16
    g = GraphPAL.from_edges(src, dst, n_partitions=P, max_id=n_vertices - 1)

    # host PSW: one sweep's random-access count vs Θ(P²)
    seeks = psw_sweep_host(g, lambda i, owner, windows: None)
    t0 = time.perf_counter()
    ranks = pagerank_host(g, n_iters=10)
    pr_time = time.perf_counter() - t0

    # convergence vs dense reference
    outdeg = np.bincount(src, minlength=n_vertices).astype(np.float64)
    r = np.ones(n_vertices)
    for _ in range(60):
        contrib = r / np.maximum(outdeg, 1)
        acc = np.zeros(n_vertices)
        np.add.at(acc, dst, contrib[src])
        r = 0.15 + 0.85 * acc
    intern = np.asarray(g.intervals.to_internal(np.arange(n_vertices)))
    ranks_long = pagerank_host(g, n_iters=40)
    err = float(np.abs(ranks_long[intern] - r).max() / r.max())

    # device PSW: window exchange vs dense gather — equal results, different
    # exchanged byte volumes (the paper's seeks -> our collective bytes)
    dg = build_device_graph(g)
    r1 = pagerank_device(dg, n_iters=3, mode="dense_gather")
    r2 = pagerank_device(dg, n_iters=3, mode="psw_windows")
    agree = float(jnp.abs(r1 - r2).max())
    # bytes: dense gather ships all vertex state to every partition;
    # windows ship only the per-(owner,consumer) unique rows
    state_bytes = 4  # one fp32 rank per vertex
    dense_bytes = P * n_vertices * state_bytes            # all-gather
    window_rows = int(np.asarray(dg.send_idx).size)       # padded windows
    window_bytes = window_rows * state_bytes

    results = {
        "P": P,
        "host_sweep_seeks": seeks,
        "theta_p_squared": P * P,
        "seeks_per_p2": seeks / (P * P),
        "pagerank_10iter_s": pr_time,
        "pagerank_rel_err_vs_dense_fixed_point": err,
        "device_modes_max_diff": agree,
        "dense_gather_bytes_per_sweep": dense_bytes,
        "psw_window_bytes_per_sweep": window_bytes,
        "window_savings": dense_bytes / max(window_bytes, 1),
    }
    save("psw", results)
    print("— §6 PSW —")
    for k, v in results.items():
        print(f"  {k}: {v}")
    assert err < 1e-3
    assert agree < 1e-3
    return results


if __name__ == "__main__":
    run()
