"""Paper Fig 7b + Fig 8c: in/out-edge query latency vs vertex degree, and
the pointer-array indexing comparison (raw binary search with simulated
block reads vs in-memory sparse index vs Elias-Gamma pinned in RAM).
Plus (ISSUE 1): batched StorageEngine frontier expansion vs per-vertex
queries on a live LSM store."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (GraphPAL, IntervalMap, LSMTree, SparseIndex,
                        decode_monotonic, encode_monotonic)

from .common import percentiles, power_law_graph, save


def frontier_expansion_lsm(src, dst, n_vertices: int,
                           frontier_size: int = 2048) -> dict:
    """Compare one frontier hop on a LIVE LSM store (levels + buffers):
    the StorageEngine's batched set-at-a-time out_neighbors_batch vs the
    per-vertex naive loop the query layer used before ISSUE 1."""
    iv = IntervalMap.for_capacity(n_vertices - 1, 16)
    t = LSMTree(iv, n_levels=3, branching=4, buffer_cap=50_000,
                max_partition_edges=400_000)
    k = src.shape[0] - min(20_000, src.shape[0] // 5)
    t.insert_edges(src[:k], dst[:k])
    t.insert_edges(src[k:], dst[k:])  # last batch stays in the buffers
    eng = t.storage_engine()

    rng = np.random.default_rng(7)
    frontier = np.unique(rng.integers(0, n_vertices, frontier_size))

    def best_of(fn, n=3):
        times, out = [], None
        for _ in range(n):
            t0 = time.perf_counter()
            out = fn()
            times.append(time.perf_counter() - t0)
        return min(times), out

    t_batched, (vals, offsets) = best_of(
        lambda: eng.out_neighbors_batch(frontier))
    t_pervertex, naive = best_of(
        lambda: [t.out_neighbors(int(v)) for v in frontier])

    # same answers (vectorization is not allowed to change semantics)
    for i in range(0, frontier.shape[0], 97):
        assert np.array_equal(np.sort(vals[offsets[i]:offsets[i + 1]]),
                              np.sort(naive[i]))
    return {
        "n_edges": int(src.shape[0]),
        "buffered_edges": int(t.total_buffered()),
        "frontier_size": int(frontier.shape[0]),
        "result_edges": int(vals.shape[0]),
        "batched_s": t_batched,
        "per_vertex_s": t_pervertex,
        "speedup": t_pervertex / max(t_batched, 1e-12),
    }


def run(scale: float = 1.0):
    n_vertices = int(100_000 * scale)
    n_edges = int(1_000_000 * scale)
    src, dst = power_law_graph(n_vertices, n_edges, seed=3)
    g = GraphPAL.from_edges(src, dst, n_partitions=16, max_id=n_vertices - 1)

    outdeg = np.bincount(src, minlength=n_vertices)
    indeg = np.bincount(dst, minlength=n_vertices)

    # (Fig 7b) latency vs degree, random vertex sample
    rng = np.random.default_rng(0)
    sample = rng.integers(0, n_vertices, 300)
    scatter = []
    for v in sample:
        t0 = time.perf_counter()
        nbrs = g.out_neighbors(int(v))
        t_out = time.perf_counter() - t0
        t0 = time.perf_counter()
        _ = g.in_neighbors(int(v))
        t_in = time.perf_counter() - t0
        scatter.append({"outdeg": int(outdeg[v]), "indeg": int(indeg[v]),
                        "out_ms": t_out * 1e3, "in_ms": t_in * 1e3})

    # (Fig 8c) pointer-array index variants — count simulated block reads
    # for 2,000 out-edge lookups
    lookups = rng.integers(0, n_vertices, 2000)
    iv = g.intervals
    interned = np.asarray(iv.to_internal(lookups))

    # raw binary search on "disk": log2(n/entries-per-block) block reads
    block_entries = 512
    raw_reads = 0
    for part in g.partitions:
        n_blocks = max(1, part.src_vertices.shape[0] // block_entries)
        raw_reads += int(np.ceil(np.log2(max(n_blocks, 2)))) * len(lookups)

    # sparse index in RAM: 1 block read per (vertex, partition) probe
    sparse_reads = 0
    t0 = time.perf_counter()
    for part in g.partitions:
        si = SparseIndex(part.src_vertices, stride=block_entries)
        for v in interned:
            si.lookup(int(v))
        sparse_reads += si.block_reads
    sparse_time = time.perf_counter() - t0

    # Elias-Gamma: whole pointer-array pinned in RAM — 0 block reads;
    # measure decode once (amortized at load time, paper §4.2.1)
    t0 = time.perf_counter()
    eg_bytes = raw_bytes = 0
    for part in g.partitions:
        if part.src_vertices.size:
            packed, bits, first = encode_monotonic(part.src_vertices + 1)
            eg_bytes += packed.nbytes
            raw_bytes += part.src_vertices.nbytes
            _ = decode_monotonic(packed, bits, first, part.src_vertices.size)
    eg_time = time.perf_counter() - t0

    frontier = frontier_expansion_lsm(src, dst, n_vertices)

    results = {
        "latency_scatter": scatter[:100],
        "frontier_expansion_lsm": frontier,
        "out_ms": percentiles([s["out_ms"] for s in scatter]),
        "in_ms": percentiles([s["in_ms"] for s in scatter]),
        "index_variants": {
            "raw_disk_block_reads": raw_reads,
            "sparse_index_block_reads": sparse_reads,
            "elias_gamma_block_reads": 0,
            "eg_compression_ratio": raw_bytes / max(eg_bytes, 1),
            "eg_decode_s": eg_time,
            "sparse_lookup_s": sparse_time,
        },
    }
    save("BENCH_query", results)
    print("— Fig 7b (query latency, ms) —")
    print(f"  out: {results['out_ms']}")
    print(f"  in : {results['in_ms']}")
    print("— Fig 8c (pointer-array index variants, simulated block reads) —")
    for k, v in results["index_variants"].items():
        print(f"  {k}: {v:.2f}" if isinstance(v, float) else f"  {k}: {v}")
    print("— ISSUE 1 (LSM frontier expansion: batched engine vs per-vertex) —")
    print(f"  batched   : {frontier['batched_s'] * 1e3:.1f} ms")
    print(f"  per-vertex: {frontier['per_vertex_s'] * 1e3:.1f} ms")
    print(f"  speedup   : {frontier['speedup']:.1f}x "
          f"({frontier['buffered_edges']} edges still buffered)")
    return results


if __name__ == "__main__":
    run()
