"""Paper Fig 7b + Fig 8c: in/out-edge query latency vs vertex degree, and
the pointer-array indexing comparison (raw binary search with simulated
block reads vs in-memory sparse index vs Elias-Gamma pinned in RAM)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (GraphPAL, SparseIndex, decode_monotonic,
                        encode_monotonic)

from .common import percentiles, power_law_graph, save


def run(scale: float = 1.0):
    n_vertices = int(100_000 * scale)
    n_edges = int(1_000_000 * scale)
    src, dst = power_law_graph(n_vertices, n_edges, seed=3)
    g = GraphPAL.from_edges(src, dst, n_partitions=16, max_id=n_vertices - 1)

    outdeg = np.bincount(src, minlength=n_vertices)
    indeg = np.bincount(dst, minlength=n_vertices)

    # (Fig 7b) latency vs degree, random vertex sample
    rng = np.random.default_rng(0)
    sample = rng.integers(0, n_vertices, 300)
    scatter = []
    for v in sample:
        t0 = time.perf_counter()
        nbrs = g.out_neighbors(int(v))
        t_out = time.perf_counter() - t0
        t0 = time.perf_counter()
        _ = g.in_neighbors(int(v))
        t_in = time.perf_counter() - t0
        scatter.append({"outdeg": int(outdeg[v]), "indeg": int(indeg[v]),
                        "out_ms": t_out * 1e3, "in_ms": t_in * 1e3})

    # (Fig 8c) pointer-array index variants — count simulated block reads
    # for 2,000 out-edge lookups
    lookups = rng.integers(0, n_vertices, 2000)
    iv = g.intervals
    interned = np.asarray(iv.to_internal(lookups))

    # raw binary search on "disk": log2(n/entries-per-block) block reads
    block_entries = 512
    raw_reads = 0
    for part in g.partitions:
        n_blocks = max(1, part.src_vertices.shape[0] // block_entries)
        raw_reads += int(np.ceil(np.log2(max(n_blocks, 2)))) * len(lookups)

    # sparse index in RAM: 1 block read per (vertex, partition) probe
    sparse_reads = 0
    t0 = time.perf_counter()
    for part in g.partitions:
        si = SparseIndex(part.src_vertices, stride=block_entries)
        for v in interned:
            si.lookup(int(v))
        sparse_reads += si.block_reads
    sparse_time = time.perf_counter() - t0

    # Elias-Gamma: whole pointer-array pinned in RAM — 0 block reads;
    # measure decode once (amortized at load time, paper §4.2.1)
    t0 = time.perf_counter()
    eg_bytes = raw_bytes = 0
    for part in g.partitions:
        if part.src_vertices.size:
            packed, bits, first = encode_monotonic(part.src_vertices + 1)
            eg_bytes += packed.nbytes
            raw_bytes += part.src_vertices.nbytes
            _ = decode_monotonic(packed, bits, first, part.src_vertices.size)
    eg_time = time.perf_counter() - t0

    results = {
        "latency_scatter": scatter[:100],
        "out_ms": percentiles([s["out_ms"] for s in scatter]),
        "in_ms": percentiles([s["in_ms"] for s in scatter]),
        "index_variants": {
            "raw_disk_block_reads": raw_reads,
            "sparse_index_block_reads": sparse_reads,
            "elias_gamma_block_reads": 0,
            "eg_compression_ratio": raw_bytes / max(eg_bytes, 1),
            "eg_decode_s": eg_time,
            "sparse_lookup_s": sparse_time,
        },
    }
    save("query", results)
    print("— Fig 7b (query latency, ms) —")
    print(f"  out: {results['out_ms']}")
    print(f"  in : {results['in_ms']}")
    print("— Fig 8c (pointer-array index variants, simulated block reads) —")
    for k, v in results["index_variants"].items():
        print(f"  {k}: {v:.2f}" if isinstance(v, float) else f"  {k}: {v}")
    return results


if __name__ == "__main__":
    run()
