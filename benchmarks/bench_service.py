"""ISSUE 4: the concurrent service tier — BENCH_service.json.

Three sections:

  1. `single_insert`: bulk-insert throughput, plain synchronous GraphDB vs
     ServiceDB (WAL + buffer append on the caller's thread, merges /
     persistence / checkpoints on the maintenance thread). The service
     path must not regress single-thread throughput (`gate_ratio`).
  2. `single_query`: batched frontier expansion on the live engine vs on a
     pinned Snapshot session of the same store — again a no-regression
     gate.
  3. `readers`: aggregate snapshot-read throughput with 1..N reader
     PROCESSES (each opens the same pinned session directory; immutable
     hard-linked files, shared page cache, zero coordination) while a
     writer thread keeps inserting into the live store. Aggregate
     throughput should grow with readers — the whole point of
     snapshot-isolated sessions.

Gates are *in-run relative* (service path vs plain path measured on the
same machine seconds apart) because the committed BENCH_insert/BENCH_query
baselines were recorded on different hardware; those baselines are echoed
into the JSON for cross-referencing. `--smoke` shrinks everything and
exits non-zero on a gate failure — the CI smoke gate.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import shutil
import sys
import tempfile
import threading
import time

import numpy as np

from .common import OUT_DIR, power_law_graph, save

GATE_RATIO = 0.6  # service path must keep >= 60% of the plain path


def _best_of(fn, n=3):
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _db_opts(n_vertices):
    return dict(max_id=n_vertices - 1, n_partitions=16, n_levels=3,
                branching=4, buffer_cap=50_000, max_partition_edges=400_000,
                persist_min_edges=4096, wal_segment_bytes=4 << 20)


def bench_single_insert(src, dst, n_vertices, workdir) -> dict:
    from repro.core import GraphDB, ServiceDB

    def plain():
        d = os.path.join(workdir, f"plain_{time.monotonic_ns()}")
        db = GraphDB.create(d, **_db_opts(n_vertices))
        db.insert_edges(src, dst)
        db.close()
        shutil.rmtree(d)

    def service():
        d = os.path.join(workdir, f"svc_{time.monotonic_ns()}")
        svc = ServiceDB.create(d, checkpoint_interval_ops=10 ** 9,
                               **_db_opts(n_vertices))
        svc.insert_edges(src, dst)
        svc.close()
        shutil.rmtree(d)

    t_plain = _best_of(plain)
    t_service = _best_of(service)
    n = int(src.shape[0])
    return {
        "n_edges": n,
        "plain_per_s": n / t_plain,
        "service_per_s": n / t_service,
        "ratio": t_plain / t_service,  # >1 means service is faster
    }


def bench_single_query(src, dst, n_vertices, workdir,
                       frontier_size=2048) -> dict:
    from repro.core import ServiceDB

    d = os.path.join(workdir, "qdb")
    svc = ServiceDB.create(d, checkpoint_interval_ops=10 ** 9,
                           **_db_opts(n_vertices))
    svc.insert_edges(src, dst)
    svc.checkpoint()
    rng = np.random.default_rng(7)
    frontier = np.unique(rng.integers(0, n_vertices, frontier_size))

    live = svc.db.storage_engine()
    t_live = _best_of(lambda: live.out_neighbors_batch(frontier))
    snap = svc.begin_snapshot()
    eng = snap.storage_engine()
    t_snap = _best_of(lambda: eng.out_neighbors_batch(frontier))
    # same answers on both paths
    a, ao = live.out_neighbors_batch(frontier)
    b, bo = eng.out_neighbors_batch(frontier)
    for i in range(0, frontier.shape[0], 97):
        assert np.array_equal(np.sort(a[ao[i]:ao[i + 1]]),
                              np.sort(b[bo[i]:bo[i + 1]]))
    out = {
        "frontier_size": int(frontier.shape[0]),
        "live_s": t_live,
        "snapshot_s": t_snap,
        "ratio": t_live / t_snap,  # >1 means the snapshot path is faster
    }
    snap.release()
    svc.close()
    return out


def _reader_worker(snap_dir, n_vertices, duration_s, seed, barrier, out_q):
    """One reader process: open the shared session dir, hammer batched
    frontier queries for `duration_s`, report vertices queried."""
    from repro.core import Snapshot

    snap = Snapshot.open(snap_dir)
    eng = snap.storage_engine()
    rng = np.random.default_rng(seed)
    eng.out_neighbors_batch(rng.integers(0, n_vertices, 256))  # warm up
    barrier.wait()
    t_end = time.perf_counter() + duration_s
    n = 0
    while time.perf_counter() < t_end:
        vs = rng.integers(0, n_vertices, 256)
        eng.out_neighbors_batch(vs)
        n += int(vs.shape[0])
    out_q.put(n)


def _run_readers(snap_dir, n_vertices, n_readers, duration_s) -> dict:
    ctx = mp.get_context("spawn")
    barrier = ctx.Barrier(n_readers)
    out_q = ctx.Queue()
    procs = [
        ctx.Process(target=_reader_worker,
                    args=(snap_dir, n_vertices, duration_s,
                          100 + i, barrier, out_q))
        for i in range(n_readers)
    ]
    for p in procs:
        p.start()
    counts = [out_q.get(timeout=duration_s * 20 + 120) for _ in procs]
    for p in procs:
        p.join()
    return {
        "aggregate_vertices_per_s": sum(counts) / duration_s,
        "per_reader": [c / duration_s for c in counts],
    }


def bench_readers(src, dst, n_vertices, workdir, reader_counts=(1, 2, 4),
                  duration_s=3.0) -> dict:
    """Two phases against ONE pinned session: (a) pure read scaling with
    1..N reader processes (N capped at the core count — with fewer cores
    than readers the measurement is CPU contention, not architecture);
    (b) coexistence: readers at the widest count while a writer thread
    floods the live store — snapshot isolation means neither side waits
    on the other, so both throughputs should hold up."""
    from repro.core import ServiceDB

    d = os.path.join(workdir, "rdb")
    svc = ServiceDB.create(d, checkpoint_interval_ops=10 ** 9,
                           **_db_opts(n_vertices))
    svc.insert_edges(src, dst)
    snap = svc.begin_snapshot()
    results = {"cpu_count": os.cpu_count(),
               "reader_counts": list(reader_counts)}

    # phase (a): scaling, no competing writer
    for n_readers in reader_counts:
        results[f"readers_{n_readers}"] = _run_readers(
            snap.dir, n_vertices, n_readers, duration_s)
    base = results["readers_1"]["aggregate_vertices_per_s"]
    multi = [results[f"readers_{n}"]["aggregate_vertices_per_s"]
             for n in reader_counts if n > 1]
    # best MULTI-reader aggregate vs 1 reader — including readers_1 in the
    # max would make the >1x gate unfailable
    results["scaling"] = (max(multi) / base) if multi else 1.0

    # phase (b): widest reader count with a concurrent writer
    stop = threading.Event()
    wrote = []

    def writer():
        rng = np.random.default_rng(11)
        n = 0
        t0 = time.perf_counter()
        while not stop.is_set():
            svc.insert_edges(rng.integers(0, n_vertices, 5000),
                             rng.integers(0, n_vertices, 5000))
            n += 5000
        wrote.append(n / (time.perf_counter() - t0))

    wt = threading.Thread(target=writer)
    wt.start()
    try:
        concurrent = _run_readers(snap.dir, n_vertices,
                                  max(reader_counts), duration_s)
    finally:
        stop.set()
        wt.join()
    results["concurrent"] = {
        "n_readers": max(reader_counts),
        "aggregate_vertices_per_s": concurrent["aggregate_vertices_per_s"],
        "writer_edges_per_s": wrote[0],
    }
    snap.release()
    svc.close()
    return results


def _committed_baselines() -> dict:
    """Echo the committed single-thread baselines for cross-reference."""
    out = {}
    for name, keys in (("BENCH_insert", ("bulk",)),
                       ("BENCH_query", ("frontier_expansion_lsm",))):
        path = os.path.join(OUT_DIR, f"{name}.json")
        try:
            with open(path) as f:
                doc = json.load(f)
            out[name] = {k: doc[k] for k in keys if k in doc}
        except (OSError, json.JSONDecodeError, KeyError):
            pass
    return out


def run(scale: float = 1.0, smoke: bool = False) -> dict:
    n_vertices = max(2000, int(100_000 * scale))
    n_edges = max(20_000, int(1_000_000 * scale))
    ncpu = os.cpu_count() or 2
    reader_counts = tuple(c for c in ((1, 2) if smoke else (1, 2, 4))
                          if c <= max(2, ncpu))
    duration_s = 1.5 if smoke else 3.0
    src, dst = power_law_graph(n_vertices, n_edges, seed=0)

    workdir = tempfile.mkdtemp(prefix="bench_service_")
    try:
        print(f"  insert: {n_edges} edges, plain vs service ...")
        insert = bench_single_insert(src, dst, n_vertices, workdir)
        print(f"    plain {insert['plain_per_s']:,.0f}/s  "
              f"service {insert['service_per_s']:,.0f}/s  "
              f"ratio {insert['ratio']:.2f}")
        print("  query: live engine vs snapshot session ...")
        query = bench_single_query(src, dst, n_vertices, workdir)
        print(f"    live {query['live_s'] * 1e3:.2f}ms  "
              f"snapshot {query['snapshot_s'] * 1e3:.2f}ms  "
              f"ratio {query['ratio']:.2f}")
        print(f"  readers: {reader_counts} processes x {duration_s}s "
              f"against one pinned session ({ncpu} cores) ...")
        readers = bench_readers(src, dst, n_vertices, workdir,
                                reader_counts=reader_counts,
                                duration_s=duration_s)
        for n in reader_counts:
            r = readers[f"readers_{n}"]
            print(f"    {n} reader(s): "
                  f"{r['aggregate_vertices_per_s']:,.0f} vertices/s")
        conc = readers["concurrent"]
        print(f"    scaling {readers['scaling']:.2f}x; with a live writer: "
              f"{conc['n_readers']} readers at "
              f"{conc['aggregate_vertices_per_s']:,.0f} vertices/s while "
              f"the writer sustained {conc['writer_edges_per_s']:,.0f} "
              "inserts/s")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    payload = {
        "scale": scale,
        "n_vertices": n_vertices,
        "n_edges": n_edges,
        "gate_ratio": GATE_RATIO,
        "single_insert": insert,
        "single_query": query,
        "readers": readers,
        "committed_baselines": _committed_baselines(),
    }
    save("BENCH_service", payload)

    failures = []
    if insert["ratio"] < GATE_RATIO:
        failures.append(f"single-thread INSERT regression: service is "
                        f"{insert['ratio']:.2f}x plain (< {GATE_RATIO})")
    if query["ratio"] < GATE_RATIO:
        failures.append(f"single-thread QUERY regression: snapshot is "
                        f"{query['ratio']:.2f}x live (< {GATE_RATIO})")
    if readers["scaling"] < 1.0:
        failures.append(f"multi-reader aggregate throughput did not exceed "
                        f"1 reader ({readers['scaling']:.2f}x)")
    for f in failures:
        print("  GATE FAIL:", f)
    payload["gate_failures"] = failures
    save("BENCH_service", payload)
    # gates abort the process only in smoke mode (the CI step); a full
    # benchmarks.run sweep records the failure in the JSON and continues
    if failures and smoke:
        sys.exit(1)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale + enforce the regression gates")
    args = ap.parse_args()
    run(scale=args.scale if not args.smoke else min(args.scale, 0.05),
        smoke=args.smoke)


if __name__ == "__main__":
    main()
