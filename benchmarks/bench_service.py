"""ISSUE 4 + ISSUE 5 + ISSUE 7 + ISSUE 8: the concurrent service tier —
BENCH_service.json.

Six sections:

  1. `single_insert`: bulk-insert throughput, plain synchronous GraphDB vs
     ServiceDB (WAL + buffer append on the caller's thread, merges /
     persistence / checkpoints on the maintenance pipeline). The service
     path must not regress single-thread throughput (`gate_ratio`).
  2. `single_query`: batched frontier expansion on the live engine vs on a
     pinned Snapshot session of the same store — again a no-regression
     gate.
  3. `readers`: aggregate snapshot-read throughput with 1..N reader
     PROCESSES (each opens the same pinned session directory; immutable
     hard-linked files, shared page cache, zero coordination) while a
     writer thread keeps inserting into the live store. Aggregate
     throughput should grow with readers — the whole point of
     snapshot-isolated sessions.
  4. `contended` (ISSUE 5): N reader THREADS issuing batched live frontier
     queries while ONE writer floods inserts and maintenance merges run
     continuously — p50/p99 per-query latency and aggregate vertices/s,
     measured two ways in the same run: the PR-4 lock-serialized path
     (pipeline=False, every read takes the service lock, so reads queue
     behind whole merges) vs the ISSUE-5 epoch path (pipeline=True,
     `read_view()` pins a published manifest, no lock ever). The gates:
     epoch aggregate throughput must beat locked by `contended_gate_x`,
     and epoch p99 during active merges must stay within
     `P99_UNCONTENDED_X` of the in-run single-threaded (uncontended) p99.
  5. `zipf` (ISSUE 8): skewed (zipfian) vs uniform read/write mix on the
     live epoch path — both reads and writer sources drawn from a hot
     contiguous id head, the interval-imbalance case a sharded deployment
     must absorb. Recorded, not gated (skew can legitimately win via
     caching or lose via hot-interval churn).
  6. `checksum` (ISSUE 7): the full durable write path and reads with
     end-to-end CRCs on vs off — checksumming must cost < 5% in-run
     (`CHECKSUM_GATE`).
  7. `observability` (ISSUE 9): the same durable insert path and a
     fixed-work 2-thread contended read with the telemetry registry
     enabled vs the global kill-switch off — full instrumentation (WAL
     latency histograms, read-heat counters, job/hop spans) must cost
     < 3% in-run (`TELEMETRY_GATE`).

Gates are *in-run relative* (service path vs plain path measured on the
same machine seconds apart) because the committed BENCH_insert/BENCH_query
baselines were recorded on different hardware; those baselines are echoed
into the JSON for cross-referencing. `--smoke` shrinks everything and
exits non-zero on a gate failure — the CI smoke gate; `--section` runs one
section alone (CI runs `--smoke --section contended` as its own step).
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import shutil
import sys
import tempfile
import threading
import time

import numpy as np

from .common import OUT_DIR, percentiles, power_law_graph, save

GATE_RATIO = 0.6  # service path must keep >= 60% of the plain path
CONTENDED_GATE_X = 2.0   # epoch aggregate vs locked aggregate (full run)
CONTENDED_GATE_X_SMOKE = 1.2  # CI-noise-tolerant smoke version
# p99 gate for live reads during active maintenance: the epoch path's tail
# must stay below the PR-4 lock-serialized tail measured in the same run
# (with margin), OR below an absolute multiple of the in-run
# single-threaded p99 — whichever bound is looser. The relative arm is the
# real regression detector (epoch degrading toward lock-like stalls); the
# absolute arm keeps the gate meaningful if the locked baseline ever stops
# collapsing on a future machine.
P99_VS_LOCKED = 0.8
P99_UNCONTENDED_X = 25.0
# ISSUE 7: end-to-end integrity must be ~free — the checksummed path must
# keep >= 95% of the unchecksummed path's speed (< 5% overhead), measured
# in the same run on both the durable write path and warm reads. The
# smoke run's builds are ~100ms, where fsync-latency jitter is
# proportionally larger, so CI tolerates more noise (same precedent as
# CONTENDED_GATE_X_SMOKE); the <5% contract is the full-scale run's.
CHECKSUM_GATE = 0.95
CHECKSUM_GATE_SMOKE = 0.80
# ISSUE 9: full telemetry (counters + histograms + spans, per-thread
# cells, no locks on the hot path) must keep >= 97% of the disabled
# path's speed (< 3% overhead) on both the durable insert path and a
# contended fixed-work read. Smoke-scale runs are ~100ms per arm where
# scheduler jitter dominates, so CI tolerates more noise.
TELEMETRY_GATE = 0.97
TELEMETRY_GATE_SMOKE = 0.85


def _best_of(fn, n=3):
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _db_opts(n_vertices):
    return dict(max_id=n_vertices - 1, n_partitions=16, n_levels=3,
                branching=4, buffer_cap=50_000, max_partition_edges=400_000,
                persist_min_edges=4096, wal_segment_bytes=4 << 20)


def bench_single_insert(src, dst, n_vertices, workdir) -> dict:
    from repro.core import GraphDB, ServiceDB

    def plain():
        d = os.path.join(workdir, f"plain_{time.monotonic_ns()}")
        db = GraphDB.create(d, **_db_opts(n_vertices))
        db.insert_edges(src, dst)
        db.close()
        shutil.rmtree(d)

    def service():
        d = os.path.join(workdir, f"svc_{time.monotonic_ns()}")
        svc = ServiceDB.create(d, checkpoint_interval_ops=10 ** 9,
                               **_db_opts(n_vertices))
        svc.insert_edges(src, dst)
        svc.close()
        shutil.rmtree(d)

    t_plain = _best_of(plain)
    t_service = _best_of(service)
    n = int(src.shape[0])
    return {
        "n_edges": n,
        "plain_per_s": n / t_plain,
        "service_per_s": n / t_service,
        "ratio": t_plain / t_service,  # >1 means service is faster
    }


def bench_single_query(src, dst, n_vertices, workdir,
                       frontier_size=2048) -> dict:
    from repro.core import ServiceDB

    d = os.path.join(workdir, "qdb")
    svc = ServiceDB.create(d, checkpoint_interval_ops=10 ** 9,
                           **_db_opts(n_vertices))
    svc.insert_edges(src, dst)
    svc.checkpoint()
    rng = np.random.default_rng(7)
    frontier = np.unique(rng.integers(0, n_vertices, frontier_size))

    live = svc.db.storage_engine()
    t_live = _best_of(lambda: live.out_neighbors_batch(frontier))
    snap = svc.begin_snapshot()
    eng = snap.storage_engine()
    t_snap = _best_of(lambda: eng.out_neighbors_batch(frontier))
    # same answers on both paths
    a, ao = live.out_neighbors_batch(frontier)
    b, bo = eng.out_neighbors_batch(frontier)
    for i in range(0, frontier.shape[0], 97):
        assert np.array_equal(np.sort(a[ao[i]:ao[i + 1]]),
                              np.sort(b[bo[i]:bo[i + 1]]))
    out = {
        "frontier_size": int(frontier.shape[0]),
        "live_s": t_live,
        "snapshot_s": t_snap,
        "ratio": t_live / t_snap,  # >1 means the snapshot path is faster
    }
    snap.release()
    svc.close()
    return out


def bench_checksum(src, dst, n_vertices, workdir,
                   frontier_size=2048) -> dict:
    """ISSUE 7 satellite: integrity checking must be ~free. Times the full
    durable write path (insert + checkpoint: per-record WAL CRCs plus
    per-section partition CRCs) and reads (cold reopen = first-touch
    verification; warm = verified sections cached) with checksums on vs
    off in the same run."""
    from repro.core import GraphDB

    rng = np.random.default_rng(13)
    frontier = np.unique(rng.integers(0, n_vertices, frontier_size))

    def build(enabled):
        d = os.path.join(workdir, f"crc_{time.monotonic_ns()}")
        db = GraphDB.create(d, checksums=enabled, **_db_opts(n_vertices))
        db.insert_edges(src, dst)
        db.checkpoint()
        db.tree.close()
        return d

    # interleave on/off builds so page-cache / fsync-latency drift hits
    # both arms equally; take the min of each arm
    times = {"on": [], "off": []}
    keep = {}
    for rep in range(5):
        for mode, enabled in (("on", True), ("off", False)):
            t0 = time.perf_counter()
            d = build(enabled)
            times[mode].append(time.perf_counter() - t0)
            if mode in keep:
                shutil.rmtree(keep.pop(mode), ignore_errors=True)
            keep[mode] = d
    out = {}
    for mode in ("on", "off"):
        db = GraphDB.open(keep[mode])
        eng = db.storage_engine()
        t0 = time.perf_counter()
        eng.out_neighbors_batch(frontier)  # cold: first-touch verify
        t_cold = time.perf_counter() - t0
        t_warm = _best_of(lambda: eng.out_neighbors_batch(frontier), n=9)
        db.tree.close()
        shutil.rmtree(keep[mode], ignore_errors=True)
        out[mode] = {"write_s": min(times[mode]), "cold_read_s": t_cold,
                     "warm_read_s": t_warm}
    out.update({
        "n_edges": int(src.shape[0]),
        # >= 1 means checksumming is free; the gate allows down to 0.95
        "write_ratio": out["off"]["write_s"] / out["on"]["write_s"],
        "cold_read_ratio": (out["off"]["cold_read_s"]
                            / out["on"]["cold_read_s"]),
        "warm_read_ratio": (out["off"]["warm_read_s"]
                            / out["on"]["warm_read_s"]),
    })
    return out


def bench_observability(src, dst, n_vertices, workdir,
                        frontier_size=2048, n_threads=2,
                        read_iters=30) -> dict:
    """ISSUE 9 tentpole gate: full instrumentation must be ~free. Times
    (a) the durable service insert path (WAL append/fsync histograms,
    collector-registered stats, tail gauges) and (b) a fixed-work
    contended read — `n_threads` threads each running `read_iters`
    per-query epoch-pinned frontier expansions (read-heat counters, hop
    spans) — with the registry enabled vs the global kill-switch off.
    Arms are interleaved and each takes its min-of-reps, so cache/fsync
    drift hits both equally; the enabled arm must additionally prove it
    recorded something (a zero-overhead no-op instrument would pass the
    ratio gate vacuously)."""
    from repro.core import ServiceDB, telemetry

    rng = np.random.default_rng(23)
    frontier = np.unique(rng.integers(0, n_vertices, frontier_size))

    def insert_once():
        d = os.path.join(workdir, f"obs_{time.monotonic_ns()}")
        svc = ServiceDB.create(d, checkpoint_interval_ops=10 ** 9,
                               **_db_opts(n_vertices))
        svc.insert_edges(src, dst)
        svc.close()
        shutil.rmtree(d)

    # one persistent store for the read arm (fixed work, not fixed time:
    # a duration-based loop would hide overhead as lower throughput)
    d = os.path.join(workdir, "obs_read")
    rsvc = ServiceDB.create(d, checkpoint_interval_ops=10 ** 9,
                            **_db_opts(n_vertices))
    rsvc.insert_edges(src, dst)
    rsvc.checkpoint()

    def read_once():
        def worker():
            for _ in range(read_iters):
                with rsvc.read_view() as view:
                    view.storage_engine().out_neighbors_batch(frontier)
        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()

    read_once()  # warm the page cache / decode paths before either arm
    times = {"insert": {"on": [], "off": []},
             "read": {"on": [], "off": []}}
    appends_on = 0
    arms = (("on", True), ("off", False))
    try:
        for rep in range(5):
            # alternate arm order per rep: drift (cpu frequency, page
            # cache, allocator state) must not systematically favor
            # whichever arm runs second
            for mode, enabled in (arms if rep % 2 == 0 else arms[::-1]):
                telemetry.set_enabled(enabled)
                t0 = time.perf_counter()
                insert_once()
                times["insert"][mode].append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                read_once()
                times["read"][mode].append(time.perf_counter() - t0)
                if enabled:
                    snap = telemetry.snapshot()
                    appends_on = int(snap["counters"].get("wal.appends", 0))
    finally:
        telemetry.set_enabled(True)
    rsvc.close()
    shutil.rmtree(d, ignore_errors=True)
    out = {
        "n_edges": int(src.shape[0]),
        "n_read_threads": n_threads,
        "read_iters": read_iters,
        "insert": {m: min(v) for m, v in times["insert"].items()},
        "read": {m: min(v) for m, v in times["read"].items()},
        # >= 1 means telemetry is free; the gate allows down to 0.97
        "wal_appends_recorded": appends_on,
    }
    out["insert_ratio"] = out["insert"]["off"] / out["insert"]["on"]
    out["read_ratio"] = out["read"]["off"] / out["read"]["on"]
    return out


def _reader_worker(snap_dir, n_vertices, duration_s, seed, barrier, out_q):
    """One reader process: open the shared session dir, hammer batched
    frontier queries for `duration_s`, report vertices queried."""
    from repro.core import Snapshot

    snap = Snapshot.open(snap_dir)
    eng = snap.storage_engine()
    rng = np.random.default_rng(seed)
    eng.out_neighbors_batch(rng.integers(0, n_vertices, 256))  # warm up
    barrier.wait()
    t_end = time.perf_counter() + duration_s
    n = 0
    while time.perf_counter() < t_end:
        vs = rng.integers(0, n_vertices, 256)
        eng.out_neighbors_batch(vs)
        n += int(vs.shape[0])
    out_q.put(n)


def _run_readers(snap_dir, n_vertices, n_readers, duration_s) -> dict:
    ctx = mp.get_context("spawn")
    barrier = ctx.Barrier(n_readers)
    out_q = ctx.Queue()
    procs = [
        ctx.Process(target=_reader_worker,
                    args=(snap_dir, n_vertices, duration_s,
                          100 + i, barrier, out_q))
        for i in range(n_readers)
    ]
    for p in procs:
        p.start()
    counts = [out_q.get(timeout=duration_s * 20 + 120) for _ in procs]
    for p in procs:
        p.join()
    return {
        "aggregate_vertices_per_s": sum(counts) / duration_s,
        "per_reader": [c / duration_s for c in counts],
    }


def bench_readers(src, dst, n_vertices, workdir, reader_counts=(1, 2, 4),
                  duration_s=3.0) -> dict:
    """Two phases against ONE pinned session: (a) pure read scaling with
    1..N reader processes (N capped at the core count — with fewer cores
    than readers the measurement is CPU contention, not architecture);
    (b) coexistence: readers at the widest count while a writer thread
    floods the live store — snapshot isolation means neither side waits
    on the other, so both throughputs should hold up."""
    from repro.core import ServiceDB

    d = os.path.join(workdir, "rdb")
    svc = ServiceDB.create(d, checkpoint_interval_ops=10 ** 9,
                           **_db_opts(n_vertices))
    svc.insert_edges(src, dst)
    snap = svc.begin_snapshot()
    results = {"cpu_count": os.cpu_count(),
               "reader_counts": list(reader_counts)}

    # phase (a): scaling, no competing writer
    for n_readers in reader_counts:
        results[f"readers_{n_readers}"] = _run_readers(
            snap.dir, n_vertices, n_readers, duration_s)
    base = results["readers_1"]["aggregate_vertices_per_s"]
    multi = [results[f"readers_{n}"]["aggregate_vertices_per_s"]
             for n in reader_counts if n > 1]
    # best MULTI-reader aggregate vs 1 reader — including readers_1 in the
    # max would make the >1x gate unfailable
    results["scaling"] = (max(multi) / base) if multi else 1.0

    # phase (b): widest reader count with a concurrent writer
    stop = threading.Event()
    wrote = []

    def writer():
        rng = np.random.default_rng(11)
        n = 0
        t0 = time.perf_counter()
        while not stop.is_set():
            svc.insert_edges(rng.integers(0, n_vertices, 5000),
                             rng.integers(0, n_vertices, 5000))
            n += 5000
        wrote.append(n / (time.perf_counter() - t0))

    wt = threading.Thread(target=writer)
    wt.start()
    try:
        concurrent = _run_readers(snap.dir, n_vertices,
                                  max(reader_counts), duration_s)
    finally:
        stop.set()
        wt.join()
    results["concurrent"] = {
        "n_readers": max(reader_counts),
        "aggregate_vertices_per_s": concurrent["aggregate_vertices_per_s"],
        "writer_edges_per_s": wrote[0],
    }
    snap.release()
    svc.close()
    return results


def _quiesce(svc, timeout_s=60.0) -> None:
    """Wait until the maintenance pipeline has drained the backlog."""
    t_end = time.perf_counter() + timeout_s
    while (svc.tree.total_buffered() > svc.tree.buffer_cap
           or svc.tree.inflight_edges()) and time.perf_counter() < t_end:
        time.sleep(0.02)


def _contended_reader(svc, mode, n_vertices, duration_s, seed, barrier, out,
                      idx):
    """One live-reader thread: batched frontier queries for `duration_s`,
    per-query latencies recorded. `locked` = the PR-4 path (service lock
    around every live read); `epoch` = ISSUE-5 read_view (no lock)."""
    rng = np.random.default_rng(seed)
    lat = []
    n = 0
    barrier.wait()
    t_end = time.perf_counter() + duration_s
    while time.perf_counter() < t_end:
        vs = rng.integers(0, n_vertices, 256)
        t0 = time.perf_counter()
        if mode == "locked":
            with svc._lock:
                svc.db.storage_engine().out_neighbors_batch(vs)
        else:
            with svc.read_view() as view:
                view.storage_engine().out_neighbors_batch(vs)
        lat.append((time.perf_counter() - t0) * 1e3)
        n += int(vs.shape[0])
    out[idx] = (lat, n)


def _contended_phase(svc, mode, n_vertices, n_readers, duration_s,
                     with_writer: bool, with_maintenance: bool = False,
                     write_rate: int = 60_000) -> dict:
    """One measurement phase. The writer offers a FIXED load (`write_rate`
    edges/s, paced) so both modes digest the same write work — an unpaced
    writer floods harder exactly when reads don't block it, which would
    compare different workloads. With `with_maintenance`, a driver thread
    keeps checkpoint/merge work running back-to-back through the whole
    window — the same driver code in both modes — so the measurement is
    literally "live reads DURING active maintenance": in the PR-4 mode the
    flush+persist cycle holds the service lock (reads queue behind it); in
    the pipelined mode it holds interval locks + a brief manifest window
    (reads never wait)."""
    stop = threading.Event()
    wrote = [0.0]
    maint_cycles = [0]

    def writer():
        rng = np.random.default_rng(17)
        n = 0
        batch = 5000
        t0 = time.perf_counter()
        while not stop.is_set():
            svc.insert_edges(rng.integers(0, n_vertices, batch),
                             rng.integers(0, n_vertices, batch))
            n += batch
            # pace to the offered rate (sleep the remainder of the slot)
            ahead = n / write_rate - (time.perf_counter() - t0)
            if ahead > 0:
                time.sleep(ahead)
        wrote[0] = n / (time.perf_counter() - t0)

    def maintenance_driver():
        while not stop.is_set():
            svc.checkpoint()  # flush backlog + persist + manifest + GC
            maint_cycles[0] += 1
            time.sleep(0.02)  # a breath, so the writer can enqueue work

    barrier = threading.Barrier(n_readers)
    out = [None] * n_readers
    readers = [
        threading.Thread(target=_contended_reader,
                         args=(svc, mode, n_vertices, duration_s, 300 + i,
                               barrier, out, i))
        for i in range(n_readers)
    ]
    flushes0 = svc.stats.flushes
    extra = []
    if with_writer:
        extra.append(threading.Thread(target=writer))
    if with_maintenance:
        extra.append(threading.Thread(target=maintenance_driver))
    for t in extra:
        t.start()
    for r in readers:
        r.start()
    for r in readers:
        r.join()
    stop.set()
    for t in extra:
        t.join()
    lats = [x for lat, _ in out for x in lat]
    agg = sum(n for _, n in out) / duration_s
    return {
        "n_readers": n_readers,
        "aggregate_vertices_per_s": agg,
        "latency_ms": percentiles(lats),
        "queries": len(lats),
        "writer_edges_per_s": wrote[0],
        "flushes_during": svc.stats.flushes - flushes0,
        "maintenance_cycles": maint_cycles[0],
    }


def bench_contended(workdir, n_readers=2, duration_s=5.0) -> dict:
    """ISSUE 5 acceptance: live-read throughput and tail latency with an
    active writer and maintenance running throughout — PR-4 lock-serialized
    vs epoch-published manifests, in ONE run on the same data and hardware.
    The service is configured in the paper's online regime: a sizeable
    store with checkpoint cadence tuned for fresh snapshot opens
    (`checkpoint_interval_ops` small), so PR-4 maintenance repeatedly
    persists the store UNDER the service lock — exactly the window where
    its live reads stall — while the pipelined mode overlaps persistence
    with merges and takes only a brief exclusive window for the manifest."""
    from repro.core import ServiceDB

    # the contended store has its OWN fixed shape (even under --smoke):
    # lock-held maintenance only hurts once merges rewrite ~1M-edge
    # partitions, and query cost only matches the online workload when the
    # graph keeps a realistic degree — a scaled-down/denser store measures
    # nothing but GIL scheduling noise, with the PR-4 baseline sailing
    # through tiny merges
    n_vertices, preload = 100_000, 2_000_000
    psrc, pdst = power_law_graph(n_vertices, preload, seed=5)
    out = {"n_readers": n_readers, "duration_s": duration_s,
           "n_vertices": n_vertices, "preload_edges": preload}
    for mode in ("locked", "epoch"):
        d = os.path.join(workdir, f"cdb_{mode}")
        svc = ServiceDB.create(
            d, max_id=n_vertices - 1, n_partitions=16, n_levels=2,
            branching=8, buffer_cap=50_000, max_partition_edges=8_000_000,
            persist_min_edges=4096, checkpoint_interval_ops=10 ** 9,
            wal_tail_budget_bytes=1 << 40,  # the driver sets the cadence
            pipeline=(mode == "epoch"))
        svc.insert_edges(psrc, pdst)
        _quiesce(svc)
        res = {"uncontended": _contended_phase(
            svc, mode, n_vertices, 1, max(1.0, duration_s / 2),
            with_writer=False)}
        res["contended"] = _contended_phase(
            svc, mode, n_vertices, n_readers, duration_s,
            with_writer=True, with_maintenance=True)
        res["max_concurrent_flushes"] = svc.stats.max_concurrent_flushes
        out[mode] = res
        svc.close()
        shutil.rmtree(d, ignore_errors=True)
    locked = out["locked"]["contended"]["aggregate_vertices_per_s"]
    epoch = out["epoch"]["contended"]["aggregate_vertices_per_s"]
    out["speedup"] = epoch / locked if locked else float("inf")
    p99_unc = out["epoch"]["uncontended"]["latency_ms"]["p99"]
    p99_con = out["epoch"]["contended"]["latency_ms"]["p99"]
    p99_lock = out["locked"]["contended"]["latency_ms"]["p99"]
    out["epoch_p99_vs_uncontended"] = (p99_con / p99_unc) if p99_unc else None
    out["epoch_p99_vs_locked"] = (p99_con / p99_lock) if p99_lock else None
    # the p99 gate bound actually applied (see P99_VS_LOCKED docstring)
    out["p99_bound_ms"] = max(p99_lock * P99_VS_LOCKED,
                              p99_unc * P99_UNCONTENDED_X)
    out["p99_ok"] = p99_con <= out["p99_bound_ms"]
    return out


def _zipf_keys(rng, n_vertices, size, alpha=1.3):
    """Skewed key sampler: zipf-ranked ids WITHOUT scattering, so the hot
    head is a contiguous low-id range — i.e. it lands in a few vertex
    intervals. That is exactly the hostile case for interval-partitioned
    stores (ISSUE 8): a handful of partitions absorb most of the traffic."""
    return (rng.zipf(alpha, size) - 1) % n_vertices


def _mix_reader(svc, sampler, duration_s, seed, barrier, out, idx):
    rng = np.random.default_rng(seed)
    lat = []
    n = 0
    barrier.wait()
    t_end = time.perf_counter() + duration_s
    while time.perf_counter() < t_end:
        vs = sampler(rng, 256)
        t0 = time.perf_counter()
        with svc.read_view() as view:
            view.storage_engine().out_neighbors_batch(vs)
        lat.append((time.perf_counter() - t0) * 1e3)
        n += int(vs.shape[0])
    out[idx] = (lat, n)


def _mix_phase(svc, sampler, n_vertices, n_readers, duration_s,
               write_rate=60_000) -> dict:
    """One read/write-mix phase: N readers on `sampler`-drawn keys, one
    paced writer whose SOURCE vertices come from the same sampler (so
    writes churn the same hot intervals the readers hammer)."""
    stop = threading.Event()
    wrote = [0.0]

    def writer():
        rng = np.random.default_rng(23)
        n = 0
        batch = 5000
        t0 = time.perf_counter()
        while not stop.is_set():
            svc.insert_edges(sampler(rng, batch),
                             rng.integers(0, n_vertices, batch))
            n += batch
            ahead = n / write_rate - (time.perf_counter() - t0)
            if ahead > 0:
                time.sleep(ahead)
        wrote[0] = n / (time.perf_counter() - t0)

    barrier = threading.Barrier(n_readers)
    out = [None] * n_readers
    readers = [
        threading.Thread(target=_mix_reader,
                         args=(svc, sampler, duration_s, 500 + i, barrier,
                               out, i))
        for i in range(n_readers)
    ]
    wt = threading.Thread(target=writer)
    wt.start()
    for r in readers:
        r.start()
    for r in readers:
        r.join()
    stop.set()
    wt.join()
    lats = [x for lat, _ in out for x in lat]
    return {
        "aggregate_vertices_per_s": sum(n for _, n in out) / duration_s,
        "latency_ms": percentiles(lats),
        "writer_edges_per_s": wrote[0],
    }


def bench_zipf(workdir, n_vertices, n_readers=2, duration_s=4.0) -> dict:
    """Skewed (zipfian) vs uniform read/write mix on the live epoch path.
    Uniform traffic spreads across all vertex intervals; the zipf mix
    concentrates both reads and writes on a contiguous hot-id head. The
    section records the throughput/latency delta plus the measured hot-set
    concentration — the imbalance a sharded deployment (bench_shard) must
    absorb when hot intervals all land on one shard. No pass/fail gate:
    skew can legitimately run FASTER (hot neighborhoods stay cached) or
    slower (buffer contention on hot intervals); the number is the point."""
    from repro.core import ServiceDB

    preload = max(100_000, n_vertices * 10)
    psrc, pdst = power_law_graph(n_vertices, preload, seed=7)
    d = os.path.join(workdir, "zipfdb")
    svc = ServiceDB.create(
        d, max_id=n_vertices - 1, n_partitions=16, n_levels=2, branching=8,
        buffer_cap=50_000, max_partition_edges=8_000_000,
        persist_min_edges=4096, checkpoint_interval_ops=10 ** 9,
        wal_tail_budget_bytes=1 << 40)
    svc.insert_edges(psrc, pdst)
    _quiesce(svc)
    alpha = 1.3
    rng = np.random.default_rng(0)
    probe = _zipf_keys(rng, n_vertices, 200_000, alpha)
    hot_cut = max(1, n_vertices // 100)
    out = {
        "n_vertices": n_vertices,
        "preload_edges": preload,
        "zipf_alpha": alpha,
        "top1pct_key_share": float((probe < hot_cut).mean()),
    }
    samplers = {
        "uniform": lambda r, k: r.integers(0, n_vertices, k),
        "zipf": lambda r, k: _zipf_keys(r, n_vertices, k, alpha),
    }
    for name, sampler in samplers.items():
        out[name] = _mix_phase(svc, sampler, n_vertices, n_readers,
                               duration_s)
    svc.close()
    shutil.rmtree(d, ignore_errors=True)
    uni = out["uniform"]["aggregate_vertices_per_s"]
    out["zipf_vs_uniform_x"] = (
        out["zipf"]["aggregate_vertices_per_s"] / uni if uni else None)
    return out


def _committed_baselines() -> dict:
    """Echo the committed single-thread baselines for cross-reference."""
    out = {}
    for name, keys in (("BENCH_insert", ("bulk",)),
                       ("BENCH_query", ("frontier_expansion_lsm",))):
        path = os.path.join(OUT_DIR, f"{name}.json")
        try:
            with open(path) as f:
                doc = json.load(f)
            out[name] = {k: doc[k] for k in keys if k in doc}
        except (OSError, json.JSONDecodeError, KeyError):
            pass
    return out


def run(scale: float = 1.0, smoke: bool = False,
        section: str = "all") -> dict:
    n_vertices = max(2000, int(100_000 * scale))
    n_edges = max(20_000, int(1_000_000 * scale))
    ncpu = os.cpu_count() or 2
    reader_counts = tuple(c for c in ((1, 2) if smoke else (1, 2, 4))
                          if c <= max(2, ncpu))
    duration_s = 1.5 if smoke else 3.0
    src, dst = power_law_graph(n_vertices, n_edges, seed=0)

    def want(name):
        if section == "base":  # the PR-4 sections, minus contended
            return name in ("insert", "query", "readers")
        return section in ("all", name)

    # merge freshly-measured sections over the committed JSON so a
    # single-section run (CI's contended step) keeps the other numbers
    payload = {}
    try:
        with open(os.path.join(OUT_DIR, "BENCH_service.json")) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        pass
    payload.update({
        "scale": scale,
        "n_vertices": n_vertices,
        "n_edges": n_edges,
        "gate_ratio": GATE_RATIO,
        "contended_gate_x": (CONTENDED_GATE_X_SMOKE if smoke
                             else CONTENDED_GATE_X),
        "p99_uncontended_x": P99_UNCONTENDED_X,
        "checksum_gate": (CHECKSUM_GATE_SMOKE if smoke
                          else CHECKSUM_GATE),
        "telemetry_gate": (TELEMETRY_GATE_SMOKE if smoke
                           else TELEMETRY_GATE),
        "committed_baselines": _committed_baselines(),
    })

    workdir = tempfile.mkdtemp(prefix="bench_service_")
    try:
        if want("insert"):
            print(f"  insert: {n_edges} edges, plain vs service ...")
            payload["single_insert"] = insert = bench_single_insert(
                src, dst, n_vertices, workdir)
            print(f"    plain {insert['plain_per_s']:,.0f}/s  "
                  f"service {insert['service_per_s']:,.0f}/s  "
                  f"ratio {insert['ratio']:.2f}")
        if want("query"):
            print("  query: live engine vs snapshot session ...")
            payload["single_query"] = query = bench_single_query(
                src, dst, n_vertices, workdir)
            print(f"    live {query['live_s'] * 1e3:.2f}ms  "
                  f"snapshot {query['snapshot_s'] * 1e3:.2f}ms  "
                  f"ratio {query['ratio']:.2f}")
        if want("readers"):
            print(f"  readers: {reader_counts} processes x {duration_s}s "
                  f"against one pinned session ({ncpu} cores) ...")
            payload["readers"] = readers = bench_readers(
                src, dst, n_vertices, workdir,
                reader_counts=reader_counts, duration_s=duration_s)
            for n in reader_counts:
                r = readers[f"readers_{n}"]
                print(f"    {n} reader(s): "
                      f"{r['aggregate_vertices_per_s']:,.0f} vertices/s")
            conc = readers["concurrent"]
            print(f"    scaling {readers['scaling']:.2f}x; with a live "
                  f"writer: {conc['n_readers']} readers at "
                  f"{conc['aggregate_vertices_per_s']:,.0f} vertices/s "
                  f"while the writer sustained "
                  f"{conc['writer_edges_per_s']:,.0f} inserts/s")
        if want("contended"):
            n_readers = min(max(2, ncpu - 1), 2 if smoke else 4)
            cdur = max(duration_s, 5.0)  # ≥ a few checkpoint cycles
            print(f"  contended: {n_readers} live-reader threads + 1 "
                  f"writer, locked (PR 4) vs epoch manifests (ISSUE 5) ...")
            payload["contended"] = cont = bench_contended(
                workdir, n_readers=n_readers, duration_s=cdur)
            for mode in ("locked", "epoch"):
                c = cont[mode]["contended"]
                print(f"    {mode:6}: {c['aggregate_vertices_per_s']:,.0f} "
                      f"verts/s  p50={c['latency_ms']['p50']:.2f}ms "
                      f"p99={c['latency_ms']['p99']:.2f}ms  "
                      f"({c['maintenance_cycles']} maintenance cycles, "
                      f"writer {c['writer_edges_per_s']:,.0f}/s)")
            print(f"    epoch/locked speedup {cont['speedup']:.2f}x; epoch "
                  f"p99 {cont['epoch']['contended']['latency_ms']['p99']:.1f}"
                  f"ms vs gate bound {cont['p99_bound_ms']:.1f}ms")
        if want("zipf"):
            n_readers = 2
            zdur = max(1.5, duration_s)
            print(f"  zipf: skewed vs uniform read/write mix, "
                  f"{n_readers} readers + 1 writer (ISSUE 8) ...")
            payload["zipf"] = zf = bench_zipf(
                workdir, n_vertices, n_readers=n_readers, duration_s=zdur)
            for name in ("uniform", "zipf"):
                z = zf[name]
                print(f"    {name:8}: {z['aggregate_vertices_per_s']:,.0f} "
                      f"verts/s  p99={z['latency_ms']['p99']:.2f}ms  "
                      f"writer {z['writer_edges_per_s']:,.0f}/s")
            print(f"    zipf/uniform {zf['zipf_vs_uniform_x']:.2f}x "
                  f"(top-1% ids drew {zf['top1pct_key_share'] * 100:.0f}% "
                  f"of traffic)")
        if want("checksum"):
            # this section's gate divides two write times; at smoke scale
            # a build is ~20ms and fsync jitter swamps the CRC cost, so
            # floor the workload regardless of --scale (still ~2s of CI)
            if n_edges >= 300_000:
                cn_vertices, csrc, cdst = n_vertices, src, dst
            else:
                cn_vertices = max(n_vertices, 30_000)
                csrc, cdst = power_law_graph(cn_vertices, 300_000, seed=1)
            print(f"  checksum: {csrc.shape[0]} edges, durable write + "
                  f"reads, CRC on vs off (ISSUE 7) ...")
            payload["checksum"] = crc = bench_checksum(
                csrc, cdst, cn_vertices, workdir)
            print(f"    write on {crc['on']['write_s']:.2f}s / off "
                  f"{crc['off']['write_s']:.2f}s (ratio "
                  f"{crc['write_ratio']:.3f}); warm read ratio "
                  f"{crc['warm_read_ratio']:.3f}; cold (first-touch "
                  f"verify) ratio {crc['cold_read_ratio']:.3f}")
        if want("observability"):
            # like checksum: the gate divides two times, so floor the
            # workload regardless of --scale (fsync jitter at smoke scale)
            if n_edges >= 300_000:
                on_vertices, osrc, odst = n_vertices, src, dst
            else:
                on_vertices = max(n_vertices, 30_000)
                osrc, odst = power_law_graph(on_vertices, 300_000, seed=2)
            print(f"  observability: {osrc.shape[0]} edges, insert + "
                  f"contended read, telemetry on vs off (ISSUE 9) ...")
            payload["observability"] = obs = bench_observability(
                osrc, odst, on_vertices, workdir)
            print(f"    insert on {obs['insert']['on']:.2f}s / off "
                  f"{obs['insert']['off']:.2f}s (ratio "
                  f"{obs['insert_ratio']:.3f}); contended read ratio "
                  f"{obs['read_ratio']:.3f}; "
                  f"{obs['wal_appends_recorded']} WAL appends recorded")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    save("BENCH_service", payload)

    failures = []
    insert = payload.get("single_insert")
    query = payload.get("single_query")
    readers = payload.get("readers")
    cont = payload.get("contended")
    if want("insert") and insert and insert["ratio"] < GATE_RATIO:
        failures.append(f"single-thread INSERT regression: service is "
                        f"{insert['ratio']:.2f}x plain (< {GATE_RATIO})")
    if want("query") and query and query["ratio"] < GATE_RATIO:
        failures.append(f"single-thread QUERY regression: snapshot is "
                        f"{query['ratio']:.2f}x live (< {GATE_RATIO})")
    if want("readers") and readers and readers["scaling"] < 1.0:
        failures.append(f"multi-reader aggregate throughput did not exceed "
                        f"1 reader ({readers['scaling']:.2f}x)")
    crc = payload.get("checksum")
    if want("checksum") and crc:
        crc_gate = payload["checksum_gate"]
        worst = min(crc["write_ratio"], crc["warm_read_ratio"])
        if worst < crc_gate:
            failures.append(
                f"checksumming overhead past the gate: write "
                f"{crc['write_ratio']:.2f}x / warm read "
                f"{crc['warm_read_ratio']:.2f}x the unchecksummed path "
                f"(< {crc_gate})")
    obs = payload.get("observability")
    if want("observability") and obs:
        obs_gate = payload["telemetry_gate"]
        worst = min(obs["insert_ratio"], obs["read_ratio"])
        if worst < obs_gate:
            failures.append(
                f"telemetry overhead past the gate: insert "
                f"{obs['insert_ratio']:.2f}x / contended read "
                f"{obs['read_ratio']:.2f}x the disabled path "
                f"(< {obs_gate})")
        if obs["wal_appends_recorded"] <= 0:
            failures.append(
                "telemetry arm recorded no WAL appends — the instrumented "
                "path did not actually instrument")
    if want("contended") and cont:
        gate_x = payload["contended_gate_x"]
        if cont["speedup"] < gate_x:
            failures.append(
                f"contended live reads: epoch path is {cont['speedup']:.2f}x"
                f" the lock-serialized path (< {gate_x}x)")
        if not cont["p99_ok"]:
            p99 = cont["epoch"]["contended"]["latency_ms"]["p99"]
            failures.append(
                f"live-read p99 during maintenance is {p99:.1f}ms, past "
                f"the in-run gate bound {cont['p99_bound_ms']:.1f}ms "
                f"(max of {P99_VS_LOCKED}x locked p99, "
                f"{P99_UNCONTENDED_X}x single-threaded p99)")
    for f in failures:
        print("  GATE FAIL:", f)
    payload["gate_failures"] = failures
    save("BENCH_service", payload)
    # gates abort the process only in smoke mode (the CI step); a full
    # benchmarks.run sweep records the failure in the JSON and continues
    if failures and smoke:
        sys.exit(1)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale + enforce the regression gates")
    ap.add_argument("--section", default="all",
                    choices=["all", "base", "insert", "query", "readers",
                             "contended", "checksum", "zipf",
                             "observability"])
    args = ap.parse_args()
    run(scale=args.scale if not args.smoke else min(args.scale, 0.05),
        smoke=args.smoke, section=args.section)


if __name__ == "__main__":
    main()
