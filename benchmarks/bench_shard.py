"""ISSUE 8: shared-nothing interval sharding — BENCH_shard.json.

Three sections, one sharded store per shard count (1, 2, 4, 8; `--smoke`
runs 1 and 2):

  1. `ingest`: scatter-insert throughput through the router. Each batch is
     split by source-vertex ownership and shipped to its shard over the
     length-prefixed IPC protocol; every shard runs its own WAL + buffer +
     maintenance pipeline, so ingest parallelism is bounded only by cores
     and fsync.
  2. `reads`: contended read throughput — a fixed pool of client threads
     (the same pool size at every shard count, so the offered load is
     constant) issues batched frontier expansions against the live router.
     Each client thread holds one private connection per shard and each
     worker serves each connection on its own handler thread, so requests
     to different shards execute in genuinely parallel processes. Per-query
     latencies and per-shard block-read deltas are recorded: the block-read
     accounting proves the read WORK (not just the RPCs) was partitioned
     across all shards.
  3. `equality`: the acceptance bitwise gate — the max-shard-count store
     and an unsharded ServiceDB are fed the SAME op prefix (same insert
     batches in the same order, then the same deletes); sorted
     out-neighborhoods over a vertex sample, 2-hop BFS levels, and
     friends-of-friends counts must match bitwise between the sharded
     engine (`consistent_engine` over a pinned ShardedView) and the
     unsharded engine.

The scaling gate is CORE-AWARE because shard processes cannot scale past
the machine: on >= 4 cores the acceptance gate applies (4-shard aggregate
read throughput >= 2.5x the 1-shard router); on 2-3 cores a 2-shard >=
1.3x gate applies (the CI smoke gate); on a single core no speedup is
physically possible, so the gate inverts into an overhead bound — the
max-shard configuration must keep >= 0.35x of the 1-shard throughput
(i.e. scatter/gather + IPC framing must not eat the store). Which gate was
applied is recorded in the JSON (`scaling_gate`) together with
`cpu_count`, so a full-scale run on real hardware is distinguishable from
a 1-core container run. The bitwise-equality and partitioned-block-read
gates apply everywhere, at every core count.

`--smoke` shrinks the store, runs shard counts (1, 2) and exits non-zero
on any gate failure — the CI step.
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import threading
import time

import numpy as np

from .common import percentiles, power_law_graph, save

SHARD_COUNTS_FULL = (1, 2, 4, 8)
SHARD_COUNTS_SMOKE = (1, 2)
# the acceptance gate (>= 4 cores): 4-shard aggregate read throughput vs
# the 1-shard router (same IPC path, so the ratio isolates sharding)
SCALE_GATE_4SHARD = 2.5
# 2-3 cores (CI runners): 2 shards must still beat 1. The smoke store is
# tiny (per-RPC framing is a larger share of each query), so CI tolerates
# more noise — same precedent as bench_service's CONTENDED_GATE_X_SMOKE.
SCALE_GATE_2SHARD = 1.3
SCALE_GATE_2SHARD_SMOKE = 1.15
# 1 core: no speedup is possible — bound the scatter/gather overhead
# instead. Measured at 2 shards (the smallest sharded config): higher
# counts on one core measure scheduler oversubscription, not the router
# (8 processes time-slicing one core is thrash by construction; those
# rows are still recorded, unguarded)
OVERHEAD_GATE_1CORE = 0.35


def _db_kw():
    """Per-shard ServiceDB shape. n_partitions must be a multiple of every
    shard count benchmarked (8 covers 1/2/4/8). The maintenance cadence is
    left to the router's checkpoint_all calls."""
    return dict(n_partitions=8, n_levels=2, branching=8,
                buffer_cap=50_000, max_partition_edges=16_000_000,
                persist_min_edges=4096, checkpoint_interval_ops=10 ** 9,
                wal_tail_budget_bytes=1 << 40)


def _op_prefix(n_vertices, n_edges, batch=200_000):
    """The SHARED op prefix: insert batches in a fixed order, then a fixed
    set of deletes. Both the sharded and unsharded stores replay exactly
    this sequence — the bitwise gate compares the results."""
    src, dst = power_law_graph(n_vertices, n_edges, seed=8)
    batches = [(src[i:i + batch], dst[i:i + batch])
               for i in range(0, n_edges, batch)]
    # delete a handful of known-present edges (exercises routed deletes)
    deletes = [(int(src[i]), int(dst[i]))
               for i in range(0, min(n_edges, 50 * 97), 97)]
    return batches, deletes


def _ingest(store, batches, deletes) -> float:
    t0 = time.perf_counter()
    for s, d in batches:
        store.insert_edges(s, d)
    for s, d in deletes:
        store.delete_edge(s, d)
    return time.perf_counter() - t0


def _read_worker(router, n_vertices, duration_s, seed, barrier, out, idx):
    """One client thread: batched frontier expansions against the live
    router. view=None reads pin a private per-op epoch worker-side."""
    rng = np.random.default_rng(seed)
    eng = router.storage_engine()
    lat = []
    n = 0
    barrier.wait()
    t_end = time.perf_counter() + duration_s
    while time.perf_counter() < t_end:
        vs = rng.integers(0, n_vertices, 512)
        t0 = time.perf_counter()
        eng.out_neighbors_batch(vs)
        lat.append((time.perf_counter() - t0) * 1e3)
        n += int(vs.shape[0])
    out[idx] = (lat, n)


def _read_phase(router, n_vertices, n_threads, duration_s) -> dict:
    io0 = router.io_stats()
    barrier = threading.Barrier(n_threads)
    out = [None] * n_threads
    threads = [
        threading.Thread(target=_read_worker,
                         args=(router, n_vertices, duration_s, 800 + i,
                               barrier, out, i))
        for i in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    io1 = router.io_stats()
    lats = [x for lat, _ in out for x in lat]
    per_shard = [
        {"shard": i,
         "block_reads": io1[i]["block_reads"] - io0[i]["block_reads"],
         "bytes_read": io1[i]["bytes_read"] - io0[i]["bytes_read"],
         "gathers": io1[i]["gathers"] - io0[i]["gathers"]}
        for i in range(len(io0))
    ]
    return {
        "n_client_threads": n_threads,
        "aggregate_vertices_per_s":
            sum(n for _, n in out) / duration_s,
        "latency_ms": percentiles(lats),
        "queries": len(lats),
        "per_shard_io": per_shard,
    }


def _khop_levels(eng, seeds, k=2):
    from repro.core import khop
    res = khop(eng, seeds, k=k)
    return [np.asarray(lv) for lv in res.levels]


def _equality(router, ref_svc, n_vertices) -> dict:
    """The bitwise gate: sharded vs unsharded on the same op prefix."""
    from repro.core import consistent_engine, two_hop_counts

    rng = np.random.default_rng(17)
    sample = rng.integers(0, n_vertices, 200)
    seeds = rng.integers(0, n_vertices, 64)
    checks = {}
    with consistent_engine(router) as eng, ref_svc.read_view() as view:
        ref_eng = view.storage_engine()
        checks["n_edges"] = bool(router.n_edges == ref_svc.n_edges)
        outs_ok = True
        for v in sample[:50]:
            a = np.sort(router.out_neighbors(int(v)))
            b = np.sort(ref_eng.out_neighbors_batch([int(v)])[0])
            if a.shape != b.shape or not np.array_equal(a, b):
                outs_ok = False
                break
        checks["out_neighbors"] = outs_ok
        a_lv = _khop_levels(eng, seeds)
        b_lv = _khop_levels(ref_eng, seeds)
        checks["khop_levels"] = bool(
            len(a_lv) == len(b_lv)
            and all(np.array_equal(x, y) for x, y in zip(a_lv, b_lv)))
        a_fof = two_hop_counts(eng, sample)
        b_fof = two_hop_counts(ref_eng, sample)
        checks["fof_counts"] = bool(
            np.array_equal(a_fof.offsets, b_fof.offsets)
            and np.array_equal(a_fof.ids, b_fof.ids)
            and np.array_equal(a_fof.counts, b_fof.counts))
    checks["all_bitwise_equal"] = all(checks.values())
    return checks


def run(scale: float = 1.0, smoke: bool = False) -> dict:
    from repro.core import ServiceDB, ShardRouter

    ncpu = os.cpu_count() or 1
    if smoke:
        n_vertices, n_edges = 4_000, 50_000
        counts = SHARD_COUNTS_SMOKE
        duration_s, n_threads = 2.0, 2
    else:
        n_vertices = max(4_000, int(200_000 * scale))
        n_edges = max(50_000, int(3_000_000 * scale))
        counts = SHARD_COUNTS_FULL
        duration_s, n_threads = 5.0, max(SHARD_COUNTS_FULL)
    batches, deletes = _op_prefix(n_vertices, n_edges,
                                  batch=max(10_000, n_edges // 16))

    payload = {
        "scale": scale,
        "smoke": smoke,
        "cpu_count": ncpu,
        "n_vertices": n_vertices,
        "n_edges": n_edges,
        "n_deletes": len(deletes),
        "shard_counts": list(counts),
        "gates": {
            "scale_4shard_x": SCALE_GATE_4SHARD,
            "scale_2shard_x": (SCALE_GATE_2SHARD_SMOKE if smoke
                               else SCALE_GATE_2SHARD),
            "overhead_1core_x": OVERHEAD_GATE_1CORE,
        },
    }
    workdir = tempfile.mkdtemp(prefix="bench_shard_")
    agg = {}
    failures = []
    try:
        # the unsharded reference: same op prefix, in-process reads
        print(f"  reference: unsharded ServiceDB, {n_edges} edges ...")
        ref_dir = os.path.join(workdir, "ref")
        ref = ServiceDB.create(ref_dir, max_id=n_vertices - 1, **_db_kw())
        t_ref = _ingest(ref, batches, deletes)
        ref.checkpoint()
        rng = np.random.default_rng(99)
        t0 = time.perf_counter()
        n_ref = 0
        t_end = t0 + max(1.0, duration_s / 2)
        with ref.read_view() as view:
            ref_eng = view.storage_engine()
            while time.perf_counter() < t_end:
                vs = rng.integers(0, n_vertices, 512)
                ref_eng.out_neighbors_batch(vs)
                n_ref += int(vs.shape[0])
        payload["unsharded"] = {
            "ingest_edges_per_s": n_edges / t_ref,
            "inprocess_read_vertices_per_s":
                n_ref / (time.perf_counter() - t0),
        }
        print(f"    ingest {n_edges / t_ref:,.0f} edges/s; in-process "
              f"reads {payload['unsharded']['inprocess_read_vertices_per_s']:,.0f} vertices/s")

        for n_shards in counts:
            d = os.path.join(workdir, f"shards_{n_shards}")
            print(f"  {n_shards} shard(s): ingest + contended reads "
                  f"({n_threads} client threads x {duration_s}s) ...")
            router = ShardRouter.create(d, max_id=n_vertices - 1,
                                        n_shards=n_shards, **_db_kw())
            try:
                t_ing = _ingest(router, batches, deletes)
                router.checkpoint_all()
                reads = _read_phase(router, n_vertices, n_threads,
                                    duration_s)
                agg[n_shards] = reads["aggregate_vertices_per_s"]
                entry = {
                    "ingest_edges_per_s": n_edges / t_ing,
                    "reads": reads,
                    "n_edges": router.n_edges,
                }
                blocks = [s["block_reads"] for s in reads["per_shard_io"]]
                entry["blocks_partitioned"] = all(b > 0 for b in blocks)
                if not entry["blocks_partitioned"]:
                    failures.append(
                        f"{n_shards}-shard store: some shard served ZERO "
                        f"block reads during the read phase "
                        f"(per-shard: {blocks}) — work not partitioned")
                payload[f"shards_{n_shards}"] = entry
                print(f"    ingest {n_edges / t_ing:,.0f} edges/s; reads "
                      f"{agg[n_shards]:,.0f} vertices/s  "
                      f"p99={reads['latency_ms']['p99']:.2f}ms  "
                      f"per-shard blocks {blocks}")
                if n_shards == counts[-1]:
                    print("  equality: sharded vs unsharded on the same "
                          "op prefix ...")
                    payload["equality"] = eq = _equality(
                        router, ref, n_vertices)
                    print(f"    {eq}")
                    if not eq["all_bitwise_equal"]:
                        bad = [k for k, v in eq.items() if not v]
                        failures.append(
                            f"sharded results NOT bitwise-equal to the "
                            f"unsharded engine: {bad}")
            finally:
                router.close()
                shutil.rmtree(d, ignore_errors=True)
        ref.close()
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    # ingest scales even on one core: each shard's WAL fsync + maintenance
    # overlap across processes (recorded, not gated — fsync-bound)
    ing = {c: payload[f"shards_{c}"]["ingest_edges_per_s"]
           for c in counts if f"shards_{c}" in payload}
    if ing.get(1):
        payload["ingest_scaling_x"] = {str(c): v / ing[1]
                                       for c, v in ing.items()}

    # --- the core-aware scaling gate -------------------------------------
    base = agg.get(1, 0.0)
    if ncpu >= 4 and 4 in agg and base:
        name, observed, required = ("4shard_vs_1", agg[4] / base,
                                    SCALE_GATE_4SHARD)
    elif ncpu >= 2 and 2 in agg and base:
        name, observed, required = ("2shard_vs_1", agg[2] / base,
                                    SCALE_GATE_2SHARD_SMOKE if smoke
                                    else SCALE_GATE_2SHARD)
    elif base and len(agg) > 1:
        m = min(c for c in agg if c > 1)
        name, observed, required = (f"overhead_1core_{m}shard",
                                    agg[m] / base, OVERHEAD_GATE_1CORE)
    else:
        name, observed, required = ("none", 0.0, 0.0)
    payload["scaling_gate"] = {
        "applied": name,
        "observed_x": observed,
        "required_x": required,
        "ok": observed >= required,
        "note": ("full acceptance gate (4-shard >= 2.5x) applies only "
                 "with >= 4 cores; this run recorded cpu_count="
                 f"{ncpu}"),
    }
    if observed < required:
        failures.append(
            f"scaling gate '{name}': {observed:.2f}x < required "
            f"{required:.2f}x (cpu_count={ncpu})")
    print(f"  scaling gate [{name}]: {observed:.2f}x "
          f"(required {required:.2f}x, {ncpu} cores) "
          f"{'OK' if observed >= required else 'FAIL'}")

    for f in failures:
        print("  GATE FAIL:", f)
    payload["gate_failures"] = failures
    save("BENCH_shard", payload)
    if failures and smoke:
        sys.exit(1)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny store, 2 shards max, enforce the gates")
    args = ap.parse_args()
    run(scale=args.scale, smoke=args.smoke)


if __name__ == "__main__":
    main()
