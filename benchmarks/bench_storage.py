"""Paper Table 1: database size (bytes/edge) across storage designs.

PAL vs (a) edge list + B-tree index (MySQL: 9 B data + ~11 B index/edge at
4-byte ids, per the paper), (b) doubly-linked edge list (Neo4j: 33-35 B/edge),
(c) doubled adjacency lists (in+out stored separately). Also measures the
Elias-Gamma pointer-array compression ratio (paper §8.4: 424 MB vs 3,383 MB).
"""
from __future__ import annotations

import numpy as np

from repro.core import GraphPAL, encode_monotonic

from .common import power_law_graph, save


def run(scale: float = 1.0):
    n_vertices = int(200_000 * scale)
    n_edges = int(2_000_000 * scale)
    src, dst = power_law_graph(n_vertices, n_edges, seed=1)
    g = GraphPAL.from_edges(src, dst, n_partitions=16, max_id=n_vertices - 1)

    pal_bytes = g.nbytes()
    # PAL with int32/int8 on-disk encoding (the paper packs 36b dst + 4b
    # type + 24b next = 8 B/edge; our in-memory arrays are wider)
    packed_edge = 8  # paper's packed entry
    pointer_raw = sum(p.src_vertices.nbytes + p.src_ptr.nbytes
                      for p in g.partitions)
    perm_bytes = sum(p.dst_perm.nbytes + p.dst_vertices.nbytes +
                     p.dst_ptr.nbytes for p in g.partitions)

    # Elias-Gamma compression of every pointer array
    eg_bytes = 0
    for p in g.partitions:
        if p.src_vertices.size:
            packed, bits, _ = encode_monotonic(p.src_vertices + 1)
            eg_bytes += packed.nbytes
            packed, bits, _ = encode_monotonic(p.src_ptr + 1)
            eg_bytes += packed.nbytes

    rows = {
        "graph": {"vertices": n_vertices, "edges": n_edges},
        "pal_packed_bytes_per_edge": packed_edge + (pointer_raw + perm_bytes)
        / n_edges,
        "pal_inmemory_bytes_per_edge": pal_bytes / n_edges,
        "pointer_array_raw_mb": pointer_raw / 1e6,
        "pointer_array_elias_gamma_mb": eg_bytes / 1e6,
        "eg_compression_ratio": pointer_raw / max(eg_bytes, 1),
        # reference designs (paper Table 1 constants)
        "edge_list_plus_btree_bytes_per_edge": 9 + 11,
        "neo4j_linked_list_bytes_per_edge": 33,
        "doubled_adjacency_bytes_per_edge": 2 * 8 + (pointer_raw * 2) / n_edges,
    }
    save("storage", rows)
    print("— Table 1 (database size) —")
    for k, v in rows.items():
        if isinstance(v, float):
            print(f"  {k}: {v:.2f}")
    return rows


if __name__ == "__main__":
    run()
