"""ISSUE 7: the crash-consistency torture sweep — BENCH_torture.json.

Enumerates crash points along the whole ingest -> merge -> checkpoint ->
GC schedule straight from the failpoint CATALOG: every write-path site,
each at several trigger offsets (hit #1, #2, #5 — early, mid, repeated),
runs the deterministic torture workload (`repro.torture`) in a subprocess
armed with `GRAPHDB_FAILPOINTS="<site>=crash@N"`, then recovers in a
FRESH subprocess and checks the prefix-equality oracle: the recovered
store must be bitwise-equal to a durable prefix of the op stream at least
as long as the acked prefix.

Recorded per site: schedules attempted, crashes actually triggered
(a site may not be crossed N times in a bounded run — recorded, not
hidden), recoveries verified, failures (must be zero). `--smoke` runs a
seeded subset of the matrix as the CI gate and exits non-zero on any
verification failure.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import time

from .common import save

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# every CATALOG site on the torture workload's write path. Read-path and
# service-read sites (part.read.section) crash nothing durable and are
# covered by the corruption tests instead.
WRITE_PATH_SITES = [
    "wal.append.write",
    "wal.append.fsync",
    "wal.segment.create",
    "wal.segment.rotate",
    "wal.compact.unlink",
    "part.write.body",
    "part.write.fsync",
    "part.write.rename",
    "store.gc.unlink",
    "store.link",
    "manifest.write",
    "manifest.rename",
    "dead.write",
    "dead.rename",
    "dir.fsync",
    "service.flush.merge",
    "service.ckpt.phaseA",
    "service.ckpt.phaseB",
]
OFFSETS = (1, 2, 5)  # crash on the 1st, 2nd, 5th crossing of the site

SMOKE_SITES = [
    "wal.append.write",
    "wal.segment.rotate",
    "part.write.rename",
    "manifest.rename",
    "service.ckpt.phaseB",
    "dir.fsync",
]
SMOKE_OFFSETS = (1, 3)

CRASH_EXIT_CODE = 41  # keep in sync with repro.core.failpoints
BATCHES = 10
BATCH_SIZE = 150


def _subprocess(cmd, dbdir, oracle, failpoints=None,
                batches=BATCHES, batch_size=BATCH_SIZE):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("GRAPHDB_FAILPOINTS", None)
    if failpoints:
        env["GRAPHDB_FAILPOINTS"] = failpoints
    return subprocess.run(
        [sys.executable, "-m", "repro.torture", cmd, dbdir,
         "--oracle", oracle, "--batches", str(batches),
         "--batch-size", str(batch_size)],
        env=env, capture_output=True, text=True, timeout=600)


def torture_one(workdir, site, offset) -> dict:
    """One cell of the matrix: crash at the offset-th crossing of the
    site, recover, verify the durable prefix."""
    tag = f"{site.replace('.', '_')}_{offset}"
    dbdir = os.path.join(workdir, tag)
    oracle = os.path.join(workdir, f"{tag}.oracle")
    spec = f"{site}=crash@{offset - 1}" if offset > 1 else f"{site}=crash"
    t0 = time.perf_counter()
    run = _subprocess("run", dbdir, oracle, failpoints=spec)
    crashed = run.returncode == CRASH_EXIT_CODE
    cell = {"site": site, "offset": offset, "crashed": crashed,
            "run_rc": run.returncode}
    if run.returncode not in (0, CRASH_EXIT_CODE):
        cell["ok"] = False
        cell["error"] = (f"workload died with rc={run.returncode}: "
                         f"{run.stderr[-500:]}")
        return cell
    ver = _subprocess("verify", dbdir, oracle)
    cell["ok"] = ver.returncode == 0
    if not cell["ok"]:
        cell["error"] = f"verify failed: {ver.stdout}\n{ver.stderr[-800:]}"
    else:
        cell["verify"] = ver.stdout.strip()
    cell["wall_s"] = time.perf_counter() - t0
    return cell


def run(smoke: bool = False) -> dict:
    sites = SMOKE_SITES if smoke else WRITE_PATH_SITES
    offsets = SMOKE_OFFSETS if smoke else OFFSETS
    matrix = [(s, o) for s in sites for o in offsets]
    print(f"  torture: {len(matrix)} crash schedules "
          f"({len(sites)} sites x offsets {offsets}) ...")
    cells = []
    failures = []
    crashes = 0
    with tempfile.TemporaryDirectory(prefix="bench_torture_") as workdir:
        for i, (site, offset) in enumerate(matrix):
            cell = torture_one(workdir, site, offset)
            cells.append(cell)
            crashes += int(cell["crashed"])
            if not cell["ok"]:
                failures.append(f"{site}@{offset}: {cell['error']}")
                print(f"    FAIL {site}@{offset}: {cell['error'][:200]}")
            elif (i + 1) % 6 == 0:
                print(f"    {i + 1}/{len(matrix)} verified "
                      f"({crashes} actual crashes so far)")
    not_crossed = [f"{c['site']}@{c['offset']}" for c in cells
                   if c["ok"] and not c["crashed"]]
    if not_crossed:
        # the site wasn't crossed `offset` times in this bounded run —
        # the clean completion still verified, but say so
        print(f"    note: {len(not_crossed)} schedules completed without "
              f"crashing (site not crossed often enough): "
              f"{', '.join(not_crossed)}")
    payload = {
        "smoke": smoke,
        "batches": BATCHES,
        "batch_size": BATCH_SIZE,
        "schedules": len(matrix),
        "crashes_triggered": crashes,
        "verified": sum(1 for c in cells if c["ok"]),
        "not_crossed": not_crossed,
        "failures": failures,
        "cells": cells,
    }
    print(f"  {payload['verified']}/{len(matrix)} schedules verified, "
          f"{crashes} real crashes, {len(failures)} failures")
    save("BENCH_torture", payload)
    if failures:
        sys.exit(1)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seeded subset of the matrix (the CI gate)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
