"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

import numpy as np

OUT_DIR = "experiments/bench"


def save(name: str, payload: dict) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def percentiles(xs, ps=(50, 75, 95, 99)):
    xs = np.asarray(xs, dtype=np.float64)
    if xs.size == 0:
        return {f"p{p}": None for p in ps}
    return {f"p{p}": float(np.percentile(xs, p)) for p in ps}


@contextmanager
def timer(out: list):
    t0 = time.perf_counter()
    yield
    out.append(time.perf_counter() - t0)


def power_law_graph(n_vertices: int, n_edges: int, alpha: float = 1.8,
                    seed: int = 0, hot_frac: float = 0.5):
    """Twitter-like structure: a zipf-hot head of celebrity destinations
    (scattered ids) mixed with uniform long-tail follows, so in-degrees are
    power-law while out-neighborhoods still expand."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges)
    hot = (rng.zipf(alpha, n_edges) - 1) % n_vertices
    hot = (hot * 2654435761) % n_vertices
    uniform = rng.integers(0, n_vertices, n_edges)
    dst = np.where(rng.random(n_edges) < hot_frac, hot, uniform)
    return src, dst
