"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from contextlib import contextmanager

import numpy as np

OUT_DIR = "experiments/bench"


def provenance() -> dict:
    """Environment fingerprint stamped into every BENCH_*.json: without it
    a regression report can't distinguish 'code got slower' from 'ran on a
    different box / backend'. Every probe is best-effort — benches must
    not fail because git or jax is absent."""
    doc = {
        "python": sys.version.split()[0],
        "cpu_count": os.cpu_count(),
        "platform": sys.platform,
    }
    try:
        doc["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        doc["git_sha"] = None
    try:
        import jax
        doc["jax_version"] = jax.__version__
        doc["jax_backend"] = jax.default_backend()
    except Exception:
        doc["jax_version"] = None
        doc["jax_backend"] = None
    return doc


def save(name: str, payload: dict) -> None:
    payload = dict(payload)
    payload.setdefault("provenance", provenance())
    try:
        from repro.core import telemetry
        payload.setdefault("metrics", telemetry.snapshot())
    except Exception:
        pass
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def percentiles(xs, ps=(50, 75, 95, 99)):
    xs = np.asarray(xs, dtype=np.float64)
    if xs.size == 0:
        return {f"p{p}": None for p in ps}
    return {f"p{p}": float(np.percentile(xs, p)) for p in ps}


@contextmanager
def timer(out: list):
    t0 = time.perf_counter()
    yield
    out.append(time.perf_counter() - t0)


def power_law_graph(n_vertices: int, n_edges: int, alpha: float = 1.8,
                    seed: int = 0, hot_frac: float = 0.5):
    """Twitter-like structure: a zipf-hot head of celebrity destinations
    (scattered ids) mixed with uniform long-tail follows, so in-degrees are
    power-law while out-neighborhoods still expand."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges)
    hot = (rng.zipf(alpha, n_edges) - 1) % n_vertices
    hot = (hot * 2654435761) % n_vertices
    uniform = rng.integers(0, n_vertices, n_edges)
    dst = np.where(rng.random(n_edges) < hot_frac, hot, uniform)
    return src, dst
