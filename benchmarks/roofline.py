"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh) cell, TPU v5e constants:

  compute term    = FLOPs_per_device / 197e12        [s]
  memory term     = HBM_bytes_per_device / 819e9     [s]
  collective term = collective_bytes_per_device / 50e9  [s]

Method notes (full discussion in EXPERIMENTS.md):
  * collective bytes come from the compiled HLO with while-loop trip-count
    multiplication (launch/dryrun.parse_collective_bytes) — exact for our
    scan-based steps;
  * XLA's cost_analysis counts while bodies ONCE, so for scanned models we
    use ANALYTIC FLOPs/byte models (formulas below, derived from the
    configs) and report the raw HLO numbers as diagnostics;
  * MODEL_FLOPS is the standard useful-work count (6·N·D train / 2·N·D
    inference (+attention); GNNs get per-op counts); the compiled/model
    ratio reflects remat recompute and capacity-padding waste.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_BYTES = 16e9


# ---------------------------------------------------------------------------
# analytic FLOP / byte models
# ---------------------------------------------------------------------------
def _lm_terms(spec, cell, mesh_devices):
    """Returns (model_flops, compiled_flops_est, hbm_bytes) per DEVICE."""
    cfg = spec.config
    d = cell["dims"]
    N, Na = cfg.n_params, cfg.n_active_params
    H, dh = cfg.n_heads, cfg.head_dim
    L = cfg.n_layers
    if cell["kind"] == "train":
        B, S = d["batch"], d["seq"]
        T = B * S
        attn = 6 * T * S * H * dh          # causal: 0.5 × (QK+PV) × 3(fwd+bwd)
        model = 6 * Na * T + attn
        # remat="full": one extra forward  => compiled ≈ model × 4/3
        compiled = model * 4 / 3
        # bytes: params fp32 fwd+bwd reads + opt (read m,v,p + write m,v,p)
        # + activations (write+read fwd, bwd, remat re-read)
        par = N * 4 * (2 + 6)
        act = L * T * cfg.d_model * 2 * 6
        hbm = par + act
    elif cell["kind"] == "prefill":
        B, S = d["batch"], d["seq"]
        T = B * S
        model = 2 * Na * T + T * S * H * dh * 2 * 0.5 * 2
        compiled = model
        hbm = N * 2 + L * T * cfg.d_model * 2 * 2 + \
            L * T * cfg.n_kv_heads * dh * 2 * 2 * 2   # cache writes
    else:  # decode
        B, S = d["batch"], d["seq"]
        model = 2 * Na * B + 4 * B * S * cfg.n_kv_heads * dh * (H // cfg.n_kv_heads)
        compiled = model
        # decode is bytes-bound: read all params + the whole KV cache
        cache = L * B * S * cfg.n_kv_heads * dh * 2 * 2
        hbm = Na * 2 + cache
    return model / mesh_devices, compiled / mesh_devices, hbm / mesh_devices


def _gnn_terms(spec, cell, mesh_devices):
    cfg = spec.config
    d = cell["dims"]
    N, E = d["n_nodes"], d["n_edges"]
    batch = d.get("batch", 1)
    N, E = N * batch, E * batch
    train_x = 3  # fwd+bwd
    if spec.name == "pna":
        dh = cfg.d_hidden
        per = cfg.n_layers * (E * 2 * (2 * dh) * dh + N * 2 * (13 * dh) * dh)
        enc = N * 2 * d["d_feat"] * dh
        model = (per + enc) * train_x
        hbm = cfg.n_layers * (E * dh * 4 * 3 + N * 13 * dh * 4 * 2) * 2
    elif spec.name == "gin-tu":
        dh = cfg.d_hidden
        per = cfg.n_layers * (E * dh + N * 2 * dh * dh * 2)
        model = (per + N * 2 * d["d_feat"] * dh) * train_x
        hbm = cfg.n_layers * (E * dh * 4 + N * dh * 4 * 4) * 2
    elif spec.name == "meshgraphnet":
        dh = cfg.d_hidden
        per = cfg.n_layers * (E * 2 * (3 * dh) * dh * 2 + N * 2 * (2 * dh) * dh * 2)
        model = per * train_x
        hbm = cfg.n_layers * (E * dh * 4 * 4 + N * dh * 4 * 4) * 2
    else:  # equiformer-v2
        C, L = cfg.d_hidden, cfg.l_max
        K2 = sum((2 * l + 1) ** 2 for l in range(L + 1))   # rot cost/edge
        nl = L + 1
        so2 = 2 * nl * nl * C * C + sum(
            4 * (nl - m) ** 2 * C * C for m in range(1, cfg.m_max + 1))
        per_edge = 2 * K2 * C * 2 * 2 + so2 + 2 * (2 * nl * C) * C
        per = cfg.n_layers * (E * per_edge + N * 2 * (L + 1) ** 2 * C * C * 2)
        model = per * train_x
        # remat_layers: extra forward
        model_c = model * 4 / 3
        hbm = cfg.n_layers * E * (L + 1) ** 2 * C * 2 * 4
        return (model / mesh_devices, model_c / mesh_devices,
                hbm / mesh_devices)
    return model / mesh_devices, model / mesh_devices, hbm / mesh_devices


def _recsys_terms(spec, cell, mesh_devices):
    cfg = spec.config
    d = cell["dims"]
    B = d["batch"]
    dm = cfg.embed_dim
    blk = cfg.n_blocks * (4 * dm * dm + 2 * dm * cfg.ff + 2 * 200 * dm * 2)
    enc = B * 200 * blk * 2
    if cell["kind"] == "train":
        R = B * 40
        head = 6 * R * cfg.padded_vocab * dm
        model = enc * 3 + head
        hbm = cfg.padded_vocab * dm * 4 * (2 + 6) + B * 200 * dm * 4 * 6
    elif cell["kind"] == "serve":
        head = 2 * B * cfg.padded_vocab * dm
        model = enc + head
        hbm = cfg.padded_vocab * dm * 4 + B * 200 * dm * 4 * 2
    else:  # retrieval
        model = enc + 2 * B * d["n_candidates"] * dm
        hbm = d["n_candidates"] * dm * 4 + B * 200 * dm * 4
    return model / mesh_devices, model / mesh_devices, hbm / mesh_devices


def analytic_terms(arch_id: str, cell: Dict, mesh_devices: int):
    from repro.configs import get_arch
    spec = get_arch(arch_id)
    if spec.family == "lm":
        return _lm_terms(spec, cell, mesh_devices)
    if spec.family == "gnn":
        return _gnn_terms(spec, cell, mesh_devices)
    return _recsys_terms(spec, cell, mesh_devices)


def analyze(dryrun_dir: str = "experiments/dryrun",
            mesh: Optional[str] = None):
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        d = json.load(open(f))
        if mesh and d.get("mesh") != mesh:
            continue
        if d["status"] == "skipped":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "mesh": d["mesh"], "status": "skipped",
                         "why": d["skip_reason"][:60]})
            continue
        if d["status"] != "ok":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "mesh": d["mesh"], "status": d["status"]})
            continue
        ndev = d["n_devices"]
        cell = {"kind": d["kind"], "dims": d["dims"]}
        model_fl, compiled_fl, hbm = analytic_terms(d["arch"], cell, ndev)
        t_comp = compiled_fl / PEAK_FLOPS
        t_mem = hbm / HBM_BW
        t_coll = d["collective_bytes_per_device"] / LINK_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        bound = max(terms.values())
        frac = t_comp / bound if bound > 0 else 0.0
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "status": "ok",
            "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
            "dominant": dominant,
            "roofline_fraction": frac,       # compute / binding term
            "model_flops_per_dev": model_fl,
            "compiled_flops_per_dev_est": compiled_fl,
            "model_over_compiled": model_fl / compiled_fl if compiled_fl else 0,
            "hlo_flops_raw": d["flops_per_device"],
            "temp_gb": d["memory"]["temp_bytes"] / 1e9,
            "fits_hbm": d["memory"]["temp_bytes"] < HBM_BYTES,
            "collective_by_kind": d.get("collective_bytes_by_kind", {}),
        })
    return rows


def print_table(rows):
    hdr = (f"{'arch':24} {'shape':14} {'mesh':6} {'comp(ms)':>9} "
           f"{'mem(ms)':>9} {'coll(ms)':>9} {'bound':>10} {'frac':>6} "
           f"{'temp GB':>8} {'fit':>4}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:24} {r['shape']:14} {r['mesh']:6} "
                  f"-- {r['status']} {r.get('why', '')}")
            continue
        print(f"{r['arch']:24} {r['shape']:14} {r['mesh']:6} "
              f"{r['compute_s']*1e3:9.2f} {r['memory_s']*1e3:9.2f} "
              f"{r['collective_s']*1e3:9.2f} {r['dominant']:>10} "
              f"{r['roofline_fraction']:6.2f} {r['temp_gb']:8.1f} "
              f"{'Y' if r['fits_hbm'] else 'N':>4}")


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else None
    rows = analyze(mesh=mesh)
    print_table(rows)
    out = "experiments/roofline.json"
    os.makedirs("experiments", exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out}")
