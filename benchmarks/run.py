"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale 1.0] [--only NAME]

Writes JSON to experiments/bench/ and prints the tables. `--scale` shrinks
graph sizes for CI (1.0 ≈ a laptop-minute per table; the paper's twitter-2010
scale is reached with --scale 1500 and a large SSD).
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (bench_disk, bench_fof, bench_insert, bench_linkbench,
               bench_multihop, bench_psw, bench_query, bench_service,
               bench_storage)

SUITES = {
    "storage": bench_storage.run,      # paper Table 1
    "insert": bench_insert.run,        # paper Fig 7a
    "linkbench": bench_linkbench.run,  # paper Table 2 + Fig 8a
    "query": bench_query.run,          # paper Fig 7b + Fig 8c
    "fof": bench_fof.run,              # paper Table 3 + Fig 8b
    "psw": bench_psw.run,              # paper §6 + device PSW
    "disk": bench_disk.run,            # ISSUE 3: out-of-core + Fig 8c real I/O
    "service": bench_service.run,      # ISSUE 4: snapshot readers + maintenance
    "multihop": bench_multihop.run,    # ISSUE 6: columnar k-hop operators
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    names = [args.only] if args.only else list(SUITES)
    for name in names:
        print(f"\n=== bench: {name} (scale={args.scale}) ===")
        t0 = time.time()
        SUITES[name](scale=args.scale)
        print(f"=== {name} done in {time.time() - t0:.1f}s ===")


if __name__ == "__main__":
    main()
