"""Distributed PSW GNN: PAL-sharded graph + ring-window message passing.

Demonstrates the TPU adaptation of the paper's Parallel Sliding Windows on
an 8-virtual-device mesh: node state sharded by vertex interval, source rows
delivered by the collective-permute ring (DESIGN.md §2), exact agreement
with the single-device reference.

  PYTHONPATH=src python examples/distributed_gnn.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import GraphPAL, build_device_graph, pagerank_device
from repro.graph.psw_ops import ring_gather, local_scatter_sum

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
print(f"mesh: {mesh.shape}")

# PAL-partitioned graph, 8 intervals = 8 shards
rng = np.random.default_rng(0)
n, e = 4096, 32768
src = rng.integers(0, n, e)
dst = rng.integers(0, n, e)
g = GraphPAL.from_edges(src, dst, n_partitions=8, max_id=n - 1)
print(f"graph: {n} vertices, {g.n_edges} edges, "
      f"partition sizes {g.partition_sizes()}")

# 1. device PSW PageRank: window exchange == dense gather
dg = build_device_graph(g)
r_dense = pagerank_device(dg, n_iters=5, mode="dense_gather")
r_psw = pagerank_device(dg, n_iters=5, mode="psw_windows")
print(f"PSW windows vs dense gather max diff: "
      f"{float(jnp.abs(r_dense - r_psw).max()):.2e}")

# 2. ring gather: one message-passing step, sharded over the mesh.
# The DeviceGraph's padded (P, E_max) layout gives interval-ALIGNED edge
# shards: shard i holds exactly partition i's edges, so destinations are
# local (the PAL property local_scatter_sum relies on).
P, L = dg.n_partitions, dg.interval_len
x = jnp.asarray(rng.normal(size=(P * L, 16)).astype(np.float32))
src_flat = dg.src.reshape(-1)
dst_flat = (dg.dst_local + jnp.arange(P)[:, None] * L).reshape(-1)
mask = dg.mask.reshape(-1).astype(x.dtype)

msgs = ring_gather(x, src_flat, mesh) * mask[:, None]   # remote rows: ring
agg = local_scatter_sum(msgs, dst_flat, P * L, mesh)    # PAL: dst local
ref = jax.ops.segment_sum(x[src_flat] * mask[:, None], dst_flat,
                          num_segments=P * L)
print(f"ring message passing vs reference max diff: "
      f"{float(jnp.abs(agg - ref).max()):.2e}")
print("done.")
