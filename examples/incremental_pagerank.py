"""Streaming ingestion + incremental analytics (paper §6.1.2, Fig 7a's
'insert + Pagerank' run): edges arrive continuously; PageRank sweeps run
in-place between batches so the authority scores track the growing graph.

  PYTHONPATH=src python examples/incremental_pagerank.py
"""
import time

import numpy as np

from repro.core import IntervalMap, LSMTree, pagerank_host
from repro.data import GraphStream

N = 50_000
iv = IntervalMap.for_capacity(N - 1, 16)
db = LSMTree(iv, n_levels=3, branching=4, buffer_cap=25_000,
             max_partition_edges=100_000)
stream = GraphStream(N, alpha=1.8, seed=0)

t0 = time.time()
total = 0
for round_ in range(10):
    src, dst = stream.next_edges(50_000)
    db.insert_edges(src, dst)
    total += 50_000
    # one incremental PSW sweep — state persists in the edge columns, so a
    # single sweep refreshes ranks rather than recomputing from scratch
    ranks = pagerank_host(db, n_iters=1)
    top = np.argsort(ranks)[-3:][::-1]
    rate = total / (time.time() - t0)
    print(f"round {round_}: {total:,} edges @ {rate:,.0f} edges/s | "
          f"top vertices {list(top)} ranks {ranks[top].round(2)}")

print(f"\nLSM stats: {db.stats}")
