"""Quickstart: GraphChi-DB in 60 seconds — build, insert, query, compute.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (IntervalMap, LSMTree, friends_of_friends,
                        pagerank_host, shortest_path)

# 1. an online graph database over PAL + LSM
iv = IntervalMap.for_capacity(max_id=99_999, n_partitions=16)
db = LSMTree(iv, n_levels=3, branching=4, buffer_cap=50_000,
             column_dtypes={"weight": np.float32})

# 2. stream edges in ONLINE (no batch mode — paper §5)
rng = np.random.default_rng(0)
src = rng.integers(0, 100_000, 500_000)
dst = rng.integers(0, 100_000, 500_000)
db.insert_edges(src, dst, columns={"weight": rng.random(500_000,
                                                        dtype=np.float32)})
print(f"inserted {db.n_edges:,} edges "
      f"(buffer flushes: {db.stats.buffer_flushes}, "
      f"push-down merges: {db.stats.pushdown_merges})")

# 3. point queries: both directions, each edge stored once (paper §4)
v = int(src[0])
print(f"out-neighbors of {v}: {len(db.out_neighbors(v))}")
print(f"in-neighbors  of {v}: {len(db.in_neighbors(v))}")

# 4. graph queries — and the batched set-at-a-time engine (DESIGN.md §5)
fof = friends_of_friends(db, v)
print(f"friends-of-friends of {v}: {fof.size}")
d = shortest_path(db, int(src[1]), int(dst[2]), max_depth=5)
print(f"shortest path: {d}")
frontier = np.unique(src[:64])
vals, offsets = db.storage_engine().out_neighbors_batch(frontier)
print(f"one batched hop from {frontier.size} vertices: {vals.size} edges")

# 5. updates and deletes (tombstones, purged at merges — paper §5.3)
db.update_edge_column(int(src[0]), int(dst[0]), "weight", 9.9)
db.delete_edge(int(src[1]), int(dst[1]))

# 6. analytical computation IN PLACE (PSW, paper §6)
ranks = pagerank_host(db, n_iters=5)
top = np.argsort(ranks)[-3:]
print(f"top-3 pagerank (internal ids): {top}, scores {ranks[top].round(3)}")

# 7. device analytics on the LIVE store: snapshot() compiles all levels +
#    in-memory buffers into immutable jnp arrays (no flush, read-only)
from repro.core import pagerank_device
db.insert_edges(rng.integers(0, 100_000, 2_000),      # fresh arrivals since
                rng.integers(0, 100_000, 2_000),      # the host sweep —
                columns={"weight": rng.random(2_000,  # these stay buffered
                                              dtype=np.float32)})
dg = db.snapshot()
r = pagerank_device(dg, n_iters=3, mode="dense_gather")
print(f"device pagerank over {dg.n_edges:,} live edges "
      f"(incl. {db.total_buffered():,} buffered): shape {tuple(r.shape)}")
print("done.")
