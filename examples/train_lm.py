"""End-to-end LM training driver (~100M-class on CPU): trains the reduced
granite config for a few hundred steps with checkpoints + resume.

  PYTHONPATH=src python examples/train_lm.py
(equivalent to: python -m repro.launch.train --arch granite-3-2b --smoke)
"""
import subprocess
import sys

subprocess.run([
    sys.executable, "-m", "repro.launch.train",
    "--arch", "granite-3-2b", "--smoke",
    "--steps", "120", "--batch", "8", "--seq", "64",
    "--ckpt-dir", "/tmp/repro_example_ckpt", "--ckpt-every", "40",
], check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
