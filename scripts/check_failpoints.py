#!/usr/bin/env python
"""Failpoint coverage lint (ISSUE 7 + ISSUE 8): four invariants —

  1. every site in the CATALOG is exercised somewhere in tests/ or
     benchmarks/ — a failpoint nobody arms is dead weight that silently
     stops guarding its I/O boundary;
  2. tests must not arm sites that are not in the CATALOG (typos never
     fire: `fp_set` rejects them at runtime, but string specs in env vars
     and parametrize lists bypass that check until the test runs);
  3. every `failpoint("...")` crossing in src/ names a CATALOG site — new
     instrumentation (e.g. the ISSUE-8 shard IPC/router sites) MUST be
     added to the catalog, or armed specs for it would be rejected;
  4. every CATALOG site is actually crossed by a `failpoint(...)` call in
     src/ — a catalog entry whose call site was refactored away is a lie.

Exit 1 with a listing on any miss. Run from the repo root:

    PYTHONPATH=src python scripts/check_failpoints.py
"""
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core.failpoints import CATALOG  # noqa: E402

SEARCH_DIRS = ("tests", "benchmarks")
# a site name can appear quoted in fp_set(...)/GRAPHDB_FAILPOINTS specs
# ("wal.append.write=crash@5") or in a Python list of spec strings
SITE_RE = re.compile(r"[a-z]+(?:\.[A-Za-z_0-9]+){1,3}")


def referenced_sites():
    found = {}
    for d in SEARCH_DIRS:
        root = os.path.join(REPO, d)
        for dirpath, _, files in os.walk(root):
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path, encoding="utf-8") as f:
                    text = f.read()
                for m in SITE_RE.finditer(text):
                    found.setdefault(m.group(0), set()).add(
                        os.path.relpath(path, REPO))
    return found


# a failpoint crossing in product code: failpoint("site.name", ...)
CROSSING_RE = re.compile(r"failpoint\(\s*[\"']([^\"']+)[\"']")


def src_crossings():
    """Map site -> src files that cross it via a literal failpoint() call."""
    found = {}
    root = os.path.join(REPO, "src")
    for dirpath, _, files in os.walk(root):
        for fn in files:
            # failpoints.py defines the mechanism; its docstring example
            # ("site.name") is not a crossing
            if not fn.endswith(".py") or fn == "failpoints.py":
                continue
            path = os.path.join(dirpath, fn)
            with open(path, encoding="utf-8") as f:
                text = f.read()
            for m in CROSSING_RE.finditer(text):
                found.setdefault(m.group(1), set()).add(
                    os.path.relpath(path, REPO))
    return found


def main() -> int:
    found = referenced_sites()
    uncovered = sorted(s for s in CATALOG if s not in found)
    crossings = src_crossings()
    uncataloged = sorted(s for s in crossings if s not in CATALOG)
    orphaned = sorted(s for s in CATALOG if s not in crossings)
    # dotted tokens that LOOK like failpoint specs but name no catalog
    # site: only flag ones appearing inside a =action spec to avoid
    # false positives on ordinary attribute access
    spec_re = re.compile(
        r"([a-z]+(?:\.[A-Za-z_0-9]+){1,3})"
        r"=(?:crash|raise|errno:[A-Z]+|delay:\d+|stall:\d+)")
    phantom = {}
    for d in SEARCH_DIRS:
        root = os.path.join(REPO, d)
        for dirpath, _, files in os.walk(root):
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path, encoding="utf-8") as f:
                    text = f.read()
                for m in spec_re.finditer(text):
                    if m.group(1) not in CATALOG:
                        phantom.setdefault(m.group(1), set()).add(
                            os.path.relpath(path, REPO))
    rc = 0
    if uncovered:
        rc = 1
        print(f"UNCOVERED failpoints ({len(uncovered)}/{len(CATALOG)}): "
              "no test or benchmark ever arms them")
        for s in uncovered:
            print(f"  {s}")
    if phantom:
        rc = 1
        print("PHANTOM failpoint specs (site not in the CATALOG — typo?):")
        for s, paths in sorted(phantom.items()):
            print(f"  {s}  ({', '.join(sorted(paths))})")
    if uncataloged:
        rc = 1
        print("UNCATALOGED src crossings (add them to failpoints.CATALOG):")
        for s in uncataloged:
            print(f"  {s}  ({', '.join(sorted(crossings[s]))})")
    if orphaned:
        rc = 1
        print("ORPHANED catalog sites (no failpoint() call in src/ crosses "
              "them — stale entry?):")
        for s in orphaned:
            print(f"  {s}")
    if rc == 0:
        print(f"ok: all {len(CATALOG)} catalog sites are crossed in src/ "
              f"and exercised by {'/'.join(SEARCH_DIRS)}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
