#!/usr/bin/env python
"""Telemetry catalog lint (ISSUE 9), the check_failpoints.py pattern
applied to the metrics/span CATALOG — three invariants:

  1. every literal name handed to a telemetry API in src/
     (`counter("...")`, `gauge(...)`, `histogram(...)`, `span(...)`)
     is in the CATALOG. The registry enforces this at runtime too
     (KeyError), but an instrument on a cold path would only blow up in
     production; the lint catches it at CI time. Names under the
     `x.` escape prefix are caller-owned (tests) and exempt.
  2. the API kind at each call site matches the catalog kind — a
     `counter("wal.fsync.seconds")` where the catalog says histogram is
     a unit bug the runtime check cannot see.
  3. every CATALOG name appears as a quoted literal somewhere in src/
     outside telemetry.py — a catalog entry whose instrument was
     refactored away is a lie (collector name-maps like
     `{"inserts": "lsm.inserts"}` count: the literal is the wiring).
  4. telemetry API calls in tests/ and benchmarks/ name cataloged (or
     `x.`-escaped) metrics too — a test asserting on a phantom name
     passes vacuously forever (ISSUE 10: the lifecycle gates read
     counters like `shard.hedges.won` out of snapshots; a typo there
     would gut the gate silently). Negative tests that deliberately
     probe unknown names opt out with a trailing `# lint: phantom-ok`.

Exit 1 with a listing on any miss. Run from the repo root:

    PYTHONPATH=src python scripts/check_metrics.py
"""
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core.telemetry import CATALOG, ESCAPE_PREFIX  # noqa: E402

# an instrument call site in product code: counter("a.b"), span("a.b", ...)
API_RE = re.compile(
    r"\b(counter|gauge|histogram|span)\(\s*[\"']([^\"']+)[\"']")
# any quoted dotted-lowercase literal (catalog wiring, name maps)
LITERAL_RE = re.compile(r"[\"']([a-z]+(?:\.[A-Za-z_0-9]+){1,3})[\"']")


def _src_files():
    root = os.path.join(REPO, "src")
    for dirpath, _, files in os.walk(root):
        for fn in files:
            # telemetry.py defines the catalog and the mechanism; its
            # own literals are declarations, not instruments
            if not fn.endswith(".py") or fn == "telemetry.py":
                continue
            yield os.path.join(dirpath, fn)


def api_sites():
    """Map (kind, name) -> src files with a literal instrument call."""
    found = {}
    for path in _src_files():
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for m in API_RE.finditer(text):
            found.setdefault((m.group(1), m.group(2)), set()).add(
                os.path.relpath(path, REPO))
    return found


def quoted_literals():
    """Every dotted quoted literal in src/ — catalog wiring evidence."""
    found = set()
    for path in _src_files():
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for m in LITERAL_RE.finditer(text):
            found.add(m.group(1))
    return found


TEST_DIRS = ("tests", "benchmarks")


def test_phantoms():
    """Map name -> test/benchmark files calling a telemetry API with a
    name the catalog does not know (escape-prefixed names exempt)."""
    found = {}
    for d in TEST_DIRS:
        root = os.path.join(REPO, d)
        for dirpath, _, files in os.walk(root):
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path, encoding="utf-8") as f:
                    lines = f.readlines()
                for line in lines:
                    # negative tests deliberately probe unknown names;
                    # they opt out explicitly
                    if "# lint: phantom-ok" in line:
                        continue
                    for m in API_RE.finditer(line):
                        name = m.group(2)
                        if (name not in CATALOG
                                and not name.startswith(ESCAPE_PREFIX)):
                            found.setdefault(name, set()).add(
                                os.path.relpath(path, REPO))
    return found


def main() -> int:
    sites = api_sites()
    uncataloged = sorted(
        (kind, name) for (kind, name) in sites
        if name not in CATALOG and not name.startswith(ESCAPE_PREFIX))
    mismatched = sorted(
        (kind, name, CATALOG[name][0]) for (kind, name) in sites
        if name in CATALOG and CATALOG[name][0] != kind)
    wired = quoted_literals()
    orphaned = sorted(n for n in CATALOG if n not in wired)
    phantoms = test_phantoms()
    rc = 0
    if uncataloged:
        rc = 1
        print("UNCATALOGED metric names (add them to telemetry.CATALOG):")
        for kind, name in uncataloged:
            print(f"  {kind}({name!r})  "
                  f"({', '.join(sorted(sites[(kind, name)]))})")
    if mismatched:
        rc = 1
        print("KIND MISMATCH (call-site API vs catalog declaration):")
        for kind, name, want in mismatched:
            print(f"  {kind}({name!r}) but the catalog declares {want}  "
                  f"({', '.join(sorted(sites[(kind, name)]))})")
    if orphaned:
        rc = 1
        print("ORPHANED catalog entries (no literal in src/ wires them — "
              "stale declaration?):")
        for name in orphaned:
            print(f"  {name}")
    if phantoms:
        rc = 1
        print("PHANTOM metric names in tests/benchmarks (not in the "
              "CATALOG — typo?):")
        for name, paths in sorted(phantoms.items()):
            print(f"  {name}  ({', '.join(sorted(paths))})")
    if rc == 0:
        print(f"ok: all {len(CATALOG)} catalog names are wired in src/, "
              f"every instrument call site is cataloged, and "
              f"{'/'.join(TEST_DIRS)} name no phantom metrics")
    return rc


if __name__ == "__main__":
    sys.exit(main())
