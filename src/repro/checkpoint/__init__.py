from .manager import CheckpointManager, restore_lsm, save_lsm
