"""Sharded, immutable, resumable checkpoints.

Design (mirrors the paper's crash-integrity argument, §7.3): every artifact
is an immutable flat file; a checkpoint is a manifest pointing at files; the
manifest is written LAST via atomic rename, so a crash mid-save can never
corrupt a restorable state — at worst the newest checkpoint is absent and
the previous manifest still points at complete files.

Features:
  * pytree save/restore as npz (one file per step by default; per-shard
    splitting hook for multi-host),
  * async save (background thread) so the train loop doesn't stall,
  * elastic re-shard on restore: arrays come back as host numpy and are
    device_put with WHATEVER sharding the new mesh dictates — N→M data
    parallel resize needs no conversion step,
  * LSM graph checkpoints are INCREMENTAL: partitions are immutable, so only
    partitions not already in the store are written (content-addressed by
    (level, index, n_edges, hash)).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

import jax

from ..core.failpoints import failpoint
from ..core.integrity import fsync_dir

__all__ = ["CheckpointManager", "save_lsm", "restore_lsm"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        items[key] = np.asarray(leaf)
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- manifest helpers ------------------------------------------------------
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, "MANIFEST.json")

    def _read_manifest(self) -> Dict[str, Any]:
        p = self._manifest_path()
        if not os.path.exists(p):
            return {"checkpoints": []}
        with open(p) as f:
            return json.load(f)

    def _write_manifest(self, m: Dict[str, Any]) -> None:
        tmp = self._manifest_path() + ".tmp"
        failpoint("manifest.write")
        with open(tmp, "w") as f:
            json.dump(m, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        failpoint("manifest.rename")
        os.replace(tmp, self._manifest_path())      # atomic
        fsync_dir(self.dir)

    # -- save/restore ----------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True) -> str:
        """Save a pytree snapshot for `step`."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _do():
            fname = f"step_{step:010d}.npz"
            fpath = os.path.join(self.dir, fname)
            items, _ = _flatten_with_paths(host_tree)
            tmp = fpath + ".tmp"
            with open(tmp, "wb") as f:       # file handle: no .npz suffixing
                np.savez(f, **items)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, fpath)           # atomic publish
            fsync_dir(self.dir)
            m = self._read_manifest()
            m["checkpoints"] = [c for c in m["checkpoints"] if c["step"] != step]
            m["checkpoints"].append({"step": step, "file": fname,
                                     "time": time.time()})
            m["checkpoints"].sort(key=lambda c: c["step"])
            while len(m["checkpoints"]) > self.keep:
                old = m["checkpoints"].pop(0)
                try:
                    os.remove(os.path.join(self.dir, old["file"]))
                except OSError:
                    pass
            self._write_manifest(m)

        if blocking:
            _do()
        else:
            self.wait()
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        return os.path.join(self.dir, f"step_{step:010d}.npz")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> Optional[int]:
        m = self._read_manifest()
        if not m["checkpoints"]:
            return None
        return m["checkpoints"][-1]["step"]

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of `template`. With `shardings` (a
        pytree of jax.sharding.Sharding or None), arrays are device_put
        accordingly — elastic re-shard to any new mesh."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints in " + self.dir)
        m = self._read_manifest()
        entry = next(c for c in m["checkpoints"] if c["step"] == step)
        data = np.load(os.path.join(self.dir, entry["file"]))
        items, treedef = _flatten_with_paths(template)
        restored = {}
        for key, tmpl in items.items():
            raw = data[key]
            if raw.dtype != tmpl.dtype:
                # ml_dtypes (bfloat16 etc.) come back as raw void bytes —
                # reinterpret with the template's dtype
                raw = (raw.view(tmpl.dtype) if raw.dtype.kind == "V"
                       else raw.astype(tmpl.dtype))
            restored[key] = raw
        leaves = [restored[k] for k in items]
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
                tree, shardings)
        return tree, step


# ---------------------------------------------------------------------------
# Incremental LSM graph checkpoints (immutability → only new partitions hit disk)
# ---------------------------------------------------------------------------
def _partition_digest(part) -> str:
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(part.src).tobytes())
    h.update(np.ascontiguousarray(part.dst).tobytes())
    return h.hexdigest()[:16]


def save_lsm(tree, directory: str) -> Dict[str, Any]:
    """Write LSM partitions not already present; returns the graph manifest.

    Partitions that already live in a content-addressed `PartitionStore`
    (a `GraphDB`'s disk tier) are HARD-LINKED into the checkpoint directory
    instead of re-serialized — the checkpoint is then a set of refs into
    the same immutable files, costing no data copy and surviving store GC
    (the inode lives until the last link drops). RAM partitions fall back
    to the npz path. Accepts a GraphDB or a bare LSMTree.

    Live buffers are captured too (`buffers.npz`, columns included) — the
    old checkpoints silently dropped unflushed edges, so a restore lost
    everything after the last flush. With buffers in the manifest the
    checkpoint is a complete recovery root on its own; the store's WAL
    segments are never referenced (restore needs no WAL replay)."""
    from ..core.disk import DiskPartition

    if hasattr(tree, "tree"):  # a GraphDB quacks like its tree
        tree = tree.tree
    os.makedirs(directory, exist_ok=True)
    manifest = {"levels": [], "intervals": {
        "n_partitions": tree.intervals.n_partitions,
        "interval_len": tree.intervals.interval_len,
    }, "written": 0, "reused": 0, "linked": 0,
        "column_dtypes": {k: np.dtype(dt).str
                          for k, dt in tree.column_dtypes.items()}}
    for li, level in enumerate(tree.levels):
        lvl = []
        for pi, part in enumerate(level):
            if isinstance(part, DiskPartition) and not part.dirty:
                fname = os.path.basename(part.path)
                fpath = os.path.join(directory, fname)
                if not os.path.exists(fpath):
                    failpoint("store.link")
                    try:
                        os.link(part.path, fpath)
                    except OSError:
                        shutil.copy2(part.path, fpath)
                    manifest["linked"] += 1
                else:
                    manifest["reused"] += 1
                entry = {"file": fname, "interval": list(part.interval),
                         "n_edges": part.n_edges, "format": "pal"}
                if part.dead is not None and part.dead.any():
                    dname = fname[:-4] + ".dead.npy"
                    with open(os.path.join(directory, dname), "wb") as df:
                        np.save(df, np.asarray(part.dead))
                    entry["dead_file"] = dname
                lvl.append(entry)
                continue
            digest = _partition_digest(part)
            fname = f"part_{digest}.npz"
            fpath = os.path.join(directory, fname)
            if not os.path.exists(fpath):
                cols = {f"col_{k}": np.asarray(v)
                        for k, v in part.columns.items()}
                np.savez(fpath, src=np.asarray(part.src),
                         dst=np.asarray(part.dst),
                         etype=np.asarray(part.etype),
                         dead=(part.dead if part.dead is not None
                               else np.zeros(0, bool)), **cols)
                manifest["written"] += 1
            else:
                manifest["reused"] += 1
            lvl.append({"file": fname, "interval": list(part.interval),
                        "n_edges": part.n_edges, "format": "npz"})
        manifest["levels"].append(lvl)
    # live (unflushed) buffers — staged internal-ID arrays, columns included
    if any(len(b) for b in getattr(tree, "buffers", [])):
        arrays = {}
        for j, b in enumerate(tree.buffers):
            if len(b) == 0:
                continue
            st = b.staging()
            arrays[f"b{j}_src"] = np.array(st.src)
            arrays[f"b{j}_dst"] = np.array(st.dst)
            arrays[f"b{j}_etype"] = np.array(st.etype)
            for k, v in st.columns.items():
                arrays[f"b{j}_col_{k}"] = np.array(v)
        tmp = os.path.join(directory, "buffers.npz.tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, os.path.join(directory, "buffers.npz"))
        manifest["buffers"] = "buffers.npz"
    tmp = os.path.join(directory, "GRAPH_MANIFEST.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(directory, "GRAPH_MANIFEST.json"))
    fsync_dir(directory)
    return manifest


def restore_lsm(directory: str, column_dtypes=None, **lsm_kwargs):
    """Rebuild an LSMTree from a graph manifest (npz or linked .pal files),
    live buffers included — restore resumes with the exact unflushed edge
    set (and attribute values) the checkpoint captured."""
    from ..core.disk import open_partition_file
    from ..core.lsm import LSMTree
    from ..core.pal import IntervalMap, build_partition

    with open(os.path.join(directory, "GRAPH_MANIFEST.json")) as f:
        manifest = json.load(f)
    iv = IntervalMap(n_partitions=manifest["intervals"]["n_partitions"],
                     interval_len=manifest["intervals"]["interval_len"])
    n_levels = len(manifest["levels"])
    branching = 1
    if n_levels > 1:
        branching = len(manifest["levels"][1]) // len(manifest["levels"][0])
    if column_dtypes is None:
        column_dtypes = {k: np.dtype(s)
                         for k, s in manifest.get("column_dtypes", {}).items()}
    tree = LSMTree(iv, n_levels=n_levels, branching=max(branching, 1),
                   column_dtypes=column_dtypes or {}, **lsm_kwargs)
    for li, lvl in enumerate(manifest["levels"]):
        for pi, entry in enumerate(lvl):
            fpath = os.path.join(directory, entry["file"])
            if entry.get("format", "npz") == "pal":
                part = open_partition_file(fpath)
                if entry.get("dead_file"):
                    part.dead = np.load(
                        os.path.join(directory, entry["dead_file"]))
                tree.levels[li][pi] = part
                continue
            data = np.load(fpath)
            cols = {k[4:]: data[k] for k in data.files if k.startswith("col_")}
            part = build_partition(tuple(entry["interval"]), data["src"],
                                   data["dst"], data["etype"], cols,
                                   presorted=True)
            if data["dead"].size:
                part.dead = data["dead"]
            tree.levels[li][pi] = part
    if manifest.get("buffers"):
        data = np.load(os.path.join(directory, manifest["buffers"]))
        for j in range(len(tree.buffers)):
            if f"b{j}_src" not in data.files:
                continue
            cols = {k[len(f"b{j}_col_"):]: data[k] for k in data.files
                    if k.startswith(f"b{j}_col_")}
            # buffer arrays are staged INTERNAL ids: restore them directly
            # (insert_edges would re-hash and re-route)
            tree.buffers[j].extend(data[f"b{j}_src"], data[f"b{j}_dst"],
                                   data[f"b{j}_etype"], cols)
            tree._buffered += int(data[f"b{j}_src"].shape[0])
    return tree
