from .base import ARCH_IDS, ArchSpec, ShapeCell, get_arch, list_archs
