"""Arch registry: every assigned architecture is a selectable config.

Each arch module exposes `spec() -> ArchSpec`. A shape cell is
(arch × shape-name); the dry-run lowers `ArchSpec.shapes[name]` on the
production mesh. Shapes marked `skip` document inapplicability
(e.g. long_500k on pure full-attention LMs — DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional

__all__ = ["ShapeCell", "ArchSpec", "get_arch", "list_archs", "ARCH_IDS"]

ARCH_IDS = [
    "granite-34b", "granite-3-2b", "qwen3-14b",
    "phi3.5-moe-42b-a6.6b", "qwen3-moe-235b-a22b",
    "pna", "gin-tu", "equiformer-v2", "meshgraphnet",
    "bert4rec",
]

_MODULES = {
    "granite-34b": "repro.configs.granite_34b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe",
    "pna": "repro.configs.pna",
    "gin-tu": "repro.configs.gin_tu",
    "equiformer-v2": "repro.configs.equiformer_v2",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "bert4rec": "repro.configs.bert4rec",
}


@dataclasses.dataclass
class ShapeCell:
    """One (arch × input-shape) dry-run cell."""

    name: str
    kind: str                 # train | prefill | decode | serve | retrieval
    dims: Dict[str, int]
    skip: Optional[str] = None  # reason string if inapplicable


@dataclasses.dataclass
class ArchSpec:
    name: str
    family: str               # lm | gnn | recsys
    config: Any               # full published config
    smoke_config: Any         # reduced config for CPU smoke tests
    shapes: Dict[str, ShapeCell]
    source: str               # citation tag from the assignment


def get_arch(arch_id: str) -> ArchSpec:
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.spec()


def list_archs():
    return list(ARCH_IDS)
