"""bert4rec [recsys] embed_dim=64 n_blocks=2 n_heads=2 seq_len=200
interaction=bidir-seq [arXiv:1904.06690; paper].

Item table: 10^6 rows (matches retrieval_cand's 1M candidate universe),
PAL-hash row-sharded (DESIGN.md §4)."""
from ..models.bert4rec import Bert4RecConfig
from .base import ArchSpec, ShapeCell


RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


def full_config() -> Bert4RecConfig:
    return Bert4RecConfig(n_items=1_000_000, embed_dim=64, n_blocks=2,
                          n_heads=2, seq_len=200)


def smoke_config() -> Bert4RecConfig:
    return Bert4RecConfig(n_items=200, embed_dim=16, n_blocks=2, n_heads=2,
                          seq_len=16)


def spec() -> ArchSpec:
    shapes = {n: ShapeCell(name=n, kind=d["kind"], dims=dict(d))
              for n, d in RECSYS_SHAPES.items()}
    return ArchSpec(name="bert4rec", family="recsys", config=full_config(),
                    smoke_config=smoke_config(), shapes=shapes,
                    source="arXiv:1904.06690")
