"""equiformer-v2 [gnn] n_layers=12 d_hidden=128 l_max=6 m_max=2 n_heads=8
equivariance=SO(2)-eSCN [arXiv:2306.12059; unverified].

Non-geometric shapes (citation/product graphs) get synthesized unit-ball
positions and hashed species ids in input_specs — the arch requires
geometry; noted in DESIGN.md §4."""
from ..models.gnn.equiformer_v2 import EquiformerV2Config
from .base import ArchSpec
from .gnn_common import gnn_shape_cells


def full_config() -> EquiformerV2Config:
    return EquiformerV2Config(n_layers=12, d_hidden=128, l_max=6, m_max=2,
                              n_heads=8)


def smoke_config() -> EquiformerV2Config:
    return EquiformerV2Config(n_layers=2, d_hidden=16, l_max=2, m_max=1,
                              n_heads=2)


def spec() -> ArchSpec:
    return ArchSpec(name="equiformer-v2", family="gnn", config=full_config(),
                    smoke_config=smoke_config(), shapes=gnn_shape_cells(),
                    source="arXiv:2306.12059")
