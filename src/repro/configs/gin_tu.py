"""gin-tu [gnn] n_layers=5 d_hidden=64 aggregator=sum eps=learnable
[arXiv:1810.00826; paper]."""
from ..models.gnn.gin import GINConfig
from .base import ArchSpec
from .gnn_common import gnn_shape_cells


def full_config() -> GINConfig:
    return GINConfig(n_layers=5, d_hidden=64)


def smoke_config() -> GINConfig:
    return GINConfig(n_layers=2, d_hidden=16, d_in=8, n_classes=3)


def spec() -> ArchSpec:
    return ArchSpec(name="gin-tu", family="gnn", config=full_config(),
                    smoke_config=smoke_config(), shapes=gnn_shape_cells(),
                    source="arXiv:1810.00826")
