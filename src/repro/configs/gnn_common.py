"""Shared GNN shape-cell definitions (assigned GNN shapes).

d_feat / n_classes follow the public datasets these shapes describe:
full_graph_sm = Cora (2708/10556/1433, 7 classes); minibatch_lg = Reddit
(232,965 nodes, 114.6M edges, d=602, 41 classes, fanout 15-10);
ogb_products (2.44M/61.86M, d=100, 47 classes); molecule = QM9-like batched
small graphs. The sampled-minibatch cell lowers the PADDED subgraph the
NeighborSampler emits: 1024 seeds -> <=1024*15 L1 -> <=15360*10 L2 nodes.
"""
from __future__ import annotations

from typing import Dict

from .base import ShapeCell

# padded sampled-subgraph sizes for minibatch_lg (seeds + fanout closure)
MB_NODES = 1024 + 1024 * 15 + 1024 * 15 * 10          # 169,984 (128-aligned)
MB_EDGES = 1024 * 15 + 1024 * 15 * 10                 # 168,960 (128-aligned)

GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7, task="node_class"),
    "minibatch_lg": dict(kind="train", n_nodes=MB_NODES, n_edges=MB_EDGES,
                         d_feat=602, n_classes=41, task="node_class",
                         seeds=1024, full_nodes=232_965,
                         full_edges=114_615_892, fanout=(15, 10)),
    "ogb_products": dict(kind="train", n_nodes=2_449_029, n_edges=61_859_140,
                         d_feat=100, n_classes=47, task="node_class"),
    "molecule": dict(kind="train", n_nodes=30, n_edges=64, batch=128,
                     d_feat=16, n_classes=1, task="graph_reg"),
}


def gnn_shape_cells() -> Dict[str, ShapeCell]:
    return {name: ShapeCell(name=name, kind=d["kind"], dims=dict(d))
            for name, d in GNN_SHAPES.items()}
