"""granite-34b [dense] 88L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""
from ..models.transformer import TransformerConfig
from .base import ArchSpec
from .lm_common import lm_shape_cells


def full_config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
        vocab_size=49152, d_head=128, qk_norm=False, remat="full",
        q_chunk=1024, kv_chunk=1024)


def smoke_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=128, d_head=16, q_chunk=16, kv_chunk=16,
        compute_dtype=jnp.float32)


def spec() -> ArchSpec:
    return ArchSpec(name="granite-34b", family="lm", config=full_config(),
                    smoke_config=smoke_config(), shapes=lm_shape_cells(),
                    source="arXiv:2405.04324; hf")
