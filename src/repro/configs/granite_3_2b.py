"""granite-3-2b [dense] 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from ..models.transformer import TransformerConfig
from .base import ArchSpec
from .lm_common import lm_shape_cells


def full_config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
        vocab_size=49155, d_head=64, remat="full",
        q_chunk=1024, kv_chunk=1024)


def smoke_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, d_head=16, q_chunk=16, kv_chunk=16,
        compute_dtype=jnp.float32)


def spec() -> ArchSpec:
    return ArchSpec(name="granite-3-2b", family="lm", config=full_config(),
                    smoke_config=smoke_config(), shapes=lm_shape_cells(),
                    source="hf:ibm-granite/granite-3.0-2b-base")
