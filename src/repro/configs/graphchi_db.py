"""The paper's own system config: GraphChi-DB storage/compute parameters
used by the benchmarks (twitter-2010-scale defaults scaled to CI size)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphChiDBConfig:
    n_partitions: int = 16          # P (paper: hundreds at billions of edges)
    lsm_levels: int = 3             # L_G
    branching: int = 4              # f (paper's experiments use 4)
    buffer_cap: int = 100_000       # in-memory edge-buffer threshold
    max_partition_edges: int = 2_000_000
    durable: bool = False           # §7.3 durable vs memory-only buffers
    elias_gamma_index: bool = True  # §4.2.1 pointer-array compression


def full_config() -> GraphChiDBConfig:
    return GraphChiDBConfig()


def bench_config(scale: float = 1.0) -> GraphChiDBConfig:
    return GraphChiDBConfig(
        buffer_cap=max(int(20_000 * scale), 1000),
        max_partition_edges=max(int(200_000 * scale), 10_000))
