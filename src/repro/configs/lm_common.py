"""Shared LM shape-cell definitions (assigned LM shapes)."""
from __future__ import annotations

from typing import Dict

from .base import ShapeCell

# assigned LM shapes: seq_len × global_batch
LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

FULL_ATTN_SKIP = ("sub-quadratic attention required; this arch is pure "
                  "full-attention (no SSM/linear/hybrid variant assigned) — "
                  "skip per assignment, see DESIGN.md §4")


def lm_shape_cells(full_attention: bool = True) -> Dict[str, ShapeCell]:
    cells = {}
    for name, d in LM_SHAPES.items():
        skip = FULL_ATTN_SKIP if (name == "long_500k" and full_attention) else None
        cells[name] = ShapeCell(name=name, kind=d["kind"],
                                dims={"seq": d["seq"], "batch": d["batch"]},
                                skip=skip)
    return cells
