"""meshgraphnet [gnn] n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2
[arXiv:2010.03409; unverified]."""
from ..models.gnn.meshgraphnet import MeshGraphNetConfig
from .base import ArchSpec
from .gnn_common import gnn_shape_cells


def full_config() -> MeshGraphNetConfig:
    return MeshGraphNetConfig(n_layers=15, d_hidden=128, mlp_layers=2)


def smoke_config() -> MeshGraphNetConfig:
    return MeshGraphNetConfig(n_layers=2, d_hidden=16, mlp_layers=2,
                              d_node_in=8, d_edge_in=4, d_out=3)


def spec() -> ArchSpec:
    return ArchSpec(name="meshgraphnet", family="gnn", config=full_config(),
                    smoke_config=smoke_config(), shapes=gnn_shape_cells(),
                    source="arXiv:2010.03409")
