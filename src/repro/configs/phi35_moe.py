"""phi3.5-moe-42b-a6.6b [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf]."""
from ..models.transformer import MoEConfig, TransformerConfig
from .base import ArchSpec
from .lm_common import lm_shape_cells


def full_config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
        vocab_size=32064, d_head=128, remat="full",
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
        q_chunk=1024, kv_chunk=1024)


def smoke_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, d_head=16, q_chunk=16, kv_chunk=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
        compute_dtype=jnp.float32)


def spec() -> ArchSpec:
    return ArchSpec(name="phi3.5-moe-42b-a6.6b", family="lm",
                    config=full_config(), smoke_config=smoke_config(),
                    shapes=lm_shape_cells(),
                    source="hf:microsoft/Phi-3.5-MoE-instruct")
