"""pna [gnn] n_layers=4 d_hidden=75 aggregators=mean-max-min-std
scalers=id-amp-atten [arXiv:2004.05718; paper]."""
from ..models.gnn.pna import PNAConfig
from .base import ArchSpec
from .gnn_common import gnn_shape_cells


def full_config() -> PNAConfig:
    return PNAConfig(n_layers=4, d_hidden=75)


def smoke_config() -> PNAConfig:
    return PNAConfig(n_layers=2, d_hidden=16, d_in=8, n_classes=3)


def spec() -> ArchSpec:
    return ArchSpec(name="pna", family="gnn", config=full_config(),
                    smoke_config=smoke_config(), shapes=gnn_shape_cells(),
                    source="arXiv:2004.05718")
