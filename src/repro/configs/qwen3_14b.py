"""qwen3-14b [dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from ..models.transformer import TransformerConfig
from .base import ArchSpec
from .lm_common import lm_shape_cells


def full_config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=17408,
        vocab_size=151936, d_head=128, qk_norm=True, remat="full",
        rope_theta=1e6, q_chunk=1024, kv_chunk=1024)


def smoke_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, d_head=16, qk_norm=True, q_chunk=16, kv_chunk=16,
        compute_dtype=jnp.float32)


def spec() -> ArchSpec:
    return ArchSpec(name="qwen3-14b", family="lm", config=full_config(),
                    smoke_config=smoke_config(), shapes=lm_shape_cells(),
                    source="hf:Qwen/Qwen3-8B")
