"""qwen3-moe-235b-a22b [moe] 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8 — qk_norm [hf:Qwen/Qwen3-30B-A3B; hf]."""
from ..models.transformer import MoEConfig, TransformerConfig
from .base import ArchSpec
from .lm_common import lm_shape_cells


def full_config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
        vocab_size=151936, d_head=128, qk_norm=True, remat="full",
        rope_theta=1e6,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
        q_chunk=1024, kv_chunk=1024)


def smoke_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, d_head=16, qk_norm=True, q_chunk=16, kv_chunk=16,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32),
        compute_dtype=jnp.float32)


def spec() -> ArchSpec:
    return ArchSpec(name="qwen3-moe-235b-a22b", family="lm",
                    config=full_config(), smoke_config=smoke_config(),
                    shapes=lm_shape_cells(),
                    source="hf:Qwen/Qwen3-30B-A3B")
