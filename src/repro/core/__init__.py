"""GraphChi-DB core: PAL + LSM + PSW + queries (the paper's contribution)."""
from .pal import (
    EdgePartition,
    GraphPAL,
    IntervalMap,
    SortedRun,
    build_partition,
    merge_runs,
    merge_runs_into_partition,
    merge_sorted_runs,
    partition_from_run,
    run_from_arrays,
    run_from_partition,
    sorted_run_index,
)
from .lsm import BufferStaging, EdgeBuffer, LSMStats, LSMTree, MergeTxn
from .manifest import EpochGuard, LevelManifest, ManifestPartition, ManifestView
from .disk import (
    DiskPartition,
    GraphDB,
    IOStats,
    PartitionStore,
    RawDiskIndex,
    SparseDiskIndex,
    open_partition_file,
    partition_digest,
    write_partition_file,
)
from .engine import (
    EdgeBatch,
    EdgeChunk,
    LSMEngine,
    ManifestEngine,
    PALEngine,
    SnapshotEngine,
    StorageEngine,
    as_engine,
)
from .service import ServiceDB, ServiceStats, Snapshot, tail_cache_stats
from .walog import SegmentedWAL
from .psw import (
    DeviceGraph,
    build_device_graph,
    edge_centric_sweep,
    edge_centric_sweep_arrays,
    pagerank_device,
    pagerank_host,
    pagerank_out_of_core,
    psw_sweep_host,
    stream_interval_buckets,
)
from .multihop import (
    EdgePredicate,
    KHopResult,
    TwoHopResult,
    dense_plan,
    expand,
    khop,
    semijoin,
    triangle_count,
    two_hop_counts,
)
from .query import (
    Frontier,
    bfs,
    bfs_perhop,
    dedup_frontier,
    friends_of_friends,
    friends_of_friends_perhop,
    shortest_path,
    shortest_path_perhop,
    traverse_out,
)
from .codec import (
    BlockedGammaPointer,
    GammaChunkedIndex,
    SparseIndex,
    decode_monotonic,
    decode_monotonic_blocked,
    elias_gamma_decode,
    elias_gamma_encode,
    encode_monotonic,
    encode_monotonic_blocked,
)

__all__ = [
    "EdgePartition", "GraphPAL", "IntervalMap", "SortedRun",
    "build_partition", "merge_runs", "merge_runs_into_partition",
    "merge_sorted_runs", "partition_from_run",
    "run_from_arrays", "run_from_partition", "sorted_run_index",
    "BufferStaging", "EdgeBuffer", "LSMStats", "LSMTree", "MergeTxn",
    "EpochGuard", "LevelManifest", "ManifestPartition", "ManifestView",
    "EdgeBatch", "EdgeChunk", "LSMEngine", "ManifestEngine", "PALEngine",
    "SnapshotEngine", "StorageEngine", "as_engine",
    "SegmentedWAL", "ServiceDB", "ServiceStats", "Snapshot",
    "tail_cache_stats",
    "DeviceGraph", "build_device_graph", "edge_centric_sweep",
    "edge_centric_sweep_arrays", "pagerank_device", "pagerank_host",
    "pagerank_out_of_core", "psw_sweep_host", "stream_interval_buckets",
    "EdgePredicate", "KHopResult", "TwoHopResult", "dense_plan", "expand",
    "khop", "semijoin", "triangle_count", "two_hop_counts",
    "Frontier", "bfs", "bfs_perhop", "dedup_frontier", "friends_of_friends",
    "friends_of_friends_perhop", "shortest_path", "shortest_path_perhop",
    "traverse_out",
    "BlockedGammaPointer", "GammaChunkedIndex", "SparseIndex",
    "decode_monotonic",
    "decode_monotonic_blocked", "elias_gamma_decode",
    "elias_gamma_encode", "encode_monotonic", "encode_monotonic_blocked",
    "DiskPartition", "GraphDB", "IOStats", "PartitionStore",
    "RawDiskIndex", "SparseDiskIndex", "open_partition_file",
    "partition_digest", "write_partition_file",
]
