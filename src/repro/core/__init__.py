"""GraphChi-DB core: PAL + LSM + PSW + queries (the paper's contribution)."""
from .pal import (
    EdgePartition,
    GraphPAL,
    IntervalMap,
    SortedRun,
    build_partition,
    merge_runs,
    merge_runs_into_partition,
    merge_sorted_runs,
    partition_from_run,
    run_from_arrays,
    run_from_partition,
    sorted_run_index,
)
from .lsm import BufferStaging, EdgeBuffer, LSMStats, LSMTree
from .engine import (
    EdgeBatch,
    EdgeChunk,
    LSMEngine,
    PALEngine,
    StorageEngine,
    as_engine,
)
from .psw import (
    DeviceGraph,
    build_device_graph,
    edge_centric_sweep,
    edge_centric_sweep_arrays,
    pagerank_device,
    pagerank_host,
    psw_sweep_host,
)
from .query import Frontier, bfs, friends_of_friends, shortest_path, traverse_out
from .codec import (
    SparseIndex,
    decode_monotonic,
    elias_gamma_decode,
    elias_gamma_encode,
    encode_monotonic,
)

__all__ = [
    "EdgePartition", "GraphPAL", "IntervalMap", "SortedRun",
    "build_partition", "merge_runs", "merge_runs_into_partition",
    "merge_sorted_runs", "partition_from_run",
    "run_from_arrays", "run_from_partition", "sorted_run_index",
    "BufferStaging", "EdgeBuffer", "LSMStats", "LSMTree",
    "EdgeBatch", "EdgeChunk", "LSMEngine", "PALEngine", "StorageEngine",
    "as_engine",
    "DeviceGraph", "build_device_graph", "edge_centric_sweep",
    "edge_centric_sweep_arrays", "pagerank_device", "pagerank_host",
    "psw_sweep_host",
    "Frontier", "bfs", "friends_of_friends", "shortest_path", "traverse_out",
    "SparseIndex", "decode_monotonic", "elias_gamma_decode",
    "elias_gamma_encode", "encode_monotonic",
]
