"""Elias-Gamma pointer-array compression + resident indexes (paper §4.2.1, §8.4).

The paper pins the pointer-array in RAM by delta-encoding the (vertex-ID,
offset) increasing sequences with Elias-Gamma codes — reported 424 MB vs
3,383 MB raw on twitter-2010, 26x faster out-edge queries. Since the disk
tier landed, this codec sits on the REAL read path: partition files store
their pointer arrays gamma-compressed, `DiskPartition` keeps only the
compressed blobs pinned and decodes on demand, and `GammaChunkedIndex` is
the paper's chunked-decode lookup structure compared against the raw and
sparse on-disk indexes in `benchmarks/bench_disk.py` (Figure 8c).

Both codec directions are bit-parallel numpy: encode scatters every code's
bits with one fancy-index write; decode finds the code boundaries by
pointer-doubling over a next-one jump table (log₂(#codes) vectorized
passes) and extracts all values with one reduceat. The original per-value
Python loops are kept as `elias_gamma_encode_ref`/`elias_gamma_decode_ref`
and the tests assert the vectorized versions are bitwise identical.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from . import telemetry

_M_BLOCK_READS = telemetry.counter("codec.block_reads")
_M_CHUNK_DECODES = telemetry.counter("codec.chunk_decodes")
_M_BLOCK_DECODES = telemetry.counter("codec.block_decodes")

__all__ = [
    "elias_gamma_encode",
    "elias_gamma_decode",
    "elias_gamma_encode_ref",
    "elias_gamma_decode_ref",
    "encode_monotonic",
    "decode_monotonic",
    "encode_monotonic_blocked",
    "decode_monotonic_blocked",
    "SparseIndex",
    "GammaChunkedIndex",
]


def _bit_length(x: np.ndarray) -> np.ndarray:
    """floor(log2(x)) + 1 for x >= 1, vectorized."""
    return np.floor(np.log2(x.astype(np.float64))).astype(np.int64) + 1


# ---------------------------------------------------------------------------
# Reference (per-value / per-bit) implementations — kept for the bitwise-
# identity tests; never on a hot path.
# ---------------------------------------------------------------------------
def elias_gamma_encode_ref(values: np.ndarray) -> Tuple[np.ndarray, int]:
    values = np.asarray(values, dtype=np.int64)
    if values.size == 0:
        return np.empty(0, np.uint8), 0
    if (values < 1).any():
        raise ValueError("Elias-Gamma requires values >= 1")
    nlens = _bit_length(values)
    total_bits = int((2 * nlens - 1).sum())
    bits = np.zeros(total_bits, dtype=np.uint8)
    code_lens = 2 * nlens - 1
    starts = np.concatenate([[0], np.cumsum(code_lens)[:-1]])
    for i in range(values.shape[0]):
        v, n, s = int(values[i]), int(nlens[i]), int(starts[i])
        for b in range(n):
            bits[s + n - 1 + b] = (v >> (n - 1 - b)) & 1
    return np.packbits(bits), total_bits


def elias_gamma_decode_ref(packed: np.ndarray, nbits: int) -> np.ndarray:
    bits = np.unpackbits(np.asarray(packed, np.uint8))[:nbits]
    out = []
    i = 0
    while i < nbits:
        n = 0
        while bits[i] == 0:
            n += 1
            i += 1
        v = 0
        for _ in range(n + 1):
            v = (v << 1) | int(bits[i])
            i += 1
        out.append(v)
    return np.asarray(out, dtype=np.int64)


# ---------------------------------------------------------------------------
# Bit-parallel implementations (the real read/write path)
# ---------------------------------------------------------------------------
def elias_gamma_encode(values: np.ndarray) -> Tuple[np.ndarray, int]:
    """Encode positive integers with Elias-Gamma: N-1 zeros then the N-bit
    binary of the value (N = bit length). Returns (packed uint8 array, nbits).
    Bitwise identical to `elias_gamma_encode_ref`, no per-value loop."""
    values = np.asarray(values, dtype=np.int64)
    if values.size == 0:
        return np.empty(0, np.uint8), 0
    if (values < 1).any():
        raise ValueError("Elias-Gamma requires values >= 1")
    nlens = _bit_length(values)
    code_lens = 2 * nlens - 1
    total_bits = int(code_lens.sum())
    starts = np.cumsum(code_lens) - code_lens  # code start bit per value
    # one flat index over every explicit (binary-part) bit of every code
    T = int(nlens.sum())
    ids = np.repeat(np.arange(values.shape[0], dtype=np.int64), nlens)
    b = np.arange(T, dtype=np.int64) - np.repeat(np.cumsum(nlens) - nlens, nlens)
    bits = np.zeros(total_bits, dtype=np.uint8)
    bits[starts[ids] + nlens[ids] - 1 + b] = (
        (values[ids] >> (nlens[ids] - 1 - b)) & 1
    ).astype(np.uint8)
    return np.packbits(bits), total_bits


def elias_gamma_decode(packed: np.ndarray, nbits: int) -> np.ndarray:
    """Decode an Elias-Gamma stream without a per-code Python loop.

    A code starting at bit s has its leading one at o = next-one(s) and ends
    at 2o - s + 1, so code starts are the orbit of 0 under the jump
    step(s) = 2·nxt1(s) - s + 1. The orbit is enumerated by pointer
    doubling — starts of the first 2^k codes plus the 2^k-fold composed
    jump table give the first 2^(k+1) — in log₂(#codes) vectorized passes;
    values are then extracted with one ragged reduceat."""
    if nbits == 0:
        return np.empty(0, np.int64)
    bits = np.unpackbits(np.asarray(packed, np.uint8), count=nbits)
    if not bits.any():
        raise ValueError("malformed Elias-Gamma stream: no set bits")
    N = int(nbits)
    nxt = _next_one_table(bits)
    pos = np.arange(N, dtype=np.int64)
    step = np.minimum(2 * nxt[:N] - pos + 1, N)  # N = absorbing "done" state
    step = np.append(step, N)
    starts = np.zeros(1, np.int64)
    jump = step
    while starts[-1] < N:
        starts = np.concatenate([starts, jump[starts]])
        if starts[-1] >= N:
            break
        jump = jump[jump]
    starts = starts[starts < N]
    return _extract_values(bits, starts, nxt)


def _next_one_table(bits: np.ndarray) -> np.ndarray:
    """nxt[i] = smallest j >= i with bits[j] == 1, else N; domain [0, N].
    One reverse minimum-accumulate pass, no binary searches."""
    N = int(bits.shape[0])
    arr = np.full(N + 1, N, np.int64)
    ones = np.flatnonzero(bits)
    arr[ones] = ones
    arr[:N] = np.minimum.accumulate(arr[N - 1::-1])[::-1]
    return arr


def _extract_values(bits: np.ndarray, starts: np.ndarray,
                    nxt: np.ndarray) -> np.ndarray:
    o = nxt[starts]                             # leading one per code
    return _extract_ragged(bits, o, o - starts)


def _extract_ragged(bits: np.ndarray, o: np.ndarray,
                    z: np.ndarray) -> np.ndarray:
    """Values of codes with leading ones `o` and zero-prefix lengths `z`:
    one ragged gather + shift + reduceat, no per-code loop. Handles any
    code length (the word-window fast path below caps at 57 bits)."""
    lens = z + 1                                # explicit binary-part length
    offs = np.cumsum(lens) - lens
    T = int(offs[-1] + lens[-1])
    b = np.arange(T, dtype=np.int64) - np.repeat(offs, lens)
    contrib = bits[np.repeat(o, lens) + b].astype(np.int64) << (np.repeat(z, lens) - b)
    return np.add.reduceat(contrib, offs)


def _extract_words(packed: np.ndarray, o: np.ndarray,
                   z: np.ndarray) -> np.ndarray:
    """Values of codes with leading ones `o` and zero-prefix lengths `z`,
    read straight out of the PACKED bytes: gather one unaligned 64-bit
    big-endian window per code, shift, mask. Requires every binary part to
    fit a window at any bit offset: z + 1 <= 57."""
    B = np.concatenate([np.asarray(packed, np.uint8), np.zeros(8, np.uint8)])
    byte0 = o >> 3
    w = np.zeros(o.shape[0], np.uint64)
    for k in range(8):
        w = (w << np.uint64(8)) | B[byte0 + k].astype(np.uint64)
    lens = (z + 1).astype(np.uint64)
    shift = np.uint64(64) - (o & 7).astype(np.uint64) - lens
    return ((w >> shift) & ((np.uint64(1) << lens) - np.uint64(1))).astype(np.int64)


def encode_monotonic(seq: np.ndarray) -> Tuple[np.ndarray, int, int]:
    """Delta + Elias-Gamma for a non-decreasing sequence (pointer-array).
    Returns (packed, nbits, first_value). Deltas are stored +1 (gamma needs >=1)."""
    seq = np.asarray(seq, dtype=np.int64)
    if seq.size == 0:
        return np.empty(0, np.uint8), 0, 0
    deltas = np.diff(seq) + 1
    packed, nbits = elias_gamma_encode(deltas)
    return packed, nbits, int(seq[0])


def decode_monotonic(packed: np.ndarray, nbits: int, first: int,
                     n: int) -> np.ndarray:
    if n == 0:
        return np.empty(0, np.int64)
    if n == 1:
        return np.asarray([first], np.int64)
    deltas = elias_gamma_decode(packed, nbits) - 1
    return np.concatenate([[first], first + np.cumsum(deltas)])


#: Codes per block in the blocked monotonic format — the sequential-
#: dependency length of blocked decode (one int64 bit-offset of directory
#: per block ≈ 1 bit/value overhead at 64).
GAMMA_BLOCK = 64


def encode_monotonic_blocked(
    seq: np.ndarray, block: int = GAMMA_BLOCK,
) -> Tuple[np.ndarray, int, int, np.ndarray]:
    """Delta + Elias-Gamma with a bit-offset directory every `block` codes.

    Returns (packed, nbits, first_value, offsets). The bit stream is
    IDENTICAL to `encode_monotonic`; the directory (`offsets[j]` = bit
    offset of delta j*block) is what lets `decode_monotonic_blocked` find
    code boundaries with only `block` sequential steps, vectorized across
    all blocks — this is the disk tier's resident-index format.
    """
    seq = np.asarray(seq, dtype=np.int64)
    if seq.size == 0:
        return np.empty(0, np.uint8), 0, 0, np.empty(0, np.int64)
    deltas = np.diff(seq) + 1
    if deltas.size == 0:
        return np.empty(0, np.uint8), 0, int(seq[0]), np.empty(0, np.int64)
    if (deltas < 1).any():
        raise ValueError("sequence must be non-decreasing")
    nlens = _bit_length(deltas)
    code_lens = 2 * nlens - 1
    starts = np.cumsum(code_lens) - code_lens
    packed, nbits = elias_gamma_encode(deltas)
    return packed, nbits, int(seq[0]), starts[::block].copy()


def decode_monotonic_blocked(
    packed: np.ndarray, nbits: int, first: int, n: int,
    offsets: np.ndarray, block: int = GAMMA_BLOCK,
) -> np.ndarray:
    """Decode a blocked monotonic stream. Boundary discovery — the only
    sequentially-dependent part of gamma decoding — runs `block` (= 64)
    vector steps over ALL blocks at once instead of one step per code, so
    decode cost is O(nbits) + 64 small vector ops regardless of length."""
    if n == 0:
        return np.empty(0, np.int64)
    if n == 1:
        return np.asarray([first], np.int64)
    m = n - 1  # deltas
    packed = np.asarray(packed, np.uint8)
    bits = np.unpackbits(packed, count=nbits)
    ones = np.flatnonzero(bits).astype(np.int64)
    N = int(nbits)
    offsets = np.asarray(offsets, np.int64)
    C = offsets.shape[0]
    counts = np.full(C, block, np.int64)
    counts[-1] = m - block * (C - 1)
    nrounds = min(block, m)
    s = offsets.copy()
    starts_mat = np.empty((nrounds, C), np.int64)
    o_mat = np.empty((nrounds, C), np.int64)
    for t in range(nrounds):
        r = np.searchsorted(ones, s)
        valid = r < ones.shape[0]
        o = np.where(valid, ones[np.minimum(r, ones.shape[0] - 1)], N)
        starts_mat[t] = s
        o_mat[t] = o
        s = np.where(valid, 2 * o - s + 1, N)   # N absorbs finished blocks
    # block j's codes are column j, rows 0..counts[j)
    mask = np.arange(nrounds)[None, :] < counts[:, None]
    o = o_mat.T[mask]
    z = o - starts_mat.T[mask]
    deltas = (_extract_words(packed, o, z) if int(z.max()) <= 56
              else _extract_ragged(bits, o, z)) - 1
    return np.concatenate([[first], first + np.cumsum(deltas)])


def gamma_decode_block_deltas(packed: np.ndarray, nbits: int,
                              offsets: np.ndarray, blocks: np.ndarray,
                              m: int, block: int = GAMMA_BLOCK) -> np.ndarray:
    """Decode ONLY the selected blocks of a blocked monotonic stream.

    Returns a (len(blocks), block) int64 matrix of the raw (+1) deltas,
    padded with 1 past the stream end so a row cumsum of (delta - 1) is
    inert beyond the real values. This is the partial-decode primitive
    behind point lookups on the compressed resident index: a query touches
    ~one 64-code block instead of the whole pointer array."""
    blocks = np.asarray(blocks, np.int64)
    offsets = np.asarray(offsets, np.int64)
    B = blocks.shape[0]
    out = np.ones((B, block), np.int64)
    if B == 0 or m == 0:
        return out
    packed = np.asarray(packed, np.uint8)
    cnt_all = np.clip(m - blocks * block, 0, block)  # live deltas per block
    # the final VALUE block may hold zero deltas (n = k*block + 1): it has
    # no directory entry and decodes to nothing — keep only live blocks
    act = np.flatnonzero(cnt_all > 0)
    if act.size == 0:
        return out
    blocks, cnt = blocks[act], cnt_all[act]
    B = blocks.shape[0]
    rounds = int(cnt.max())
    # compact ONLY the selected blocks' bytes — decode cost is the bytes
    # the query touches, independent of the whole stream's length
    ends = np.append(offsets[1:], nbits)
    lo, hi = offsets[blocks], ends[blocks]
    byte_lo = lo >> 3
    byte_len = ((hi + 7) >> 3) - byte_lo
    base = np.cumsum(byte_len) - byte_len       # sub-buffer byte offset
    T = int(base[-1] + byte_len[-1])
    gidx = np.arange(T, dtype=np.int64) - np.repeat(base, byte_len) \
        + np.repeat(byte_lo, byte_len)
    sub = packed[gidx]
    bits = np.unpackbits(sub)
    ones = np.flatnonzero(bits).astype(np.int64)
    N = int(bits.shape[0])
    # each code's walk stays inside its own block's bit range, so the
    # per-block walks run in the shared sub-bit space without interfering
    s = base * 8 + (lo - byte_lo * 8)
    s_mat = np.empty((rounds, B), np.int64)
    o_mat = np.empty((rounds, B), np.int64)
    for t in range(rounds):
        r = np.searchsorted(ones, s)
        valid = r < ones.shape[0]
        o = np.where(valid, ones[np.minimum(r, ones.shape[0] - 1)], N)
        s_mat[t] = s
        o_mat[t] = o
        s = np.where(valid, 2 * o - s + 1, N)
    tmask = np.arange(rounds)[None, :] < cnt[:, None]
    o_sel = o_mat.T[tmask]
    z_sel = o_sel - s_mat.T[tmask]
    if o_sel.size:
        vals = (_extract_words(sub, o_sel, z_sel)
                if int(z_sel.max()) <= 56
                else _extract_ragged(bits, o_sel, z_sel))
        dec = np.ones((B, block), np.int64)
        dec[:, :rounds][tmask] = vals
        out[act] = dec  # scatter live rows back (delta-less rows stay 1s)
    return out


class BlockedGammaPointer:
    """A pointer array resident ONLY in compressed form: gamma blobs + a
    64-code bit-offset directory + the raw first VALUE of each block
    (1/64th of the data). Queries decode just the blocks they touch — the
    paper's chunked pointer-array design (§4.2.1) — so lookup cost is
    O(frontier), never O(index).

    `searchsorted`/`values_at` require the underlying array to be sorted
    (searchsorted additionally assumes strictly increasing keys, which
    holds for the vertex arrays it serves)."""

    _PAD = np.iinfo(np.int64).max

    def __init__(self, packed: np.ndarray, offsets: np.ndarray, nbits: int,
                 first: int, n: int, firsts: np.ndarray,
                 block: int = GAMMA_BLOCK):
        self.packed = np.asarray(packed, np.uint8)
        self.offsets = np.asarray(offsets, np.int64)
        self.nbits = int(nbits)
        self.first = int(first)
        self.n = int(n)
        self.firsts = np.asarray(firsts, np.int64)
        self.block = int(block)

    @classmethod
    def from_array(cls, arr: np.ndarray,
                   block: int = GAMMA_BLOCK) -> "BlockedGammaPointer":
        arr = np.asarray(arr, np.int64)
        packed, nbits, first, offsets = encode_monotonic_blocked(arr, block)
        return cls(packed, offsets, nbits, first, int(arr.shape[0]),
                   arr[::block].copy(), block)

    def nbytes(self) -> int:
        return self.packed.nbytes + self.offsets.nbytes + self.firsts.nbytes

    def _decode_blocks(self, blocks: np.ndarray) -> np.ndarray:
        """(len(blocks), block) matrix of VALUES, padded with int64 max."""
        K = self.block
        _M_BLOCK_DECODES.inc(int(blocks.shape[0]))
        deltas = gamma_decode_block_deltas(
            self.packed, self.nbits, self.offsets, blocks, self.n - 1, K)
        vals = np.empty((blocks.shape[0], K), np.int64)
        vals[:, 0] = self.firsts[blocks]
        np.cumsum(deltas[:, :-1] - 1, axis=1, out=deltas[:, :-1])
        vals[:, 1:] = vals[:, :1] + deltas[:, :-1]
        cnt_v = np.clip(self.n - blocks * K, 0, K)
        vals[np.arange(K)[None, :] >= cnt_v[:, None]] = self._PAD
        return vals

    def searchsorted(self, keys) -> np.ndarray:
        """np.searchsorted(decode_all(), keys, side='left'), decoding at
        most one block per distinct key."""
        return self.searchsorted_with_values(keys)[0]

    def searchsorted_with_values(self, keys) -> Tuple[np.ndarray, np.ndarray]:
        """(insertion index, value AT that index) in one decode pass — the
        point-lookup primitive (find a vertex, check it exists). The value
        is arbitrary where the index lands past the end; callers mask with
        `idx < n`."""
        keys = np.asarray(keys, np.int64)
        if self.n == 0:
            z = np.zeros(keys.shape, np.int64)
            return z, z.copy()
        b = np.searchsorted(self.firsts, keys, side="right") - 1
        b = np.maximum(b, 0)
        ub = np.unique(b)
        mat = self._decode_blocks(ub)
        row = np.searchsorted(ub, b)
        K = self.block
        within = (mat[row] < keys[..., None]).sum(axis=-1)
        # within == K → the key lands at the NEXT block's first value,
        # which is resident in the directory — no second decode
        vals = np.where(
            within < K,
            np.take_along_axis(mat[row], np.minimum(within, K - 1)[..., None],
                               axis=-1)[..., 0],
            self.firsts[np.minimum(b + 1, self.firsts.shape[0] - 1)])
        return b * K + within, vals

    def values_at(self, idx) -> np.ndarray:
        idx = np.asarray(idx, np.int64)
        b = idx // self.block
        ub = np.unique(b)
        mat = self._decode_blocks(ub)
        return mat[np.searchsorted(ub, b), idx % self.block]

    def decode_all(self) -> np.ndarray:
        return decode_monotonic_blocked(self.packed, self.nbits, self.first,
                                        self.n, self.offsets, self.block)


class SparseIndex:
    """In-memory sparse index over an on-disk sorted array (paper §4.2.1,
    second option): every `stride`-th key is kept in RAM; a lookup consults
    the sparse index then reads one block — `keys` may be a live `np.memmap`
    so the block read is a real page fault, and the count reproduces
    Figure 8c."""

    def __init__(self, keys: np.ndarray, stride: int = 64):
        self.keys = np.asarray(keys)
        self.stride = stride
        self.sparse = np.array(self.keys[::stride])  # resident copy
        self.block_reads = 0

    def lookup(self, k) -> int:
        """Index of k in keys, or -1. One block read per lookup."""
        j = int(np.searchsorted(self.sparse, k, side="right")) - 1
        j = max(j, 0)
        lo = j * self.stride
        hi = min(lo + self.stride, self.keys.shape[0])
        self.block_reads += 1
        _M_BLOCK_READS.inc()
        i = lo + int(np.searchsorted(self.keys[lo:hi], k))
        if i < hi and self.keys[i] == k:
            return i
        return -1

    def nbytes(self) -> int:
        return self.sparse.nbytes


class GammaChunkedIndex:
    """The paper's third pointer-array option: the sorted key array lives in
    RAM *compressed*, split into fixed-size chunks each delta+Elias-Gamma
    coded. A lookup binary-searches the (small) chunk-first directory, then
    decodes exactly ONE chunk with the bit-parallel decoder — zero disk
    reads, compressed-size residency, CPU-for-RAM as in §8.4."""

    def __init__(self, keys: np.ndarray, chunk: int = 1024):
        keys = np.asarray(keys, dtype=np.int64)
        self.n = int(keys.shape[0])
        self.chunk = int(chunk)
        self.firsts = keys[::chunk].copy() if self.n else np.empty(0, np.int64)
        self.blobs: List[Tuple[np.ndarray, int, int, int]] = []
        for c in range(0, self.n, chunk):
            part = keys[c:c + chunk]
            packed, nbits, first = encode_monotonic(part)
            self.blobs.append((packed, nbits, first, int(part.shape[0])))
        self.chunk_decodes = 0

    def decode_chunk(self, j: int) -> np.ndarray:
        packed, nbits, first, n = self.blobs[j]
        self.chunk_decodes += 1
        _M_CHUNK_DECODES.inc()
        return decode_monotonic(packed, nbits, first, n)

    def decode_all(self) -> np.ndarray:
        if self.n == 0:
            return np.empty(0, np.int64)
        return np.concatenate([self.decode_chunk(j)
                               for j in range(len(self.blobs))])

    def lookup(self, k) -> int:
        """Index of k in the original array, or -1."""
        if self.n == 0:
            return -1
        j = int(np.searchsorted(self.firsts, k, side="right")) - 1
        j = max(j, 0)
        keys = self.decode_chunk(j)
        i = int(np.searchsorted(keys, k))
        if i < keys.shape[0] and keys[i] == k:
            return j * self.chunk + i
        return -1

    def nbytes(self) -> int:
        """Pinned bytes: compressed blobs + chunk directory."""
        return self.firsts.nbytes + sum(p.nbytes for p, _, _, _ in self.blobs)
