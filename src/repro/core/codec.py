"""Elias-Gamma pointer-array compression + sparse index (paper §4.2.1, §8.4).

The paper pins the pointer-array in RAM by delta-encoding the (vertex-ID,
offset) increasing sequences with Elias-Gamma codes — reported 424 MB vs
3,383 MB raw on twitter-2010, 26x faster out-edge queries. We keep the codec
as a real, exercised component: checkpoints store pointer arrays compressed,
and the benchmarks reproduce the paper's index-variant comparison
(raw on "disk" vs sparse index vs Elias-Gamma in RAM).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = [
    "elias_gamma_encode",
    "elias_gamma_decode",
    "encode_monotonic",
    "decode_monotonic",
    "SparseIndex",
]


def _bit_length(x: np.ndarray) -> np.ndarray:
    """floor(log2(x)) + 1 for x >= 1, vectorized."""
    return np.floor(np.log2(x.astype(np.float64))).astype(np.int64) + 1


def elias_gamma_encode(values: np.ndarray) -> Tuple[np.ndarray, int]:
    """Encode positive integers with Elias-Gamma: N-1 zeros then the N-bit
    binary of the value (N = bit length). Returns (packed uint8 array, nbits)."""
    values = np.asarray(values, dtype=np.int64)
    if values.size == 0:
        return np.empty(0, np.uint8), 0
    if (values < 1).any():
        raise ValueError("Elias-Gamma requires values >= 1")
    nlens = _bit_length(values)
    total_bits = int((2 * nlens - 1).sum())
    bits = np.zeros(total_bits, dtype=np.uint8)
    # positions where each code's explicit binary part starts
    code_lens = 2 * nlens - 1
    starts = np.concatenate([[0], np.cumsum(code_lens)[:-1]])
    for i in range(values.shape[0]):  # vectorize per-bit below; loop per value
        v, n, s = int(values[i]), int(nlens[i]), int(starts[i])
        # n-1 zeros already in place; write binary of v at s + n - 1
        for b in range(n):
            bits[s + n - 1 + b] = (v >> (n - 1 - b)) & 1
    return np.packbits(bits), total_bits


def elias_gamma_decode(packed: np.ndarray, nbits: int) -> np.ndarray:
    bits = np.unpackbits(np.asarray(packed, np.uint8))[:nbits]
    out = []
    i = 0
    while i < nbits:
        n = 0
        while bits[i] == 0:
            n += 1
            i += 1
        v = 0
        for _ in range(n + 1):
            v = (v << 1) | int(bits[i])
            i += 1
        out.append(v)
    return np.asarray(out, dtype=np.int64)


def encode_monotonic(seq: np.ndarray) -> Tuple[np.ndarray, int, int]:
    """Delta + Elias-Gamma for a non-decreasing sequence (pointer-array).
    Returns (packed, nbits, first_value). Deltas are stored +1 (gamma needs >=1)."""
    seq = np.asarray(seq, dtype=np.int64)
    if seq.size == 0:
        return np.empty(0, np.uint8), 0, 0
    deltas = np.diff(seq) + 1
    packed, nbits = elias_gamma_encode(deltas)
    return packed, nbits, int(seq[0])


def decode_monotonic(packed: np.ndarray, nbits: int, first: int,
                     n: int) -> np.ndarray:
    if n == 0:
        return np.empty(0, np.int64)
    if n == 1:
        return np.asarray([first], np.int64)
    deltas = elias_gamma_decode(packed, nbits) - 1
    return np.concatenate([[first], first + np.cumsum(deltas)])


class SparseIndex:
    """In-memory sparse index over an on-disk sorted array (paper §4.2.1,
    second option): every `stride`-th key is kept in RAM; a lookup consults
    the sparse index then 'reads one block' — we count those block reads so
    benchmarks can reproduce Figure 8c."""

    def __init__(self, keys: np.ndarray, stride: int = 64):
        self.keys = np.asarray(keys)
        self.stride = stride
        self.sparse = self.keys[::stride].copy()
        self.block_reads = 0

    def lookup(self, k) -> int:
        """Index of k in keys, or -1. One simulated block read per lookup."""
        j = int(np.searchsorted(self.sparse, k, side="right")) - 1
        j = max(j, 0)
        lo = j * self.stride
        hi = min(lo + self.stride, self.keys.shape[0])
        self.block_reads += 1
        i = lo + int(np.searchsorted(self.keys[lo:hi], k))
        if i < hi and self.keys[i] == k:
            return i
        return -1

    def nbytes(self) -> int:
        return self.sparse.nbytes
