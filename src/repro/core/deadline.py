"""Request-lifecycle primitives (ISSUE 10): deadlines, jittered backoff,
and per-shard circuit breakers.

The paper's online-database claim (§5–6) assumes the store stays
responsive when parts of it are slow. These are the building blocks the
router (core/shardrouter.py) and the serving front end (core/frontdesk.py)
compose into that behavior:

  * `Deadline` — a monotonic-clock budget every request carries. It rides
    across the shard RPC boundary as *remaining seconds* in frame meta
    (`to_budget`/`from_budget`): AF_UNIX peers share CLOCK_MONOTONIC, but
    shipping the remainder rather than an absolute instant keeps the wire
    format clock-agnostic. The router derives per-call socket timeouts
    from it; the worker re-checks it before executing an op so work whose
    caller already gave up is shed, not performed.
  * `deadline_scope` / `current_deadline` — a thread-local ambient stack
    (the telemetry-context pattern): the front desk scopes a batch, and
    every shard RPC under it inherits the budget without threading a
    parameter through the engine/operator layers.
  * `backoff_delays` — exponential backoff with equal jitter
    (d/2 + U(0, d/2)), the retry pacing for idempotent reads. Jitter is
    what keeps N clients that failed together from retrying together;
    pass a seeded `random.Random` for reproducible tests.
  * `CircuitBreaker` — the classic closed → open → half-open machine.
    CLOSED counts consecutive failures (transport errors, timeouts,
    latency-over-threshold "slow" outcomes fed from the telemetry
    histograms); at `failure_threshold` it OPENs and calls fail fast
    (`ShardOverloadError` router-side) instead of queueing onto a sick
    worker. After `open_s` one probe is admitted (HALF_OPEN): success
    closes the breaker, failure re-opens it with the clock reset.

`DeadlineExceeded` and `OverloadError` live in core/integrity.py with the
rest of the typed error taxonomy.
"""
from __future__ import annotations

import random
import threading
import time
from contextlib import contextmanager
from typing import Iterator, Optional

from .integrity import DeadlineExceeded

__all__ = [
    "CircuitBreaker",
    "Deadline",
    "backoff_delays",
    "current_deadline",
    "deadline_scope",
]


class Deadline:
    """An absolute give-up instant on the monotonic clock.

    Every accessor is cheap (one `time.monotonic()` call); a Deadline is
    immutable and may be shared across threads (a broadcast's sub-requests
    all race the same instant)."""

    __slots__ = ("at",)

    def __init__(self, at: float):
        self.at = float(at)

    @classmethod
    def after(cls, seconds: float) -> "Deadline":
        return cls(time.monotonic() + float(seconds))

    def remaining(self) -> float:
        """Seconds left; negative once expired (callers clamp as needed)."""
        return self.at - time.monotonic()

    def expired(self) -> bool:
        return time.monotonic() >= self.at

    def check(self, what: str = "request") -> None:
        """Raise `DeadlineExceeded` if the budget is gone — the typed
        shed every lifecycle stage calls before starting work it could
        not finish in time."""
        rem = self.remaining()
        if rem <= 0.0:
            raise DeadlineExceeded(what, -rem)

    def timeout(self, cap: Optional[float] = None,
                floor: float = 1e-3) -> float:
        """A socket/wait timeout derived from the remaining budget: never
        below `floor` (a zero timeout means non-blocking, which is not
        what a deadline wants) and never above `cap` when given."""
        t = self.remaining()
        if cap is not None and t > cap:
            t = cap
        return max(float(floor), t)

    # -- wire format (shard RPC frame meta) --------------------------------
    def to_budget(self) -> float:
        """The remaining budget in seconds — what crosses the process
        boundary (clock-agnostic; the peer rebuilds its own instant)."""
        return self.remaining()

    @classmethod
    def from_budget(cls, budget) -> Optional["Deadline"]:
        if budget is None:
            return None
        return cls.after(float(budget))

    def __repr__(self) -> str:
        return f"Deadline(remaining={self.remaining():.4f}s)"


# ---------------------------------------------------------------------------
# ambient deadline (thread-local, the telemetry-context pattern)
# ---------------------------------------------------------------------------
_ctx = threading.local()


def current_deadline() -> Optional[Deadline]:
    stack = getattr(_ctx, "stack", None)
    return stack[-1] if stack else None


@contextmanager
def deadline_scope(deadline: Optional[Deadline]):
    """Make `deadline` the ambient budget for this thread: shard RPCs
    issued anywhere under the scope (engine slabs, multihop operators)
    inherit it without parameter plumbing. `None` is a no-op so call
    sites stay unconditional."""
    if deadline is None:
        yield
        return
    stack = getattr(_ctx, "stack", None)
    if stack is None:
        stack = _ctx.stack = []
    stack.append(deadline)
    try:
        yield
    finally:
        stack.pop()


# ---------------------------------------------------------------------------
# retry pacing
# ---------------------------------------------------------------------------
def backoff_delays(base_s: float, cap_s: float, attempts: int,
                   rng: Optional[random.Random] = None) -> Iterator[float]:
    """Exponential backoff with equal jitter: attempt k sleeps
    `d/2 + U(0, d/2)` where `d = min(cap, base * 2**k)`. Equal jitter
    keeps the expected pacing of plain exponential backoff while
    decorrelating clients that failed at the same instant. Pass a seeded
    `random.Random` for deterministic tests."""
    r = rng.random if rng is not None else random.random
    for k in range(attempts):
        d = min(float(cap_s), float(base_s) * (2.0 ** k))
        yield d * 0.5 + r() * d * 0.5


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------
class CircuitBreaker:
    """Closed → open → half-open breaker over one dependency (one shard).

    CLOSED: `allow()` always True; `failure_threshold` CONSECUTIVE
    failures trip it OPEN (any success resets the streak). OPEN: `allow()`
    False — the caller fails fast with a typed overload error instead of
    adding load to a sick worker — until `open_s` has passed, when exactly
    one caller wins the HALF_OPEN probe slot. The probe's outcome decides:
    success closes the breaker (streak cleared), failure re-opens it with
    the clock reset. Thread-safe; every transition is O(1) under one lock.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, failure_threshold: int = 5, open_s: float = 1.0):
        self.failure_threshold = int(failure_threshold)
        self.open_s = float(open_s)
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._failures = 0          # consecutive, in CLOSED
        self._opened_at = 0.0
        self._probing = False       # the single HALF_OPEN slot
        self.trips = 0              # open transitions (telemetry feed)

    @property
    def state(self) -> str:
        with self._lock:
            return self._effective_state()

    def _effective_state(self) -> str:
        """Caller holds the lock. OPEN lazily becomes HALF_OPEN once the
        cool-down has passed (no timer thread: state advances when
        observed)."""
        if (self._state == self.OPEN
                and time.monotonic() - self._opened_at >= self.open_s):
            self._state = self.HALF_OPEN
            self._probing = False
        return self._state

    def allow(self) -> bool:
        """May a call proceed? OPEN rejects; HALF_OPEN admits exactly one
        probe (the rest keep failing fast until its outcome is recorded)."""
        with self._lock:
            st = self._effective_state()
            if st == self.CLOSED:
                return True
            if st == self.HALF_OPEN and not self._probing:
                self._probing = True
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._probing = False
            self._state = self.CLOSED

    def record_failure(self) -> bool:
        """Record a failure (transport error, timeout, or a slow call the
        caller classified as a failure). Returns True when THIS record
        tripped the breaker open — the caller increments the trip metric
        exactly once per open transition."""
        with self._lock:
            st = self._effective_state()
            if st == self.HALF_OPEN:
                # the probe failed: straight back to OPEN, clock reset
                self._state = self.OPEN
                self._opened_at = time.monotonic()
                self._probing = False
                self.trips += 1
                return True
            self._failures += 1
            if st == self.CLOSED and self._failures >= self.failure_threshold:
                self._state = self.OPEN
                self._opened_at = time.monotonic()
                self.trips += 1
                return True
            return False

    def reset(self) -> None:
        with self._lock:
            self._state = self.CLOSED
            self._failures = 0
            self._probing = False
