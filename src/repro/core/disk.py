"""The on-disk storage tier: mmap-backed partition files + GraphDB (paper §4, §7.3).

This module makes the paper's headline claim real: graphs much larger than
RAM served from flat files on disk, with only the (Elias-Gamma-compressed)
pointer-array index pinned in memory (§4.2.1, §8.4).

  * `write_partition_file` / `open_partition_file`: one flat file per
    immutable `EdgePartition` — a JSON header, then 64-byte-aligned raw
    sections for the edge columns (src/dst/etype), the dst permutation and
    every attribute column, plus BOTH a raw and a blocked-Elias-Gamma copy
    of the four pointer arrays. Edge columns are accessed through
    `np.memmap` (the OS pages in only the ranges a query touches); the
    pointer arrays come back either decoded-from-gamma (resident mode) or
    as raw memmaps (the paper's Figure 8 "on disk" baseline).
  * `DiskPartition`: an `EdgePartition` whose big arrays are lazy memmaps
    and whose pointer index is decoded on demand from pinned compressed
    blobs; `evict()` drops every mapping and decoded cache (the pinned
    blobs stay), bounding resident memory.
  * `PartitionStore`: a content-addressed directory of partition files
    (`parts/part_<digest>.pal`) written via atomic rename — immutability
    makes dedup, checkpoint hard-links, and GC trivial.
  * `GraphDB`: the durable database directory — an `LSMTree` whose merged
    partitions are flushed to the store (via the tree's `partition_sink`),
    an atomically-renamed `MANIFEST.json`, and the tree's WAL. Recovery =
    open the manifest's partitions + replay the WAL tail. Close→reopen and
    crash→reopen both yield bitwise-identical query results (tested).
  * `RawDiskIndex` / `SparseDiskIndex`: explicit `os.pread`-based pointer
    lookups with REAL counted block reads, the disk baselines that
    `benchmarks/bench_disk.py` compares against the resident
    `GammaChunkedIndex` (paper Figure 8c).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import mmap
import os
import shutil
import struct
import threading
import weakref
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from . import telemetry
from .codec import (
    GAMMA_BLOCK,
    BlockedGammaPointer,
    SparseIndex,
    encode_monotonic_blocked,
)
from .failpoints import failpoint
from .integrity import (
    CKSUM_ALGO,
    CRC_ALGO,
    CorruptionError,
    RecoveryError,
    checksum32,
    crc32,
    fsync_dir,
)
from .lsm import EdgeBuffer, LSMTree
from .pal import EdgePartition, IntervalMap, build_partition
from .walog import SegmentedWAL

__all__ = [
    "IOStats",
    "DiskPartition",
    "PartitionStore",
    "GraphDB",
    "RawDiskIndex",
    "SparseDiskIndex",
    "partition_digest",
    "replay_ops",
    "write_partition_file",
    "open_partition_file",
]

_MAGIC = b"PALPART1"
_ALIGN = 64
_PTR_ARRAYS = ("src_vertices", "src_ptr", "dst_vertices", "dst_ptr")

# process-wide disk-tier accounting (ISSUE 9): IOStats instances keep their
# per-store attributes, and ALSO write through to the registry so one
# snapshot unifies every store/snapshot/shard-worker in the process
_M_DISK_BLOCKS = telemetry.counter("disk.block_reads")
_M_DISK_BYTES = telemetry.counter("disk.bytes_read")
_M_DISK_GATHERS = telemetry.counter("disk.gathers")


# ---------------------------------------------------------------------------
# Block-read accounting
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class IOStats:
    """Counts the disk blocks a query path touches. For memmapped columns
    the OS does the actual read, so we account the DISTINCT blocks covered
    by each gather — the number of page faults a cold cache would take,
    i.e. the paper's block-read cost model with real positions."""

    block_size: int = 4096
    block_reads: int = 0
    bytes_read: int = 0
    gathers: int = 0

    def account_gather(self, pos: np.ndarray, itemsize: int) -> None:
        if len(pos) == 0:
            return
        pos = np.asarray(pos, np.int64)
        blocks = np.unique(pos * itemsize // self.block_size)
        nb = int(blocks.shape[0])
        nbytes = int(pos.shape[0]) * itemsize
        self.block_reads += nb
        self.bytes_read += nbytes
        self.gathers += 1
        _M_DISK_BLOCKS.inc(nb)
        _M_DISK_BYTES.inc(nbytes)
        _M_DISK_GATHERS.inc()

    def account_range(self, a: int, b: int, itemsize: int) -> None:
        if b <= a:
            return
        lo = a * itemsize // self.block_size
        hi = (b * itemsize - 1) // self.block_size
        nb = int(hi - lo + 1)
        nbytes = (b - a) * itemsize
        self.block_reads += nb
        self.bytes_read += nbytes
        self.gathers += 1
        _M_DISK_BLOCKS.inc(nb)
        _M_DISK_BYTES.inc(nbytes)
        _M_DISK_GATHERS.inc()

    def snapshot(self) -> Dict[str, int]:
        return {"block_reads": self.block_reads, "bytes_read": self.bytes_read,
                "gathers": self.gathers, "block_size": self.block_size}


# ---------------------------------------------------------------------------
# Partition file format
# ---------------------------------------------------------------------------
def partition_digest(part: EdgePartition) -> str:
    """Content address over everything a partition file persists."""
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(part.src).tobytes())
    h.update(np.ascontiguousarray(part.dst).tobytes())
    h.update(np.ascontiguousarray(part.etype).tobytes())
    for k in sorted(part.columns):
        h.update(k.encode())
        h.update(np.ascontiguousarray(part.columns[k]).tobytes())
    return h.hexdigest()[:16]


def _pad(f, align: int = _ALIGN) -> int:
    off = f.tell()
    rem = off % align
    if rem:
        f.write(b"\0" * (align - rem))
        off += align - rem
    return off


def write_partition_file(path: str, part: EdgePartition,
                         fsync: bool = True, checksums: bool = True) -> None:
    """Serialize a partition to one flat file: magic, JSON header, aligned
    raw sections. Written to a per-thread-unique `<path>.tmp*` then
    atomically renamed — a crash mid-write can never leave a half-file at
    the published path, and two maintenance workers racing to persist the
    same digest each write their own temp (last rename wins, same bytes).
    With `fsync=False` durability is deferred: correct as long as the
    caller syncs before publishing a manifest that references the file (a
    torn unreferenced file is never read by recovery).

    With `checksums=True` (the default since ISSUE 7) the file is format
    version 2: the header carries a CRC-32 per 64B-aligned section (plus
    its own trailing CRC), and readers verify each section lazily on first
    touch — bit rot under the mmap becomes a typed `CorruptionError`
    instead of garbage edges. Version-1 files stay readable (unverified)."""
    sections: Dict[str, Tuple[int, str, int]] = {}
    gamma: Dict[str, Dict[str, int]] = {}
    crcs: Dict[str, int] = {}

    arrays: List[Tuple[str, np.ndarray]] = [
        ("src", np.ascontiguousarray(part.src, np.int64)),
        ("dst", np.ascontiguousarray(part.dst, np.int64)),
        ("etype", np.ascontiguousarray(part.etype, np.int8)),
        ("dst_perm", np.ascontiguousarray(part.dst_perm, np.int64)),
    ]
    for k in sorted(part.columns):
        arrays.append((f"col_{k}", np.ascontiguousarray(part.columns[k])))
    gamma_blobs: List[Tuple[str, np.ndarray, np.ndarray, int, int, int]] = []
    for name in _PTR_ARRAYS:
        arr = np.ascontiguousarray(getattr(part, name), np.int64)
        arrays.append((f"{name}_raw", arr))
        # every GAMMA_BLOCK-th raw value: the resident block directory that
        # lets lookups decode one chunk instead of the whole array
        arrays.append((f"sf_{name}", arr[::GAMMA_BLOCK].copy()))
        packed, nbits, first, offsets = encode_monotonic_blocked(arr)
        gamma_blobs.append((name, packed, offsets, nbits, first, int(arr.shape[0])))

    tmp = f"{path}.tmp{os.getpid()}_{threading.get_ident()}"
    with open(tmp, "wb") as f:
        f.write(_MAGIC)
        f.write(b"\0" * 8)  # header-length placeholder
        # reserve generous header space by writing it twice: first pass with
        # zero offsets to learn its size, then seek back with real offsets
        header_probe = _header_json(part, sections, gamma, crcs, probe=True,
                                    arrays=arrays, blobs=gamma_blobs,
                                    checksums=checksums)
        f.write(header_probe)
        f.write(b"\0" * 4)  # header-CRC placeholder (v2)
        _pad(f)
        failpoint("part.write.body")

        def _emit(name: str, data: bytes, dtype_str: str, n: int) -> None:
            off = _pad(f)
            sections[name] = (off, dtype_str, n)
            if checksums:
                crcs[name] = checksum32(data)
            f.write(data)

        for name, arr in arrays:
            _emit(name, arr.tobytes(), arr.dtype.str, int(arr.shape[0]))
        for name, packed, offsets, nbits, first, n in gamma_blobs:
            _emit(f"g_{name}", packed.tobytes(), "|u1", int(packed.shape[0]))
            _emit(f"gd_{name}",
                  np.ascontiguousarray(offsets, np.int64).tobytes(),
                  "<i8", int(offsets.shape[0]))
            gamma[name] = {"nbits": nbits, "first": first, "n": n}
        header = _header_json(part, sections, gamma, crcs, probe=False,
                              arrays=arrays, blobs=gamma_blobs,
                              checksums=checksums)
        assert len(header) == len(header_probe), "header size drifted"
        f.seek(len(_MAGIC))
        f.write(np.uint64(len(header)).tobytes())
        f.write(header)
        f.write(struct.pack("<I", crc32(header)))
        f.flush()
        if fsync:
            failpoint("part.write.fsync")
            os.fsync(f.fileno())
    failpoint("part.write.rename")
    os.replace(tmp, path)
    # the rename is atomic but its directory entry is only durable once the
    # parent directory is synced (ISSUE 7 satellite); deferred-fsync writes
    # get their dir sync from PartitionStore.sync before publication
    if fsync:
        fsync_dir(path)


def _header_json(part, sections, gamma, crcs, probe: bool, arrays, blobs,
                 checksums: bool = True) -> bytes:
    if probe:
        # same shape/keys as the real header, with fixed-width placeholder
        # numbers so the byte length matches the final write
        sections = {name: (2 ** 52, arr.dtype.str, int(arr.shape[0]))
                    for name, arr in arrays}
        for name, packed, offsets, nbits, first, n in blobs:
            sections[f"g_{name}"] = (2 ** 52, "|u1", int(packed.shape[0]))
            sections[f"gd_{name}"] = (2 ** 52, "<i8", int(offsets.shape[0]))
        gamma = {name: {"nbits": nbits, "first": first, "n": n}
                 for name, packed, offsets, nbits, first, n in blobs}
        crcs = {k: 0 for k in sections} if checksums else {}
    else:
        sections = {k: (int(v[0]) + 2 ** 52, v[1], v[2])
                    for k, v in sections.items()}  # keep fixed width
    doc = {
        "version": 2 if checksums else 1,
        "interval": [int(part.interval[0]), int(part.interval[1])],
        "n_edges": int(part.n_edges),
        "columns": sorted(part.columns),
        "gamma_block": GAMMA_BLOCK,
        "sections": {k: list(v) for k, v in sections.items()},
        "gamma": gamma,
    }
    if checksums:
        # same fixed-width bias trick for the checksum values (u32 < 2**52)
        doc["crc_algo"] = CKSUM_ALGO
        doc["crc"] = {k: int(v) + 2 ** 52 for k, v in crcs.items()}
    return json.dumps(doc, sort_keys=True).encode()


def _read_header(path: str) -> Dict[str, Any]:
    try:
        with open(path, "rb") as f:
            magic = f.read(8)
            if magic != _MAGIC:
                raise CorruptionError(path, "not a partition file (bad magic)")
            hlen = int(np.frombuffer(f.read(8), np.uint64)[0])
            raw = f.read(hlen)
            doc = json.loads(raw)
            if int(doc.get("version", 1)) >= 2:
                trailer = f.read(4)
                if (len(trailer) < 4
                        or struct.unpack("<I", trailer)[0] != crc32(raw)):
                    raise CorruptionError(path, "partition header failed CRC")
    except CorruptionError:
        raise
    except (OSError, ValueError, KeyError, json.JSONDecodeError,
            struct.error) as e:
        if isinstance(e, FileNotFoundError):
            raise
        raise CorruptionError(path, f"unreadable partition header: {e}")
    # undo the fixed-width offset bias
    doc["sections"] = {k: (int(v[0]) - 2 ** 52, v[1], int(v[2]))
                       for k, v in doc["sections"].items()}
    if doc.get("crc"):
        doc["crc"] = {k: int(v) - 2 ** 52 for k, v in doc["crc"].items()}
    return doc


def open_partition_file(path: str, io: Optional[IOStats] = None,
                        index_mode: str = "gamma",
                        verify: bool = True) -> "DiskPartition":
    return DiskPartition(path, _read_header(path), io=io,
                         index_mode=index_mode, verify=verify)


# ---------------------------------------------------------------------------
# DiskPartition — EdgePartition over a partition file
# ---------------------------------------------------------------------------
class DiskPartition(EdgePartition):
    """An `EdgePartition` whose edge arrays are lazy `np.memmap` views of a
    partition file and whose pointer index is decoded on demand from
    gamma blobs pinned in RAM (`index_mode="gamma"`), or memmapped raw
    (`index_mode="raw"`, the Figure-8 on-disk baseline).

    In-place mutations the LSM model allows (attribute writes, etype edits,
    tombstones) materialize the touched array into RAM (copy-on-write);
    such a partition reports `dirty` and is rewritten at the next
    `GraphDB.checkpoint()`. `evict()` drops every mapping and decoded
    cache — only `resident_nbytes()` bytes stay pinned."""

    def __init__(self, path: str, header: Dict[str, Any],
                 io: Optional[IOStats] = None, index_mode: str = "gamma",
                 verify: bool = True):
        assert index_mode in ("gamma", "raw"), index_mode
        self.path = path
        self.header = header
        self.io = io
        self.index_mode = index_mode
        # per-section CRC verification, lazy on first touch (format v2;
        # v1 files carry no CRCs and skip it). `_verified` persists across
        # evict() — re-verification of long-lived partitions is the
        # background scrub's job (GraphDB.scrub), not the query path's.
        self._crc = header.get("crc") if verify else None
        # the header names its algorithm: wsum32 files (current writer)
        # and crc32-zlib files (earlier v2 writers) both verify
        self._crc_fn = (crc32 if header.get("crc_algo") == CRC_ALGO
                        else checksum32)
        self._verified: set = set()
        # stores WITHOUT a residency budget (the service tier's default)
        # set this: queries then use the fully-decoded pointer arrays —
        # decoded ONCE per immutable partition and cached — instead of
        # re-decoding gamma blocks on every lookup. Under a budget it
        # stays False and lookups keep the chunked-decode path whose
        # resident footprint is just the compressed blobs.
        self.index_resident = False
        self.interval = (int(header["interval"][0]), int(header["interval"][1]))
        self.dead: Optional[np.ndarray] = None
        self._mm: Dict[str, np.ndarray] = {}    # section -> memmap (evictable)
        self._ram: Dict[str, np.ndarray] = {}   # copy-on-write overrides
        self._idx: Dict[str, np.ndarray] = {}   # fully-decoded ptrs (evictable)
        # pinned: compressed blobs + bit-offset directory + block firsts —
        # the ONLY per-partition state that survives eviction
        self._bp: Dict[str, BlockedGammaPointer] = {}
        if index_mode == "gamma":
            blk = int(header.get("gamma_block", GAMMA_BLOCK))
            for name in _PTR_ARRAYS:
                meta = header["gamma"][name]
                self._bp[name] = BlockedGammaPointer(
                    self._read_section(f"g_{name}"),
                    self._read_section(f"gd_{name}"),
                    meta["nbits"], meta["first"], meta["n"],
                    self._read_section(f"sf_{name}"), blk)
        self.columns = _ColumnDict(self)

    # -- raw I/O --------------------------------------------------------------
    def _section_spec(self, name: str) -> Tuple[int, np.dtype, int]:
        off, dt, n = self.header["sections"][name]
        return off, np.dtype(dt), n

    def _verify(self, name: str, data) -> None:
        """Check one section against its header CRC on FIRST touch (the
        cost is one linear pass over bytes a query is about to fault in
        anyway; later touches are free). Typed failure, never garbage."""
        if self._crc is None or name in self._verified:
            return
        want = self._crc.get(name)
        if want is not None and self._crc_fn(data) != want:
            raise CorruptionError(
                self.path, f"section {name!r} failed its checksum "
                           f"(stored {want:#010x})")
        self._verified.add(name)

    def _read_section(self, name: str) -> np.ndarray:
        """Eager read (small pinned things: gamma blobs, directories)."""
        failpoint("part.read.section")
        off, dt, n = self._section_spec(name)
        with open(self.path, "rb") as f:
            f.seek(off)
            raw = f.read(n * dt.itemsize)
        self._verify(name, raw)
        return np.frombuffer(raw, dt)

    def _mmap(self, name: str) -> np.ndarray:
        arr = self._mm.get(name)
        if arr is None:
            off, dt, n = self._section_spec(name)
            arr = np.memmap(self.path, dtype=dt, mode="r", offset=off,
                            shape=(n,))
            if n:
                self._verify(name, memoryview(arr).cast("B"))
            self._mm[name] = arr
        return arr

    def _edge_array(self, name: str) -> np.ndarray:
        override = self._ram.get(name)
        return override if override is not None else self._mmap(name)

    # -- the EdgePartition surface --------------------------------------------
    @property
    def src(self) -> np.ndarray:
        return self._edge_array("src")

    @property
    def dst(self) -> np.ndarray:
        return self._edge_array("dst")

    @property
    def etype(self) -> np.ndarray:
        return self._edge_array("etype")

    @property
    def dst_perm(self) -> np.ndarray:
        return self._edge_array("dst_perm")

    def _pointer(self, name: str) -> np.ndarray:
        """Full decoded pointer array — the compatibility path (dirty
        rewrites, direct field access). Queries never need it: they go
        through `lookup_adj_ranges`/`dst_ptr_bounds`, which decode only
        the touched blocks."""
        arr = self._idx.get(name)
        if arr is not None:
            return arr
        if self.index_mode == "gamma":
            arr = self._bp[name].decode_all()
            self._idx[name] = arr
        else:
            arr = self._mmap(f"{name}_raw")
        return arr

    # -- chunked-decode query paths (paper §4.2.1) -----------------------------
    def lookup_adj_ranges(self, vis: np.ndarray, direction: str):
        """For each queried internal vertex, its [start, end) range — into
        the edge-array for "out", into dst_perm for "in" — resolved
        against the COMPRESSED resident index: one binary search over the
        block firsts + a decode of only the touched 64-code blocks.
        Returns (hit query indices, starts, ends), or None when this
        partition has no compressed index (raw mode) or prefers its
        decoded-and-cached pointer arrays (`index_resident`)."""
        if self.index_mode != "gamma" or self.index_resident:
            return None
        names = (("src_vertices", "src_ptr") if direction == "out"
                 else ("dst_vertices", "dst_ptr"))
        V, P = self._bp[names[0]], self._bp[names[1]]
        empty = np.empty(0, np.int64)
        if V.n == 0:
            return empty, empty, empty
        vis = np.asarray(vis, np.int64)
        idx, vals = V.searchsorted_with_values(vis)  # one decode pass
        hit = np.flatnonzero((idx < V.n) & (vals == vis))
        if hit.size == 0:
            return empty, empty, empty
        ki = idx[hit]
        # one fused decode for both range endpoints
        both = P.values_at(np.concatenate([ki, ki + 1]))
        return hit, both[: ki.shape[0]], both[ki.shape[0]:]

    def dst_ptr_bounds(self, lo: int, hi: int):
        """[pa, pb) range of dst_perm whose destinations fall in [lo, hi)
        — the out-of-core PSW bucket slice — from the compressed index.
        None in raw mode (caller falls back to the decoded arrays)."""
        if self.index_mode != "gamma":
            return None
        V, P = self._bp["dst_vertices"], self._bp["dst_ptr"]
        if V.n == 0:
            return 0, 0
        ab = V.searchsorted(np.asarray([lo, hi], np.int64))
        bounds = P.values_at(np.minimum(ab, V.n))
        return int(bounds[0]), int(bounds[1])

    # scalar query overrides: a frontier of one through the chunked path
    def out_edge_range(self, v: int) -> Tuple[int, int]:
        res = self.lookup_adj_ranges(np.asarray([v], np.int64), "out")
        if res is None:
            return super().out_edge_range(v)
        hit, starts, ends = res
        if hit.size:
            return int(starts[0]), int(ends[0])
        return 0, 0

    def in_edges(self, v: int) -> np.ndarray:
        res = self.lookup_adj_ranges(np.asarray([v], np.int64), "in")
        if res is None:
            return super().in_edges(v)
        hit, starts, ends = res
        if hit.size == 0:
            return np.empty(0, np.int64)
        pos = np.asarray(self.dst_perm[int(starts[0]):int(ends[0])], np.int64)
        return self._live(pos)

    @property
    def src_vertices(self) -> np.ndarray:
        return self._pointer("src_vertices")

    @property
    def src_ptr(self) -> np.ndarray:
        return self._pointer("src_ptr")

    @property
    def dst_vertices(self) -> np.ndarray:
        return self._pointer("dst_vertices")

    @property
    def dst_ptr(self) -> np.ndarray:
        return self._pointer("dst_ptr")

    @property
    def n_edges(self) -> int:
        return int(self.header["n_edges"])

    # -- copy-on-write mutations ----------------------------------------------
    def _materialize(self, name: str) -> np.ndarray:
        arr = self._ram.get(name)
        if arr is None:
            arr = np.array(self._mmap(name))
            self._ram[name] = arr
        return arr

    def set_etype(self, pos, values) -> None:
        self._materialize("etype")[pos] = values

    def set_column(self, name: str, pos, values) -> None:
        self.columns.materialize(name)[pos] = values

    @property
    def dirty(self) -> bool:
        """The partition FILE is stale (in-place column/etype writes).
        Tombstones do NOT dirty the file — `dead` is persisted as a
        sidecar, so a tombstoned partition still hard-links/dedups by
        content."""
        return bool(self._ram) or self.columns.has_overrides()

    # -- residency ------------------------------------------------------------
    def evict(self) -> None:
        """Drop every memmap and decoded pointer cache. Pinned compressed
        blobs, RAM overrides (dirty state), and tombstones survive."""
        self._mm.clear()
        self._idx.clear()
        self.columns.evict()

    def advise_dontneed(self) -> None:
        """Tell the kernel this partition's file pages won't be re-read
        (PSW sweeps touch each bucket once per pass). Two hints, both
        advisory and platform-guarded: `madvise(DONTNEED)` drops the
        mappings' PTEs (RSS), and `posix_fadvise(POSIX_FADV_DONTNEED)`
        asks the kernel to drop the file's clean PAGE-CACHE pages — for a
        read-only shared file mapping madvise alone leaves the cache copy
        in place, so without the fadvise a streaming scan would still
        churn hotter data out."""
        advise = getattr(mmap.mmap, "madvise", None)
        flag = getattr(mmap, "MADV_DONTNEED", None)
        if advise is not None and flag is not None:
            for arr in self._mm.values():
                m = getattr(arr, "_mmap", None)
                if m is not None:
                    try:
                        m.madvise(flag)
                    except (OSError, ValueError):
                        pass  # platform refused the hint; purely advisory
        fadvise = getattr(os, "posix_fadvise", None)
        fflag = getattr(os, "POSIX_FADV_DONTNEED", None)
        if fadvise is not None and fflag is not None and self._mm:
            try:
                fd = os.open(self.path, os.O_RDONLY)
                try:
                    fadvise(fd, 0, 0, fflag)  # whole file
                finally:
                    os.close(fd)
            except OSError:
                pass

    def resident_nbytes(self) -> int:
        """Bytes pinned regardless of eviction: the compressed index
        (gamma blobs + bit-offset directories + block firsts)."""
        return sum(bp.nbytes() for bp in self._bp.values())

    def cached_nbytes(self) -> int:
        """Evictable bytes currently materialized (decoded pointers + RAM
        overrides; memmap pages are the OS's to count)."""
        n = sum(a.nbytes for a in self._idx.values())
        n += sum(a.nbytes for a in self._ram.values())
        n += self.columns.override_nbytes()
        return n

    def nbytes(self) -> int:
        return os.path.getsize(self.path)


class _ColumnDict(dict):
    """The `columns` mapping of a DiskPartition: values are memmaps until
    written, then RAM overrides. Plain-dict writes (e.g. PageRank's
    `columns["pr"] = ranks`) just shadow the file copy. Holds its partition
    weakly — the partition owns the dict, and a strong back-edge would put
    every replaced partition's mappings at the GC's mercy."""

    def __init__(self, part: DiskPartition):
        super().__init__()
        self._part = weakref.ref(part)
        self._overridden: set = set()
        for name in part.header["columns"]:
            super().__setitem__(name, None)  # placeholder, filled lazily

    def __getitem__(self, key):
        val = super().__getitem__(key)
        if val is None:
            val = self._part()._mmap(f"col_{key}")
            super().__setitem__(key, val)
        return val

    def get(self, key, default=None):
        if key not in self:
            return default
        return self[key]

    def __setitem__(self, key, value):
        self._overridden.add(key)
        super().__setitem__(key, value)

    def values(self):
        return [self[k] for k in self.keys()]

    def items(self):
        return [(k, self[k]) for k in self.keys()]

    def materialize(self, key) -> np.ndarray:
        if key not in self._overridden:
            self[key] = np.array(self[key])
        return super().__getitem__(key)

    def has_overrides(self) -> bool:
        return bool(self._overridden)

    def override_nbytes(self) -> int:
        return sum(np.asarray(super(_ColumnDict, self).__getitem__(k)).nbytes
                   for k in self._overridden)

    def evict(self) -> None:
        for k in self.keys():
            if k not in self._overridden:
                super().__setitem__(k, None)


def _link_or_copy(src: str, dst: str) -> str:
    """Hard-link (pin the inode, zero data copy); copy across filesystems."""
    if not os.path.exists(dst):
        failpoint("store.link")
        try:
            os.link(src, dst)
        except OSError:
            shutil.copy2(src, dst)
    return dst


# ---------------------------------------------------------------------------
# Content-addressed partition store
# ---------------------------------------------------------------------------
class PartitionStore:
    """`parts/part_<digest>.pal` under a database directory. Immutable files
    + atomic rename publishing: a digest either fully exists or doesn't,
    so dedup (same content → same file), checkpoint hard-links, and GC are
    all trivially safe."""

    def __init__(self, directory: str, io: Optional[IOStats] = None,
                 checksums: bool = True):
        self.dir = os.path.join(directory, "parts")
        os.makedirs(self.dir, exist_ok=True)
        self.io = io
        self.checksums = bool(checksums)
        self._unsynced: set = set()

    def path_of(self, digest: str) -> str:
        return os.path.join(self.dir, f"part_{digest}.pal")

    def put(self, part: EdgePartition, fsync: bool = False) -> str:
        """Write-if-absent. Merge-path writes defer fsync (hundreds of
        syncs per bulk load otherwise); `sync(digests)` settles the debt
        before a manifest references them."""
        digest = partition_digest(part)
        path = self.path_of(digest)
        if not os.path.exists(path):
            write_partition_file(path, part, fsync=fsync,
                                 checksums=self.checksums)
            if not fsync:
                self._unsynced.add(digest)
        return digest

    def sync(self, digests) -> None:
        synced = 0
        for digest in list(digests):
            if digest in self._unsynced:
                path = self.path_of(digest)
                if os.path.exists(path):
                    fd = os.open(path, os.O_RDONLY)
                    try:
                        failpoint("part.write.fsync")
                        os.fsync(fd)
                    finally:
                        os.close(fd)
                    synced += 1
                self._unsynced.discard(digest)
        if synced:
            # one dir sync settles every deferred rename's directory entry
            fsync_dir(self.dir)

    def open(self, digest: str, index_mode: str = "gamma") -> DiskPartition:
        return open_partition_file(self.path_of(digest), io=self.io,
                                   index_mode=index_mode,
                                   verify=self.checksums)

    def gc(self, keep_digests) -> int:
        """Delete store files whose digest is not in `keep_digests`.
        Checkpoint hard-links live in other directories and keep the inode
        alive on their own."""
        keep = {f"part_{d}.pal" for d in keep_digests}
        removed = 0
        for fname in os.listdir(self.dir):
            if fname.endswith(".pal") and fname not in keep:
                failpoint("store.gc.unlink")
                os.remove(os.path.join(self.dir, fname))
                removed += 1
            elif ".pal.tmp" in fname:
                # abandoned temp from a crashed writer; an ACTIVE worker's
                # temp carries its live (pid, thread) suffix — colliding
                # with one is possible only for a recycled pid, and the
                # worker's atomic rename re-publishes identical bytes
                try:
                    os.remove(os.path.join(self.dir, fname))
                except OSError:
                    pass
        return removed

    def link_into(self, digest: str, dest_dir: str) -> str:
        """Hard-link a partition file into `dest_dir` (checkpoints,
        snapshot pins); falls back to a copy across filesystems."""
        src = self.path_of(digest)
        return _link_or_copy(src, os.path.join(dest_dir,
                                               os.path.basename(src)))


# ---------------------------------------------------------------------------
# Typed WAL replay (shared by GraphDB recovery and snapshot sessions)
# ---------------------------------------------------------------------------
def replay_ops(tree: LSMTree, ops) -> int:
    """Apply a typed WAL op stream (walog.SegmentedWAL.replay) to a tree in
    log order. Ops carry INTERNAL ids; the tree API takes original ids, so
    each op round-trips through the reversible hash. Returns ops applied.
    The caller must have suspended WAL logging on the tree."""
    iv = tree.intervals
    n = 0
    for op in ops:
        kind = op[0]
        if kind == "insert":
            _, s, d, t, cols = op
            tree.insert_edges(np.asarray(iv.to_original(s)),
                              np.asarray(iv.to_original(d)), etype=t,
                              columns=cols)
        elif kind == "delete":
            _, s, d = op
            tree.delete_edge(int(iv.to_original(s)), int(iv.to_original(d)))
        else:
            _, name, s, d, val = op
            tree.update_edge_column(int(iv.to_original(s)),
                                    int(iv.to_original(d)), name, val)
        n += 1
    return n


# ---------------------------------------------------------------------------
# GraphDB — the durable database directory
# ---------------------------------------------------------------------------
class GraphDB:
    """An LSM graph store that lives in a directory:

        dbdir/MANIFEST.json   atomically-renamed recovery root
        dbdir/wal/            segmented typed WAL (walog.SegmentedWAL)
        dbdir/parts/          content-addressed immutable partition files

    Merged partitions above `persist_min_edges` are flushed to disk as they
    are produced (the LSM's `partition_sink`) and replaced in the tree by
    mmap-backed `DiskPartition`s; smaller/hot top partitions stay in RAM
    and are covered by the WAL. `checkpoint()` persists everything, writes
    the manifest (recording the WAL offset it covers), and GCs unreferenced
    store files. Recovery (`GraphDB.open`) = manifest partitions + WAL
    replay from the recorded offset. Single writer per directory."""

    MANIFEST = "MANIFEST.json"

    def __init__(self, directory: str, tree: LSMTree, config: Dict[str, Any],
                 io: Optional[IOStats] = None):
        self.dir = directory
        self.io = io or IOStats()
        self.store = PartitionStore(directory, io=self.io,
                                    checksums=config.get("checksums", True))
        self.tree = tree
        self.config = config
        self.persist_min_edges = int(config.get("persist_min_edges", 4096))
        self.resident_budget_bytes = config.get("resident_budget_bytes")
        # integrity accounting (ISSUE 7): every detected corruption /
        # quarantine / rebuild is appended here — `integrity_report()`
        # surfaces what was lost vs recovered instead of serving garbage
        self.integrity_log: List[Dict[str, Any]] = []
        # per-partition touch recency (monotone clock) for LRU-first
        # eviction; partitions never touched sort oldest
        self._touch_clock = itertools.count(1)
        tree.partition_sink = self._sink
        # the engine calls this after it is done with a slab inside one
        # batched query, letting a budgeted store release decoded indexes
        # mid-batch instead of accumulating one per slab
        tree.release_slab = self._release_slab

    # -- lifecycle -------------------------------------------------------------
    @classmethod
    def create(
        cls,
        directory: str,
        max_id: int,
        n_partitions: int = 8,
        n_levels: int = 2,
        branching: int = 8,
        buffer_cap: int = 100_000,
        max_partition_edges: int = 2_000_000,
        column_dtypes: Optional[Dict[str, np.dtype]] = None,
        durable: bool = True,
        wal_sync: str = "commit",
        persist_min_edges: int = 4096,
        resident_budget_bytes: Optional[int] = None,
        wal_segment_bytes: int = 4 << 20,
        checksums: bool = True,
        wal_keep_history: bool = False,
    ) -> "GraphDB":
        os.makedirs(directory, exist_ok=True)
        if os.path.exists(os.path.join(directory, cls.MANIFEST)):
            raise FileExistsError(
                f"{directory} already holds a GraphDB — use GraphDB.open")
        iv = IntervalMap.for_capacity(max_id, n_partitions)
        column_dtypes = {k: np.dtype(v) for k, v in (column_dtypes or {}).items()}
        wal = (SegmentedWAL(os.path.join(directory, "wal"),
                            column_dtypes=column_dtypes, sync=wal_sync,
                            segment_bytes=wal_segment_bytes, crc=checksums)
               if durable else None)
        tree = LSMTree(
            iv, n_levels=n_levels, branching=branching, buffer_cap=buffer_cap,
            max_partition_edges=max_partition_edges,
            column_dtypes=column_dtypes, durable=durable,
            wal=wal, wal_sync=wal_sync)
        config = {
            "n_partitions": iv.n_partitions,
            "interval_len": iv.interval_len,
            "n_levels": n_levels,
            "branching": branching,
            "buffer_cap": buffer_cap,
            "max_partition_edges": max_partition_edges,
            "column_dtypes": {k: dt.str for k, dt in column_dtypes.items()},
            "durable": durable,
            "wal_sync": wal_sync,
            "persist_min_edges": persist_min_edges,
            "resident_budget_bytes": resident_budget_bytes,
            "wal_segment_bytes": wal_segment_bytes,
            "checksums": bool(checksums),
            "wal_keep_history": bool(wal_keep_history),
        }
        db = cls(directory, tree, config)
        db._write_manifest(wal_offset=db._wal_offset())
        return db

    @classmethod
    def open(cls, directory: str) -> "GraphDB":
        """Recover a GraphDB: manifest partitions + WAL tail replay."""
        mpath = os.path.join(directory, cls.MANIFEST)
        with open(mpath) as f:
            manifest = json.load(f)
        config = manifest["config"]
        iv = IntervalMap(n_partitions=config["n_partitions"],
                         interval_len=config["interval_len"])
        column_dtypes = {k: np.dtype(s)
                         for k, s in config["column_dtypes"].items()}
        wal = (SegmentedWAL(
                   os.path.join(directory, "wal"),
                   column_dtypes=column_dtypes, sync=config["wal_sync"],
                   segment_bytes=int(config.get("wal_segment_bytes", 4 << 20)),
                   crc=config.get("checksums", True))
               if config["durable"] else None)
        tree = LSMTree(
            iv, n_levels=config["n_levels"], branching=config["branching"],
            buffer_cap=config["buffer_cap"],
            max_partition_edges=config["max_partition_edges"],
            column_dtypes=column_dtypes, durable=config["durable"],
            wal=wal, wal_sync=config["wal_sync"])
        db = cls(directory, tree, config)
        lost = []
        for li, level in enumerate(manifest["levels"]):
            for pi, entry in enumerate(level):
                if entry is None:
                    continue
                try:
                    part = db._open_part(entry["digest"])
                except (CorruptionError, FileNotFoundError) as exc:
                    # a manifest-referenced partition is unreadable: move
                    # it out of the store (if it exists at all) and leave
                    # the slot's default empty partition — the WAL decides
                    # below whether the data is recoverable
                    db._quarantine_files(entry["digest"])
                    db.integrity_log.append({
                        "event": "quarantine", "digest": entry["digest"],
                        "interval": list(entry["interval"]),
                        "level": li, "slot": pi, "detail": str(exc),
                    })
                    lost.append(entry)
                    continue
                dead_path = os.path.join(db.store.dir,
                                         f"part_{entry['digest']}.dead.npy")
                if entry.get("dead") and os.path.exists(dead_path):
                    part.dead = np.load(dead_path)
                tree.levels[li][pi] = part
        legacy = os.path.join(directory, "wal.log")
        if wal is not None and os.path.exists(legacy):
            # pre-segmented-WAL database: its manifest's wal_offset indexes
            # wal.log. Replay the legacy tail WITH logging on (the records
            # re-enter the segmented WAL), retire the file, and checkpoint
            # so the manifest's offset re-anchors on the new log.
            s, d, ty = LSMTree.replay_wal(
                legacy, offset=int(manifest.get("wal_offset", 0)))
            if s.shape[0]:
                iv = tree.intervals
                tree.insert_edges(np.asarray(iv.to_original(s)),
                                  np.asarray(iv.to_original(d)), etype=ty)
            os.replace(legacy, legacy + ".migrated")
            db.checkpoint()
        elif lost and db._full_history_available():
            # quarantined partitions, but the WAL still reaches back to
            # offset 0: rebuild the WHOLE store from the log (surviving
            # partitions hold state the pre-compaction log also carries,
            # so they are dropped and re-derived — correctness over speed)
            db._rebuild_from_wal()
            db.integrity_log.append({
                "event": "rebuild", "recovered": [e["digest"] for e in lost],
            })
        else:
            db._replay_wal_tail(int(manifest.get("wal_offset", 0)))
            for e in lost:
                # compaction already dropped the log below the manifest
                # offset: the quarantined interval's pre-offset state is
                # gone. Report the unrecoverable range — never serve
                # silently-wrong (empty) data as if it were complete.
                db.integrity_log.append({
                    "event": "unrecoverable", "digest": e["digest"],
                    "interval": list(e["interval"]),
                    "n_edges_lost": int(e["n_edges"]),
                })
        # recovery installed partitions by direct slot assignment; publish
        # so epoch readers see the recovered store even with an empty tail
        tree.publish()
        return db

    def _wal_offset(self) -> int:
        if self.tree.wal is None:
            return 0
        self.tree.wal_flush(fsync=False)
        return self.tree.wal.tail_offset()

    def _replay_wal_tail(self, offset: int,
                         end: Optional[int] = None) -> None:
        """Apply the typed WAL tail in log order — inserts (with their
        attribute columns), tombstones, and column writes all replay, so
        recovery restores EVERY mutation since the covered offset, not just
        the edge triples (ISSUE 4 satellite: buffered columns survived)."""
        if self.tree.wal is None:
            return
        # the tail records are already in the WAL — re-applying must not
        # append them again, so logging is suspended for the replay
        wal, self.tree.wal = self.tree.wal, None
        try:
            replay_ops(self.tree, wal.replay(offset=offset, end=end))
        finally:
            self.tree.wal = wal

    # -- integrity: quarantine / rebuild / scrub (ISSUE 7) ---------------------
    def _quarantine_files(self, digest: str) -> List[str]:
        """Move a corrupt partition file (and its tombstone sidecar) out of
        the store into `dbdir/quarantine/` so nothing can re-open it. The
        bytes are preserved for forensics, not deleted."""
        qdir = os.path.join(self.dir, "quarantine")
        moved = []
        for fname in (f"part_{digest}.pal", f"part_{digest}.dead.npy"):
            src = os.path.join(self.store.dir, fname)
            if os.path.exists(src):
                os.makedirs(qdir, exist_ok=True)
                os.replace(src, os.path.join(qdir, fname))
                moved.append(fname)
        if moved:
            fsync_dir(self.store.dir)
        self.store._unsynced.discard(digest)
        return moved

    def _empty_slot(self, interval) -> EdgePartition:
        return build_partition(
            (int(interval[0]), int(interval[1])),
            np.empty(0, np.int64), np.empty(0, np.int64),
            columns={k: np.empty(0, dt)
                     for k, dt in self.tree.column_dtypes.items()})

    def quarantine(self, digest: str, detail: str = "corruption") -> bool:
        """Drop a live corrupt partition: quarantine its file, replace its
        tree slot with an empty partition, and publish — reads keep flowing
        from every surviving level (plus buffered/WAL-covered state) while
        the quarantined interval's persisted edges are reported, not served
        as garbage. The manifest is NOT rewritten here: the next checkpoint
        re-derives it, and a crash-before-then reopen re-detects the missing
        file and re-quarantines (or rebuilds from a full-history WAL)."""
        hit = False
        for li, level in enumerate(self.tree.levels):
            for pi, part in enumerate(level):
                if (isinstance(part, DiskPartition)
                        and os.path.basename(part.path)[5:-4] == digest):
                    entry = {
                        "event": "quarantine", "digest": digest,
                        "interval": [int(part.interval[0]),
                                     int(part.interval[1])],
                        "level": li, "slot": pi, "detail": detail,
                        "n_edges_lost": int(part.n_edges),
                    }
                    part.evict()
                    self.tree.levels[li][pi] = self._empty_slot(part.interval)
                    self.integrity_log.append(entry)
                    hit = True
        self._quarantine_files(digest)
        if hit:
            self.tree.publish()
        return hit

    def _full_history_available(self) -> bool:
        """True when the WAL still starts at offset 0 (never compacted past
        the first record) — the whole store is re-derivable from the log."""
        if self.tree.wal is None:
            return False
        segs = self.tree.wal.segments()
        return bool(segs) and int(segs[0][0]) == 0

    def _rebuild_from_wal(self) -> int:
        """Full-store rebuild: reset every level slot and buffer to empty,
        then replay the ENTIRE log from offset 0 (logging suspended).
        Only sound when `_full_history_available()`."""
        tree = self.tree
        for li, level in enumerate(tree.levels):
            for pi, part in enumerate(level):
                if isinstance(part, DiskPartition):
                    part.evict()
                tree.levels[li][pi] = self._empty_slot(part.interval)
        tree.buffers = [EdgeBuffer(tree.column_dtypes)
                        for _ in tree.levels[0]]
        tree._buffered = 0
        tree._pending = [[] for _ in tree.buffers]
        tree._inflight_edges = 0
        wal, tree.wal = tree.wal, None
        try:
            n = replay_ops(tree, wal.replay(offset=0))
        finally:
            tree.wal = wal
        tree.publish()
        return n

    def scrub(self, limit: Optional[int] = None) -> Dict[str, Any]:
        """Background integrity scrub: re-verify every section CRC of up to
        `limit` live partition files AND re-hash their content digests
        against the content address. Corrupt partitions are quarantined
        (reads keep flowing from survivors). Returns a report dict."""
        checked, quarantined = 0, []
        for part in list(self._disk_partitions()):
            if limit is not None and checked >= limit:
                break
            digest = os.path.basename(part.path)[5:-4]
            checked += 1
            try:
                # a fresh verifying open: touches every section (CRC check
                # on first touch) without disturbing the live partition's
                # caches, then re-derives the content address
                probe = open_partition_file(part.path, verify=True)
                try:
                    found = partition_digest(probe)
                finally:
                    probe.evict()
                if found != digest:
                    raise CorruptionError(
                        part.path,
                        f"content digest {found} != address {digest}")
            except CorruptionError as exc:
                quarantined.append(digest)
                self.quarantine(digest, detail=str(exc))
            except FileNotFoundError:
                quarantined.append(digest)
                self.quarantine(digest, detail="file missing")
        return {"checked": checked, "quarantined": quarantined}

    def integrity_report(self) -> Dict[str, Any]:
        """What corruption was seen, what was recovered, what was lost."""
        return {
            "events": list(self.integrity_log),
            "quarantined": [e["digest"] for e in self.integrity_log
                            if e["event"] == "quarantine"],
            "unrecoverable": [
                {"interval": e["interval"],
                 "n_edges_lost": e["n_edges_lost"]}
                for e in self.integrity_log
                if e["event"] == "unrecoverable"],
        }

    # -- the LSM partition sink -----------------------------------------------
    def _open_part(self, digest: str) -> DiskPartition:
        """Open a store partition with the db's residency policy: without a
        budget, pointer lookups decode once and stay cached (service-tier
        repeat queries); with one, they stay chunked-decode."""
        dp = self.store.open(digest)
        dp.index_resident = self.resident_budget_bytes is None
        return dp

    def _sink(self, level: int, j: int, part: EdgePartition) -> EdgePartition:
        """Called by the tree whenever a merge produces a new partition.
        Large partitions go to disk immediately (and come back mmapped);
        small hot ones stay in RAM, covered by the WAL until checkpoint."""
        if isinstance(part, DiskPartition) or part.n_edges < self.persist_min_edges:
            return part
        digest = self.store.put(part)
        dp = self._open_part(digest)
        self._touch(dp)
        self.maybe_evict()
        return dp

    # -- residency -------------------------------------------------------------
    def _disk_partitions(self) -> List[DiskPartition]:
        return [p for lv in self.tree.levels for p in lv
                if isinstance(p, DiskPartition)]

    def evict(self) -> None:
        for p in self._disk_partitions():
            p.evict()

    def _touch(self, part: EdgePartition) -> None:
        part._touch = next(self._touch_clock)

    def maybe_evict(self) -> None:
        """Evict LRU-first until the decoded/override cache fits the budget
        — partitions a recent query touched keep their caches; cold ones
        (oldest touch stamp, or never touched) give theirs up first. The
        old behavior dropped EVERY partition's cache the moment the total
        crossed the budget, churning the hot set on every merge."""
        budget = self.resident_budget_bytes
        if budget is None:
            return
        parts = self._disk_partitions()
        total = sum(p.cached_nbytes() for p in parts)
        if total <= budget:
            return
        for p in sorted(parts, key=lambda p: getattr(p, "_touch", 0)):
            if total <= budget:
                break
            c = p.cached_nbytes()
            if c:
                p.evict()
                # credit only what eviction actually reclaimed — RAM
                # overrides (dirty column/etype state) survive evict()
                total -= c - p.cached_nbytes()

    def _release_slab(self, part: EdgePartition) -> None:
        """With a residency budget, a batched query releases each slab's
        mappings (and any decoded cache) as soon as it is done with it —
        the pages a gather faulted in leave RSS before the next slab
        faults its own, so a whole-store batch peaks at ONE slab's
        footprint. Remapping is a cheap syscall and the kernel page cache
        stays warm. Every release also stamps touch recency, feeding the
        LRU order `maybe_evict` uses on the insert path."""
        if isinstance(part, DiskPartition):
            self._touch(part)
            if self.resident_budget_bytes is not None:
                part.evict()

    def resident_nbytes(self) -> Dict[str, int]:
        parts = self._disk_partitions()
        return {
            "pinned_index": sum(p.resident_nbytes() for p in parts),
            "cached": sum(p.cached_nbytes() for p in parts),
            "ram_partitions": sum(
                p.nbytes() for lv in self.tree.levels for p in lv
                if not isinstance(p, DiskPartition)),
            "buffers": sum(
                len(b) * 17 for b in self.tree.buffers),
            "on_disk": sum(p.nbytes() for p in parts),
        }

    # -- durability ------------------------------------------------------------
    def checkpoint(self) -> Dict[str, Any]:
        """Flush buffers, persist every non-empty partition, publish the
        manifest (atomic rename), GC unreferenced store files."""
        self.tree.flush_all()
        for li, level in enumerate(self.tree.levels):
            for pi, part in enumerate(level):
                if part.n_edges == 0:
                    continue
                if not isinstance(part, DiskPartition) or part.dirty:
                    digest = self.store.put(part)
                    dp = self._open_part(digest)
                    dp.dead = (None if part.dead is None
                               else np.asarray(part.dead))
                    self.tree.levels[li][pi] = dp
                    part = dp
                if part.dead is not None and part.dead.any():
                    self._write_dead_sidecar(
                        os.path.basename(part.path)[5:-4], part.dead)
        # the checkpoint swapped RAM/dirty partitions for fresh mmap-backed
        # ones; publish so new epoch readers pin the persisted state (and
        # the fresh `dead` refs get sealed before any further tombstone)
        self.tree.publish()
        # settle deferred fsyncs for every file the manifest will reference
        keep = {os.path.basename(p.path)[5:-4]
                for p in self._disk_partitions()}
        self.store.sync(keep)
        manifest = self._write_manifest(wal_offset=self._wal_offset())
        # deferred reclamation: files referenced by manifests that epoch
        # readers may still pin survive this GC round and fall out of the
        # keep-set once the last pin releases (core/manifest.py)
        self.store.gc({e["digest"] for lv in manifest["levels"]
                       for e in lv if e} | self.tree.pinned_digests())
        self._gc_dead_files(manifest)
        # WAL compaction: segments wholly below the covered offset carry
        # only state the manifest already persists. Snapshot sessions that
        # still need those bytes hold hard links — deleting here only drops
        # the store's name for the inode, never the session's.
        # `wal_keep_history` retains the full log instead: with checksums
        # on, the whole store is then re-derivable from offset 0, so a
        # corrupt partition can be REBUILT rather than reported lost
        # (ISSUE 7 — recoverability traded against log space).
        if (self.tree.wal is not None
                and not self.config.get("wal_keep_history")):
            self.tree.wal.compact(int(manifest["wal_offset"]))
        return manifest

    SNAPSHOT = "SNAPSHOT.json"

    def pin_snapshot(self, dest_dir: str,
                     pinned_offset: Optional[int] = None) -> Dict[str, Any]:
        """Pin the database's CURRENT logical state into `dest_dir` without
        copying data: hard-link the last published manifest's partition
        files (+ dead sidecars) and every WAL segment carrying records in
        [manifest.wal_offset, tail), then write SNAPSHOT.json recording the
        pinned tail offset. The linked inodes survive store GC and WAL
        compaction, so the session stays readable — and bitwise stable up
        to its pinned offset — no matter what the writer does next.
        Single-writer callers may call this directly; under concurrency the
        service tier (core/service.py) serializes it with mutations.

        `pinned_offset` pins at a PAST logical offset instead of the tail —
        the epoch-view bridge (ISSUE 8): passing a `ManifestView.wal_tail`
        yields a session whose replayed state equals that pinned view, so
        an in-process epoch becomes addressable from another process. The
        offset must be at or past the offset the on-disk manifest covers
        (an older one would need WAL bytes a later checkpoint may already
        have compacted away, and un-replaying a manifest is impossible)."""
        if self.tree.wal is None:
            raise ValueError("snapshots need a durable GraphDB (the WAL "
                             "covers RAM partitions and live buffers)")
        manifest = self._read_manifest()
        self.tree.wal_flush(fsync=False)
        if pinned_offset is None:
            pinned = self.tree.wal.tail_offset()
        else:
            pinned = int(pinned_offset)
            covered = int(manifest["wal_offset"])
            if pinned < covered:
                raise ValueError(
                    f"pinned_offset {pinned} predates the checkpointed "
                    f"manifest (covers WAL up to {covered}); a view that "
                    f"old cannot be reconstructed from the current store")
        os.makedirs(dest_dir)
        for lv in manifest["levels"]:
            for e in lv:
                if e is None:
                    continue
                self.store.link_into(e["digest"], dest_dir)
                if e.get("dead"):
                    _link_or_copy(
                        os.path.join(self.store.dir,
                                     f"part_{e['digest']}.dead.npy"),
                        os.path.join(dest_dir,
                                     f"part_{e['digest']}.dead.npy"))
        wal_dir = os.path.join(dest_dir, "wal")
        os.makedirs(wal_dir)
        covered = int(manifest["wal_offset"])
        for base, end, path in self.tree.wal.segments():
            if end > covered and base < pinned:
                _link_or_copy(path,
                              os.path.join(wal_dir, os.path.basename(path)))
        doc = dict(manifest)
        doc["pinned_offset"] = int(pinned)
        tmp = os.path.join(dest_dir, self.SNAPSHOT + ".tmp")
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        failpoint("snapshot.json.rename")
        os.replace(tmp, os.path.join(dest_dir, self.SNAPSHOT))
        fsync_dir(dest_dir)
        return doc

    def _write_dead_sidecar(self, digest: str, dead: np.ndarray) -> None:
        """Tombstones persist OUTSIDE the (content-addressed, immutable)
        partition file. Synced like the manifest: deletes are only durable
        at checkpoint, so the sidecar must actually be on disk before the
        manifest declares the WAL offset covered."""
        tmp = os.path.join(self.store.dir, f"part_{digest}.dead.npy.tmp")
        failpoint("dead.write")
        with open(tmp, "wb") as df:
            np.save(df, np.asarray(dead))
            df.flush()
            os.fsync(df.fileno())
        failpoint("dead.rename")
        os.replace(tmp, os.path.join(self.store.dir,
                                     f"part_{digest}.dead.npy"))
        fsync_dir(self.store.dir)

    def _gc_dead_files(self, manifest: Dict[str, Any]) -> None:
        live = {f"part_{e['digest']}.dead.npy"
                for lv in manifest["levels"] for e in lv
                if e and e.get("dead")}
        for fname in os.listdir(self.store.dir):
            if fname.endswith(".dead.npy") and fname not in live:
                os.remove(os.path.join(self.store.dir, fname))

    def _read_manifest(self) -> Dict[str, Any]:
        with open(os.path.join(self.dir, self.MANIFEST)) as f:
            return json.load(f)

    def _write_manifest(self, wal_offset: int) -> Dict[str, Any]:
        levels = []
        for level in self.tree.levels:
            entries = []
            for part in level:
                if isinstance(part, DiskPartition):
                    digest = os.path.basename(part.path)[5:-4]
                    entries.append({
                        "digest": digest,
                        "interval": [int(part.interval[0]), int(part.interval[1])],
                        "n_edges": part.n_edges,
                        "dead": bool(part.dead is not None and part.dead.any()),
                    })
                else:
                    entries.append(None)  # empty or RAM-only: WAL covers it
            levels.append(entries)
        manifest = {"config": self.config, "levels": levels,
                    "wal_offset": int(wal_offset)}
        tmp = os.path.join(self.dir, self.MANIFEST + ".tmp")
        failpoint("manifest.write")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        failpoint("manifest.rename")
        os.replace(tmp, os.path.join(self.dir, self.MANIFEST))
        fsync_dir(self.dir)
        return manifest

    def close(self) -> None:
        self.checkpoint()
        self.tree.close()
        self.evict()

    # -- delegation (GraphDB quacks like its tree) ------------------------------
    @property
    def intervals(self) -> IntervalMap:
        return self.tree.intervals

    @property
    def buffers(self):
        return self.tree.buffers

    @property
    def levels(self):
        return self.tree.levels

    @property
    def n_edges(self) -> int:
        return self.tree.n_edges

    def insert_edge(self, *a, **kw):
        return self.tree.insert_edge(*a, **kw)

    def insert_edges(self, *a, **kw):
        return self.tree.insert_edges(*a, **kw)

    def delete_edge(self, *a, **kw):
        return self.tree.delete_edge(*a, **kw)

    def update_edge_column(self, *a, **kw):
        return self.tree.update_edge_column(*a, **kw)

    def out_neighbors(self, v: int) -> np.ndarray:
        return self.tree.out_neighbors(v)

    def in_neighbors(self, v: int) -> np.ndarray:
        return self.tree.in_neighbors(v)

    def storage_engine(self):
        return self.tree.storage_engine()

    def read_view(self):
        """Pinned lock-free read view (core/manifest.py)."""
        return self.tree.read_view()

    def snapshot(self, **kw):
        return self.tree.snapshot(**kw)

    def all_partitions(self):
        return self.tree.all_partitions()

    def flush_all(self) -> None:
        self.tree.flush_all()

    def to_coo(self):
        return self.tree.to_coo()


# ---------------------------------------------------------------------------
# Figure-8 index readers: REAL counted block reads via os.pread
# ---------------------------------------------------------------------------
class RawDiskIndex:
    """Binary search over an on-disk sorted int64 array with block-granular
    `os.pread`s — the paper's "pointer array on disk" baseline. Every probe
    reads one real `block_size` block and counts it; RAM footprint is one
    block."""

    def __init__(self, path: str, offset: int, n: int, block_size: int = 4096):
        self.path = path
        self.offset = offset
        self.n = n
        self.block_size = block_size
        self.keys_per_block = block_size // 8
        self.n_blocks = -(-n // self.keys_per_block) if n else 0
        self.block_reads = 0
        self._fd = os.open(path, os.O_RDONLY)

    def _read_block(self, b: int) -> np.ndarray:
        self.block_reads += 1
        telemetry.counter("codec.block_reads").inc()
        lo = b * self.keys_per_block
        hi = min(lo + self.keys_per_block, self.n)
        raw = os.pread(self._fd, (hi - lo) * 8, self.offset + lo * 8)
        return np.frombuffer(raw, np.int64)

    def lookup(self, k: int) -> int:
        """Index of k, or -1 — a block-granular binary search, log₂(#blocks)
        real reads plus one for the final block."""
        lo_b, hi_b = 0, self.n_blocks - 1
        if self.n_blocks == 0:
            return -1
        while lo_b < hi_b:
            mid = (lo_b + hi_b + 1) // 2
            first = self._read_block(mid)[0]
            if first <= k:
                lo_b = mid
            else:
                hi_b = mid - 1
        blk = self._read_block(lo_b)
        i = int(np.searchsorted(blk, k))
        if i < blk.shape[0] and blk[i] == k:
            return lo_b * self.keys_per_block + i
        return -1

    def nbytes(self) -> int:
        return self.block_size  # one block buffer

    def close(self) -> None:
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1


class SparseDiskIndex:
    """The paper's sparse option with real I/O: every `stride`-th key is
    resident; a lookup is one RAM binary search + ONE real block read."""

    def __init__(self, path: str, offset: int, n: int, stride: int = 512,
                 block_size: int = 4096):
        self.raw = RawDiskIndex(path, offset, n, block_size=max(block_size,
                                                                stride * 8))
        self.stride = stride
        keys = np.memmap(path, np.int64, mode="r", offset=offset, shape=(n,))
        self.sparse = np.array(keys[::stride])
        del keys

    @property
    def block_reads(self) -> int:
        return self.raw.block_reads

    def lookup(self, k: int) -> int:
        j = int(np.searchsorted(self.sparse, k, side="right")) - 1
        j = max(j, 0)
        lo = j * self.stride
        hi = min(lo + self.stride, self.raw.n)
        self.raw.block_reads += 1
        telemetry.counter("codec.block_reads").inc()
        raw = os.pread(self.raw._fd, (hi - lo) * 8, self.raw.offset + lo * 8)
        blk = np.frombuffer(raw, np.int64)
        i = int(np.searchsorted(blk, k))
        if i < blk.shape[0] and blk[i] == k:
            return lo + i
        return -1

    def nbytes(self) -> int:
        return self.sparse.nbytes

    def close(self) -> None:
        self.raw.close()
