"""StorageEngine — the unified, vectorized read path over PAL / LSM storage.

DESIGN.md §5. The paper's promise is ONE structure serving both online
queries and analytical computation; this module is the interface that makes
the promise hold on both backends without the query layer knowing which one
it is talking to.

Primitives are *set-at-a-time*: a whole frontier of vertices goes in, a
CSR-grouped result comes out. Per storage slab (an immutable edge partition
on any LSM level, or a live in-memory edge buffer) the engine issues ONE
vectorized `searchsorted` of the frontier against the slab's pointer-array
(partitions) or staged sort order (buffers), expands the hit ranges without
a Python loop, and regroups the union by query vertex. This is the paper's
frontier-batched FoF strategy (§8.1) generalized to every traversal
operator.

Slab layout recap (why the binary searches below are correct):
  * a partition's edge-array is (src, dst)-sorted with a sparse CSR over
    sources (`src_vertices`/`src_ptr`) and a CSC permutation over
    destinations (`dst_vertices`/`dst_ptr`/`dst_perm`);
  * partitions on one level cover disjoint destination intervals, and each
    buffer feeds exactly one top-level partition — so in-edge queries may
    probe every slab: non-owners miss in O(log) with zero hits;
  * tombstoned edges (`dead`) are filtered after range expansion.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import telemetry

# per-interval read heat (ISSUE 9): every edge position a disk-tier slab
# serves is charged to its interval — the input the ROADMAP's heat-aware
# merge scheduling reads
_M_READ_HEAT = telemetry.counter("disk.interval.read_edges")

__all__ = [
    "EdgeBatch",
    "EdgeChunk",
    "StorageEngine",
    "PALEngine",
    "LSMEngine",
    "ManifestEngine",
    "SnapshotEngine",
    "as_engine",
]


# ---------------------------------------------------------------------------
# Result containers
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class EdgeBatch:
    """CSR-grouped result of a batched edge query: the edges adjacent to
    vs[i] occupy flat positions offsets[i]:offsets[i+1]. IDs are original."""

    vs: np.ndarray                  # (Q,) the queried vertices
    offsets: np.ndarray             # (Q+1,) int64
    src: np.ndarray                 # (T,) int64 original IDs
    dst: np.ndarray                 # (T,) int64 original IDs
    etype: np.ndarray               # (T,) int8
    columns: Dict[str, np.ndarray]  # requested attribute columns, positional

    def slice_of(self, i: int) -> slice:
        return slice(int(self.offsets[i]), int(self.offsets[i + 1]))


@dataclasses.dataclass
class EdgeChunk:
    """One physical slab of live edges in INTERNAL IDs — what bottom-up
    sweeps and degree passes stream instead of branching on storage class."""

    src: np.ndarray
    dst: np.ndarray


# ---------------------------------------------------------------------------
# Vectorized range machinery
# ---------------------------------------------------------------------------
def _expand_ranges(starts: np.ndarray, ends: np.ndarray,
                   owners: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenate [starts[k], ends[k]) ranges into one position array plus
    the owner id repeated per element — no Python loop. The classic
    cumsum-of-ones trick: within a run steps are +1; at each run boundary the
    step jumps to the next range's start."""
    counts = (ends - starts).astype(np.int64)
    nz = counts > 0
    if not nz.all():
        starts, counts, owners = starts[nz], counts[nz], owners[nz]
    if counts.size == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    cum = np.cumsum(counts)
    steps = np.ones(int(cum[-1]), np.int64)
    steps[0] = starts[0]
    steps[cum[:-1]] = starts[1:] - starts[:-1] - counts[:-1] + 1
    return np.cumsum(steps), np.repeat(owners, counts)


def _searchsorted_ranges(keys: np.ndarray,
                         vis: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """One binary search of the whole frontier against a slab's sorted key
    array. Returns (hit query indices, index into keys per hit)."""
    if keys.shape[0] == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    idx = np.searchsorted(keys, vis)
    idx = np.minimum(idx, keys.shape[0] - 1)
    hit = np.nonzero(keys[idx] == vis)[0]
    return hit, idx[hit]


# ---------------------------------------------------------------------------
# Slab adapters: one batched lookup protocol over partitions and buffers
# ---------------------------------------------------------------------------
class _PartitionSlab:
    def __init__(self, part):
        self.part = part
        self.interval = part.interval  # [lo, hi) of internal destinations
        # disk tier (core/disk.py): mmap-backed partitions carry IOStats;
        # every gather from the edge arrays below is a real page-cache read
        # of only the hit ranges, and we account the blocks it touches
        self.io = getattr(part, "io", None)
        self._heat_label = (f"{self.interval[0]}:{self.interval[1]}"
                            if self.io is not None else None)
        self.n_edges = part.n_edges
        # chunked-decode hook, resolved once (slabs are reused across a
        # manifest's whole pin lifetime): None for RAM partitions and for
        # disk partitions preferring their decoded resident index
        self.lookup = (None if getattr(part, "index_resident", False)
                       else getattr(part, "lookup_adj_ranges", None))

    def positions_batch(self, vis: np.ndarray,
                        direction: str) -> Tuple[np.ndarray, np.ndarray]:
        """(edge-array positions, query-owner index) of live adjacent edges.
        The searchsorted runs against the RAM-resident pointer index; only
        the hit ranges are then read from the (possibly mmapped) edge
        arrays."""
        part = self.part
        if self.n_edges == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        # disk partitions resolve ranges against their COMPRESSED resident
        # index (chunked decode of only the touched blocks) instead of the
        # fully-decoded pointer arrays
        lookup = self.lookup
        ranges = lookup(vis, direction) if lookup is not None else None
        if ranges is not None:
            hit, starts, ends = ranges
        elif direction == "out":
            hit, ki = _searchsorted_ranges(part.src_vertices, vis)
            starts, ends = part.src_ptr[ki], part.src_ptr[ki + 1]
        else:
            hit, ki = _searchsorted_ranges(part.dst_vertices, vis)
            starts, ends = part.dst_ptr[ki], part.dst_ptr[ki + 1]
        if direction == "out":
            pos, owner = _expand_ranges(starts, ends, hit)
        else:
            perm_pos, owner = _expand_ranges(starts, ends, hit)
            if self.io is not None:
                self.io.account_gather(perm_pos, 8)  # dst_perm read
            pos = np.asarray(part.dst_perm[perm_pos], np.int64)
        if part.dead is not None and pos.size:
            live = ~part.dead[pos]
            pos, owner = pos[live], owner[live]
        if self._heat_label is not None and pos.size:
            _M_READ_HEAT.inc(int(pos.size), label=self._heat_label)
        return pos, owner

    def src_at(self, pos):
        if self.io is not None:
            self.io.account_gather(pos, 8)
        return self.part.src[pos]

    def dst_at(self, pos):
        if self.io is not None:
            self.io.account_gather(pos, 8)
        return self.part.dst[pos]

    def etype_at(self, pos):
        if self.io is not None:
            self.io.account_gather(pos, 1)
        return self.part.etype[pos]

    def column_at(self, name, pos, dtype):
        col = self.part.columns.get(name)
        if col is None:
            return np.zeros(pos.shape[0], dtype)
        if self.io is not None:
            self.io.account_gather(pos, col.dtype.itemsize)
        return col[pos]

    def column_names(self):
        return self.part.columns.keys()

    def column_dtype(self, name):
        col = self.part.columns.get(name)
        return None if col is None else col.dtype

    def chunk(self) -> Optional[EdgeChunk]:
        part = self.part
        if part.n_edges == 0:
            return None
        if self.io is not None:  # sequential whole-slab scan: src + dst
            self.io.account_range(0, part.n_edges, 16)
        if part.dead is None or not part.dead.any():
            return EdgeChunk(part.src, part.dst)
        live = ~part.dead
        return EdgeChunk(part.src[live], part.dst[live])


class _BufferSlab:
    """Batched lookups over one frozen BufferStaging — a live buffer's
    current staging (snapped once per slab, i.e. once per batched call), a
    manifest-published staging, or an in-flight drained batch awaiting its
    merge commit. Sort-order caches live on the staging itself, shared by
    every slab (and thread) that reads it — the lazy build is idempotent."""

    def __init__(self, st, interval):
        self.interval = interval  # the fed top-level partition's interval
        self.st = st

    def positions_batch(self, vis: np.ndarray,
                        direction: str) -> Tuple[np.ndarray, np.ndarray]:
        st = self.st
        order, keys = (st.src_sorted_view() if direction == "out"
                       else st.dst_sorted_view())
        lo = np.searchsorted(keys, vis, side="left")
        hi = np.searchsorted(keys, vis, side="right")
        spos, owner = _expand_ranges(lo, hi, np.arange(vis.shape[0], dtype=np.int64))
        return order[spos], owner

    def src_at(self, pos):
        return self.st.src[pos]

    def dst_at(self, pos):
        return self.st.dst[pos]

    def etype_at(self, pos):
        return self.st.etype[pos]

    def column_at(self, name, pos, dtype):
        col = self.st.columns.get(name)
        if col is None:
            return np.zeros(pos.shape[0], dtype)
        return col[pos]

    def column_names(self):
        return self.st.columns.keys()

    def column_dtype(self, name):
        col = self.st.columns.get(name)
        return None if col is None else col.dtype

    def chunk(self) -> Optional[EdgeChunk]:
        if self.st.src.shape[0] == 0:
            return None
        return EdgeChunk(self.st.src, self.st.dst)


def _slab_positions(slab, vis: np.ndarray,
                    direction: str) -> Tuple[np.ndarray, np.ndarray]:
    """Probe one slab with the frontier. Destinations partition by interval,
    so for in-edge queries only the sub-frontier inside the slab's interval
    can hit — the rest is masked off before the binary search (a buffer or
    partition is never probed for vertices it cannot own)."""
    if direction == "in":
        lo, hi = slab.interval
        m = (vis >= lo) & (vis < hi)
        if not m.any():
            return np.empty(0, np.int64), np.empty(0, np.int64)
        sel = np.flatnonzero(m)
        pos, owner = slab.positions_batch(vis[sel], direction)
        return pos, sel[owner]
    return slab.positions_batch(vis, direction)


def _group(chunks: List[np.ndarray], owners: List[np.ndarray],
           n_queries: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Regroup concatenated per-slab hits by query vertex. Returns
    (stable sort order over the concatenation, owner per element, offsets)."""
    offsets = np.zeros(n_queries + 1, np.int64)
    if not chunks:
        return np.empty(0, np.int64), np.empty(0, np.int64), offsets
    owner = np.concatenate(owners)
    order = np.argsort(owner, kind="stable")
    counts = np.bincount(owner, minlength=n_queries)
    np.cumsum(counts, out=offsets[1:])
    return order, owner, offsets


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------
class StorageEngine:
    """Vectorized set-at-a-time read interface over a graph store.

    Subclasses provide `_slabs()`; everything else is shared. All public
    methods take and return ORIGINAL vertex IDs (the reversible hash is
    applied at the boundary, paper §7.2).
    """

    #: hop-execution modes this engine can serve (core/multihop.py checks
    #: before choosing one): "sparse" = per-slab probes via expand_frontier;
    #: "stream" = whole-store edge_chunks sweeps; "kernel" = dense Pallas
    #: plans built from the full edge set. Engines that cannot enumerate
    #: every edge cheaply (the sharded scatter/gather engine — shipping the
    #: whole edge set over IPC per hop would drown the win) restrict this
    #: to ("sparse",) and the density heuristic clamps to it.
    supported_hop_modes: Tuple[str, ...] = ("sparse", "stream", "kernel")

    def __init__(self, graph):
        self.graph = graph

    @property
    def intervals(self):
        return self.graph.intervals

    @property
    def n_internal_vertices(self) -> int:
        return self.graph.intervals.max_vertices

    def _slabs(self) -> Iterator:
        raise NotImplementedError

    # -- batched traversal primitives ----------------------------------------
    def out_neighbors_batch(self, vs: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        """Out-neighbors of every v in vs. Returns (values, offsets):
        values[offsets[i]:offsets[i+1]] are vs[i]'s out-neighbors."""
        return self._neighbors_batch(vs, "out")

    def in_neighbors_batch(self, vs: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        return self._neighbors_batch(vs, "in")

    def expand_frontier(self, vs, direction: str = "out", predicate=None,
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Flat one-hop expansion: (owner index into vs, neighbor) pairs in
        ORIGINAL ids, UNGROUPED and in no particular order.

        This is the multi-hop fast path (core/multihop.py): operators that
        immediately re-sort the union by packed (owner, neighbor) keys do not
        need `_neighbors_batch`'s stable per-vertex regrouping, so the
        argsort over the whole hit set is skipped entirely.

        `predicate` is pushed into the slab scan: an object with
        `mask(slab, pos) -> bool array` evaluated on edge-array positions
        BEFORE the destination gather, so non-matching edges never
        materialize into the result (only their positions are touched).
        """
        vs = np.asarray(vs, dtype=np.int64).ravel()
        iv = self.intervals
        vis = np.asarray(iv.to_internal(vs))
        release = getattr(self.graph, "release_slab", None)
        vals, owners = [], []
        for slab in self._slabs():
            pos, owner = _slab_positions(slab, vis, direction)
            if pos.size and predicate is not None:
                keep = predicate.mask(slab, pos)
                pos, owner = pos[keep], owner[keep]
            if pos.size:
                vals.append(slab.dst_at(pos) if direction == "out"
                            else slab.src_at(pos))
                owners.append(owner)
            if release is not None:
                part = getattr(slab, "part", None)
                if part is not None:
                    release(part)
        if not vals:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        flat = np.concatenate(vals)
        return (np.concatenate(owners),
                np.asarray(iv.to_original(flat), np.int64))

    def out_degree_batch(self, vs) -> np.ndarray:
        return self._degree_batch(vs, "out")

    def in_degree_batch(self, vs) -> np.ndarray:
        return self._degree_batch(vs, "in")

    def _degree_batch(self, vs, direction: str) -> np.ndarray:
        """Live-edge degree per query vertex (multi-edges counted) without
        gathering a single endpoint: positions are counted per owner right
        after the range expansion, so the cost is the pointer-index probes
        plus one bincount per slab."""
        vs = np.asarray(vs, dtype=np.int64).ravel()
        vis = np.asarray(self.intervals.to_internal(vs))
        deg = np.zeros(vs.shape[0], np.int64)
        release = getattr(self.graph, "release_slab", None)
        for slab in self._slabs():
            pos, owner = _slab_positions(slab, vis, direction)
            if pos.size:
                deg += np.bincount(owner, minlength=vs.shape[0])
            if release is not None:
                part = getattr(slab, "part", None)
                if part is not None:
                    release(part)
        return deg

    # -- derived-plan memoization (dense frontier plans, edge-key sets) ------
    def plan_cache(self) -> Dict:
        """Mutable memo dict for whole-store derived read structures
        (core/multihop.py dense plans, packed edge-key sets). Entries are
        keyed by `cache_token()` so a stale plan is never served after the
        store mutates; engines over immutable state share the dict across
        readers (idempotent fills, same contract as the manifest cache)."""
        cache = getattr(self, "_plan_cache", None)
        if cache is None:
            cache = self._plan_cache = {}
        return cache

    def cache_token(self):
        """Content fingerprint for plan keying, or None when the store
        cannot be fingerprinted (disables caching, never staleness)."""
        g = self.graph
        epochs = getattr(g, "epochs", None)
        if epochs is not None:
            cur = epochs.current
            if cur is not None:
                return ("epoch", cur.version)
        n_edges = getattr(g, "n_edges", None)
        buffered = getattr(g, "total_buffered", None)
        if n_edges is None:
            return None
        return ("edges", int(n_edges),
                int(buffered()) if buffered is not None else 0)

    def _neighbors_batch(self, vs, direction: str):
        vs = np.asarray(vs, dtype=np.int64).ravel()
        iv = self.intervals
        vis = np.asarray(iv.to_internal(vs))
        # disk tier: a batch probes EVERY slab, so a store with a residency
        # budget can release each slab's decoded index/mmaps as soon as the
        # batch is done with it (all reads for a slab happen in its loop
        # iteration; the gathered results are copies)
        release = getattr(self.graph, "release_slab", None)
        vals, owners = [], []
        for slab in self._slabs():
            pos, owner = _slab_positions(slab, vis, direction)
            if pos.size:
                vals.append(slab.dst_at(pos) if direction == "out"
                            else slab.src_at(pos))
                owners.append(owner)
            if release is not None:
                part = getattr(slab, "part", None)
                if part is not None:
                    release(part)
        order, _, offsets = _group(vals, owners, vs.shape[0])
        if order.size == 0:
            return np.empty(0, np.int64), offsets
        flat = np.concatenate(vals)[order]
        return np.asarray(iv.to_original(flat), np.int64), offsets

    def edge_columns_batch(self, vs: Sequence[int],
                           names: Optional[Sequence[str]] = None,
                           direction: str = "out") -> EdgeBatch:
        """Adjacent edges of every v in vs with their attribute columns —
        the set-at-a-time analogue of the paper's positional column reads
        (§4.3), grouped CSR-style by query vertex."""
        vs = np.asarray(vs, dtype=np.int64).ravel()
        iv = self.intervals
        vis = np.asarray(iv.to_internal(vs))
        slabs = list(self._slabs())
        # declared dtypes (LSM) or whatever columns the slabs carry (PAL)
        dtypes = dict(getattr(self.graph, "column_dtypes", {}) or {})
        if names is None:
            names = list(dtypes) or sorted(
                {k for s in slabs for k in s.column_names()})

        def dtype_of(name):
            if name in dtypes:
                return dtypes[name]
            for s in slabs:
                dt = s.column_dtype(name)
                if dt is not None:
                    return dt
            return np.float64

        hits = []  # (slab, pos, owner)
        for slab in slabs:
            pos, owner = _slab_positions(slab, vis, direction)
            if pos.size:
                hits.append((slab, pos, owner))
        order, _, offsets = _group([h[1] for h in hits],
                                   [h[2] for h in hits], vs.shape[0])
        if order.size == 0:
            return EdgeBatch(vs, offsets, np.empty(0, np.int64),
                             np.empty(0, np.int64), np.empty(0, np.int8),
                             {k: np.empty(0, dtype_of(k)) for k in names})
        src = np.concatenate([s.src_at(p) for s, p, _ in hits])[order]
        dst = np.concatenate([s.dst_at(p) for s, p, _ in hits])[order]
        etype = np.concatenate([s.etype_at(p) for s, p, _ in hits])[order]
        columns = {}
        for k in names:
            dt = dtype_of(k)
            columns[k] = np.concatenate(
                [s.column_at(k, p, dt) for s, p, _ in hits])[order]
        release = getattr(self.graph, "release_slab", None)
        if release is not None:
            for slab in slabs:
                part = getattr(slab, "part", None)
                if part is not None:
                    release(part)
        return EdgeBatch(
            vs, offsets,
            np.asarray(iv.to_original(src), np.int64),
            np.asarray(iv.to_original(dst), np.int64),
            etype, columns,
        )

    # -- whole-store streaming (bottom-up sweeps, degree passes) -------------
    def edge_chunks(self) -> Iterator[EdgeChunk]:
        """Stream every live edge once, slab by slab, in internal IDs."""
        for slab in self._slabs():
            chunk = slab.chunk()
            if chunk is not None and chunk.src.shape[0]:
                yield chunk

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        return self.graph.to_coo()


class PALEngine(StorageEngine):
    """StorageEngine over a bulk-built GraphPAL (one slab per partition)."""

    def _slabs(self):
        for part in self.graph.partitions:
            yield _PartitionSlab(part)


class LSMEngine(StorageEngine):
    """StorageEngine over a live LSMTree: every partition of every level,
    the in-memory edge buffers (newest data, staged sorted views), and any
    drained batches whose merge is still in flight on the maintenance
    pipeline (`pending_stagings`) — a mid-merge batch is visible exactly
    once: as a pending slab before its commit, in the merged partitions
    after."""

    def _slabs(self):
        for level in self.graph.levels:
            for part in level:
                yield _PartitionSlab(part)
        pending = getattr(self.graph, "pending_stagings", None)
        if pending is not None:
            for st, interval in pending():
                if st.src.shape[0]:
                    yield _BufferSlab(st, interval)
        for buf, top in zip(self.graph.buffers, self.graph.levels[0]):
            if len(buf):
                yield _BufferSlab(buf.staging(), top.interval)


class ManifestEngine(StorageEngine):
    """StorageEngine over a pinned `ManifestView` (core/manifest.py) — the
    LOCK-FREE live read path. Slabs come from one published manifest:
    partition proxies carrying publication-time tombstone arrays, plus the
    frozen buffer/pending stagings. Everything is immutable for the pin's
    lifetime, so any number of reader threads share one view (and its lazy
    sort/index caches) with zero coordination with the writer, merges,
    checkpoints, or GC. There is deliberately no release hook: views do
    not evict — reclamation is the epoch guard's job."""

    def _slabs(self):
        m = self.graph.manifest
        return m.derived("slabs", lambda: (
            [_PartitionSlab(mp) for lv in m.levels for mp in lv]
            + [_BufferSlab(st, interval)
               for st, interval in m.staging_slabs()]))

    def plan_cache(self):
        # derived plans live on the manifest itself: shared by every reader
        # of this publication, dropped wholesale when the writer republishes
        return self.graph.manifest.cache

    def cache_token(self):
        return ("manifest",)  # one manifest == one immutable edge set


class SnapshotEngine(LSMEngine):
    """Engine over a pinned `Snapshot`'s private tree (core/service.py).

    Same slab protocol as the live LSM engine, but the backing state is
    immutable for the session's whole lifetime: there is no release hook
    (the snapshot tree carries no residency budget), so decoded caches and
    staged sort orders persist across batches — a session issuing many
    frontier queries pays each slab's index materialization once. Mutation
    never reaches here; `Snapshot` exposes no write methods."""

    writable = False


def as_engine(g) -> StorageEngine:
    """Coerce a graph store (or an engine) to its StorageEngine — the only
    dispatch point; the query layer never inspects storage classes."""
    if isinstance(g, StorageEngine):
        return g
    maker = getattr(g, "storage_engine", None)
    if maker is None:
        raise TypeError(
            f"{type(g).__name__} exposes no storage_engine(); expected a "
            "GraphPAL, LSMTree, or StorageEngine")
    return maker()
