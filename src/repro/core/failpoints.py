"""Deterministic fault-injection failpoints (ISSUE 7 tentpole).

Every I/O boundary in the storage and service tiers carries a *named
injection site* — a `failpoint("site.name")` call that is a near-free dict
probe when nothing is armed, and fires a configured fault when it is. The
torture suite (tests/test_torture.py, benchmarks/bench_torture.py)
enumerates crash points along the ingest→merge→checkpoint→GC schedule by
arming one site at a time in a subprocess; unit tests arm errno faults to
drive the quarantine / read-only / recovery paths deterministically.

Sites and policies:

  * The **catalog** (`CATALOG`) is the closed set of legal site names with
    a one-line description each. `failpoint()` on an uncataloged name is a
    programming error (raises immediately), so the catalog can't drift
    from the code — and `scripts/check_failpoints.py` lints that every
    cataloged site is exercised by at least one test.
  * **Trigger policies** — a site fires its action when its hit counter
    satisfies the armed spec:
      - fire-once (the default: `count=1`),
      - fire-after-N (`after=N` skips the first N hits),
      - fire-K-times (`count=K`, or `count=None` for every hit),
      - seeded probability (`prob=p, seed=s`: an armed site carries its own
        `random.Random(seed)` so a run is reproducible from the seed).
  * **Actions**:
      - `"crash"`  — `os._exit(CRASH_EXIT_CODE)`: the process dies at the
        injection point with no cleanup, `atexit`, or buffer flushing —
        the closest a test can get to pulling the power,
      - `"errno:ENOSPC"` (any errno name) — raise `OSError(errno, ...)`
        exactly as the syscall under the site would,
      - `"raise"` — raise `FailpointError` (a typed, catchable fault),
      - `"delay:50"` / `"stall:50"` — sleep that many MILLISECONDS at the
        site, then continue (ISSUE 10): the gray-failure injector. A
        crash or errno models a dead component; a delay models the far
        more common *slow* one — the latency-chaos harness
        (benchmarks/bench_chaos.py) arms `shard.worker.op=delay:50` with
        a seeded probability to make one shard's tail heavy while every
        byte stays correct. `stall` is an alias of `delay` (reads better
        when the injected latency exceeds the caller's timeout),
      - any callable — invoked with the site name (custom behaviors).

Arming:

  * In-process: `fp_set("wal.append.fsync", "errno:ENOSPC", count=None)`,
    then `fp_clear()` (every test must clear; `fp_clear` is idempotent).
  * Across a process boundary (the torture harness): the environment
    variable `GRAPHDB_FAILPOINTS` is parsed at import time. Grammar, sites
    separated by `;`:

        site=action[@after][xcount]

    e.g. `GRAPHDB_FAILPOINTS="part.write.rename=crash@2"` crashes the
    process the 3rd time a partition-file rename is attempted, and
    `wal.append.write=errno:ENOSPC@0x0` arms ENOSPC on every WAL write
    (`x0` = unlimited count).

Hit counters (`fp_hits`) count every evaluation of an armed OR unarmed
site, letting regression tests assert that a code path actually crossed
an injection site (e.g. "the manifest publish fsynced its directory").
Counting only starts after `fp_trace(True)`/arming to keep the fast path
free for production use.
"""
from __future__ import annotations

import errno as _errno
import os
import random
import threading
import time
from typing import Callable, Dict, Optional, Union

__all__ = [
    "CATALOG",
    "CRASH_EXIT_CODE",
    "FailpointError",
    "failpoint",
    "fp_set",
    "fp_clear",
    "fp_hits",
    "fp_trace",
    "fp_armed",
]

# The exit code a "crash" action dies with — the torture harness asserts it
# to distinguish an injected crash from an ordinary failure.
CRASH_EXIT_CODE = 41

ENV_VAR = "GRAPHDB_FAILPOINTS"


class FailpointError(RuntimeError):
    """The typed fault the `"raise"` action injects."""

    def __init__(self, site: str):
        super().__init__(f"injected failpoint: {site}")
        self.site = site


# ---------------------------------------------------------------------------
# The catalog: every legal injection site, with where it lives
# ---------------------------------------------------------------------------
CATALOG: Dict[str, str] = {
    # --- segmented WAL (core/walog.py) ---
    "wal.append.write":    "record bytes written to the active segment",
    "wal.append.fsync":    "fsync of the active segment (sync=always/flush)",
    "wal.segment.create":  "new segment file created + header fsynced",
    "wal.segment.rotate":  "sealing fsync of a full segment before rotation",
    "wal.compact.unlink":  "deletion of a fully-covered segment",
    # --- partition files (core/disk.py) ---
    "part.write.body":     "partition-file section bytes written to the tmp",
    "part.write.fsync":    "partition-file fsync before publication",
    "part.write.rename":   "atomic rename publishing a partition file",
    "part.read.section":   "eager pread of a pinned section (gamma blobs)",
    "store.gc.unlink":     "deletion of an unreferenced store file",
    "store.link":          "hard-link of a store file (checkpoint/snapshot)",
    # --- manifest + sidecars (core/disk.py) ---
    "manifest.write":      "MANIFEST.json tmp written + fsynced",
    "manifest.rename":     "atomic rename publishing MANIFEST.json",
    "dead.write":          "tombstone sidecar tmp written + fsynced",
    "dead.rename":         "atomic rename publishing a tombstone sidecar",
    "dir.fsync":           "fsync of a parent directory after a rename",
    # --- snapshot pins (core/disk.py) ---
    "snapshot.json.rename": "atomic rename publishing SNAPSHOT.json",
    # --- maintenance pipeline (core/service.py) ---
    "service.flush.merge":  "a pipelined flush job's merge+persist stage",
    "service.ckpt.phaseA":  "checkpoint phase A per-partition persist",
    "service.ckpt.phaseB":  "checkpoint phase B exclusive commit",
    "service.scrub":        "background scrub of one partition file",
    # --- shard router / worker IPC (core/shardrouter.py) ---
    "shard.rpc.send":       "a frame about to be written to a shard socket",
    "shard.rpc.recv":       "a received frame's header+checksum verification",
    "shard.worker.op":      "a shard worker dispatching one decoded request",
    "shard.worker.serve":   "a spawned shard worker entering its accept loop",
    # --- serving front end (core/frontdesk.py) ---
    "frontdesk.dispatch":   "a front-desk dispatcher executing one batch",
}


# ---------------------------------------------------------------------------
# Armed-spec state
# ---------------------------------------------------------------------------
class _Spec:
    __slots__ = ("action", "after", "count", "prob", "rng", "fired")

    def __init__(self, action, after: int, count: Optional[int],
                 prob: Optional[float], seed: Optional[int]):
        self.action = action
        self.after = int(after)
        self.count = count  # None = unlimited
        self.prob = prob
        self.rng = random.Random(seed) if prob is not None else None
        self.fired = 0


_LOCK = threading.Lock()
_ARMED: Dict[str, _Spec] = {}
_HITS: Dict[str, int] = {}
_TRACING = False


def fp_trace(on: bool = True) -> None:
    """Enable hit counting for UNARMED sites too (tests asserting a code
    path crossed a site without injecting any fault)."""
    global _TRACING
    with _LOCK:
        _TRACING = bool(on)
        if not on:
            _HITS.clear()


def fp_armed(name: str) -> bool:
    return name in _ARMED


def fp_set(name: str, action: Union[str, Callable], after: int = 0,
           count: Optional[int] = 1, prob: Optional[float] = None,
           seed: Optional[int] = None) -> None:
    """Arm a site. `after` hits are skipped, then the action fires on up to
    `count` subsequent hits (None = every hit), each gated by `prob` when
    given (seeded — reproducible)."""
    if name not in CATALOG:
        raise KeyError(f"unknown failpoint {name!r} — add it to "
                       f"failpoints.CATALOG")
    with _LOCK:
        _ARMED[name] = _Spec(action, after, count, prob, seed)


def fp_clear(name: Optional[str] = None) -> None:
    with _LOCK:
        if name is None:
            _ARMED.clear()
            _HITS.clear()
        else:
            _ARMED.pop(name, None)
            _HITS.pop(name, None)


def fp_hits(name: str) -> int:
    with _LOCK:
        return _HITS.get(name, 0)


def _run_action(action, name: str):
    if callable(action):
        return action(name)
    if action == "crash":
        # no cleanup, no atexit, no flushing — the power-pull analogue
        os._exit(CRASH_EXIT_CODE)
    if action == "raise":
        raise FailpointError(name)
    if isinstance(action, str) and action.startswith("errno:"):
        code = getattr(_errno, action[6:])
        raise OSError(code, f"injected {action[6:]} at failpoint {name}")
    if isinstance(action, str) and (action.startswith("delay:")
                                    or action.startswith("stall:")):
        # injected latency, in milliseconds — the site then proceeds
        # normally (the work completes, just late: a gray failure)
        time.sleep(float(action.partition(":")[2]) / 1e3)
        return
    raise ValueError(f"unknown failpoint action {action!r} at {name}")


def failpoint(name: str) -> None:
    """The injection site. Near-free when nothing is armed (one dict probe
    on an empty dict); evaluates the armed spec otherwise."""
    if not _ARMED and not _TRACING:
        if __debug__ and name not in CATALOG:
            raise KeyError(f"uncataloged failpoint site {name!r}")
        return
    with _LOCK:
        if __debug__ and name not in CATALOG:
            raise KeyError(f"uncataloged failpoint site {name!r}")
        if _TRACING or name in _ARMED:
            _HITS[name] = _HITS.get(name, 0) + 1
        spec = _ARMED.get(name)
        if spec is None:
            return
        hit = _HITS[name]
        if hit <= spec.after:
            return
        if spec.count is not None and spec.fired >= spec.count:
            return
        if spec.rng is not None and spec.rng.random() >= spec.prob:
            return
        spec.fired += 1
        action = spec.action
    # run the action OUTSIDE the lock: a crash holds nothing, and a raised
    # fault must not leave the registry lock held for other threads
    _run_action(action, name)


# ---------------------------------------------------------------------------
# Environment arming (the torture harness's cross-process channel)
# ---------------------------------------------------------------------------
def _parse_env(value: str) -> None:
    """`site=action[@after][xcount]` separated by `;`. `x0` = unlimited."""
    for entry in value.split(";"):
        entry = entry.strip()
        if not entry:
            continue
        name, _, rhs = entry.partition("=")
        after, count = 0, 1
        if "x" in rhs.rpartition("@")[2] or ("@" not in rhs and
                                             rhs.rpartition("x")[2].isdigit()):
            rhs, _, c = rhs.rpartition("x")
            count = None if c == "0" else int(c)
        if "@" in rhs:
            rhs, _, a = rhs.rpartition("@")
            after = int(a)
        fp_set(name.strip(), rhs.strip(), after=after, count=count)


if os.environ.get(ENV_VAR):
    _parse_env(os.environ[ENV_VAR])
