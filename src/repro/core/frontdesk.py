"""Admission-controlled serving front end (ISSUE 10 tentpole).

The paper's online-graph-database claim (§5-6) is not just throughput —
it is throughput under load WITHOUT unbounded queues. `FrontDesk` is the
serving layer in front of a store (a `ServiceDB` or a `ShardRouter`)
that turns many concurrent point requests into the engine's
set-at-a-time batched reads while refusing, typed and in microseconds,
any request it predicts it cannot finish in time:

  * **Bounded queue, typed shedding.** One FIFO request queue with a
    hard cap. Admission sheds with `OverloadError` — `queue_full` when
    the cap is hit, `queue_delay` when the EWMA-estimated drain time
    already exceeds the request's remaining deadline budget, `read_only`
    / `backpressure` for writes the backing `ServiceDB` could not accept
    (its `admission_state()`), `closed` after shutdown. Shedding happens
    in the submitting thread BEFORE enqueue: the caller learns in
    microseconds, and no doomed work ever occupies a dispatcher.
  * **Coalescing.** Dispatcher threads drain the queue in same-kind
    batches: concurrent `out_neighbors`/`in_neighbors` point lookups
    become one `*_neighbors_batch` slab sweep, `fof` requests one
    `multihop.two_hop_counts` seed batch, `getrange` one
    `edge_columns_batch`, and inserts one grouped `insert_edges` — the
    set-at-a-time engine surface (DESIGN.md §10) doing for serving what
    it already did for analytics. Batch results come back in canonical
    sorted order, so answers are independent of batching, hedging, and
    shard merge history (the chaos bench's bitwise gate).
  * **Deadline discipline.** Every request carries a `Deadline`
    (explicit, ambient `deadline_scope`, or the configured default). It
    is checked at admission, re-checked when the dispatcher picks the
    request up (a request that expired while queued is answered
    `DeadlineExceeded` without touching the engine), scoped around the
    engine call (shard RPCs under it inherit the budget — timeouts,
    retry pacing, hedges), and checked once more at delivery: a result
    that arrives past its deadline is replaced by `DeadlineExceeded`,
    so NO request ever completes late without a typed error.
  * **Engine scope.** Over a `ServiceDB` each batch reads one epoch view
    (lock-free pin); over a `ShardRouter` batches use the live hedged
    scatter/gather engine — per-op pins, first-response-wins hedging
    (pinned cross-shard views are connection-scoped and must not cross
    dispatcher threads).

The dispatcher crosses the `frontdesk.dispatch` failpoint per batch, so
the chaos suite can inject dispatcher-side latency; every decision is
counted in the `frontdesk.*` telemetry catalog.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

import numpy as np

from . import telemetry
from .deadline import Deadline, current_deadline, deadline_scope
from .failpoints import failpoint
from .integrity import DeadlineExceeded, OverloadError

__all__ = ["FrontDesk", "FrontDeskStats"]

_M_REQS = telemetry.counter("frontdesk.requests")
_M_SHEDS = telemetry.counter("frontdesk.sheds")
_M_BATCHES = telemetry.counter("frontdesk.batches")
_M_BATCHED = telemetry.counter("frontdesk.batched_ops")
_M_QUEUE_S = telemetry.histogram("frontdesk.queue.seconds")
_M_DEPTH = telemetry.gauge("frontdesk.depth")
_M_DEADLINE = telemetry.counter("request.deadline_exceeded")

_READ_OPS = ("out_neighbors", "in_neighbors", "fof", "getrange")
_OPS = _READ_OPS + ("insert",)


@dataclasses.dataclass
class FrontDeskStats:
    admitted: int = 0
    shed: int = 0
    batches: int = 0
    batched_ops: int = 0
    deadline_misses: int = 0    # typed-late: queued-past or delivered-past


class _Req:
    __slots__ = ("op", "args", "deadline", "future", "t_enq")

    def __init__(self, op: str, args: Dict[str, Any],
                 deadline: Optional[Deadline]):
        self.op = op
        self.args = args
        self.deadline = deadline
        self.future: Future = Future()
        self.t_enq = time.perf_counter()


class FrontDesk:
    """The admission-controlled request front end (module docstring).

    `submit(op, deadline=..., **args)` returns a `concurrent.futures.
    Future`; the sync helpers (`out_neighbors`, `in_neighbors`,
    `friends_of_friends`, `getrange`, `insert_edges`) submit and wait.
    Admission failures raise synchronously in the submitting thread
    (`OverloadError` / `DeadlineExceeded`); failures after admission are
    delivered through the future, always typed.
    """

    def __init__(self, store, queue_cap: int = 1024, max_batch: int = 256,
                 dispatchers: int = 1,
                 default_deadline_s: Optional[float] = None,
                 drain_ewma_alpha: float = 0.2):
        self.store = store
        self.queue_cap = int(queue_cap)
        self.max_batch = int(max_batch)
        self.default_deadline_s = default_deadline_s
        self.stats = FrontDeskStats()
        self._alpha = float(drain_ewma_alpha)
        self._req_s_ewma = 0.0          # EWMA seconds per admitted request
        self._adm_cache = (-1e9, None)  # (monotonic, admission_state doc)
        self._q: deque = deque()
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._closed = False
        self._threads = [
            threading.Thread(target=self._run, name=f"frontdesk-{i}",
                             daemon=True)
            for i in range(max(1, int(dispatchers)))
        ]
        for t in self._threads:
            t.start()

    # -- admission (submitting thread) ----------------------------------------
    def _shed(self, reason: str, detail: str = "") -> None:
        _M_SHEDS.inc(label=reason)
        self.stats.shed += 1
        raise OverloadError(reason, detail)

    def _write_admission(self) -> None:
        """Shed writes the backing service could not accept — read-only
        degradation and writer backpressure (ISSUE 5/7 machinery) become
        front-door sheds instead of a blocked dispatcher. Polled state is
        briefly cached: admission must stay microseconds."""
        poll = getattr(self.store, "admission_state", None)
        if poll is None:
            return  # ShardRouter: each worker enforces its own bounds
        now = time.monotonic()
        if now - self._adm_cache[0] > 0.05:
            self._adm_cache = (now, poll())
        adm = self._adm_cache[1]
        if adm is None or adm.get("accepting_writes", True):
            return
        if adm.get("read_only"):
            self._shed("read_only", str(adm.get("read_only_reason") or ""))
        self._shed("backpressure",
                   f"backlog {adm.get('backlog_edges')} > bound "
                   f"{adm.get('backpressure_edges')}")

    def _estimated_queue_delay(self, depth: int) -> float:
        """Predicted time until a request admitted NOW gets dispatched:
        queue depth x the EWMA per-request service time, split across
        dispatchers. Zero until the first batch completes — the front
        desk never sheds on a cold estimate."""
        return depth * self._req_s_ewma / max(1, len(self._threads))

    def submit(self, op: str, deadline: Optional[Deadline] = None,
               **args) -> Future:
        if op not in _OPS:
            raise ValueError(f"unknown front-desk op {op!r} "
                             f"(expected one of {_OPS})")
        dl = deadline if deadline is not None else current_deadline()
        if dl is None and self.default_deadline_s is not None:
            dl = Deadline.after(self.default_deadline_s)
        if self._closed:
            self._shed("closed")
        if dl is not None and dl.expired():
            _M_DEADLINE.inc(label="frontdesk")
            self.stats.deadline_misses += 1
            raise DeadlineExceeded(f"frontdesk {op} (at admission)",
                                   -dl.remaining())
        if op == "insert":
            self._write_admission()
        req = _Req(op, args, dl)
        with self._nonempty:
            if self._closed:
                self._shed("closed")
            depth = len(self._q)
            if depth >= self.queue_cap:
                self._shed("queue_full", f"depth {depth}")
            if dl is not None:
                est = self._estimated_queue_delay(depth)
                if est > max(0.0, dl.remaining()):
                    self._shed("queue_delay",
                               f"estimated {est * 1e3:.1f}ms wait > "
                               f"{max(0.0, dl.remaining()) * 1e3:.1f}ms "
                               f"budget")
            self._q.append(req)
            _M_DEPTH.set(len(self._q))
            self._nonempty.notify()
        _M_REQS.inc(label=op)
        self.stats.admitted += 1
        return req.future

    # -- sync helpers ----------------------------------------------------------
    def out_neighbors(self, v: int, deadline: Optional[Deadline] = None
                      ) -> np.ndarray:
        return self.submit("out_neighbors", deadline, v=int(v)).result()

    def in_neighbors(self, v: int, deadline: Optional[Deadline] = None
                     ) -> np.ndarray:
        return self.submit("in_neighbors", deadline, v=int(v)).result()

    def friends_of_friends(self, v: int,
                           deadline: Optional[Deadline] = None
                           ) -> np.ndarray:
        return self.submit("fof", deadline, v=int(v)).result()

    def getrange(self, v: int, deadline: Optional[Deadline] = None
                 ) -> Dict[str, Any]:
        return self.submit("getrange", deadline, v=int(v)).result()

    def insert_edges(self, src, dst, etype=None,
                     deadline: Optional[Deadline] = None) -> int:
        return self.submit(
            "insert", deadline,
            src=np.asarray(src, np.int64), dst=np.asarray(dst, np.int64),
            etype=None if etype is None else np.asarray(etype)).result()

    # -- dispatch (worker threads) ---------------------------------------------
    def _take_batch(self) -> Optional[List[_Req]]:
        """Pop up to `max_batch` SAME-KIND requests. The batch kind is the
        queue head's (FIFO head never starves); later same-kind requests
        are pulled forward past other kinds — that cross-kind reorder is
        what makes coalescing real under a mixed op stream, and requests
        are independent (each carries its own deadline). Returns None
        only when closed AND drained."""
        with self._nonempty:
            while not self._q and not self._closed:
                self._nonempty.wait(timeout=0.1)
            if not self._q:
                return None
            kind = self._q[0].op
            batch: List[_Req] = []
            rest: List[_Req] = []
            while self._q and len(batch) < self.max_batch:
                r = self._q.popleft()
                (batch if r.op == kind else rest).append(r)
            self._q.extendleft(reversed(rest))
            _M_DEPTH.set(len(self._q))
            return batch

    def _run(self) -> None:
        while True:
            batch = self._take_batch()
            if batch is None:
                return
            try:
                self._dispatch(batch)
            except Exception as exc:  # noqa: BLE001 — deliver, never die
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(exc)

    def _dispatch(self, batch: List[_Req]) -> None:
        now = time.perf_counter()
        live: List[_Req] = []
        for r in batch:
            _M_QUEUE_S.observe(now - r.t_enq, label=r.op)
            if r.deadline is not None and r.deadline.expired():
                # expired while queued: answered typed, engine untouched
                _M_DEADLINE.inc(label="frontdesk")
                self.stats.deadline_misses += 1
                r.future.set_exception(DeadlineExceeded(
                    f"frontdesk {r.op} (expired in queue)",
                    -r.deadline.remaining()))
            else:
                live.append(r)
        if not live:
            return
        failpoint("frontdesk.dispatch")
        kind = live[0].op
        # the engine call runs under the batch's LOOSEST deadline (any
        # no-deadline member => unscoped): members with tighter budgets
        # are enforced individually at delivery below
        scope = None
        if all(r.deadline is not None for r in live):
            scope = max((r.deadline for r in live), key=lambda d: d.at)
        t0 = time.perf_counter()
        try:
            with deadline_scope(scope):
                results = self._execute(kind, live)
        except Exception as exc:  # typed errors fan out to every member
            for r in live:
                r.future.set_exception(exc)
            return
        dt = time.perf_counter() - t0
        per_req = dt / len(live)
        self._req_s_ewma = (per_req if self._req_s_ewma == 0.0 else
                            (1.0 - self._alpha) * self._req_s_ewma
                            + self._alpha * per_req)
        _M_BATCHES.inc(label=kind)
        _M_BATCHED.inc(len(live), label=kind)
        self.stats.batches += 1
        self.stats.batched_ops += len(live)
        for r, res in zip(live, results):
            if r.deadline is not None and r.deadline.expired():
                # finished, but late: deliver typed — the "no request
                # completes past its deadline without a typed error" gate
                _M_DEADLINE.inc(label="frontdesk")
                self.stats.deadline_misses += 1
                r.future.set_exception(DeadlineExceeded(
                    f"frontdesk {r.op} (finished late)",
                    -r.deadline.remaining()))
            else:
                r.future.set_result(res)

    @contextmanager
    def _engine_scope(self):
        """One engine per batch. ServiceDB: a lock-free epoch view (the
        whole batch reads one frozen manifest). ShardRouter: the LIVE
        scatter/gather engine — hedged, per-op pins (a pinned cross-shard
        view is connection-scoped and cannot be shared with hedge
        threads). Anything else: as_engine passthrough."""
        store = self.store
        if hasattr(store, "pin_view"):
            yield store.storage_engine()
        elif hasattr(store, "read_view"):
            with store.read_view() as view:
                yield view.storage_engine()
        else:
            from .engine import as_engine
            yield as_engine(store)

    def _execute(self, kind: str, live: List[_Req]) -> List[Any]:
        if kind == "insert":
            srcs = [r.args["src"] for r in live]
            dsts = [r.args["dst"] for r in live]
            etypes = [r.args.get("etype") for r in live]
            src = np.concatenate([np.asarray(s, np.int64).ravel()
                                  for s in srcs])
            dst = np.concatenate([np.asarray(d, np.int64).ravel()
                                  for d in dsts])
            etype = None
            if any(e is not None for e in etypes):
                etype = np.concatenate([
                    (np.zeros(np.asarray(s).size, np.int64) if e is None
                     else np.asarray(e, np.int64).ravel())
                    for s, e in zip(srcs, etypes)])
            # ONE grouped write: per-shard scatter (router) or one WAL
            # group commit (service) instead of N tiny ones
            self.store.insert_edges(src, dst, etype=etype)
            return [int(np.asarray(s).size) for s in srcs]

        vs = np.asarray([r.args["v"] for r in live], np.int64)
        with self._engine_scope() as eng:
            if kind in ("out_neighbors", "in_neighbors"):
                direction = "out" if kind == "out_neighbors" else "in"
                vals, offs = eng._neighbors_batch(vs, direction)
                # canonical sorted order: answers independent of slab
                # order, shard merge history, and who won a hedge
                return [np.sort(vals[offs[i]:offs[i + 1]])
                        for i in range(len(live))]
            if kind == "fof":
                from .multihop import two_hop_counts
                res = two_hop_counts(eng, vs)
                return [res.ids[res.slice_of(i)] for i in range(len(live))]
            if kind == "getrange":
                eb = eng.edge_columns_batch(vs)
                offs = eb.offsets
                out = []
                for i in range(len(live)):
                    sl = slice(int(offs[i]), int(offs[i + 1]))
                    out.append({
                        "src": eb.src[sl], "dst": eb.dst[sl],
                        "etype": eb.etype[sl],
                        "columns": {k: c[sl]
                                    for k, c in eb.columns.items()},
                    })
                return out
        raise ValueError(f"unknown front-desk op {kind!r}")

    # -- lifecycle -------------------------------------------------------------
    def depth(self) -> int:
        with self._lock:
            return len(self._q)

    def close(self, drain: bool = True) -> None:
        """Stop admitting, drain (or shed) the queue, join dispatchers.
        Idempotent. With `drain=False` queued requests are failed typed
        (`OverloadError("closed")`) instead of executed."""
        with self._nonempty:
            if self._closed:
                closed_already = True
            else:
                closed_already = False
                self._closed = True
                if not drain:
                    while self._q:
                        r = self._q.popleft()
                        _M_SHEDS.inc(label="closed")
                        self.stats.shed += 1
                        r.future.set_exception(OverloadError("closed"))
                    _M_DEPTH.set(0)
            self._nonempty.notify_all()
        if closed_already:
            return
        for t in self._threads:
            t.join(timeout=30.0)

    def __enter__(self) -> "FrontDesk":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
