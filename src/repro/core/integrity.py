"""End-to-end integrity primitives (ISSUE 7): checksums, typed failure
errors, and directory-fsync durability helpers.

Checksum algorithms — always recorded *by name* in the header that
carries the values, so files are self-describing and old files stay
readable after an algorithm switch:

  * `crc32` (`"crc32-zlib"`): CRC-32 via `zlib.crc32`. Used for tiny
    fixed inputs (partition header trailers, small WAL records) where
    its per-call overhead is nil.
  * `checksum32` (`"wsum32"`): the bulk-data checksum — 4 KiB block sums
    combined with position-dependent odd weights and folded to 32 bits,
    all in vectorized numpy. On machines whose zlib lacks a hardware CRC
    path this runs at memory bandwidth (~10-15x `zlib.crc32`), which is
    what keeps full-coverage checksumming under the <5% overhead gate
    (`bench_service.py --section checksum`). It detects bit flips,
    torn/stale/zeroed ranges, and block reorders; it is NOT
    cryptographic — content *addresses* use sha1 digests.
  * `record_checksum`: the WAL record checksum — `crc32` under 1 KiB,
    `checksum32` above (deterministic by length, so readers agree).

Coverage map (DESIGN.md §11):

  * every WAL record carries a trailing u32 checksum over its record
    bytes (core/walog.py; segment header `"crc": 2` = record_checksum,
    `"crc": 1` = plain crc32, absent = unchecksummed pre-ISSUE-7 —
    all three parse),
  * every 64B-aligned section of a partition file carries a checksum in
    the (versioned) header, verified lazily on first touch
    (core/disk.py, format version 2; version-1 files stay readable).

The error taxonomy is the contract "fail typed, never garbage":

    GraphDBError
    ├── CorruptionError     bytes on disk disagree with their checksum /
    │                       digest / format (path + detail attached)
    │   └── WALCorruptionError   a WAL record body failed its CRC
    ├── RecoveryError       recovery inputs are structurally impossible
    │   └── WALGapError     the segment chain has a hole (missing segment)
    ├── ReadOnlyError       the service shed to read-only mode; writes are
    │                       rejected until the condition clears
    ├── DeadlineExceeded    the request's time budget ran out before the
    │                       work completed (ISSUE 10; also a TimeoutError)
    └── OverloadError       admission control shed the request — the
                            system chose not to start work it could not
                            finish in time (queue full, breaker open, …)

`fsync_dir` closes the classic rename-durability hole: `os.replace` makes
a publish atomic, but the *directory entry* itself is only durable once
the parent directory is fsynced — without it, a power failure after the
rename can forget the file (or resurrect the old name). Every atomic
publish in the storage tier (MANIFEST.json, SNAPSHOT.json, partition
files, tombstone sidecars, WAL segment creation) now syncs its parent
directory (ISSUE 7 satellite), each crossing the `dir.fsync` failpoint.
"""
from __future__ import annotations

import os
import zlib

import numpy as np

from .failpoints import failpoint

__all__ = [
    "CRC_ALGO",
    "CKSUM_ALGO",
    "crc32",
    "checksum32",
    "record_checksum",
    "fsync_dir",
    "GraphDBError",
    "CorruptionError",
    "WALCorruptionError",
    "RecoveryError",
    "WALGapError",
    "ReadOnlyError",
    "DeadlineExceeded",
    "OverloadError",
]

CRC_ALGO = "crc32-zlib"
CKSUM_ALGO = "wsum32"


def crc32(data, value: int = 0) -> int:
    """CRC-32 of a bytes-like (memoryview-friendly: numpy arrays pass
    through `memoryview` without a copy)."""
    return zlib.crc32(data, value) & 0xFFFFFFFF


_CK_BLOCK = 512                             # uint64 words / block = 4 KiB
_CK_STEP = np.uint64(0x9E3779B97F4A7C15)    # odd (golden-ratio) multiplier
_CK_MASK = (1 << 64) - 1
_ck_weights_cache = np.empty(0, np.uint64)


def _ck_weights(n: int) -> np.ndarray:
    global _ck_weights_cache
    if _ck_weights_cache.shape[0] < n:
        idx = np.arange(1, n + 1, dtype=np.uint64)
        _ck_weights_cache = (idx * _CK_STEP) | np.uint64(1)
    return _ck_weights_cache[:n]


def checksum32(data) -> int:
    """Bulk-data checksum (`CKSUM_ALGO`) at memory bandwidth: 4 KiB block
    sums x position-dependent odd weights, folded to 32 bits. Accepts any
    C-contiguous bytes-like (bytes, numpy array, memmap) without copying.
    All arithmetic wraps mod 2**64 (numpy array ops wrap silently; the
    scalar accumulation stays in Python ints to avoid overflow warnings).
    """
    mv = memoryview(data)
    if mv.ndim != 1 or mv.itemsize != 1:
        mv = mv.cast("B")
    n = len(mv)
    total = n
    head = n - (n & 7)
    if head:
        v = np.frombuffer(mv[:head], dtype="<u8")
        whole = (v.shape[0] // _CK_BLOCK) * _CK_BLOCK
        if whole:
            bs = v[:whole].reshape(-1, _CK_BLOCK).sum(axis=1,
                                                      dtype=np.uint64)
            total += int(np.add.reduce(bs * _ck_weights(bs.shape[0])))
        tail = v[whole:]
        if tail.shape[0]:
            # the partial block: weighted per-word under a shifted phase
            # so bytes cannot migrate between regions unnoticed
            total += int(np.add.reduce(
                (tail * _ck_weights(tail.shape[0])) * _CK_STEP))
    rem = n & 7
    if rem:
        total += (int.from_bytes(mv[head:], "little") * int(_CK_STEP)
                  + rem)
    total &= _CK_MASK
    return ((total >> 32) ^ total) & 0xFFFFFFFF


_RECORD_SMALL = 1024


def record_checksum(data) -> int:
    """WAL record checksum (segment header `"crc": 2`): plain CRC-32 for
    small records (crc32's per-call overhead is nil and numpy's isn't),
    `checksum32` for bulk group-commit records. Deterministic by record
    length, so writer and replayer always agree."""
    if len(data) < _RECORD_SMALL:
        return crc32(data)
    return checksum32(data)


class GraphDBError(Exception):
    """Base of every typed storage/service failure."""


class CorruptionError(GraphDBError, ValueError):
    """On-disk bytes disagree with their checksum, digest, or format.
    Also a `ValueError`: pre-ISSUE-7 callers caught bad-magic/format
    failures as ValueError and still can."""

    def __init__(self, path: str, detail: str):
        super().__init__(f"{path}: {detail}")
        self.path = path
        self.detail = detail


class WALCorruptionError(CorruptionError):
    """A WAL record inside the acknowledged stream failed its CRC. Carries
    the global offset of the first bad record: everything before it is a
    valid durable prefix the caller may keep."""

    def __init__(self, path: str, offset: int, detail: str):
        super().__init__(path, f"{detail} (first bad offset {offset})")
        self.offset = offset


class RecoveryError(GraphDBError):
    """Recovery inputs are structurally impossible (not mere bit rot):
    missing segment files, a manifest referencing absent partitions, …"""


class WALGapError(RecoveryError):
    """The WAL segment chain has a hole: a segment's base offset is past
    the end of its predecessor. Replaying across the gap would silently
    drop acknowledged mutations, so recovery must fail typed instead."""

    def __init__(self, directory: str, expected: int, found: int):
        super().__init__(
            f"{directory}: WAL segment chain gap — expected a segment "
            f"covering offset {expected}, next segment starts at {found}")
        self.expected = expected
        self.found = found


class ReadOnlyError(GraphDBError, RuntimeError):
    """The service shed to read-only mode (ENOSPC / repeated persist
    failure). Epoch reads and snapshot sessions stay live; writes are
    rejected with this error until the condition clears. Also a
    `RuntimeError`: callers that treated any writer-path failure as
    RuntimeError keep working."""

    def __init__(self, reason: str):
        super().__init__(f"service is read-only: {reason}")
        self.reason = reason


class DeadlineExceeded(GraphDBError, TimeoutError):
    """The request's time budget ran out (ISSUE 10). Raised by whichever
    lifecycle stage first notices — admission, a queue drain, a socket
    timeout the router derived from the deadline, or a shard worker
    checking the budget before executing an op. Also a `TimeoutError`, so
    callers treating any timeout generically keep working. `late_by` is
    how far past the deadline the check ran (seconds, >= 0)."""

    def __init__(self, what: str = "request", late_by: float = 0.0):
        super().__init__(f"deadline exceeded: {what} "
                         f"(late by {max(0.0, late_by) * 1e3:.1f}ms)")
        self.what = what
        self.late_by = max(0.0, float(late_by))


class OverloadError(GraphDBError):
    """Admission control shed the request (ISSUE 10): the system refused
    to START work it predicted it could not finish within the request's
    deadline — a bounded queue was full, estimated queue delay exceeded
    the budget, writer backpressure was at its bound, or a circuit
    breaker was open. Shedding is the fast path: the caller learns in
    microseconds instead of waiting out a doomed request. `reason` is a
    stable machine-readable tag (`queue_full`, `queue_delay`,
    `backpressure`, `breaker_open`, …)."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"overloaded ({reason})"
                         + (f": {detail}" if detail else ""))
        self.reason = reason


def fsync_dir(path: str) -> None:
    """fsync the DIRECTORY containing `path` (or `path` itself if it is
    one) so a just-renamed entry survives power failure. Advisory on
    platforms whose directories refuse O_RDONLY open/fsync (Windows)."""
    d = path if os.path.isdir(path) else os.path.dirname(path) or "."
    failpoint("dir.fsync")
    try:
        fd = os.open(d, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)
