"""LSM-tree of PAL edge partitions (paper §5).

Immutable edge partitions are stacked in a log-structured merge tree:

  * level 0 (top) is the coarsest — few partitions, each covering the union
    of its descendants' vertex intervals — and is the only level with
    in-memory edge buffers (paper §5.2);
  * inserts land in the buffer of the top partition whose interval contains
    the edge's destination;
  * when total buffered edges exceed `buffer_cap`, the fullest buffer is
    sort-merged with its on-disk partition into a NEW immutable partition
    (the old one is dropped only after the new one is built — paper §7.3's
    crash-integrity argument);
  * when a partition outgrows `max_partition_edges`, it is emptied downstream
    into its f children (push-down merge), so each edge is rewritten only
    O(log |E|) times instead of O(|E|/R) (paper §5.1 vs §5.2);
  * deletes are tombstones purged at merge time; attribute updates write the
    columns in place (paper §5.3);
  * optional durability: a write-ahead log capturing each insert before it
    reaches a buffer ("durable buffers", paper §7.3).
"""
from __future__ import annotations

import dataclasses
import os
import struct
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .pal import EdgePartition, IntervalMap, build_partition

__all__ = ["BufferStaging", "EdgeBuffer", "LSMTree", "LSMStats"]


@dataclasses.dataclass
class BufferStaging:
    """Immutable numpy view of a buffer's contents, rebuilt lazily after
    mutations. The src/dst sort orders (binary-searchable like a
    partition's pointer-array) are built on first *batched* use only, so a
    workload that interleaves single-edge mutations with point queries
    pays the old O(n) scan, never a per-mutation re-sort."""

    src: np.ndarray                 # (B,) int64, append order
    dst: np.ndarray                 # (B,) int64
    etype: np.ndarray               # (B,) int8
    columns: Dict[str, np.ndarray]  # positional, append order
    _src_order: Optional[np.ndarray] = None   # (B,) argsort(src), stable
    _src_sorted: Optional[np.ndarray] = None  # (B,) src[_src_order]
    _dst_order: Optional[np.ndarray] = None
    _dst_sorted: Optional[np.ndarray] = None

    def src_sorted_view(self) -> Tuple[np.ndarray, np.ndarray]:
        """(order, sorted) over src — built once per staging generation."""
        if self._src_order is None:
            self._src_order = np.argsort(self.src, kind="stable")
            self._src_sorted = self.src[self._src_order]
        return self._src_order, self._src_sorted

    def dst_sorted_view(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._dst_order is None:
            self._dst_order = np.argsort(self.dst, kind="stable")
            self._dst_sorted = self.dst[self._dst_order]
        return self._dst_order, self._dst_sorted


class EdgeBuffer:
    """In-memory buffer of new edges for one top-level partition (paper §5.1).

    Buffers also hold the edge attribute columns, and are searched by
    queries/computation alongside the on-disk partitions. Array staging is
    cached and invalidated on mutation, so repeated queries between inserts
    never re-convert the Python lists.
    """

    def __init__(self, column_dtypes: Dict[str, np.dtype]):
        self.src: List[int] = []
        self.dst: List[int] = []
        self.etype: List[int] = []
        self.columns: Dict[str, list] = {k: [] for k in column_dtypes}
        self.column_dtypes = dict(column_dtypes)
        self._staging: Optional[BufferStaging] = None

    def __len__(self) -> int:
        return len(self.src)

    def _invalidate(self) -> None:
        self._staging = None

    def staging(self) -> BufferStaging:
        if self._staging is None:
            self._staging = BufferStaging(
                src=np.asarray(self.src, dtype=np.int64),
                dst=np.asarray(self.dst, dtype=np.int64),
                etype=np.asarray(self.etype, dtype=np.int8),
                columns={
                    k: np.asarray(v, dtype=self.column_dtypes[k])
                    for k, v in self.columns.items()
                },
            )
        return self._staging

    def append(self, src: int, dst: int, etype: int, cols: Dict) -> None:
        self.src.append(src)
        self.dst.append(dst)
        self.etype.append(etype)
        for k in self.columns:
            self.columns[k].append(cols.get(k, 0))
        self._invalidate()

    def extend(self, src, dst, etype, cols: Dict) -> None:
        self.src.extend(int(x) for x in src)
        self.dst.extend(int(x) for x in dst)
        self.etype.extend(int(x) for x in etype)
        n = len(src)
        for k in self.columns:
            v = cols.get(k)
            if v is None:
                self.columns[k].extend([0] * n)
            else:
                self.columns[k].extend(v)
        self._invalidate()

    def drain(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Dict[str, np.ndarray]]:
        st = self.staging()
        out = (st.src, st.dst, st.etype, st.columns)
        self.src, self.dst, self.etype = [], [], []
        self.columns = {k: [] for k in self.columns}
        self._invalidate()
        return out

    def set_column(self, name: str, pos: int, value) -> None:
        self.columns[name][pos] = value
        self._invalidate()

    def filter_mask(self, keep: np.ndarray) -> None:
        """Drop rows where keep is False (buffer-side delete, paper §5.3)."""
        st = self.staging()
        self.src = st.src[keep].tolist()
        self.dst = st.dst[keep].tolist()
        self.etype = st.etype[keep].tolist()
        self.columns = {k: v[keep].tolist() for k, v in st.columns.items()}
        self._invalidate()

    # point queries: binary search when the sorted view already exists (a
    # batched query built it), linear scan on the staged array otherwise
    def out_edges_of(self, v: int):
        st = self.staging()
        if st._src_order is None:
            return np.nonzero(st.src == v)[0]
        order, keys = st.src_sorted_view()
        a = np.searchsorted(keys, v, side="left")
        b = np.searchsorted(keys, v, side="right")
        return order[a:b]  # stable sort → ascending positions

    def in_edges_of(self, v: int):
        st = self.staging()
        if st._dst_order is None:
            return np.nonzero(st.dst == v)[0]
        order, keys = st.dst_sorted_view()
        a = np.searchsorted(keys, v, side="left")
        b = np.searchsorted(keys, v, side="right")
        return order[a:b]


@dataclasses.dataclass
class LSMStats:
    inserts: int = 0
    buffer_flushes: int = 0
    pushdown_merges: int = 0
    edges_rewritten: int = 0  # total edges written during merges
    splits: int = 0
    deletes: int = 0
    purged_tombstones: int = 0


class LSMTree:
    """LSM-tree over PAL edge partitions.

    `levels[0]` is the top (coarsest, buffered); `levels[-1]` is the bottom
    with `n_partitions` leaf partitions — matching the paper's Figure 5
    orientation (buffers feed the top, overflow pushes toward the leaves).
    """

    def __init__(
        self,
        intervals: IntervalMap,
        n_levels: int = 3,
        branching: int = 4,
        buffer_cap: int = 100_000,
        max_partition_edges: int = 2_000_000,
        column_dtypes: Optional[Dict[str, np.dtype]] = None,
        durable: bool = False,
        wal_path: Optional[str] = None,
    ):
        p = intervals.n_partitions
        assert p % (branching ** (n_levels - 1)) == 0, (
            f"n_partitions={p} must be divisible by branching^(levels-1)="
            f"{branching ** (n_levels - 1)}"
        )
        self.intervals = intervals
        self.branching = branching
        self.buffer_cap = buffer_cap
        self.max_partition_edges = max_partition_edges
        self.column_dtypes = dict(column_dtypes or {})
        self.stats = LSMStats()

        # level i has p / f^(L-1-i) partitions; level L-1 has p
        self.levels: List[List[EdgePartition]] = []
        for i in range(n_levels):
            n_parts = p // (branching ** (n_levels - 1 - i))
            span = intervals.max_vertices // n_parts
            level = [
                build_partition(
                    (j * span, (j + 1) * span),
                    np.empty(0, np.int64),
                    np.empty(0, np.int64),
                    columns={k: np.empty(0, dt) for k, dt in self.column_dtypes.items()},
                )
                for j in range(n_parts)
            ]
            self.levels.append(level)
        self.buffers: List[EdgeBuffer] = [
            EdgeBuffer(self.column_dtypes) for _ in self.levels[0]
        ]

        # durability (paper §7.3): WAL written+flushed before buffer insert
        self.durable = durable
        self._wal = None
        if durable:
            self._wal = open(wal_path or "/tmp/graphchi_db.wal", "ab", buffering=0)
        self._engine = None

    def storage_engine(self):
        """Vectorized set-at-a-time read interface across ALL levels and the
        live buffers (engine.py, DESIGN.md §5)."""
        if self._engine is None:
            from .engine import LSMEngine
            self._engine = LSMEngine(self)
        return self._engine

    # -- geometry ---------------------------------------------------------------
    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def partitions_per_level(self) -> List[int]:
        return [len(lv) for lv in self.levels]

    def _top_index_of(self, intern_dst: int) -> int:
        span = self.intervals.max_vertices // len(self.levels[0])
        return int(intern_dst) // span

    # -- inserts (paper §5) -------------------------------------------------------
    def insert_edge(self, src: int, dst: int, etype: int = 0, **cols) -> None:
        isrc = int(self.intervals.to_internal(src))
        idst = int(self.intervals.to_internal(dst))
        if self._wal is not None:
            self._wal.write(struct.pack("<qqb", isrc, idst, etype))
        self.buffers[self._top_index_of(idst)].append(isrc, idst, etype, cols)
        self.stats.inserts += 1
        if self.total_buffered() > self.buffer_cap:
            self.flush_fullest_buffer()

    def insert_edges(self, src, dst, etype=None, columns: Optional[Dict] = None) -> None:
        """Bulk insert — still through the online path (buffers + merges)."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        etype = np.zeros(src.shape[0], np.int8) if etype is None else np.asarray(etype)
        columns = columns or {}
        isrc = self.intervals.to_internal(src)
        idst = self.intervals.to_internal(dst)
        if self._wal is not None:
            rec = np.rec.fromarrays(
                [isrc, idst, etype.astype(np.int8)], names="s,d,t"
            )
            self._wal.write(rec.tobytes())
        span = self.intervals.max_vertices // len(self.levels[0])
        top = idst // span
        for i in np.unique(top):
            m = top == i
            self.buffers[int(i)].extend(
                isrc[m], idst[m], etype[m],
                {k: np.asarray(v)[m] for k, v in columns.items()},
            )
        self.stats.inserts += int(src.shape[0])
        while self.total_buffered() > self.buffer_cap:
            self.flush_fullest_buffer()

    def total_buffered(self) -> int:
        return sum(len(b) for b in self.buffers)

    # -- merges -------------------------------------------------------------------
    def flush_fullest_buffer(self) -> None:
        """Merge the fullest buffer with its top-level partition (paper §5.2)."""
        j = int(np.argmax([len(b) for b in self.buffers]))
        if len(self.buffers[j]) == 0:
            return
        bsrc, bdst, btype, bcols = self.buffers[j].drain()
        self.levels[0][j] = self._merge_into(self.levels[0][j], bsrc, bdst, btype, bcols)
        self.stats.buffer_flushes += 1
        self._maybe_pushdown(0, j)

    def _merge_into(self, part: EdgePartition, src, dst, etype, cols) -> EdgePartition:
        """Sorted merge producing a NEW immutable partition; tombstoned edges
        of the old partition are purged here (paper §5.3)."""
        live = np.ones(part.n_edges, bool) if part.dead is None else ~part.dead
        self.stats.purged_tombstones += int(part.n_edges - live.sum())
        msrc = np.concatenate([part.src[live], src])
        mdst = np.concatenate([part.dst[live], dst])
        mtyp = np.concatenate([part.etype[live], etype])
        mcols = {}
        for k, dt in self.column_dtypes.items():
            old = part.columns.get(k, np.zeros(part.n_edges, dt))[live]
            new = cols.get(k, np.zeros(src.shape[0], dt))
            mcols[k] = np.concatenate([old, new])
        self.stats.edges_rewritten += int(msrc.shape[0])
        return build_partition(part.interval, msrc, mdst, mtyp, mcols)

    def _maybe_pushdown(self, level: int, j: int) -> None:
        """If partition (level, j) exceeds the size cap, empty it into its f
        children at the next level (paper §5.2). Bottom level splits instead."""
        part = self.levels[level][j]
        if part.n_edges <= self.max_partition_edges:
            return
        if level == self.n_levels - 1:
            # paper: "If leaves grow too large, we can add a new level";
            # equivalently we grow the leaf cap — record the event.
            self.stats.splits += 1
            return
        f = len(self.levels[level + 1]) // len(self.levels[level])
        child_span = self.intervals.max_vertices // len(self.levels[level + 1])
        live = np.ones(part.n_edges, bool) if part.dead is None else ~part.dead
        csrc, cdst, ctyp = part.src[live], part.dst[live], part.etype[live]
        ccols = {
            k: part.columns.get(k, np.zeros(part.n_edges, dt))[live]
            for k, dt in self.column_dtypes.items()
        }
        child_of = cdst // child_span
        for c in np.unique(child_of):
            m = child_of == c
            self.levels[level + 1][int(c)] = self._merge_into(
                self.levels[level + 1][int(c)],
                csrc[m], cdst[m], ctyp[m],
                {k: v[m] for k, v in ccols.items()},
            )
        # emptied parent — new empty immutable partition
        self.levels[level][j] = build_partition(
            part.interval, np.empty(0, np.int64), np.empty(0, np.int64),
            columns={k: np.empty(0, dt) for k, dt in self.column_dtypes.items()},
        )
        self.stats.pushdown_merges += 1
        for c in np.unique(child_of):
            self._maybe_pushdown(level + 1, int(c))

    def flush_all(self) -> None:
        while self.total_buffered() > 0:
            self.flush_fullest_buffer()

    # -- queries across the tree (paper §5.2.1) -------------------------------------
    def out_edges(self, v: int) -> List[Tuple[int, int, int]]:
        """(level, partition_idx, edge_pos) across all levels + buffers.
        Cost: every partition on every level may hold out-edges."""
        vi = int(self.intervals.to_internal(v))
        hits = []
        for li, level in enumerate(self.levels):
            for pi, part in enumerate(level):
                for pos in part.out_edges(vi):
                    hits.append((li, pi, int(pos)))
        return hits

    def in_edges(self, v: int) -> List[Tuple[int, int, int]]:
        """Only ONE partition per level can own v's in-edges (paper: cost
        bounded by L_G + edges)."""
        vi = int(self.intervals.to_internal(v))
        hits = []
        for li, level in enumerate(self.levels):
            span = self.intervals.max_vertices // len(level)
            pi = vi // span
            for pos in level[pi].in_edges(vi):
                hits.append((li, int(pi), int(pos)))
        return hits

    def out_neighbors(self, v: int) -> np.ndarray:
        vi = int(self.intervals.to_internal(v))
        chunks = []
        for level in self.levels:
            for part in level:
                pos = part.out_edges(vi)
                if pos.size:
                    chunks.append(part.dst[pos])
        for buf in self.buffers:
            if len(buf):
                idx = buf.out_edges_of(vi)
                if idx.size:
                    chunks.append(buf.staging().dst[idx])
        if not chunks:
            return np.empty(0, np.int64)
        return np.asarray(self.intervals.to_original(np.concatenate(chunks)))

    def in_neighbors(self, v: int) -> np.ndarray:
        vi = int(self.intervals.to_internal(v))
        chunks = []
        for level in self.levels:
            span = self.intervals.max_vertices // len(level)
            part = level[vi // span]
            pos = part.in_edges(vi)
            if pos.size:
                chunks.append(part.src[pos])
        for buf in self.buffers:
            if len(buf):
                idx = buf.in_edges_of(vi)
                if idx.size:
                    chunks.append(buf.staging().src[idx])
        if not chunks:
            return np.empty(0, np.int64)
        return np.asarray(self.intervals.to_original(np.concatenate(chunks)))

    # -- updates / deletes (paper §5.3) ----------------------------------------------
    def update_edge_column(self, src: int, dst: int, name: str, value) -> bool:
        """Direct in-place column write on the newest matching edge."""
        isrc = int(self.intervals.to_internal(src))
        idst = int(self.intervals.to_internal(dst))
        # buffers are newest
        bj = self._top_index_of(idst)
        buf = self.buffers[bj]
        if len(buf):
            st = buf.staging()
            hit = np.nonzero((st.src == isrc) & (st.dst == idst))[0]
            if hit.size:
                buf.set_column(name, int(hit[-1]), value)
                return True
        for level in self.levels:
            span = self.intervals.max_vertices // len(level)
            part = level[idst // span]
            a, b = part.out_edge_range(isrc)
            pos = np.arange(a, b)
            pos = pos[part.dst[pos] == idst] if pos.size else pos
            pos = part._live(pos)
            if pos.size:
                part.set_column(name, pos[-1], value)
                return True
        return False

    def delete_edge(self, src: int, dst: int) -> bool:
        """Tombstone the edge everywhere it appears (purged at merges)."""
        isrc = int(self.intervals.to_internal(src))
        idst = int(self.intervals.to_internal(dst))
        found = False
        bj = self._top_index_of(idst)
        buf = self.buffers[bj]
        if len(buf):
            st = buf.staging()
            keep = ~((st.src == isrc) & (st.dst == idst))
            if not keep.all():
                found = True
                buf.filter_mask(keep)
        for level in self.levels:
            span = self.intervals.max_vertices // len(level)
            part = level[idst // span]
            a, b = part.out_edge_range(isrc)
            pos = np.arange(a, b)
            if pos.size:
                pos = pos[part.dst[pos] == idst]
                pos = part._live(pos)
                if pos.size:
                    part.tombstone(pos)
                    found = True
        if found:
            self.stats.deletes += 1
        return found

    # -- exports ------------------------------------------------------------------
    @property
    def n_edges(self) -> int:
        n = sum(p.n_live_edges for lv in self.levels for p in lv)
        return n + self.total_buffered()

    def all_partitions(self) -> List[EdgePartition]:
        return [p for lv in self.levels for p in lv]

    def snapshot(self, with_window_plan: bool = True):
        """Compile ALL levels plus the live in-memory buffers into an
        immutable `DeviceGraph` (jnp arrays) for the PSW / Pallas compute
        path — analytics run directly against the online store without
        flushing or otherwise mutating it. Edges are re-bucketed by
        destination interval and canonically (dst, src)-sorted, so the
        snapshot of an LSM store is bit-identical to the snapshot of a
        bulk-built GraphPAL holding the same live edges."""
        from .psw import build_device_graph
        return build_device_graph(self, with_window_plan=with_window_plan)

    def to_coo(self):
        ss, dd = [], []
        for part in self.all_partitions():
            live = np.ones(part.n_edges, bool) if part.dead is None else ~part.dead
            ss.append(part.src[live])
            dd.append(part.dst[live])
        for buf in self.buffers:
            if len(buf):
                st = buf.staging()
                ss.append(st.src)
                dd.append(st.dst)
        s = np.concatenate(ss) if ss else np.empty(0, np.int64)
        d = np.concatenate(dd) if dd else np.empty(0, np.int64)
        return (np.asarray(self.intervals.to_original(s)),
                np.asarray(self.intervals.to_original(d)))

    def close(self) -> None:
        if self._wal is not None:
            self._wal.close()
            self._wal = None

    # -- WAL recovery (paper §7.3 durability) ----------------------------------------
    @staticmethod
    def replay_wal(path: str) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        raw = np.fromfile(path, dtype=np.dtype([("s", "<i8"), ("d", "<i8"), ("t", "i1")]))
        return raw["s"], raw["d"], raw["t"]
