"""LSM-tree of PAL edge partitions (paper §5).

Immutable edge partitions are stacked in a log-structured merge tree:

  * level 0 (top) is the coarsest — few partitions, each covering the union
    of its descendants' vertex intervals — and is the only level with
    in-memory edge buffers (paper §5.2);
  * inserts land in the buffer of the top partition whose interval contains
    the edge's destination;
  * when total buffered edges exceed `buffer_cap`, the fullest buffer is
    sort-merged with its on-disk partition into a NEW immutable partition
    (the old one is dropped only after the new one is built — paper §7.3's
    crash-integrity argument);
  * when a partition outgrows `max_partition_edges`, it is emptied downstream
    into its f children (push-down merge), so each edge is rewritten only
    O(log |E|) times instead of O(|E|/R) (paper §5.1 vs §5.2);
  * deletes are tombstones purged at merge time; attribute updates write the
    columns in place (paper §5.3);
  * optional durability: a write-ahead log capturing each insert before it
    reaches a buffer ("durable buffers", paper §7.3).
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import struct
import tempfile
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .pal import (
    _MAX_PACKED_BOUND,
    EdgePartition,
    IntervalMap,
    SortedRun,
    build_partition,
    merge_runs,
    merge_runs_into_partition,
    partition_from_run,
    run_from_arrays,
    run_from_partition,
)

__all__ = ["BufferStaging", "EdgeBuffer", "LSMTree", "LSMStats"]


@dataclasses.dataclass
class BufferStaging:
    """Immutable numpy view of a buffer's contents, rebuilt lazily after
    mutations. The src/dst sort orders (binary-searchable like a
    partition's pointer-array) are built on first *batched* use only, so a
    workload that interleaves single-edge mutations with point queries
    pays the old O(n) scan, never a per-mutation re-sort."""

    src: np.ndarray                 # (B,) int64, append order
    dst: np.ndarray                 # (B,) int64
    etype: np.ndarray               # (B,) int8
    columns: Dict[str, np.ndarray]  # positional, append order
    _src_order: Optional[np.ndarray] = None   # (B,) argsort(src), stable
    _src_sorted: Optional[np.ndarray] = None  # (B,) src[_src_order]
    _dst_order: Optional[np.ndarray] = None
    _dst_sorted: Optional[np.ndarray] = None

    def src_sorted_view(self) -> Tuple[np.ndarray, np.ndarray]:
        """(order, sorted) over src — built once per staging generation."""
        if self._src_order is None:
            self._src_order = np.argsort(self.src, kind="stable")
            self._src_sorted = self.src[self._src_order]
        return self._src_order, self._src_sorted

    def dst_sorted_view(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._dst_order is None:
            self._dst_order = np.argsort(self.dst, kind="stable")
            self._dst_sorted = self.dst[self._dst_order]
        return self._dst_order, self._dst_sorted


class EdgeBuffer:
    """Columnar in-memory buffer of new edges for one top-level partition
    (paper §5.1, DESIGN.md §6).

    All state lives in amortized-doubling numpy arrays (`_src/_dst/_etype`
    plus one array per declared attribute column) with a length counter, so
    `append`/`extend` are pure vectorized writes and `staging()` is a
    zero-copy slice view of the backing arrays. Staging views are cached
    and invalidated on any length-changing mutation; holders must not cache
    a staging across buffer mutations.
    """

    _INITIAL_CAP = 256

    def __init__(self, column_dtypes: Dict[str, np.dtype]):
        self.column_dtypes = dict(column_dtypes)
        self._cap = self._INITIAL_CAP
        self._len = 0
        self._src = np.empty(self._cap, np.int64)
        self._dst = np.empty(self._cap, np.int64)
        self._etype = np.empty(self._cap, np.int8)
        self._cols: Dict[str, np.ndarray] = {
            k: np.empty(self._cap, dt) for k, dt in self.column_dtypes.items()
        }
        self._staging: Optional[BufferStaging] = None

    def __len__(self) -> int:
        return self._len

    def _invalidate(self) -> None:
        self._staging = None

    def _reserve(self, extra: int) -> None:
        need = self._len + int(extra)
        if need <= self._cap:
            return
        cap = self._cap
        while cap < need:
            cap *= 2

        def grow(arr):
            out = np.empty(cap, arr.dtype)
            out[: self._len] = arr[: self._len]
            return out

        self._src = grow(self._src)
        self._dst = grow(self._dst)
        self._etype = grow(self._etype)
        self._cols = {k: grow(v) for k, v in self._cols.items()}
        self._cap = cap

    def staging(self) -> BufferStaging:
        if self._staging is None:
            n = self._len
            self._staging = BufferStaging(
                src=self._src[:n],
                dst=self._dst[:n],
                etype=self._etype[:n],
                columns={k: v[:n] for k, v in self._cols.items()},
            )
        return self._staging

    def append(self, src: int, dst: int, etype: int, cols: Dict) -> None:
        self._reserve(1)
        i = self._len
        self._src[i] = src
        self._dst[i] = dst
        self._etype[i] = etype
        for k, col in self._cols.items():
            col[i] = cols.get(k, 0)
        self._len = i + 1
        self._invalidate()

    def extend(self, src, dst, etype, cols: Dict) -> None:
        src = np.asarray(src, dtype=np.int64)
        n = src.shape[0]
        if n == 0:
            return
        self._reserve(n)
        i = self._len
        self._src[i:i + n] = src
        self._dst[i:i + n] = np.asarray(dst, dtype=np.int64)
        self._etype[i:i + n] = np.asarray(etype, dtype=np.int8)
        for k, col in self._cols.items():
            v = cols.get(k)
            col[i:i + n] = 0 if v is None else np.asarray(v, dtype=col.dtype)
        self._len = i + n
        self._invalidate()

    def drain(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Dict[str, np.ndarray]]:
        """Hand out the staged views and reset. The views alias the backing
        arrays and are only valid until the next mutation — the merge that
        consumes them copies during its reorder/scatter. (The service
        tier's maintenance thread holds the service lock through the whole
        drain+merge, so writers cannot reuse the drained slots mid-merge.)"""
        st = self.staging()
        out = (st.src, st.dst, st.etype, st.columns)
        self._len = 0
        self._invalidate()
        return out

    def set_column(self, name: str, pos: int, value) -> None:
        # staging columns alias the backing arrays and sort orders are
        # unaffected by an attribute write, so no invalidation needed
        self._cols[name][pos] = value

    def filter_mask(self, keep: np.ndarray) -> None:
        """Drop rows where keep is False (buffer-side delete, paper §5.3) by
        compacting the backing arrays in place — array-native, no list
        round-trip. Boolean fancy-indexing copies before the assignment, so
        the overlapping write is safe."""
        keep = np.asarray(keep, dtype=bool)
        n = self._len
        m = int(keep.sum())
        if m != n:
            self._src[:m] = self._src[:n][keep]
            self._dst[:m] = self._dst[:n][keep]
            self._etype[:m] = self._etype[:n][keep]
            for col in self._cols.values():
                col[:m] = col[:n][keep]
            self._len = m
        self._invalidate()

    # point queries: binary search when the sorted view already exists (a
    # batched query built it), linear scan on the staged array otherwise
    def out_edges_of(self, v: int):
        st = self.staging()
        if st._src_order is None:
            return np.nonzero(st.src == v)[0]
        order, keys = st.src_sorted_view()
        a = np.searchsorted(keys, v, side="left")
        b = np.searchsorted(keys, v, side="right")
        return order[a:b]  # stable sort → ascending positions

    def in_edges_of(self, v: int):
        st = self.staging()
        if st._dst_order is None:
            return np.nonzero(st.dst == v)[0]
        order, keys = st.dst_sorted_view()
        a = np.searchsorted(keys, v, side="left")
        b = np.searchsorted(keys, v, side="right")
        return order[a:b]


_WAL_COUNTER = itertools.count()


def _default_wal_path() -> str:
    """Per-instance WAL path: pid + a process-wide counter, never shared."""
    return os.path.join(
        tempfile.gettempdir(),
        f"graphchi_db_{os.getpid()}_{next(_WAL_COUNTER)}.wal")


@dataclasses.dataclass
class LSMStats:
    inserts: int = 0
    buffer_flushes: int = 0
    pushdown_merges: int = 0
    edges_rewritten: int = 0  # total edges written during merges
    splits: int = 0
    deletes: int = 0
    purged_tombstones: int = 0


class LSMTree:
    """LSM-tree over PAL edge partitions.

    `levels[0]` is the top (coarsest, buffered); `levels[-1]` is the bottom
    with `n_partitions` leaf partitions — matching the paper's Figure 5
    orientation (buffers feed the top, overflow pushes toward the leaves).
    """

    def __init__(
        self,
        intervals: IntervalMap,
        n_levels: int = 3,
        branching: int = 4,
        buffer_cap: int = 100_000,
        max_partition_edges: int = 2_000_000,
        column_dtypes: Optional[Dict[str, np.dtype]] = None,
        durable: bool = False,
        wal_path: Optional[str] = None,
        wal_sync: str = "commit",
        wal: Optional[object] = None,
        auto_flush: bool = True,
        partition_sink: Optional[
            Callable[[int, int, EdgePartition], EdgePartition]] = None,
    ):
        p = intervals.n_partitions
        assert p % (branching ** (n_levels - 1)) == 0, (
            f"n_partitions={p} must be divisible by branching^(levels-1)="
            f"{branching ** (n_levels - 1)}"
        )
        self.intervals = intervals
        self.branching = branching
        self.buffer_cap = buffer_cap
        self.max_partition_edges = max_partition_edges
        self.column_dtypes = dict(column_dtypes or {})
        self.stats = LSMStats()

        # level i has p / f^(L-1-i) partitions; level L-1 has p
        self.levels: List[List[EdgePartition]] = []
        for i in range(n_levels):
            n_parts = p // (branching ** (n_levels - 1 - i))
            span = intervals.max_vertices // n_parts
            level = [
                build_partition(
                    (j * span, (j + 1) * span),
                    np.empty(0, np.int64),
                    np.empty(0, np.int64),
                    columns={k: np.empty(0, dt) for k, dt in self.column_dtypes.items()},
                )
                for j in range(n_parts)
            ]
            self.levels.append(level)
        self.buffers: List[EdgeBuffer] = [
            EdgeBuffer(self.column_dtypes) for _ in self.levels[0]
        ]
        # O(1) buffered-edge counter (maintained at every buffer mutation);
        # replaces the per-insert sum over all buffers
        self._buffered = 0

        # durability (paper §7.3): group-commit WAL — records of one insert
        # call coalesce into ONE buffered write, then the sync policy runs:
        #   "always": flush + fsync per insert call (true durability)
        #   "commit": flush to the OS per insert call (survives process
        #             crash, not power loss) — the default
        #   "close":  buffered until flush()/close()
        self.durable = durable
        assert wal_sync in ("always", "commit", "close"), wal_sync
        self.wal_sync = wal_sync
        # typed WAL object (core/walog.SegmentedWAL): when set, it REPLACES
        # the legacy raw-record file below and additionally records columns,
        # tombstones, and in-place column writes (ISSUE 4)
        self.wal = wal
        # with auto_flush off, inserts only append (WAL + buffers) on the
        # caller's thread; draining merges is the maintenance thread's job
        # (core/service.py) — the insert path never runs a merge
        self.auto_flush = auto_flush
        self._wal = None
        self.wal_path: Optional[str] = None
        if durable and wal is None:
            # every tree gets its OWN log: the old global /tmp default let
            # two trees in one process interleave records, and replay_wal
            # then resurrected foreign edges (regression-tested)
            self.wal_path = wal_path or _default_wal_path()
            self._wal = open(self.wal_path, "ab", buffering=1 << 20)
        # disk tier hook (core/disk.py): every partition a merge installs
        # is offered to the sink, which may persist it and hand back an
        # mmap-backed replacement
        self.partition_sink = partition_sink
        self._engine = None

    def _wal_append(self, payload: bytes) -> None:
        self._wal.write(payload)
        if self.wal_sync == "commit":
            self._wal.flush()
        elif self.wal_sync == "always":
            self._wal.flush()
            os.fsync(self._wal.fileno())

    def storage_engine(self):
        """Vectorized set-at-a-time read interface across ALL levels and the
        live buffers (engine.py, DESIGN.md §5)."""
        if self._engine is None:
            from .engine import LSMEngine
            self._engine = LSMEngine(self)
        return self._engine

    # -- geometry ---------------------------------------------------------------
    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def partitions_per_level(self) -> List[int]:
        return [len(lv) for lv in self.levels]

    def _top_index_of(self, intern_dst: int) -> int:
        span = self.intervals.max_vertices // len(self.levels[0])
        return int(intern_dst) // span

    # -- inserts (paper §5) -------------------------------------------------------
    def insert_edge(self, src: int, dst: int, etype: int = 0, **cols) -> None:
        isrc = self.intervals.to_internal_scalar(src)
        idst = self.intervals.to_internal_scalar(dst)
        if self.wal is not None:
            self.wal.append_inserts([isrc], [idst], [etype], cols)
        elif self._wal is not None:
            self._wal_append(struct.pack("<qqb", isrc, idst, etype))
        self.buffers[self._top_index_of(idst)].append(isrc, idst, etype, cols)
        self.stats.inserts += 1
        self._buffered += 1
        if self._buffered > self.buffer_cap and self.auto_flush:
            self.flush_fullest_buffer()

    def insert_edges(self, src, dst, etype=None, columns: Optional[Dict] = None) -> None:
        """Bulk insert — still through the online path (buffers + merges)."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        etype = np.zeros(src.shape[0], np.int8) if etype is None else np.asarray(etype)
        columns = columns or {}
        isrc = self.intervals.to_internal(src)
        idst = self.intervals.to_internal(dst)
        if self.wal is not None:
            # ONE group-commit record, attribute columns included
            self.wal.append_inserts(isrc, idst, etype, columns)
        elif self._wal is not None:
            rec = np.rec.fromarrays(
                [isrc, idst, etype.astype(np.int8)], names="s,d,t"
            )
            self._wal_append(rec.tobytes())  # ONE group-commit write
        if len(self.buffers) == 1:  # single top partition: no routing pass
            self.buffers[0].extend(isrc, idst, etype, columns)
        else:
            span = self.intervals.max_vertices // len(self.levels[0])
            top = idst // span
            for i in np.unique(top):
                m = top == i
                self.buffers[int(i)].extend(
                    isrc[m], idst[m], etype[m],
                    {k: np.asarray(v)[m] for k, v in columns.items()},
                )
        self.stats.inserts += int(src.shape[0])
        self._buffered += int(src.shape[0])
        while self._buffered > self.buffer_cap and self.auto_flush:
            self.flush_fullest_buffer()

    def total_buffered(self) -> int:
        return self._buffered

    # -- merges -------------------------------------------------------------------
    def _install(self, level: int, j: int, part: EdgePartition) -> None:
        """Every partition a merge produces is installed through here so the
        disk tier (GraphDB's partition_sink) can flush it to a file and
        substitute an mmap-backed view. The replaced partition's mappings
        are dropped eagerly — its object may linger briefly in a GC cycle,
        but its pages must leave RSS now."""
        if self.partition_sink is not None:
            part = self.partition_sink(level, j, part)
        old = self.levels[level][j]
        self.levels[level][j] = part
        if old is not part:
            evict = getattr(old, "evict", None)
            if evict is not None:
                evict()

    def _empty_partition(self, interval) -> EdgePartition:
        return build_partition(
            interval, np.empty(0, np.int64), np.empty(0, np.int64),
            columns={k: np.empty(0, dt) for k, dt in self.column_dtypes.items()},
        )

    def _linear_merge_ok(self, n_total: int) -> bool:
        kb = self.intervals.max_vertices
        return kb <= _MAX_PACKED_BOUND and kb * (n_total + 1) < 2 ** 63

    def flush_fullest_buffer(self) -> None:
        """Merge the fullest buffer with its top-level partition (paper §5.2)."""
        j = int(np.argmax([len(b) for b in self.buffers]))
        buf = self.buffers[j]
        if len(buf) == 0:
            return
        self._buffered -= len(buf)
        bsrc, bdst, btype, bcols = buf.drain()
        self.stats.buffer_flushes += 1
        if self._linear_merge_ok(self.levels[0][j].n_edges + int(bsrc.shape[0])):
            run = run_from_arrays(bsrc, bdst, btype, bcols,
                                  key_bound=self.intervals.max_vertices)
            self._absorb(0, j, run)
        else:
            self._install(0, j, self._merge_into(
                self.levels[0][j], bsrc, bdst, btype, bcols))
            self._maybe_pushdown(0, j)

    def _absorb(self, level: int, j: int, run: "SortedRun") -> None:
        """Merge a sorted run into partition (level, j). When the merged
        partition would immediately overflow into its children anyway,
        short-circuit: combine partition + run into one sorted run and
        distribute it straight down, skipping a full partition (re)build —
        this halves rewrites at every non-leaf level."""
        part = self.levels[level][j]
        n_dead = 0 if part.dead is None else int(part.dead.sum())
        n_total = part.n_edges - n_dead + run.n_edges
        if (n_total > self.max_partition_edges and level < self.n_levels - 1
                and self._linear_merge_ok(n_total)):
            a = run_from_partition(
                part, live=None if part.dead is None else ~part.dead,
                columns=self.column_dtypes.keys())
            combined = merge_runs(a, run, self.intervals.max_vertices,
                                  self.column_dtypes)
            self.stats.purged_tombstones += n_dead
            self.stats.edges_rewritten += combined.n_edges
            self.stats.pushdown_merges += 1
            self.levels[level][j] = self._empty_partition(part.interval)
            self._distribute_to_children(level, combined)
            return
        self._install(level, j, self._merge_into(
            part, run.src, run.dst, run.etype, run.columns,
            presorted=True, run=run))
        self._maybe_pushdown(level, j)

    def _merge_into(self, part: EdgePartition, src, dst, etype, cols,
                    presorted: bool = False,
                    run: Optional["SortedRun"] = None) -> EdgePartition:
        """Linear-time sorted merge producing a NEW immutable partition
        (DESIGN.md §6); tombstoned edges of the old partition are purged
        here (paper §5.3). Only the incoming run is sorted (skipped when it
        is a presorted push-down subset, whose dst order arrives prebuilt in
        `run`); the partition side and every index rebuild are O(n) off the
        merge interleave permutation."""
        n_dead = 0 if part.dead is None else int(part.dead.sum())
        n_live = part.n_edges - n_dead
        self.stats.purged_tombstones += n_dead
        n_total = n_live + int(src.shape[0])
        self.stats.edges_rewritten += n_total
        key_bound = self.intervals.max_vertices
        if key_bound <= _MAX_PACKED_BOUND and key_bound * (n_total + 1) < 2 ** 63:
            b = run if run is not None else run_from_arrays(
                src, dst, etype, cols, presorted=presorted,
                key_bound=key_bound)
            if n_live == 0:  # empty target: index the run directly
                return partition_from_run(part.interval, b, self.column_dtypes)
            a = run_from_partition(
                part, live=None if part.dead is None else ~part.dead,
                columns=self.column_dtypes.keys())
            return merge_runs_into_partition(
                part.interval, a, b, key_bound, self.column_dtypes)
        # (src, dst) does not pack into an int64 merge key at this vertex
        # capacity — fall back to the full re-sort build
        live = np.ones(part.n_edges, bool) if part.dead is None else ~part.dead
        msrc = np.concatenate([part.src[live], src])
        mdst = np.concatenate([part.dst[live], dst])
        mtyp = np.concatenate([part.etype[live], etype])
        mcols = {}
        for k, dt in self.column_dtypes.items():
            old = part.columns.get(k, np.zeros(part.n_edges, dt))[live]
            new = cols.get(k, np.zeros(src.shape[0], dt))
            mcols[k] = np.concatenate([old, new])
        return build_partition(part.interval, msrc, mdst, mtyp, mcols)

    def _maybe_pushdown(self, level: int, j: int) -> None:
        """If partition (level, j) exceeds the size cap, empty it into its f
        children at the next level (paper §5.2). Bottom level splits instead."""
        part = self.levels[level][j]
        if part.n_edges <= self.max_partition_edges:
            return
        if level == self.n_levels - 1:
            # paper: "If leaves grow too large, we can add a new level";
            # equivalently we grow the leaf cap — record the event.
            self.stats.splits += 1
            return
        n_dead = 0 if part.dead is None else int(part.dead.sum())
        parent = run_from_partition(
            part, live=None if part.dead is None else ~part.dead,
            columns=self.column_dtypes.keys())
        self.stats.purged_tombstones += n_dead
        # emptied parent — new empty immutable partition
        self.levels[level][j] = self._empty_partition(part.interval)
        self.stats.pushdown_merges += 1
        self._distribute_to_children(level, parent)

    def _distribute_to_children(self, level: int, parent: "SortedRun") -> None:
        """Split a sorted run by child interval and merge each piece into
        its child partition (paper §5.2). Children cover disjoint dst
        ranges, so each child occupies one contiguous slice of the parent's
        dst order: its parent positions are that slice, its edge order is
        those positions sorted, and its local dst order is the slice ranked
        against them — O(m log m) per child, no full-parent passes."""
        if parent.n_edges == 0:
            return
        child_span = self.intervals.max_vertices // len(self.levels[level + 1])
        order = parent.dst_order
        pdst_sorted = parent.dst[order]
        c_lo = int(pdst_sorted[0]) // child_span
        c_hi = int(pdst_sorted[-1]) // child_span
        inv = np.empty(parent.n_edges, np.int64)  # parent pos -> child pos
        children = []
        for c in range(c_lo, c_hi + 1):
            lo = np.searchsorted(pdst_sorted, c * child_span, side="left")
            hi = np.searchsorted(pdst_sorted, (c + 1) * child_span, side="left")
            if hi == lo:
                continue
            slice_pos = order[lo:hi]          # parent positions, dst-ordered
            pos_c = np.sort(slice_pos)        # = child edges in (src, dst) order
            inv[pos_c] = np.arange(pos_c.shape[0], dtype=np.int64)
            child = SortedRun(
                src=parent.src[pos_c], dst=parent.dst[pos_c],
                etype=parent.etype[pos_c],
                columns={k: v[pos_c] for k, v in parent.columns.items()},
                dst_order=inv[slice_pos],
            )
            children.append((c, child))
        for c, child in children:
            self._absorb(level + 1, c, child)

    def flush_all(self) -> None:
        while self.total_buffered() > 0:
            self.flush_fullest_buffer()

    # -- queries across the tree (paper §5.2.1) -------------------------------------
    def out_edges(self, v: int) -> List[Tuple[int, int, int]]:
        """(level, partition_idx, edge_pos) across all levels + buffers.
        Cost: every partition on every level may hold out-edges."""
        vi = int(self.intervals.to_internal(v))
        hits = []
        for li, level in enumerate(self.levels):
            for pi, part in enumerate(level):
                for pos in part.out_edges(vi):
                    hits.append((li, pi, int(pos)))
        return hits

    def in_edges(self, v: int) -> List[Tuple[int, int, int]]:
        """Only ONE partition per level can own v's in-edges (paper: cost
        bounded by L_G + edges)."""
        vi = int(self.intervals.to_internal(v))
        hits = []
        for li, level in enumerate(self.levels):
            span = self.intervals.max_vertices // len(level)
            pi = vi // span
            for pos in level[pi].in_edges(vi):
                hits.append((li, int(pi), int(pos)))
        return hits

    def out_neighbors(self, v: int) -> np.ndarray:
        vi = int(self.intervals.to_internal(v))
        chunks = []
        for level in self.levels:
            for part in level:
                pos = part.out_edges(vi)
                if pos.size:
                    chunks.append(part.dst[pos])
        for buf in self.buffers:
            if len(buf):
                idx = buf.out_edges_of(vi)
                if idx.size:
                    chunks.append(buf.staging().dst[idx])
        if not chunks:
            return np.empty(0, np.int64)
        return np.asarray(self.intervals.to_original(np.concatenate(chunks)))

    def in_neighbors(self, v: int) -> np.ndarray:
        vi = int(self.intervals.to_internal(v))
        chunks = []
        for level in self.levels:
            span = self.intervals.max_vertices // len(level)
            part = level[vi // span]
            pos = part.in_edges(vi)
            if pos.size:
                chunks.append(part.src[pos])
        # buffers partition by destination interval: only the owning buffer
        # can hold v's in-edges — probe just that one
        buf = self.buffers[self._top_index_of(vi)]
        if len(buf):
            idx = buf.in_edges_of(vi)
            if idx.size:
                chunks.append(buf.staging().src[idx])
        if not chunks:
            return np.empty(0, np.int64)
        return np.asarray(self.intervals.to_original(np.concatenate(chunks)))

    # -- updates / deletes (paper §5.3) ----------------------------------------------
    def update_edge_column(self, src: int, dst: int, name: str, value) -> bool:
        """Direct in-place column write on the newest matching edge."""
        isrc = int(self.intervals.to_internal(src))
        idst = int(self.intervals.to_internal(dst))
        # buffers are newest
        bj = self._top_index_of(idst)
        buf = self.buffers[bj]
        if len(buf):
            st = buf.staging()
            hit = np.nonzero((st.src == isrc) & (st.dst == idst))[0]
            if hit.size:
                buf.set_column(name, int(hit[-1]), value)
                if self.wal is not None:
                    self.wal.append_column(name, isrc, idst, value)
                return True
        for level in self.levels:
            span = self.intervals.max_vertices // len(level)
            part = level[idst // span]
            a, b = part.out_edge_range(isrc)
            pos = np.arange(a, b)
            pos = pos[part.dst[pos] == idst] if pos.size else pos
            pos = part._live(pos)
            if pos.size:
                part.set_column(name, pos[-1], value)
                if self.wal is not None:
                    self.wal.append_column(name, isrc, idst, value)
                return True
        return False

    def delete_edge(self, src: int, dst: int) -> bool:
        """Tombstone the edge everywhere it appears (purged at merges)."""
        isrc = int(self.intervals.to_internal(src))
        idst = int(self.intervals.to_internal(dst))
        found = False
        bj = self._top_index_of(idst)
        buf = self.buffers[bj]
        if len(buf):
            st = buf.staging()
            keep = ~((st.src == isrc) & (st.dst == idst))
            removed = int(keep.shape[0] - keep.sum())
            if removed:
                found = True
                buf.filter_mask(keep)
                self._buffered -= removed
        for level in self.levels:
            span = self.intervals.max_vertices // len(level)
            part = level[idst // span]
            a, b = part.out_edge_range(isrc)
            pos = np.arange(a, b)
            if pos.size:
                pos = pos[part.dst[pos] == idst]
                pos = part._live(pos)
                if pos.size:
                    part.tombstone(pos)
                    found = True
        if found:
            self.stats.deletes += 1
            if self.wal is not None:  # tombstones are durable pre-checkpoint
                self.wal.append_delete(isrc, idst)
        return found

    # -- exports ------------------------------------------------------------------
    @property
    def n_edges(self) -> int:
        n = sum(p.n_live_edges for lv in self.levels for p in lv)
        return n + self.total_buffered()

    def all_partitions(self) -> List[EdgePartition]:
        return [p for lv in self.levels for p in lv]

    def snapshot(self, with_window_plan: bool = True):
        """Compile ALL levels plus the live in-memory buffers into an
        immutable `DeviceGraph` (jnp arrays) for the PSW / Pallas compute
        path — analytics run directly against the online store without
        flushing or otherwise mutating it. Edges are re-bucketed by
        destination interval and canonically (dst, src)-sorted, so the
        snapshot of an LSM store is bit-identical to the snapshot of a
        bulk-built GraphPAL holding the same live edges."""
        from .psw import build_device_graph
        return build_device_graph(self, with_window_plan=with_window_plan)

    def to_coo(self):
        ss, dd = [], []
        for part in self.all_partitions():
            live = np.ones(part.n_edges, bool) if part.dead is None else ~part.dead
            ss.append(part.src[live])
            dd.append(part.dst[live])
        for buf in self.buffers:
            if len(buf):
                st = buf.staging()
                ss.append(st.src)
                dd.append(st.dst)
        s = np.concatenate(ss) if ss else np.empty(0, np.int64)
        d = np.concatenate(dd) if dd else np.empty(0, np.int64)
        return (np.asarray(self.intervals.to_original(s)),
                np.asarray(self.intervals.to_original(d)))

    def wal_flush(self, fsync: bool = True) -> None:
        """Explicit durability point: push buffered WAL records to the OS
        and (optionally) to stable storage, regardless of sync policy."""
        if self.wal is not None:
            self.wal.flush(fsync=fsync)
        if self._wal is not None:
            self._wal.flush()
            if fsync:
                os.fsync(self._wal.fileno())

    def close(self) -> None:
        if self.wal is not None:
            self.wal.close()
            self.wal = None
        if self._wal is not None:
            self.wal_flush(fsync=True)
            self._wal.close()
            self._wal = None

    # -- WAL recovery (paper §7.3 durability) ----------------------------------------
    @staticmethod
    def replay_wal(path: str,
                   offset: int = 0) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Decode WAL records from byte `offset` on — a GraphDB manifest
        records the offset its persisted partitions cover, so recovery
        replays only the tail."""
        dt = np.dtype([("s", "<i8"), ("d", "<i8"), ("t", "i1")])
        with open(path, "rb") as f:
            f.seek(offset)
            buf = f.read()
        n = len(buf) // dt.itemsize  # a torn trailing record is dropped
        raw = np.frombuffer(buf[: n * dt.itemsize], dtype=dt)
        return raw["s"], raw["d"], raw["t"]
