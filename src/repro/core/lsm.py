"""LSM-tree of PAL edge partitions (paper §5).

Immutable edge partitions are stacked in a log-structured merge tree:

  * level 0 (top) is the coarsest — few partitions, each covering the union
    of its descendants' vertex intervals — and is the only level with
    in-memory edge buffers (paper §5.2);
  * inserts land in the buffer of the top partition whose interval contains
    the edge's destination;
  * when total buffered edges exceed `buffer_cap`, the fullest buffer is
    sort-merged with its on-disk partition into a NEW immutable partition
    (the old one is dropped only after the new one is built — paper §7.3's
    crash-integrity argument);
  * when a partition outgrows `max_partition_edges`, it is emptied downstream
    into its f children (push-down merge), so each edge is rewritten only
    O(log |E|) times instead of O(|E|/R) (paper §5.1 vs §5.2);
  * deletes are tombstones purged at merge time; attribute updates write the
    columns in place (paper §5.3);
  * optional durability: a write-ahead log capturing each insert before it
    reaches a buffer ("durable buffers", paper §7.3).
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import struct
import tempfile
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import telemetry
from .manifest import EpochGuard, LevelManifest, ManifestPartition, ManifestView
from .pal import (
    _MAX_PACKED_BOUND,
    EdgePartition,
    IntervalMap,
    SortedRun,
    build_partition,
    merge_runs,
    merge_runs_into_partition,
    partition_from_run,
    run_from_arrays,
    run_from_partition,
)

__all__ = ["BufferStaging", "EdgeBuffer", "LSMTree", "LSMStats", "MergeTxn"]


class BufferStaging:
    """Immutable logical view of a buffer's first `n` rows, built lazily:
    construction only captures the backing-array references and the length
    (cheap enough to run on EVERY single-edge insert's manifest publish —
    ISSUE 5); the `[:n]` slice views and the src/dst sort orders
    (binary-searchable like a partition's pointer-array) materialize on
    first use. Captured backing arrays are append-stable: rows `[0, n)`
    never change after capture (growth reallocates, deletes compact into
    fresh arrays), so a staging stays bitwise-valid forever."""

    __slots__ = ("_fsrc", "_fdst", "_fetype", "_fcols", "n",
                 "_src", "_dst", "_etype", "_columns",
                 "_src_order", "_src_sorted", "_dst_order", "_dst_sorted")

    def __init__(self, src, dst, etype, columns, n: Optional[int] = None):
        self._fsrc = src
        self._fdst = dst
        self._fetype = etype
        self._fcols = columns
        self.n = int(src.shape[0] if n is None else n)
        self._src = self._dst = self._etype = self._columns = None
        self._src_order = self._src_sorted = None
        self._dst_order = self._dst_sorted = None

    # lazy [:n] views — idempotent benign-race fills, shared by readers
    @property
    def src(self) -> np.ndarray:
        v = self._src
        if v is None:
            v = self._fsrc[: self.n]
            self._src = v
        return v

    @property
    def dst(self) -> np.ndarray:
        v = self._dst
        if v is None:
            v = self._fdst[: self.n]
            self._dst = v
        return v

    @property
    def etype(self) -> np.ndarray:
        v = self._etype
        if v is None:
            v = self._fetype[: self.n]
            self._etype = v
        return v

    @property
    def columns(self) -> Dict[str, np.ndarray]:
        v = self._columns
        if v is None:
            n = self.n
            v = {k: a[:n] for k, a in self._fcols.items()}
            self._columns = v
        return v

    def src_sorted_view(self) -> Tuple[np.ndarray, np.ndarray]:
        """(order, sorted) over src — built once per staging generation.
        Published stagings are shared by concurrent reader threads: the
        build works on locals and assigns the guard field LAST, so a racing
        reader either sees both caches or rebuilds the same (deterministic)
        arrays itself — never a half-published pair."""
        order = self._src_order
        if order is None:
            order = np.argsort(self.src, kind="stable")
            srt = self.src[order]
            self._src_sorted = srt
            self._src_order = order  # publish last: guards _src_sorted
        else:
            srt = self._src_sorted
        return order, srt

    def dst_sorted_view(self) -> Tuple[np.ndarray, np.ndarray]:
        order = self._dst_order
        if order is None:
            order = np.argsort(self.dst, kind="stable")
            srt = self.dst[order]
            self._dst_sorted = srt
            self._dst_order = order
        else:
            srt = self._dst_sorted
        return order, srt


class EdgeBuffer:
    """Columnar in-memory buffer of new edges for one top-level partition
    (paper §5.1, DESIGN.md §6).

    All state lives in amortized-doubling numpy arrays (`_src/_dst/_etype`
    plus one array per declared attribute column) with a length counter, so
    `append`/`extend` are pure vectorized writes and `staging()` is a
    zero-copy slice view of the backing arrays. Staging views are cached
    and invalidated on any length-changing mutation; holders must not cache
    a staging across buffer mutations.
    """

    _INITIAL_CAP = 256

    def __init__(self, column_dtypes: Dict[str, np.dtype]):
        self.column_dtypes = dict(column_dtypes)
        self._cap = self._INITIAL_CAP
        self._len = 0
        self._src = np.empty(self._cap, np.int64)
        self._dst = np.empty(self._cap, np.int64)
        self._etype = np.empty(self._cap, np.int8)
        self._cols: Dict[str, np.ndarray] = {
            k: np.empty(self._cap, dt) for k, dt in self.column_dtypes.items()
        }
        self._staging: Optional[BufferStaging] = None

    def __len__(self) -> int:
        return self._len

    def _invalidate(self) -> None:
        self._staging = None

    def _reserve(self, extra: int) -> None:
        need = self._len + int(extra)
        if need <= self._cap:
            return
        cap = self._cap
        while cap < need:
            cap *= 2

        def grow(arr):
            out = np.empty(cap, arr.dtype)
            out[: self._len] = arr[: self._len]
            return out

        self._src = grow(self._src)
        self._dst = grow(self._dst)
        self._etype = grow(self._etype)
        self._cols = {k: grow(v) for k, v in self._cols.items()}
        self._cap = cap

    def staging(self) -> BufferStaging:
        if self._staging is None:
            self._staging = BufferStaging(
                self._src, self._dst, self._etype, self._cols, n=self._len)
        return self._staging

    def append(self, src: int, dst: int, etype: int, cols: Dict) -> None:
        self._reserve(1)
        i = self._len
        self._src[i] = src
        self._dst[i] = dst
        self._etype[i] = etype
        for k, col in self._cols.items():
            col[i] = cols.get(k, 0)
        self._len = i + 1
        self._invalidate()

    def extend(self, src, dst, etype, cols: Dict) -> None:
        src = np.asarray(src, dtype=np.int64)
        n = src.shape[0]
        if n == 0:
            return
        self._reserve(n)
        i = self._len
        self._src[i:i + n] = src
        self._dst[i:i + n] = np.asarray(dst, dtype=np.int64)
        self._etype[i:i + n] = np.asarray(etype, dtype=np.int8)
        for k, col in self._cols.items():
            v = cols.get(k)
            col[i:i + n] = 0 if v is None else np.asarray(v, dtype=col.dtype)
        self._len = i + n
        self._invalidate()

    def drain(self) -> BufferStaging:
        """Hand out the current staging and DETACH: the buffer restarts on
        fresh backing arrays, so the drained views stay bitwise-valid for
        as long as anyone holds them — the merge worker consuming them off
        the writer's lock, and every published manifest that still lists
        them as a pending slab (core/manifest.py)."""
        st = self.staging()
        # fresh arrays at the SAME capacity: the old blocks (released when
        # the merge commits and the last manifest drops the staging) and
        # the next drain's allocations share size classes, so the
        # detach-per-drain churn doesn't fragment the allocator heap
        self._len = 0
        self._src = np.empty(self._cap, np.int64)
        self._dst = np.empty(self._cap, np.int64)
        self._etype = np.empty(self._cap, np.int8)
        self._cols = {k: np.empty(self._cap, dt)
                      for k, dt in self.column_dtypes.items()}
        self._invalidate()
        return st

    def set_column(self, name: str, pos: int, value) -> None:
        # staging columns alias the backing arrays and sort orders are
        # unaffected by an attribute write, so no invalidation needed.
        # Published manifests alias these arrays too: column writes are
        # deliberately non-transactional (paper §5.3 in-place semantics) —
        # a pinned view may see a newer value, never a torn structure.
        self._cols[name][pos] = value

    def filter_mask(self, keep: np.ndarray) -> None:
        """Drop rows where keep is False (buffer-side delete, paper §5.3).
        The kept rows are compacted into FRESH backing arrays (same cost as
        the old in-place fancy-index compaction, which also copied every
        kept row) — published manifests and in-flight merges keep aliasing
        the untouched old arrays, so a buffered delete can never tear a
        lock-free reader's view."""
        keep = np.asarray(keep, dtype=bool)
        n = self._len
        m = int(keep.sum())
        if m != n:
            def compact(arr):
                out = np.empty(self._cap, arr.dtype)
                out[:m] = arr[:n][keep]
                return out

            self._src = compact(self._src)
            self._dst = compact(self._dst)
            self._etype = compact(self._etype)
            self._cols = {k: compact(v) for k, v in self._cols.items()}
            self._len = m
        self._invalidate()

    # point queries: binary search when the sorted view already exists (a
    # batched query built it), linear scan on the staged array otherwise
    def out_edges_of(self, v: int):
        st = self.staging()
        if st._src_order is None:
            return np.nonzero(st.src == v)[0]
        order, keys = st.src_sorted_view()
        a = np.searchsorted(keys, v, side="left")
        b = np.searchsorted(keys, v, side="right")
        return order[a:b]  # stable sort → ascending positions

    def in_edges_of(self, v: int):
        st = self.staging()
        if st._dst_order is None:
            return np.nonzero(st.dst == v)[0]
        order, keys = st.dst_sorted_view()
        a = np.searchsorted(keys, v, side="left")
        b = np.searchsorted(keys, v, side="right")
        return order[a:b]


_WAL_COUNTER = itertools.count()


def _default_wal_path() -> str:
    """Per-instance WAL path: pid + a process-wide counter, never shared."""
    return os.path.join(
        tempfile.gettempdir(),
        f"graphchi_db_{os.getpid()}_{next(_WAL_COUNTER)}.wal")


# registry names for the LSMStats collector (ISSUE 9) — live instances
# (trees of stores AND of open snapshots) are summed at snapshot time
_LSM_STATS_METRICS = {
    "inserts": "lsm.inserts",
    "buffer_flushes": "lsm.buffer_flushes",
    "pushdown_merges": "lsm.pushdown_merges",
    "edges_rewritten": "lsm.edges_rewritten",
    "splits": "lsm.splits",
    "deletes": "lsm.deletes",
    "purged_tombstones": "lsm.purged_tombstones",
}


@dataclasses.dataclass
class LSMStats:
    inserts: int = 0
    buffer_flushes: int = 0
    pushdown_merges: int = 0
    edges_rewritten: int = 0  # total edges written during merges
    splits: int = 0
    deletes: int = 0
    purged_tombstones: int = 0

    def merge_from(self, other: "LSMStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


class MergeTxn:
    """One buffer-flush merge prepared OFF the writer's lock.

    The heavy work of a flush — sorting the drained run, the linear merge
    interleaves, partition rebuilds, and (via the partition sink) writing
    the new partition files — runs against a private overlay of the levels:
    `get` reads through to the live tree, `install` records the replacement
    locally. Nothing the tree publishes changes until `LSMTree.commit_txn`
    applies the whole overlay and publishes ONE new manifest, so concurrent
    lock-free readers see the pre-merge state or the post-merge state,
    never a half-distributed push-down. Disjointness is the caller's
    contract: at most one in-flight txn per top-level interval (the
    maintenance pipeline's per-interval locks), and a txn only ever touches
    partitions inside its top partition's destination interval."""

    def __init__(self, tree: "LSMTree", j: int, staging: BufferStaging):
        self.tree = tree
        self.j = j
        self.staging = staging
        self.updates: Dict[Tuple[int, int], EdgePartition] = {}
        self.stats = LSMStats()

    def get(self, level: int, j: int) -> EdgePartition:
        part = self.updates.get((level, j))
        return part if part is not None else self.tree.levels[level][j]

    def retire_live(self, level: int, j: int,
                    replacement: EdgePartition) -> None:
        """Drop the live (pre-merge) partition's mappings and decoded
        caches NOW, mid-cascade, like the pre-txn install path did — the
        merge just streamed its pages, and waiting for commit would keep
        every replaced partition of a push-down cascade resident at once.
        Safe under pinned manifests: eviction only unmaps; an epoch reader
        lazily re-mmaps (the file survives GC via pinned_digests)."""
        live = self.tree.levels[level][j]
        if live is not replacement and (level, j) not in self.updates:
            evict = getattr(live, "evict", None)
            if evict is not None:
                evict()

    def install(self, level: int, j: int, part: EdgePartition) -> None:
        """Route through the disk tier's sink (persistence happens HERE, on
        the worker, off every lock) and record the replacement."""
        if self.tree.partition_sink is not None:
            part = self.tree.partition_sink(level, j, part)
        self.retire_live(level, j, part)
        self.updates[(level, j)] = part


class LSMTree:
    """LSM-tree over PAL edge partitions.

    `levels[0]` is the top (coarsest, buffered); `levels[-1]` is the bottom
    with `n_partitions` leaf partitions — matching the paper's Figure 5
    orientation (buffers feed the top, overflow pushes toward the leaves).
    """

    def __init__(
        self,
        intervals: IntervalMap,
        n_levels: int = 3,
        branching: int = 4,
        buffer_cap: int = 100_000,
        max_partition_edges: int = 2_000_000,
        column_dtypes: Optional[Dict[str, np.dtype]] = None,
        durable: bool = False,
        wal_path: Optional[str] = None,
        wal_sync: str = "commit",
        wal: Optional[object] = None,
        auto_flush: bool = True,
        partition_sink: Optional[
            Callable[[int, int, EdgePartition], EdgePartition]] = None,
    ):
        p = intervals.n_partitions
        assert p % (branching ** (n_levels - 1)) == 0, (
            f"n_partitions={p} must be divisible by branching^(levels-1)="
            f"{branching ** (n_levels - 1)}"
        )
        self.intervals = intervals
        self.branching = branching
        self.buffer_cap = buffer_cap
        self.max_partition_edges = max_partition_edges
        self.column_dtypes = dict(column_dtypes or {})
        self.stats = LSMStats()
        # ISSUE 9: fold the per-tree counter bag into telemetry snapshots
        # (read-side collector — the attributes above stay the live state
        # and the `+=` write path is untouched)
        telemetry.register_stats(self.stats, _LSM_STATS_METRICS)

        # level i has p / f^(L-1-i) partitions; level L-1 has p
        self.levels: List[List[EdgePartition]] = []
        for i in range(n_levels):
            n_parts = p // (branching ** (n_levels - 1 - i))
            span = intervals.max_vertices // n_parts
            level = [
                build_partition(
                    (j * span, (j + 1) * span),
                    np.empty(0, np.int64),
                    np.empty(0, np.int64),
                    columns={k: np.empty(0, dt) for k, dt in self.column_dtypes.items()},
                )
                for j in range(n_parts)
            ]
            self.levels.append(level)
        self.buffers: List[EdgeBuffer] = [
            EdgeBuffer(self.column_dtypes) for _ in self.levels[0]
        ]
        # O(1) buffered-edge counter (maintained at every buffer mutation);
        # replaces the per-insert sum over all buffers
        self._buffered = 0
        # drained-but-not-yet-committed staging views, per top buffer: the
        # maintenance pipeline merges them off the writer's lock while
        # published manifests keep exposing them as read slabs (ISSUE 5)
        self._pending: List[List[BufferStaging]] = [[] for _ in self.buffers]
        self._inflight_edges = 0
        # epoch-published manifests: the lock-free live read path
        self.epochs = EpochGuard()
        self._mversion = 0

        # durability (paper §7.3): group-commit WAL — records of one insert
        # call coalesce into ONE buffered write, then the sync policy runs:
        #   "always": flush + fsync per insert call (true durability)
        #   "commit": flush to the OS per insert call (survives process
        #             crash, not power loss) — the default
        #   "close":  buffered until flush()/close()
        self.durable = durable
        assert wal_sync in ("always", "commit", "close"), wal_sync
        self.wal_sync = wal_sync
        # typed WAL object (core/walog.SegmentedWAL): when set, it REPLACES
        # the legacy raw-record file below and additionally records columns,
        # tombstones, and in-place column writes (ISSUE 4)
        self.wal = wal
        # with auto_flush off, inserts only append (WAL + buffers) on the
        # caller's thread; draining merges is the maintenance thread's job
        # (core/service.py) — the insert path never runs a merge
        self.auto_flush = auto_flush
        self._wal = None
        self.wal_path: Optional[str] = None
        if durable and wal is None:
            # every tree gets its OWN log: the old global /tmp default let
            # two trees in one process interleave records, and replay_wal
            # then resurrected foreign edges (regression-tested)
            self.wal_path = wal_path or _default_wal_path()
            self._wal = open(self.wal_path, "ab", buffering=1 << 20)
        # disk tier hook (core/disk.py): every partition a merge installs
        # is offered to the sink, which may persist it and hand back an
        # mmap-backed replacement
        self.partition_sink = partition_sink
        self._engine = None
        self.publish()  # manifest v0: readers can pin from birth

    def _wal_append(self, payload: bytes) -> None:
        self._wal.write(payload)
        if self.wal_sync == "commit":
            self._wal.flush()
        elif self.wal_sync == "always":
            self._wal.flush()
            os.fsync(self._wal.fileno())

    def storage_engine(self):
        """Vectorized set-at-a-time read interface across ALL levels and the
        live buffers (engine.py, DESIGN.md §5)."""
        if self._engine is None:
            from .engine import LSMEngine
            self._engine = LSMEngine(self)
        return self._engine

    # -- epoch publication (ISSUE 5, DESIGN.md §9) ------------------------------
    def publish(self) -> LevelManifest:
        """Full manifest publication: capture every partition (sealing its
        tombstone array — the next tombstone write copies), every buffer's
        staging, and the in-flight pending drains, and swap the manifest in
        ONE reference assignment. Caller must be the (serialized) writer:
        the mutating thread itself, or a maintenance job holding the
        service lock for its commit."""
        levels = []
        for lv in self.levels:
            row = []
            for part in lv:
                mp = ManifestPartition(part)
                if mp.dead is not None:
                    part._dead_sealed = True
                row.append(mp)
            levels.append(tuple(row))
        wal_tail = 0
        if self.wal is not None:
            try:
                wal_tail = self.wal.tail_offset()
            except Exception:
                wal_tail = 0
        self._mversion += 1
        m = LevelManifest(
            version=self._mversion,
            levels=tuple(levels),
            stagings=tuple(b.staging() for b in self.buffers),
            pending=tuple(tuple(p) for p in self._pending),
            wal_tail=wal_tail,
        )
        self.epochs.publish(m)
        return m

    def publish_partitions(self, coords, buffer_idxs) -> None:
        """Targeted publication for mutations that touch a known partition
        path (deletes): recapture and reseal only the partitions at
        `coords` = [(level, idx), ...] plus the listed buffers' stagings —
        O(levels + one level row) instead of a full O(partitions)
        recapture per delete."""
        cur = self.epochs.current
        levels = list(cur.levels)
        for li, pi in coords:
            part = self.levels[li][pi]
            mp = ManifestPartition(part)
            if mp.dead is not None:
                part._dead_sealed = True
            row = list(levels[li])
            row[pi] = mp
            levels[li] = tuple(row)
        stagings = list(cur.stagings)
        for j in buffer_idxs:
            stagings[j] = self.buffers[j].staging()
        self._mversion += 1
        m = LevelManifest(self._mversion, tuple(levels), tuple(stagings),
                          cur.pending, self._fresh_wal_tail(cur.wal_tail))
        self.epochs.publish(m)

    def _fresh_wal_tail(self, fallback: int) -> int:
        """The post-append WAL tail for a targeted publish. Stamping it on
        every manifest (ISSUE 8) makes each published epoch *addressable*:
        `pin_snapshot(pinned_offset=view.wal_tail)` reconstructs exactly
        that view's logical state in another process. The mutation paths
        append to the WAL before publishing, so the tail read here covers
        everything the manifest contains."""
        if self.wal is None:
            return fallback
        try:
            return self.wal.tail_offset()
        except Exception:
            return fallback

    def publish_buffers(self, idxs) -> None:
        """Cheap publication for append-only buffer changes: splice the
        updated buffers' fresh stagings into the current manifest (no
        partition recapture — appends never disturb sealed state). This
        runs on EVERY insert call, single-edge included: staging capture,
        the manifest splice, and the epoch swap are all O(1) reference
        plumbing (measured ~a microsecond)."""
        cur = self.epochs.current
        stagings = list(cur.stagings)
        for j in idxs:
            stagings[j] = self.buffers[j].staging()
        self._mversion += 1
        self.epochs.publish(cur.with_stagings(
            self._mversion, tuple(stagings),
            wal_tail=self._fresh_wal_tail(cur.wal_tail)))

    def read_view(self) -> ManifestView:
        """Pin the current manifest under an epoch guard and return a
        read-only store view — THE live read path: no lock shared with the
        writer or with maintenance is ever taken. Release (or use as a
        context manager) when done; an unreleased view defers reclamation
        of the partitions/files it references."""
        m, slot = self.epochs.pin()
        return ManifestView(self, m, slot)

    def pinned_digests(self) -> set:
        """Digests of disk partitions referenced by the current manifest or
        any retired manifest a reader may still pin — files checkpoint GC
        must NOT delete (deferred reclamation)."""
        out = set()
        for m in self.epochs.live_manifests():
            for mp in m.partitions():
                path = getattr(mp.part, "path", None)
                if path is not None:
                    out.add(os.path.basename(path)[5:-4])
        return out

    def pending_stagings(self) -> List[Tuple[BufferStaging, Tuple[int, int]]]:
        """(staging, top interval) of every drained-but-uncommitted batch —
        extra read slabs the LIVE engine must include mid-flight."""
        out = []
        for j, lst in enumerate(self._pending):
            for st in lst:
                out.append((st, self.levels[0][j].interval))
        return out

    def inflight_edges(self) -> int:
        return self._inflight_edges

    # -- geometry ---------------------------------------------------------------
    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def partitions_per_level(self) -> List[int]:
        return [len(lv) for lv in self.levels]

    def _top_index_of(self, intern_dst: int) -> int:
        span = self.intervals.max_vertices // len(self.levels[0])
        return int(intern_dst) // span

    # -- inserts (paper §5) -------------------------------------------------------
    def insert_edge(self, src: int, dst: int, etype: int = 0, **cols) -> None:
        isrc = self.intervals.to_internal_scalar(src)
        idst = self.intervals.to_internal_scalar(dst)
        if self.wal is not None:
            self.wal.append_inserts([isrc], [idst], [etype], cols)
        elif self._wal is not None:
            self._wal_append(struct.pack("<qqb", isrc, idst, etype))
        j = self._top_index_of(idst)
        self.buffers[j].append(isrc, idst, etype, cols)
        self.stats.inserts += 1
        self._buffered += 1
        self.publish_buffers((j,))
        if self._buffered > self.buffer_cap and self.auto_flush:
            self.flush_fullest_buffer()

    def insert_edges(self, src, dst, etype=None, columns: Optional[Dict] = None) -> None:
        """Bulk insert — still through the online path (buffers + merges)."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        etype = np.zeros(src.shape[0], np.int8) if etype is None else np.asarray(etype)
        columns = columns or {}
        isrc = self.intervals.to_internal(src)
        idst = self.intervals.to_internal(dst)
        if self.wal is not None:
            # ONE group-commit record, attribute columns included
            self.wal.append_inserts(isrc, idst, etype, columns)
        elif self._wal is not None:
            rec = np.rec.fromarrays(
                [isrc, idst, etype.astype(np.int8)], names="s,d,t"
            )
            self._wal_append(rec.tobytes())  # ONE group-commit write
        if len(self.buffers) == 1:  # single top partition: no routing pass
            self.buffers[0].extend(isrc, idst, etype, columns)
            touched = (0,)
        else:
            span = self.intervals.max_vertices // len(self.levels[0])
            top = idst // span
            touched = tuple(int(i) for i in np.unique(top))
            for i in touched:
                m = top == i
                self.buffers[i].extend(
                    isrc[m], idst[m], etype[m],
                    {k: np.asarray(v)[m] for k, v in columns.items()},
                )
        self.stats.inserts += int(src.shape[0])
        self._buffered += int(src.shape[0])
        self.publish_buffers(touched)
        while self._buffered > self.buffer_cap and self.auto_flush:
            self.flush_fullest_buffer()

    def total_buffered(self) -> int:
        return self._buffered

    # -- merges (txn-based: prepared off-lock, committed atomically) --------------
    def _empty_partition(self, interval) -> EdgePartition:
        return build_partition(
            interval, np.empty(0, np.int64), np.empty(0, np.int64),
            columns={k: np.empty(0, dt) for k, dt in self.column_dtypes.items()},
        )

    def _linear_merge_ok(self, n_total: int) -> bool:
        kb = self.intervals.max_vertices
        return kb <= _MAX_PACKED_BOUND and kb * (n_total + 1) < 2 ** 63

    def drain_buffer(self, j: int) -> Optional[BufferStaging]:
        """Detach buffer j's contents as an immutable staging and stage it
        on the pending list (published manifests keep serving it as a read
        slab until the merge commits). Caller must be the serialized
        writer side (service lock held, or single-threaded use)."""
        buf = self.buffers[j]
        if len(buf) == 0:
            return None
        st = buf.drain()
        n = int(st.src.shape[0])
        self._buffered -= n
        self._inflight_edges += n
        self._pending[j].append(st)
        self.stats.buffer_flushes += 1
        self.publish()  # readers now see (old partitions + pending slab)
        return st

    def build_flush_txn(self, j: int, st: BufferStaging) -> MergeTxn:
        """The expensive half of a flush, safe to run WITHOUT the writer
        lock as long as the caller holds the top-interval-j merge slot
        (core/service.py's per-interval locks): merge the drained staging
        through partition (0, j)'s subtree into a private overlay."""
        txn = MergeTxn(self, j, st)
        bsrc, bdst, btype, bcols = st.src, st.dst, st.etype, st.columns
        if self._linear_merge_ok(txn.get(0, j).n_edges + int(bsrc.shape[0])):
            run = run_from_arrays(bsrc, bdst, btype, bcols,
                                  key_bound=self.intervals.max_vertices)
            self._absorb(txn, 0, j, run)
        else:
            txn.install(0, j, self._merge_into(
                txn, txn.get(0, j), bsrc, bdst, btype, bcols))
            self._maybe_pushdown(txn, 0, j)
        return txn

    def commit_txn(self, txn: MergeTxn) -> None:
        """Apply a prepared merge atomically: swap every touched partition
        slot, retire the pending staging, fold the txn's stats in, and
        publish ONE post-merge manifest. Must run on the serialized writer
        side (service lock). Replaced partitions' mappings are dropped
        eagerly — epoch-pinned readers lazily re-mmap (their files survive
        GC via `pinned_digests`), so this only trims RSS."""
        for (li, pi), part in txn.updates.items():
            old = self.levels[li][pi]
            self.levels[li][pi] = part
            if old is not part:
                evict = getattr(old, "evict", None)
                if evict is not None:
                    evict()
        self._pending[txn.j].remove(txn.staging)
        self._inflight_edges -= int(txn.staging.src.shape[0])
        self.stats.merge_from(txn.stats)
        self.publish()

    def flush_fullest_buffer(self) -> None:
        """Merge the fullest buffer with its top-level partition (paper
        §5.2) — the synchronous path: drain, build, commit back-to-back.
        The pipelined path (core/service.py) runs the same three calls with
        only drain/commit under the service lock."""
        j = int(np.argmax([len(b) for b in self.buffers]))
        st = self.drain_buffer(j)
        if st is None:
            return
        self.commit_txn(self.build_flush_txn(j, st))

    def _absorb(self, txn: MergeTxn, level: int, j: int,
                run: "SortedRun") -> None:
        """Merge a sorted run into partition (level, j). When the merged
        partition would immediately overflow into its children anyway,
        short-circuit: combine partition + run into one sorted run and
        distribute it straight down, skipping a full partition (re)build —
        this halves rewrites at every non-leaf level."""
        part = txn.get(level, j)
        n_dead = 0 if part.dead is None else int(part.dead.sum())
        n_total = part.n_edges - n_dead + run.n_edges
        if (n_total > self.max_partition_edges and level < self.n_levels - 1
                and self._linear_merge_ok(n_total)):
            a = run_from_partition(
                part, live=None if part.dead is None else ~part.dead,
                columns=self.column_dtypes.keys())
            combined = merge_runs(a, run, self.intervals.max_vertices,
                                  self.column_dtypes)
            txn.stats.purged_tombstones += n_dead
            txn.stats.edges_rewritten += combined.n_edges
            txn.stats.pushdown_merges += 1
            empty = self._empty_partition(part.interval)
            txn.retire_live(level, j, empty)
            txn.updates[(level, j)] = empty
            self._distribute_to_children(txn, level, combined)
            return
        txn.install(level, j, self._merge_into(
            txn, part, run.src, run.dst, run.etype, run.columns,
            presorted=True, run=run))
        self._maybe_pushdown(txn, level, j)

    def _merge_into(self, txn: MergeTxn, part: EdgePartition,
                    src, dst, etype, cols, presorted: bool = False,
                    run: Optional["SortedRun"] = None) -> EdgePartition:
        """Linear-time sorted merge producing a NEW immutable partition
        (DESIGN.md §6); tombstoned edges of the old partition are purged
        here (paper §5.3). Only the incoming run is sorted (skipped when it
        is a presorted push-down subset, whose dst order arrives prebuilt in
        `run`); the partition side and every index rebuild are O(n) off the
        merge interleave permutation."""
        n_dead = 0 if part.dead is None else int(part.dead.sum())
        n_live = part.n_edges - n_dead
        txn.stats.purged_tombstones += n_dead
        n_total = n_live + int(src.shape[0])
        txn.stats.edges_rewritten += n_total
        key_bound = self.intervals.max_vertices
        if key_bound <= _MAX_PACKED_BOUND and key_bound * (n_total + 1) < 2 ** 63:
            b = run if run is not None else run_from_arrays(
                src, dst, etype, cols, presorted=presorted,
                key_bound=key_bound)
            if n_live == 0:  # empty target: index the run directly
                return partition_from_run(part.interval, b, self.column_dtypes)
            a = run_from_partition(
                part, live=None if part.dead is None else ~part.dead,
                columns=self.column_dtypes.keys())
            return merge_runs_into_partition(
                part.interval, a, b, key_bound, self.column_dtypes)
        # (src, dst) does not pack into an int64 merge key at this vertex
        # capacity — fall back to the full re-sort build
        live = np.ones(part.n_edges, bool) if part.dead is None else ~part.dead
        msrc = np.concatenate([part.src[live], src])
        mdst = np.concatenate([part.dst[live], dst])
        mtyp = np.concatenate([part.etype[live], etype])
        mcols = {}
        for k, dt in self.column_dtypes.items():
            old = part.columns.get(k, np.zeros(part.n_edges, dt))[live]
            new = cols.get(k, np.zeros(src.shape[0], dt))
            mcols[k] = np.concatenate([old, new])
        return build_partition(part.interval, msrc, mdst, mtyp, mcols)

    def _maybe_pushdown(self, txn: MergeTxn, level: int, j: int) -> None:
        """If partition (level, j) exceeds the size cap, empty it into its f
        children at the next level (paper §5.2). Bottom level splits instead."""
        part = txn.get(level, j)
        if part.n_edges <= self.max_partition_edges:
            return
        if level == self.n_levels - 1:
            # paper: "If leaves grow too large, we can add a new level";
            # equivalently we grow the leaf cap — record the event.
            txn.stats.splits += 1
            return
        n_dead = 0 if part.dead is None else int(part.dead.sum())
        parent = run_from_partition(
            part, live=None if part.dead is None else ~part.dead,
            columns=self.column_dtypes.keys())
        txn.stats.purged_tombstones += n_dead
        # emptied parent — new empty immutable partition
        empty = self._empty_partition(part.interval)
        txn.retire_live(level, j, empty)
        txn.updates[(level, j)] = empty
        txn.stats.pushdown_merges += 1
        self._distribute_to_children(txn, level, parent)

    def _distribute_to_children(self, txn: MergeTxn, level: int,
                                parent: "SortedRun") -> None:
        """Split a sorted run by child interval and merge each piece into
        its child partition (paper §5.2). Children cover disjoint dst
        ranges, so each child occupies one contiguous slice of the parent's
        dst order: its parent positions are that slice, its edge order is
        those positions sorted, and its local dst order is the slice ranked
        against them — O(m log m) per child, no full-parent passes."""
        if parent.n_edges == 0:
            return
        child_span = self.intervals.max_vertices // len(self.levels[level + 1])
        order = parent.dst_order
        pdst_sorted = parent.dst[order]
        c_lo = int(pdst_sorted[0]) // child_span
        c_hi = int(pdst_sorted[-1]) // child_span
        inv = np.empty(parent.n_edges, np.int64)  # parent pos -> child pos
        children = []
        for c in range(c_lo, c_hi + 1):
            lo = np.searchsorted(pdst_sorted, c * child_span, side="left")
            hi = np.searchsorted(pdst_sorted, (c + 1) * child_span, side="left")
            if hi == lo:
                continue
            slice_pos = order[lo:hi]          # parent positions, dst-ordered
            pos_c = np.sort(slice_pos)        # = child edges in (src, dst) order
            inv[pos_c] = np.arange(pos_c.shape[0], dtype=np.int64)
            child = SortedRun(
                src=parent.src[pos_c], dst=parent.dst[pos_c],
                etype=parent.etype[pos_c],
                columns={k: v[pos_c] for k, v in parent.columns.items()},
                dst_order=inv[slice_pos],
            )
            children.append((c, child))
        for c, child in children:
            self._absorb(txn, level + 1, c, child)

    def flush_all(self) -> None:
        # commit any orphaned in-flight drains first (a pipeline worker
        # that died between drain and commit leaves its staging pending;
        # checkpointing without merging it would advance the covered WAL
        # offset past edges no partition holds)
        for j, lst in enumerate(self._pending):
            for st in list(lst):
                self.commit_txn(self.build_flush_txn(j, st))
        while self.total_buffered() > 0:
            self.flush_fullest_buffer()

    # -- queries across the tree (paper §5.2.1) -------------------------------------
    BUFFER_LEVEL = -1  # hit level index addressing a live edge buffer

    @staticmethod
    def _add_hit_rows(rows: list, li: int, pi: int, pos: np.ndarray) -> None:
        """Append one slab's hits as (H, 3) rows of (level, idx, pos) —
        the single definition of the hit-row layout `columns_for_hits`
        consumes."""
        if pos.size:
            row = np.empty((pos.shape[0], 3), np.int64)
            row[:, 0] = li
            row[:, 1] = pi
            row[:, 2] = pos
            rows.append(row)

    def out_edge_hits(self, v: int) -> np.ndarray:
        """(H, 3) int64 array of (level, partition_idx, edge_pos) hits
        across all levels AND the live buffers — buffer hits carry level
        `BUFFER_LEVEL` (-1) and address buffer j's append order.
        (Pre-ISSUE-5 the hit list silently skipped buffered edges, so
        positional column reads missed the newest data.) Built with one
        stack per slab, no per-edge Python objects — feed it straight to
        `columns_for_hits`."""
        vi = int(self.intervals.to_internal(v))
        rows: list = []
        for li, level in enumerate(self.levels):
            for pi, part in enumerate(level):
                self._add_hit_rows(rows, li, pi, part.out_edges(vi))
        for bj, buf in enumerate(self.buffers):
            if len(buf):
                self._add_hit_rows(rows, self.BUFFER_LEVEL, bj,
                                   np.asarray(buf.out_edges_of(vi)))
        if not rows:
            return np.empty((0, 3), np.int64)
        return np.concatenate(rows)

    def in_edge_hits(self, v: int) -> np.ndarray:
        """Like `out_edge_hits` for in-edges: only ONE partition per level
        (and one buffer) can own v's in-edges (paper: cost bounded by
        L_G + edges)."""
        vi = int(self.intervals.to_internal(v))
        rows: list = []
        for li, level in enumerate(self.levels):
            span = self.intervals.max_vertices // len(level)
            pi = vi // span
            self._add_hit_rows(rows, li, pi, level[pi].in_edges(vi))
        bj = self._top_index_of(vi)
        if len(self.buffers[bj]):
            self._add_hit_rows(rows, self.BUFFER_LEVEL, bj,
                               np.asarray(self.buffers[bj].in_edges_of(vi)))
        if not rows:
            return np.empty((0, 3), np.int64)
        return np.concatenate(rows)

    def out_edges(self, v: int) -> List[Tuple[int, int, int]]:
        """Tuple-list form of `out_edge_hits` (compatibility surface)."""
        return [(int(a), int(b), int(c)) for a, b, c in self.out_edge_hits(v)]

    def in_edges(self, v: int) -> List[Tuple[int, int, int]]:
        """Tuple-list form of `in_edge_hits` (compatibility surface)."""
        return [(int(a), int(b), int(c)) for a, b, c in self.in_edge_hits(v)]

    def columns_for_hits(self, hits, name: str) -> np.ndarray:
        """Positional column values for a hit array/list from
        `out_edge_hits` / `out_edges` (+ `in_` variants) — ONE vectorized
        gather per distinct slab instead of a Python loop per hit, and
        buffer hits (level -1) resolve against the staged columns, which
        the per-hit pattern could not address at all (ISSUE 5 satellite;
        bench_linkbench `edge_getrange`)."""
        dtype = self.column_dtypes.get(name, np.dtype(np.float64))
        h = np.asarray(hits, np.int64).reshape(-1, 3)
        if h.shape[0] == 0:
            return np.empty(0, dtype)
        out = np.empty(h.shape[0], dtype)
        width = max(len(self.buffers), len(self.levels[-1])) + 1
        slab_key = h[:, 0] * width + h[:, 1]
        for key in np.unique(slab_key):
            m = slab_key == key
            hm = h[m]
            li, pi = int(hm[0, 0]), int(hm[0, 1])
            pos = hm[:, 2]
            if li == self.BUFFER_LEVEL:
                col = self.buffers[pi].staging().columns.get(name)
            else:
                col = self.levels[li][pi].columns.get(name)
            out[m] = np.zeros(1, dtype) if col is None \
                else np.asarray(col)[pos]
        return out

    def out_neighbors(self, v: int) -> np.ndarray:
        vi = int(self.intervals.to_internal(v))
        chunks = []
        for level in self.levels:
            for part in level:
                pos = part.out_edges(vi)
                if pos.size:
                    chunks.append(part.dst[pos])
        for buf in self.buffers:
            if len(buf):
                idx = buf.out_edges_of(vi)
                if idx.size:
                    chunks.append(buf.staging().dst[idx])
        for lst in self._pending:  # drained batches whose merge is in flight
            for st in lst:
                hit = st.dst[st.src == vi]
                if hit.size:
                    chunks.append(hit)
        if not chunks:
            return np.empty(0, np.int64)
        return np.asarray(self.intervals.to_original(np.concatenate(chunks)))

    def in_neighbors(self, v: int) -> np.ndarray:
        vi = int(self.intervals.to_internal(v))
        chunks = []
        for level in self.levels:
            span = self.intervals.max_vertices // len(level)
            part = level[vi // span]
            pos = part.in_edges(vi)
            if pos.size:
                chunks.append(part.src[pos])
        # buffers partition by destination interval: only the owning buffer
        # (and its in-flight drains) can hold v's in-edges — probe just those
        bj = self._top_index_of(vi)
        buf = self.buffers[bj]
        if len(buf):
            idx = buf.in_edges_of(vi)
            if idx.size:
                chunks.append(buf.staging().src[idx])
        for st in self._pending[bj]:
            hit = st.src[st.dst == vi]
            if hit.size:
                chunks.append(hit)
        if not chunks:
            return np.empty(0, np.int64)
        return np.asarray(self.intervals.to_original(np.concatenate(chunks)))

    # -- updates / deletes (paper §5.3) ----------------------------------------------
    def update_edge_column(self, src: int, dst: int, name: str, value) -> bool:
        """Direct in-place column write on the newest matching edge."""
        isrc = int(self.intervals.to_internal(src))
        idst = int(self.intervals.to_internal(dst))
        # buffers are newest
        bj = self._top_index_of(idst)
        buf = self.buffers[bj]
        if len(buf):
            st = buf.staging()
            hit = np.nonzero((st.src == isrc) & (st.dst == idst))[0]
            if hit.size:
                buf.set_column(name, int(hit[-1]), value)
                if self.wal is not None:
                    self.wal.append_column(name, isrc, idst, value)
                return True
        for level in self.levels:
            span = self.intervals.max_vertices // len(level)
            part = level[idst // span]
            a, b = part.out_edge_range(isrc)
            pos = np.arange(a, b)
            pos = pos[part.dst[pos] == idst] if pos.size else pos
            pos = part._live(pos)
            if pos.size:
                part.set_column(name, pos[-1], value)
                if self.wal is not None:
                    self.wal.append_column(name, isrc, idst, value)
                return True
        return False

    def delete_edge(self, src: int, dst: int) -> bool:
        """Tombstone the edge everywhere it appears (purged at merges)."""
        isrc = int(self.intervals.to_internal(src))
        idst = int(self.intervals.to_internal(dst))
        found = False
        bj = self._top_index_of(idst)
        buf = self.buffers[bj]
        if len(buf):
            st = buf.staging()
            keep = ~((st.src == isrc) & (st.dst == idst))
            removed = int(keep.shape[0] - keep.sum())
            if removed:
                found = True
                buf.filter_mask(keep)
                self._buffered -= removed
        for level in self.levels:
            span = self.intervals.max_vertices // len(level)
            part = level[idst // span]
            a, b = part.out_edge_range(isrc)
            pos = np.arange(a, b)
            if pos.size:
                pos = pos[part.dst[pos] == idst]
                pos = part._live(pos)
                if pos.size:
                    part.tombstone(pos)
                    found = True
        if found:
            self.stats.deletes += 1
            if self.wal is not None:  # tombstones are durable pre-checkpoint
                self.wal.append_delete(isrc, idst)
            # targeted publish of exactly the touched dst path: tombstone
            # COW + buffer compaction left the old manifest bitwise-intact;
            # new readers must see the delete
            coords = [(li, idst // (self.intervals.max_vertices
                                    // len(level)))
                      for li, level in enumerate(self.levels)]
            self.publish_partitions(coords, (bj,))
        return found

    # -- exports ------------------------------------------------------------------
    @property
    def n_edges(self) -> int:
        n = sum(p.n_live_edges for lv in self.levels for p in lv)
        return n + self.total_buffered() + self._inflight_edges

    def all_partitions(self) -> List[EdgePartition]:
        return [p for lv in self.levels for p in lv]

    def snapshot(self, with_window_plan: bool = True):
        """Compile ALL levels plus the live in-memory buffers into an
        immutable `DeviceGraph` (jnp arrays) for the PSW / Pallas compute
        path — analytics run directly against the online store without
        flushing or otherwise mutating it. Edges are re-bucketed by
        destination interval and canonically (dst, src)-sorted, so the
        snapshot of an LSM store is bit-identical to the snapshot of a
        bulk-built GraphPAL holding the same live edges."""
        from .psw import build_device_graph
        return build_device_graph(self, with_window_plan=with_window_plan)

    def to_coo(self):
        ss, dd = [], []
        for part in self.all_partitions():
            live = np.ones(part.n_edges, bool) if part.dead is None else ~part.dead
            ss.append(part.src[live])
            dd.append(part.dst[live])
        for buf in self.buffers:
            if len(buf):
                st = buf.staging()
                ss.append(st.src)
                dd.append(st.dst)
        for lst in self._pending:
            for st in lst:
                ss.append(st.src)
                dd.append(st.dst)
        s = np.concatenate(ss) if ss else np.empty(0, np.int64)
        d = np.concatenate(dd) if dd else np.empty(0, np.int64)
        return (np.asarray(self.intervals.to_original(s)),
                np.asarray(self.intervals.to_original(d)))

    def wal_flush(self, fsync: bool = True) -> None:
        """Explicit durability point: push buffered WAL records to the OS
        and (optionally) to stable storage, regardless of sync policy."""
        if self.wal is not None:
            self.wal.flush(fsync=fsync)
        if self._wal is not None:
            self._wal.flush()
            if fsync:
                os.fsync(self._wal.fileno())

    def close(self) -> None:
        if self.wal is not None:
            self.wal.close()
            self.wal = None
        if self._wal is not None:
            self.wal_flush(fsync=True)
            self._wal.close()
            self._wal = None

    # -- WAL recovery (paper §7.3 durability) ----------------------------------------
    @staticmethod
    def replay_wal(path: str,
                   offset: int = 0) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Decode WAL records from byte `offset` on — a GraphDB manifest
        records the offset its persisted partitions cover, so recovery
        replays only the tail."""
        dt = np.dtype([("s", "<i8"), ("d", "<i8"), ("t", "i1")])
        with open(path, "rb") as f:
            f.seek(offset)
            buf = f.read()
        n = len(buf) // dt.itemsize  # a torn trailing record is dropped
        raw = np.frombuffer(buf[: n * dt.itemsize], dtype=dt)
        return raw["s"], raw["d"], raw["t"]
