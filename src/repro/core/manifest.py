"""Epoch-published level manifests — the lock-free live read path (ISSUE 5).

PR 4's service tier made *snapshot* reads writer-free, but every live read
still serialized with the writer (and with whole merges) through the single
service lock. This module removes the lock from the read path entirely with
the standard RCU/epoch scheme over the LSM's immutable building blocks:

  * `LevelManifest` — an immutable view descriptor of the whole store at one
    instant: every partition of every level (each captured together with its
    tombstone array *as of publication*), the sealed staging view of every
    top-level edge buffer, and the staging views of drained-but-not-yet-
    merged buffers in flight through the maintenance pipeline. Publishing a
    manifest is ONE reference assignment; nothing in a published manifest is
    ever mutated afterwards (writers copy-on-write the pieces they change —
    see `EdgePartition.tombstone` and `EdgeBuffer.filter_mask`).
  * `EpochGuard` — per-reader-thread pin slots with hazard-pointer style
    validation, plus a retired-manifest list for deferred reclamation: a
    superseded manifest (and hence the partition files it references) is
    only released once no reader pins a version at or below it. The store's
    checkpoint GC asks `pinned_digests` before deleting partition files, so
    a reader that pinned a manifest minutes ago can still lazily re-mmap a
    partition that merges have long since replaced.
  * `ManifestView` — a pinned manifest wrapped in the store duck-type the
    query layer speaks (`intervals` / `all_partitions` / `buffers` /
    `to_coo` / `storage_engine`), so FoF/BFS, batched engine queries, and
    out-of-core PSW streaming all run against one frozen, consistent state
    with ZERO writer coordination.

Consistency contract (DESIGN.md §9): the edge *structure* a pinned view
exposes (src/dst/etype/tombstones) is bitwise-equal to the store after some
prefix of the mutation log — publication happens only at mutation-batch and
merge-commit boundaries, and in-place structural mutation of published
state is impossible by construction. Attribute-column writes are the one
deliberate exception: like the paper's §5.3 direct positional writes they
are non-transactional, so a pinned view may observe a newer column value
(never a torn structure).
"""
from __future__ import annotations

import threading
import weakref
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import telemetry

_M_PUBLISHES = telemetry.counter("manifest.publishes")
_M_PINS = telemetry.counter("manifest.pins")
_M_RETIRES = telemetry.counter("manifest.retires")
_M_EPOCH = telemetry.gauge("manifest.epoch")
_M_PIN_LAG = telemetry.gauge("manifest.pin_lag")

__all__ = [
    "EpochGuard",
    "LevelManifest",
    "ManifestPartition",
    "ManifestView",
]


class ManifestPartition:
    """One partition as captured by a manifest: the (immutable) partition
    plus its tombstone array *at publication*. `tombstone()` on the live
    partition copies-on-write once it has been sealed by a publish, so the
    reference held here never changes content. Everything else is forwarded
    to the partition — its arrays, indexes, and files are immutable by the
    LSM's construction."""

    __slots__ = ("part", "dead")

    def __init__(self, part):
        self.part = part
        self.dead: Optional[np.ndarray] = part.dead

    def __getattr__(self, name):
        return getattr(self.part, name)

    @property
    def n_live_edges(self) -> int:
        if self.dead is None:
            return self.part.n_edges
        return int(self.part.n_edges - self.dead.sum())


class LevelManifest:
    """Immutable descriptor of the store's entire live read state.

    `stagings[j]` is buffer j's frozen staging view; `pending[j]` holds the
    staging views of buffer j's drained batches whose merge has not yet
    committed — a reader that includes them sees exactly the pre-merge
    logical state, and the commit publish atomically swaps them for the
    merged partitions. `wal_tail` is informational (feedback scheduling).
    `cache` memoizes derived read structures (engine slab lists): a
    manifest is immutable, so they are built once and shared by every
    reader thread pinning it (idempotent benign-race fills). A slotted
    plain class, not a dataclass — one of these is constructed on EVERY
    single-edge insert, and dataclass/`replace` overhead measurably taxed
    the write path."""

    __slots__ = ("version", "levels", "stagings", "pending", "wal_tail",
                 "cache")

    def __init__(self, version: int,
                 levels: Tuple[Tuple[ManifestPartition, ...], ...],
                 stagings: Tuple, pending: Tuple, wal_tail: int = 0):
        self.version = version
        self.levels = levels
        self.stagings = stagings
        self.pending = pending
        self.wal_tail = wal_tail
        self.cache: Dict = {}

    def with_stagings(self, version: int, stagings: Tuple,
                      wal_tail: Optional[int] = None) -> "LevelManifest":
        """The insert-path splice: same partitions/pending, new buffer
        stagings, fresh cache. `wal_tail` updates the manifest's logical
        offset (the insert path passes the post-append tail so the manifest
        is *addressable*: pinning a session at exactly `wal_tail` replays
        to exactly this manifest's logical state)."""
        return LevelManifest(version, self.levels, stagings, self.pending,
                             self.wal_tail if wal_tail is None else wal_tail)

    def partitions(self) -> List[ManifestPartition]:
        return [p for lv in self.levels for p in lv]

    def derived(self, key, builder):
        """Memoized derived read structure (engine slab lists, multihop
        dense plans, edge-key sets). A manifest is immutable, so the build
        is idempotent: concurrent readers may race to fill the same key and
        one winner's value sticks — no lock, no staleness."""
        val = self.cache.get(key)
        if val is None:
            val = self.cache[key] = builder()
        return val

    def staging_slabs(self):
        """(staging, interval) for every buffer + in-flight staging, the
        interval being the fed top-level partition's."""
        out = []
        for j, mp in enumerate(self.levels[0]):
            for st in self.pending[j]:
                if st.src.shape[0]:
                    out.append((st, mp.part.interval))
            st = self.stagings[j]
            if st.src.shape[0]:
                out.append((st, mp.part.interval))
        return out

    @property
    def n_edges(self) -> int:
        n = sum(p.n_live_edges for p in self.partitions())
        for st, _ in self.staging_slabs():
            n += int(st.src.shape[0])
        return n


class _Slot:
    """One reader thread's pin slot: the manifest versions it currently
    holds (a stack — nested views are allowed), plus a weak ref to the
    owning thread so slots of exited threads can be pruned."""

    __slots__ = ("pins", "thread")

    def __init__(self):
        self.pins: List[int] = []
        self.thread = weakref.ref(threading.current_thread())


class EpochGuard:
    """Epoch-based publication + deferred reclamation over LevelManifests.

    Writers (serialized among themselves by the caller — the service lock,
    or plain single-threaded use) swap `current` via `publish`. Readers pin
    with hazard-pointer validation: write the version into the thread's
    slot, then re-check that the manifest is still current — if a publish
    raced in between, retry. Once a pin is visible, `trim` keeps every
    retired manifest at or above the minimum pinned version, which keeps
    alive (a) the Python object graph — partitions, staging arrays — by
    plain reference, and (b) the on-disk partition files, because checkpoint
    GC consults `pinned_digests` callers build from `live_manifests`."""

    def __init__(self):
        self.current: Optional[LevelManifest] = None
        self._retired: List[LevelManifest] = []
        self._tls = threading.local()
        self._slots: List[_Slot] = []
        self._slots_lock = threading.Lock()

    # -- reader side (lock-free: no writer-shared mutex) ----------------------
    def _slot(self) -> _Slot:
        slot = getattr(self._tls, "slot", None)
        if slot is None:
            slot = _Slot()
            with self._slots_lock:  # registration only, once per thread
                # prune slots of exited threads (no live pins) so a
                # thread-churning service doesn't grow the scan set —
                # amortized over registrations, which are rare
                self._slots = [s for s in self._slots
                               if s.pins or s.thread() is not None]
                self._slots.append(slot)
            self._tls.slot = slot
        return slot

    def pin(self) -> Tuple[LevelManifest, _Slot]:
        """Pin and return the current manifest. The validation loop closes
        the classic epoch race: if a publish superseded (and possibly
        reclaimed) the manifest between our read and our pin becoming
        visible, the re-check fails and we retry on the new current."""
        slot = self._slot()
        _M_PINS.inc()
        while True:
            m = self.current
            slot.pins.append(m.version)
            if self.current is m:
                return m, slot
            slot.pins.remove(m.version)

    def unpin(self, slot: _Slot, version: int) -> None:
        slot.pins.remove(version)

    # -- writer side (caller-serialized) --------------------------------------
    def publish(self, manifest: LevelManifest) -> None:
        old = self.current
        self.current = manifest  # the atomic swap: readers see old or new
        _M_PUBLISHES.inc()
        _M_EPOCH.set(manifest.version)
        if old is not None:
            if not self._slots:
                # fast path: no reader thread has EVER registered a pin
                # slot, so nothing can still hold `old` — registration
                # precedes pinning, and a pin of `old` validated before
                # this swap implies its slot was already visible here
                self._retired.clear()
                _M_RETIRES.inc()  # `old` reclaimed immediately
                _M_PIN_LAG.set(0)
            else:
                self._retired.append(old)
                self.trim()

    def pinned_versions(self) -> set:
        """The exact manifest versions readers currently pin. A pin only
        ever dereferences its own version (pin() records the version of
        the manifest it returned), so retirement can filter by exact
        membership — one long-lived reader at version V must NOT retain
        every manifest published after V."""
        out: set = set()
        with self._slots_lock:
            slots = list(self._slots)
        reclaimed = False
        for slot in slots:
            if slot.pins:
                t = slot.thread()
                if t is None or not t.is_alive():
                    # the owning thread exited without unpinning (ISSUE 7
                    # satellite): it can never dereference the pin again,
                    # so counting it would block manifest retirement and
                    # store GC forever. Reclaim the abandoned slot — no
                    # race: only the (dead) owner ever appends to it.
                    slot.pins.clear()
                    reclaimed = True
                    continue
            out.update(slot.pins)
        if reclaimed:
            with self._slots_lock:
                live = []
                for s in self._slots:
                    t = s.thread()
                    if s.pins or (t is not None and t.is_alive()):
                        live.append(s)
                self._slots = live
        return out

    def min_pinned(self) -> Optional[int]:
        pins = self.pinned_versions()
        return min(pins) if pins else None

    def trim(self) -> int:
        """Drop retired manifests no pinned reader can still be using.
        Returns how many stayed deferred."""
        if not self._retired:
            return 0
        pins = self.pinned_versions()
        before = len(self._retired)
        if not pins:
            self._retired.clear()
        else:
            self._retired = [m for m in self._retired if m.version in pins]
        dropped = before - len(self._retired)
        if dropped:
            _M_RETIRES.inc(dropped)
        cur = self.current
        if cur is not None:
            oldest = min(pins) if pins else cur.version
            _M_PIN_LAG.set(int(cur.version - oldest))
        return len(self._retired)

    def live_manifests(self) -> List[LevelManifest]:
        """Current + every retired-but-possibly-pinned manifest — the set
        whose partition files must survive GC."""
        self.trim()
        out = list(self._retired)
        if self.current is not None:
            out.append(self.current)
        return out


class _FrozenBuffer:
    """Duck-type shim presenting a frozen BufferStaging as an EdgeBuffer to
    code that iterates `store.buffers` (PSW bucket streaming)."""

    __slots__ = ("_st",)

    def __init__(self, st):
        self._st = st

    def __len__(self) -> int:
        return int(self._st.src.shape[0])

    def staging(self):
        return self._st


class ManifestView:
    """A pinned, read-only, self-consistent view of a live store.

    Obtained from `LSMTree.read_view()` (or `GraphDB` / `ServiceDB`
    delegation); use as a context manager, or call `release()` when done —
    holding a view defers reclamation of everything it references. All
    queries on one view answer from ONE published manifest: a traversal that
    issues many batched calls against `storage_engine()` sees a single
    frozen state regardless of concurrent writers and merges."""

    def __init__(self, tree, manifest: LevelManifest, slot: _Slot):
        self.tree = tree
        self.manifest = manifest
        self._slot = slot
        self._engine = None
        self._released = False

    # -- lifecycle ------------------------------------------------------------
    def release(self) -> None:
        if not self._released:
            self._released = True
            self.tree.epochs.unpin(self._slot, self.manifest.version)

    close = release

    def __enter__(self) -> "ManifestView":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __del__(self):  # backstop: a leaked view must not pin files forever
        try:
            self.release()
        except Exception:
            pass

    # -- store duck type ------------------------------------------------------
    @property
    def intervals(self):
        return self.tree.intervals

    @property
    def column_dtypes(self) -> Dict[str, np.dtype]:
        return self.tree.column_dtypes

    @property
    def version(self) -> int:
        return self.manifest.version

    @property
    def wal_tail(self) -> int:
        """The WAL offset this view's manifest is addressable at: every
        targeted publish stamps the post-append tail (ISSUE 8), so a
        snapshot pinned at exactly this offset replays to exactly this
        view's logical state — the bridge that lets an epoch view cross a
        process boundary via `GraphDB.pin_snapshot(pinned_offset=...)`."""
        return self.manifest.wal_tail

    @property
    def n_edges(self) -> int:
        return self.manifest.n_edges

    def all_partitions(self) -> List[ManifestPartition]:
        return self.manifest.partitions()

    @property
    def levels(self):
        return self.manifest.levels

    @property
    def buffers(self) -> List[_FrozenBuffer]:
        """Frozen buffer shims (live stagings + in-flight drains) for code
        that streams `store.buffers` — e.g. `psw.stream_interval_buckets`."""
        return [_FrozenBuffer(st) for st, _ in self.manifest.staging_slabs()]

    def storage_engine(self):
        if self._engine is None:
            from .engine import ManifestEngine
            self._engine = ManifestEngine(self)
        return self._engine

    # -- queries (all answered from the pinned manifest) ----------------------
    def out_neighbors(self, v: int) -> np.ndarray:
        vals, _ = self.storage_engine().out_neighbors_batch([v])
        return vals

    def in_neighbors(self, v: int) -> np.ndarray:
        vals, _ = self.storage_engine().in_neighbors_batch([v])
        return vals

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        iv = self.tree.intervals
        ss, dd = [], []
        for mp in self.manifest.partitions():
            if mp.part.n_edges == 0:
                continue
            if mp.dead is None:
                ss.append(np.asarray(mp.part.src))
                dd.append(np.asarray(mp.part.dst))
            else:
                live = ~mp.dead
                ss.append(np.asarray(mp.part.src)[live])
                dd.append(np.asarray(mp.part.dst)[live])
        for st, _ in self.manifest.staging_slabs():
            ss.append(st.src)
            dd.append(st.dst)
        s = np.concatenate(ss) if ss else np.empty(0, np.int64)
        d = np.concatenate(dd) if dd else np.empty(0, np.int64)
        return (np.asarray(iv.to_original(s)), np.asarray(iv.to_original(d)))

    def snapshot(self, **kw):
        """Compile the pinned state into a DeviceGraph (PSW analytics)."""
        from .psw import build_device_graph
        return build_device_graph(self, **kw)
