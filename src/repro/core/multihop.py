"""Vectorized multi-hop execution over PAL/LSM slabs (DESIGN.md §10).

The query layer's single-hop primitives (engine.py) already beat per-vertex
calls ~40x by batching a whole frontier per slab probe; this module applies
the same set-at-a-time treatment ACROSS hops. A multi-hop query is composed
from four columnar operators — the factorized-list style of Gupta et al.
(PAPERS.md) over the paper's partitioned adjacency lists:

  * `expand`    — one hop for the whole frontier at once: flat
                  (owner, neighbor) pairs straight off the slab scan, no
                  per-vertex regrouping (engine.expand_frontier);
  * `filter`    — `EdgePredicate`, pushed INTO the slab scan: the predicate
                  is evaluated on edge-array positions before the endpoint
                  gather, so non-matching edges never materialize;
  * `semijoin`  — membership of packed keys against a sorted key set
                  (searchsorted), used for per-seed exclusion sets,
                  visited-set subtraction, and edge-set closure probes;
  * `aggregate` — distinct/count reduction of packed (group, value) keys
                  via one sort-unique.

Everything between engine calls is columnar numpy on packed int64 keys
(`group * n_internal_vertices + vertex`); per-hop dedup and frontier
compaction are sort/unique/searchsorted, never a Python loop over vertices.

Dense frontiers additionally get a device path: a `FrontierPlan`
(kernels/frontier_expand) lays the store's deduplicated edge set out as
virtual-row ELL tiles and a Pallas kernel expands indicator columns on the
accelerator; `khop(dense="auto")` picks sparse probes, a bottom-up edge
stream, or the kernel by frontier density (§10.3). Plans and packed edge-key
sets are memoized on the engine's `plan_cache()` keyed by `cache_token()`,
so a `ManifestView` shares them across every reader of one publication and
a mutated store can never serve a stale plan.

All operators speak only the `StorageEngine` protocol — they run identically
on a live `LSMTree`, a bulk `GraphPAL`, an mmap-backed `GraphDB`, and a
lock-free `ManifestView` epoch snapshot.
"""
from __future__ import annotations

import dataclasses
import operator
import time
from typing import Any, Optional, Sequence

import numpy as np

from . import telemetry
from .engine import StorageEngine, _expand_ranges, as_engine

_M_HOPS = telemetry.counter("multihop.hops")
_M_HOP_S = telemetry.histogram("multihop.hop.seconds")

GraphLike = Any

__all__ = [
    "EdgePredicate",
    "KHopResult",
    "TwoHopResult",
    "aggregate_counts",
    "compact_frontier",
    "dense_plan",
    "expand",
    "khop",
    "semijoin",
    "triangle_count",
    "two_hop_counts",
]

# dense plans keep (n_internal_vertices × frontier_block) float32 indicator
# panels resident; past this vertex count the panel alone would dwarf the
# frontier work, so `dense="auto"` never picks the kernel path above it
DENSE_MAX_VERTICES = 4_000_000
_SEED_BLOCK = 128  # dense 2-hop: one kernel feature-tile of seed columns


# ---------------------------------------------------------------------------
# Columnar set primitives (sorted int64 arrays)
# ---------------------------------------------------------------------------
def compact_frontier(ids) -> np.ndarray:
    """Sorted-unique int64 frontier from any raw id batch."""
    return np.unique(np.asarray(ids, np.int64).ravel())


def semijoin(keys: np.ndarray, table: np.ndarray) -> np.ndarray:
    """Membership mask of `keys` (any order) against a SORTED key set —
    one searchsorted, the operator behind exclusion sets and closure
    probes."""
    keys = np.asarray(keys, np.int64)
    if table.shape[0] == 0:
        return np.zeros(keys.shape[0], bool)
    i = np.minimum(np.searchsorted(table, keys), table.shape[0] - 1)
    return table[i] == keys


def aggregate_counts(keys: np.ndarray):
    """Distinct packed keys + multiplicities: one sort-unique, the columnar
    GROUP BY COUNT over (group, value) keys."""
    return np.unique(np.asarray(keys, np.int64), return_counts=True)


def _setdiff_sorted(a: np.ndarray, table: np.ndarray) -> np.ndarray:
    """a (sorted) minus a sorted key set, order preserved."""
    return a[~semijoin(a, table)]


def _union_sorted(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Union of two sorted arrays with b disjoint from a (one merge pass)."""
    if a.shape[0] == 0:
        return b
    if b.shape[0] == 0:
        return a
    return np.insert(a, np.searchsorted(a, b), b)


def _csr_offsets(groups: np.ndarray, n_groups: int) -> np.ndarray:
    offsets = np.zeros(n_groups + 1, np.int64)
    np.cumsum(np.bincount(groups, minlength=n_groups), out=offsets[1:])
    return offsets


# ---------------------------------------------------------------------------
# filter — predicate pushdown into the slab scan
# ---------------------------------------------------------------------------
_OPS = {
    "==": operator.eq, "!=": operator.ne,
    "<": operator.lt, "<=": operator.le,
    ">": operator.gt, ">=": operator.ge,
}


@dataclasses.dataclass(frozen=True)
class EdgePredicate:
    """Edge filter evaluated per slab on edge-array POSITIONS, before any
    endpoint gather — the engine drops failing positions, so filtered-out
    edges never reach the query layer (only their etype/attribute cells are
    read, positionally, per the paper's columnar edge-value layout §4.3).

    `etype` filters the type column; `column`/`op`/`value` filter one named
    attribute column. Both present means AND."""

    etype: Optional[int] = None
    column: Optional[str] = None
    op: str = ">="
    value: float = 0.0

    def mask(self, slab, pos: np.ndarray) -> np.ndarray:
        keep = np.ones(pos.shape[0], bool)
        if self.etype is not None:
            keep &= np.asarray(slab.etype_at(pos)) == self.etype
        if self.column is not None:
            col = np.asarray(slab.column_at(self.column, pos, np.float64))
            keep &= _OPS[self.op](col, self.value)
        return keep


# ---------------------------------------------------------------------------
# expand — one whole-frontier hop
# ---------------------------------------------------------------------------
def expand(g: GraphLike, frontier, direction: str = "out",
           predicate: Optional[EdgePredicate] = None):
    """One hop for the whole frontier: flat (owner index, neighbor) pairs in
    original ids, ungrouped. The multi-hop building block — downstream
    operators re-sort by packed keys anyway, so the per-vertex CSR regroup
    of `out_neighbors_batch` is skipped."""
    return as_engine(g).expand_frontier(frontier, direction, predicate)


def _expand_grouped(eng: StorageEngine, vs: np.ndarray, direction: str,
                    predicate: Optional[EdgePredicate]):
    """CSR regrouping of expand() by owner: (values, offsets) like
    `out_neighbors_batch`, but predicate-capable."""
    owner, nb = eng.expand_frontier(vs, direction, predicate)
    order = np.argsort(owner, kind="stable")
    return nb[order], _csr_offsets(owner, vs.shape[0])


def _expand_stream(eng: StorageEngine, frontier: np.ndarray,
                   direction: str = "out") -> np.ndarray:
    """Bottom-up expansion (Beamer / paper §7.4): stream every live edge
    once and keep endpoints whose other side is in the frontier — O(|E|)
    sequential, cheaper than per-slab probes once the frontier is a large
    fraction of V."""
    iv = eng.intervals
    n = eng.n_internal_vertices
    mask = np.zeros(n + 1, bool)
    mask[np.minimum(frontier, n)] = True
    out = []
    for chunk in eng.edge_chunks():
        key = chunk.src if direction == "out" else chunk.dst
        m = mask[np.asarray(iv.to_original(key), np.int64)]
        if m.any():
            other = chunk.dst if direction == "out" else chunk.src
            out.append(np.asarray(iv.to_original(other[m]), np.int64))
    if not out:
        return np.empty(0, np.int64)
    return np.unique(np.concatenate(out))


# ---------------------------------------------------------------------------
# Dense path: virtual-row ELL plan + Pallas frontier-expansion kernel
# ---------------------------------------------------------------------------
_PLAN_KEY = "multihop:dense_plan"
_EDGE_KEYS = "multihop:edge_keys"


def _memoized(eng: StorageEngine, name: str, builder):
    token = eng.cache_token()
    if token is None:
        return builder()
    cache = eng.plan_cache()
    key = (name, token)
    val = cache.get(key)
    if val is None:
        val = cache[key] = builder()
    return val


def _edge_keys_internal(eng: StorageEngine) -> np.ndarray:
    """Sorted-unique packed (src * M + dst) keys of the live edge set,
    internal ids — the closure table for semijoin probes (triangles) and
    the input to dense plans. Memoized per store content."""
    def build():
        M = np.int64(eng.n_internal_vertices)
        parts = [np.asarray(c.src, np.int64) * M + np.asarray(c.dst, np.int64)
                 for c in eng.edge_chunks()]
        if not parts:
            return np.empty(0, np.int64)
        return np.unique(np.concatenate(parts))
    return _memoized(eng, _EDGE_KEYS, build)


def dense_plan(g: GraphLike, direction: str = "out"):
    """Build (or fetch the memoized) frontier-expansion plan: the store's
    deduplicated edge set as destination-grouped virtual-row ELL tiles
    (kernels/frontier_expand). `direction="in"` builds the transposed
    plan."""
    eng = as_engine(g)
    M = eng.n_internal_vertices
    if M > DENSE_MAX_VERTICES:
        raise ValueError(
            f"dense plan disabled above {DENSE_MAX_VERTICES} internal "
            f"vertices (store has {M}): the indicator panel would dominate")

    def build():
        from ..kernels.frontier_expand import build_frontier_plan
        keys = _edge_keys_internal(eng)
        s = keys // M
        d = keys % M
        if direction == "out":
            return build_frontier_plan(s, d, n_src=M, n_dst=M)
        return build_frontier_plan(d, s, n_src=M, n_dst=M)

    return _memoized(eng, (_PLAN_KEY, direction), build)


def _plan_cached(eng: StorageEngine, direction: str) -> bool:
    token = eng.cache_token()
    return (token is not None
            and ((_PLAN_KEY, direction), token) in eng.plan_cache())


def _expand_dense(eng: StorageEngine, frontier: np.ndarray,
                  direction: str) -> np.ndarray:
    """Kernel hop: scatter the frontier into a one-column indicator, run the
    frontier-expansion kernel, read back the touched destinations."""
    from ..kernels.frontier_expand import frontier_expand_counts
    plan = dense_plan(eng, direction)
    iv = eng.intervals
    x = np.zeros((eng.n_internal_vertices, 1), np.float32)
    x[np.asarray(iv.to_internal(frontier), np.int64), 0] = 1.0
    counts = frontier_expand_counts(plan, x)
    nxt = np.flatnonzero(counts[:, 0] > 0)
    return np.sort(np.asarray(iv.to_original(nxt), np.int64))


def _hop_mode(eng: StorageEngine, frontier_size: int, dense: str,
              threshold: float, predicate) -> str:
    """The density heuristic (§10.3). Predicates force the sparse path —
    pushdown only exists in the slab scan. Below `threshold · |V|` the
    frontier is sparse: per-slab searchsorted probes touch only adjacent
    edges. Above it, every edge is worth a look: use the Pallas plan when
    one is already memoized for this store content (repeated analytics
    amortized it) and the store is small enough to hold indicator panels;
    otherwise a one-shot bottom-up edge stream, which needs no prep."""
    if predicate is not None or dense == "never":
        return "sparse"
    supported = getattr(eng, "supported_hop_modes",
                        ("sparse", "stream", "kernel"))
    if dense in ("kernel", "stream"):
        # an engine that cannot serve the requested mode (the sharded
        # scatter/gather engine only probes — ISSUE 8) clamps to sparse
        # rather than erroring: mode is an execution hint, not semantics
        return dense if dense in supported else "sparse"
    if frontier_size <= threshold * eng.n_internal_vertices:
        return "sparse"
    if ("kernel" in supported and _plan_cached(eng, "out")
            and eng.n_internal_vertices <= DENSE_MAX_VERTICES):
        return "kernel"
    return "stream" if "stream" in supported else "sparse"


# ---------------------------------------------------------------------------
# k-hop expansion (BFS levels) with columnar visited-set management
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class KHopResult:
    """levels[d] = vertices first reached at depth d (sorted); levels[0] is
    the compacted seed set. visited = sorted union of all levels."""

    levels: list
    visited: np.ndarray

    def depth_of(self, v: int) -> Optional[int]:
        for d, lv in enumerate(self.levels):
            i = np.searchsorted(lv, v)
            if i < lv.shape[0] and lv[i] == v:
                return d
        return None


def khop(g: GraphLike, seeds, k: int, direction: str = "out",
         predicate: Optional[EdgePredicate] = None, dense: str = "auto",
         dense_threshold: float = 0.05) -> KHopResult:
    """Whole-frontier k-hop expansion. Each hop expands the previous level
    in ONE engine call (or one kernel launch / edge stream, per the density
    heuristic), then subtracts the visited set and merges — all columnar.
    With `predicate`, only edges passing the pushed-down filter are
    traversed (attribute-filtered traversal)."""
    eng = as_engine(g)
    frontier = compact_frontier(seeds)
    visited = frontier
    levels = [frontier]
    for hop in range(k):
        if frontier.shape[0] == 0:
            break
        mode = _hop_mode(eng, frontier.shape[0], dense, dense_threshold,
                         predicate)
        with telemetry.span("multihop.hop", hop=hop, mode=mode,
                            frontier=int(frontier.shape[0])) as sp:
            t0 = time.perf_counter()
            if mode == "kernel":
                nxt = _expand_dense(eng, frontier, direction)
            elif mode == "stream":
                nxt = _expand_stream(eng, frontier, direction)
            else:
                _, nb = eng.expand_frontier(frontier, direction, predicate)
                nxt = np.unique(nb)
            fresh = _setdiff_sorted(nxt, visited)
            sp.tag(fresh=int(fresh.shape[0]))
            _M_HOPS.inc(label=mode)
            _M_HOP_S.observe(time.perf_counter() - t0)
        if fresh.shape[0] == 0:
            break
        visited = _union_sorted(visited, fresh)
        levels.append(fresh)
        frontier = fresh
    return KHopResult(levels, visited)


# ---------------------------------------------------------------------------
# 2-hop intersection: friends-of-friends with counts
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TwoHopResult:
    """CSR per seed: ids[offsets[i]:offsets[i+1]] are seed i's two-hop
    vertices (sorted), counts[...] the number of DISTINCT middle friends
    through which each is reachable — the paper's FoF answer (§8.4) plus
    the intersection cardinality."""

    seeds: np.ndarray
    offsets: np.ndarray
    ids: np.ndarray
    counts: np.ndarray

    def slice_of(self, i: int) -> slice:
        return slice(int(self.offsets[i]), int(self.offsets[i + 1]))


def _empty_two_hop(seeds: np.ndarray) -> TwoHopResult:
    return TwoHopResult(seeds, np.zeros(seeds.shape[0] + 1, np.int64),
                        np.empty(0, np.int64), np.empty(0, np.int64))


def two_hop_counts(g: GraphLike, seeds, direction: str = "out",
                   max_friends: Optional[int] = None, exclude: bool = True,
                   predicate: Optional[EdgePredicate] = None,
                   dense: str = "never") -> TwoHopResult:
    """Friends-of-friends with counts for a whole seed batch: expand twice,
    dedup (seed, friend) and (path, target) pairs on packed keys, aggregate
    distinct middles per (seed, target), and semijoin away the seeds' own
    friend sets (`exclude`, the paper's selectOut filter).

    `max_friends` truncates each seed's friend list to its first
    `max_friends` in sorted id order — bitwise the per-seed semantics of
    `query.friends_of_friends`. `dense="kernel"` routes both hops through
    the Pallas frontier-expansion plan (requires no predicate/truncation);
    results are bitwise-identical to the sparse path (§10.4)."""
    n_seeds = int(np.asarray(seeds).size)
    with telemetry.span("multihop.two_hop", seeds=n_seeds, dense=dense):
        return _two_hop_counts(g, seeds, direction, max_friends, exclude,
                               predicate, dense)


def _two_hop_counts(g, seeds, direction, max_friends, exclude, predicate,
                    dense) -> TwoHopResult:
    eng = as_engine(g)
    seeds = np.asarray(seeds, np.int64).ravel()
    S = seeds.shape[0]
    if S == 0:
        return _empty_two_hop(seeds)
    if dense == "kernel":
        if predicate is not None or max_friends is not None:
            raise ValueError("dense 2-hop supports neither predicates nor "
                             "max_friends truncation")
        return _two_hop_dense(eng, seeds, direction, exclude)
    M = np.int64(eng.n_internal_vertices)

    # hop 1 + aggregate: distinct (seed, friend), sorted by packed key
    owner, nb = eng.expand_frontier(seeds, direction, predicate)
    fk = np.unique(owner * M + nb)
    s_idx, fr = fk // M, fk % M
    if max_friends is not None:
        cnt = np.bincount(s_idx, minlength=S)
        starts = np.repeat(np.cumsum(cnt) - cnt, cnt)
        keep = np.arange(fk.shape[0]) - starts < max_friends
        fk, s_idx, fr = fk[keep], s_idx[keep], fr[keep]
    if fr.shape[0] == 0:
        return _empty_two_hop(seeds)

    # hop 2 on the UNIQUE friend set, joined back to (seed, friend) pairs
    uf = np.unique(fr)
    vals, offs = _expand_grouped(eng, uf, direction, predicate)
    fpos = np.searchsorted(uf, fr)
    pos, pair = _expand_ranges(offs[fpos], offs[fpos + 1],
                               np.arange(fr.shape[0], dtype=np.int64))
    # aggregate twice: distinct (path, target) collapses multi-edges, then
    # distinct-middle counts per (seed, target)
    pk = np.unique(pair * M + vals[pos])
    sk, counts = aggregate_counts(s_idx[pk // M] * M + pk % M)
    if exclude:
        selfk = np.arange(S, dtype=np.int64) * M + seeds
        keep = ~(semijoin(sk, fk) | semijoin(sk, selfk))
        sk, counts = sk[keep], counts[keep]
    return TwoHopResult(seeds, _csr_offsets(sk // M, S), sk % M,
                        counts.astype(np.int64))


def _two_hop_dense(eng: StorageEngine, seeds: np.ndarray, direction: str,
                   exclude: bool) -> TwoHopResult:
    """Kernel 2-hop: seeds become indicator columns; hop 1 is binarized to
    the distinct-friend panel, hop 2's accumulation IS the distinct-middle
    count (float32 counts are integer-exact far below 2**24). Seeds stream
    through in `_SEED_BLOCK`-column panels — one kernel feature tile."""
    from ..kernels.frontier_expand import frontier_expand_counts
    plan = dense_plan(eng, direction)
    iv = eng.intervals
    M = np.int64(eng.n_internal_vertices)
    S = seeds.shape[0]
    si = np.asarray(iv.to_internal(seeds), np.int64)
    sk_parts, cnt_parts, fk_parts = [], [], []
    for c0 in range(0, S, _SEED_BLOCK):
        blk = si[c0:c0 + _SEED_BLOCK]
        x = np.zeros((int(M), blk.shape[0]), np.float32)
        x[blk, np.arange(blk.shape[0])] = 1.0
        c1 = frontier_expand_counts(plan, x)            # (M, B) 0/1: edges
        c2 = frontier_expand_counts(plan, (c1 > 0).astype(np.float32))
        w, j = np.nonzero(c2)
        cnt_parts.append(np.rint(c2[w, j]).astype(np.int64))
        wo = np.asarray(iv.to_original(w), np.int64)
        sk_parts.append((c0 + j) * M + wo)
        if exclude:
            fw, fj = np.nonzero(c1)
            fk_parts.append((c0 + fj) * M
                            + np.asarray(iv.to_original(fw), np.int64))
    if not sk_parts:
        return _empty_two_hop(seeds)
    sk = np.concatenate(sk_parts)
    counts = np.concatenate(cnt_parts)
    if exclude:
        fk = np.sort(np.concatenate(fk_parts)) if fk_parts \
            else np.empty(0, np.int64)
        selfk = np.arange(S, dtype=np.int64) * M + seeds
        keep = ~(semijoin(sk, fk) | semijoin(sk, selfk))
        sk, counts = sk[keep], counts[keep]
    order = np.argsort(sk)  # (seed, target-id) order, matching sparse
    sk, counts = sk[order], counts[order]
    return TwoHopResult(seeds, _csr_offsets(sk // M, S), sk % M, counts)


# ---------------------------------------------------------------------------
# Triangle counting: wedge cross-product + edge-set semijoin
# ---------------------------------------------------------------------------
def triangle_count(g: GraphLike, middles=None,
                   wedge_budget: int = 4_000_000) -> int:
    """Directed closed-wedge count: |{(u, v, w) : u→v, v→w, u→w}| over the
    DISTINCT edge set, summed per middle vertex v. Per chunk of middles the
    (distinct in-nbr × distinct out-nbr) wedge cross-product is built
    columnar and semijoined against the packed edge-key set; `wedge_budget`
    bounds resident wedges (chunks are sized by the degree product
    estimate, fetched via the no-gather degree batch)."""
    eng = as_engine(g)
    iv = eng.intervals
    M = np.int64(eng.n_internal_vertices)
    ekeys = _edge_keys_internal(eng)
    if ekeys.shape[0] == 0:
        return 0
    if middles is None:
        # only a vertex with both in- and out-edges closes a wedge
        mids_i = np.intersect1d(np.unique(ekeys // M), np.unique(ekeys % M),
                                assume_unique=True)
        mids = np.sort(np.asarray(iv.to_original(mids_i), np.int64))
    else:
        mids = compact_frontier(middles)
    if mids.shape[0] == 0:
        return 0
    est = eng.in_degree_batch(mids) * eng.out_degree_batch(mids)
    nz = est > 0
    mids, est = mids[nz], est[nz]
    total = 0
    cum = np.cumsum(est)
    start = 0
    while start < mids.shape[0]:
        limit = (cum[start - 1] if start else 0) + wedge_budget
        stop = max(int(np.searchsorted(cum, limit, side="right")), start + 1)
        total += _triangle_chunk(eng, mids[start:stop], ekeys, M)
        start = stop
    return int(total)


def _triangle_chunk(eng: StorageEngine, mids: np.ndarray, ekeys: np.ndarray,
                    M: np.int64) -> int:
    iv = eng.intervals
    o_in, u = eng.expand_frontier(mids, "in")
    if u.shape[0] == 0:
        return 0
    o_out, w = eng.expand_frontier(mids, "out")
    if w.shape[0] == 0:
        return 0
    # aggregate to distinct (middle, neighbor), internal ids for the probe
    ik = np.unique(o_in * M + np.asarray(iv.to_internal(u), np.int64))
    ok = np.unique(o_out * M + np.asarray(iv.to_internal(w), np.int64))
    io_, iu = ik // M, ik % M
    oo_, ow = ok // M, ok % M
    ooff = _csr_offsets(oo_, mids.shape[0])
    # expand: every in-entry against its middle's whole out-range
    pos, ie = _expand_ranges(ooff[io_], ooff[io_ + 1],
                             np.arange(io_.shape[0], dtype=np.int64))
    if pos.shape[0] == 0:
        return 0
    # semijoin the wedges against the edge-set closure table
    return int(semijoin(iu[ie] * M + ow[pos], ekeys).sum())
