"""Partitioned Adjacency Lists (PAL) — the paper's core data structure.

Faithful to GraphChi-DB (Kyrola & Guestrin, 2014) §4 with the TPU adaptation
documented in DESIGN.md §2:

  * the vertex-ID range is split into P intervals; edge-partition(i) stores
    every edge whose *destination* lies in interval(i), sorted by *source*;
  * each edge is stored exactly once, both directions are queryable;
  * the paper's in-edge linked list (next-with-same-dst offsets) is replaced
    by an immutable dst-sort permutation + dst pointer array (CSC within the
    partition) — pointer chasing has no TPU analogue;
  * edge attributes are columnar and positional: the edge's index in the
    edge-array is the key into every column (paper §4.3);
  * vertex attributes are columnar per interval with O(1) positional access
    (paper §4.4);
  * interval balancing uses the paper's reversible hash (§7.2).

Construction and queries are host-side numpy (this is the database layer);
`device_arrays()` exports immutable jnp views for the compute layer (PSW,
GNN message passing, Pallas kernels).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "IntervalMap",
    "EdgePartition",
    "GraphPAL",
    "SortedRun",
    "build_partition",
    "merge_sorted_runs",
    "merge_runs",
    "merge_runs_into_partition",
    "partition_from_run",
    "run_from_arrays",
    "run_from_partition",
    "sorted_run_index",
]


# ---------------------------------------------------------------------------
# Intervals + reversible hash (paper §4.1, §7.2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class IntervalMap:
    """P equal-length vertex intervals over internal IDs [0, P*L).

    The paper's reversible hash maps original IDs to internal IDs so that
    consecutive original IDs land in *different* intervals, balancing
    power-law edge distributions without dynamic interval management:

        intern = (orig mod P) * L + (orig div P)
        orig   = (intern mod L) * P + (intern div L)

    (The paper's §7.2 decode line swaps div/mod — an apparent typo; the
    formula above is the true inverse of its encode, verified by the
    round-trip property test.)
    """

    n_partitions: int
    interval_len: int

    @property
    def max_vertices(self) -> int:
        return self.n_partitions * self.interval_len

    @classmethod
    def for_capacity(cls, max_id: int, n_partitions: int) -> "IntervalMap":
        interval_len = -(-int(max_id + 1) // n_partitions)  # ceil div
        return cls(n_partitions=n_partitions, interval_len=interval_len)

    # -- reversible hash -----------------------------------------------------
    def to_internal(self, orig):
        orig = np.asarray(orig, dtype=np.int64)
        p, ell = self.n_partitions, self.interval_len
        return (orig % p) * ell + (orig // p)

    def to_internal_scalar(self, orig: int) -> int:
        """Scalar reversible hash in pure Python — hot single-edge paths
        avoid the per-call array round-trip of `to_internal`."""
        return (orig % self.n_partitions) * self.interval_len \
            + orig // self.n_partitions

    def to_original(self, intern):
        intern = np.asarray(intern, dtype=np.int64)
        p, ell = self.n_partitions, self.interval_len
        return (intern % ell) * p + (intern // ell)

    # -- interval lookup (O(1), "mathematically", paper §7.2) ----------------
    def interval_of(self, intern):
        return np.asarray(intern, dtype=np.int64) // self.interval_len

    def interval_range(self, i: int) -> Tuple[int, int]:
        lo = i * self.interval_len
        return lo, lo + self.interval_len

    def local_offset(self, intern):
        """Offset within owning interval — positional vertex-column key."""
        return np.asarray(intern, dtype=np.int64) % self.interval_len


# ---------------------------------------------------------------------------
# Edge partition (paper §4.1.1, with CSC-perm adaptation)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class EdgePartition:
    """Immutable destination-interval edge partition.

    Edge order (the 'edge-array'): sorted by (src, dst). Attribute columns
    are positional w.r.t. this order. The only permitted in-place mutation
    mirrors the paper: edge-type change, attribute-column writes, and
    tombstoning (§5.3) — none of which reorder or resize the arrays.
    """

    interval: Tuple[int, int]  # [lo, hi) of internal destination IDs
    src: np.ndarray            # (E,) int64, ascending
    dst: np.ndarray            # (E,) int64, within interval
    etype: np.ndarray          # (E,) int8  (paper: 4-bit type)
    # sparse CSR over sources (paper's pointer-array; sparse format §4.1.1)
    src_vertices: np.ndarray   # (S,) unique sources, ascending
    src_ptr: np.ndarray        # (S+1,) offsets into edge-array
    # dst access (replaces the in-edge linked list; DESIGN.md §2)
    dst_perm: np.ndarray       # (E,) permutation sorting edges by dst
    dst_vertices: np.ndarray   # (D,) unique destinations, ascending
    dst_ptr: np.ndarray        # (D+1,) offsets into dst_perm
    # columnar edge attributes, positional (paper §4.3)
    columns: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    # tombstones (paper §5.3): permanent removal happens at merge time
    dead: Optional[np.ndarray] = None  # (E,) bool or None
    # set by manifest publication (core/manifest.py): the NEXT tombstone
    # write must copy `dead` instead of mutating the published array
    _dead_sealed: bool = False

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_live_edges(self) -> int:
        if self.dead is None:
            return self.n_edges
        return int(self.n_edges - self.dead.sum())

    def nbytes(self) -> int:
        n = self.src.nbytes + self.dst.nbytes + self.etype.nbytes
        n += self.src_vertices.nbytes + self.src_ptr.nbytes
        n += self.dst_perm.nbytes + self.dst_vertices.nbytes + self.dst_ptr.nbytes
        for c in self.columns.values():
            n += c.nbytes
        return n

    # -- primitive queries (paper §4.2) --------------------------------------
    def out_edge_range(self, v: int) -> Tuple[int, int]:
        """Edge-array range [a, b) of v's out-edges (binary search on the
        pointer-array, paper §4.2.1). Empty range if none."""
        i = np.searchsorted(self.src_vertices, v)
        if i < self.src_vertices.shape[0] and self.src_vertices[i] == v:
            return int(self.src_ptr[i]), int(self.src_ptr[i + 1])
        return 0, 0

    def out_edges(self, v: int) -> np.ndarray:
        """Positions in the edge-array of v's live out-edges."""
        a, b = self.out_edge_range(v)
        pos = np.arange(a, b, dtype=np.int64)
        return self._live(pos)

    def in_edges(self, v: int) -> np.ndarray:
        """Positions in the edge-array of v's live in-edges (via dst-perm —
        the paper walks the linked list; we take one contiguous perm slice)."""
        i = np.searchsorted(self.dst_vertices, v)
        if i < self.dst_vertices.shape[0] and self.dst_vertices[i] == v:
            pos = self.dst_perm[self.dst_ptr[i]:self.dst_ptr[i + 1]]
            return self._live(np.asarray(pos, dtype=np.int64))
        return np.empty(0, dtype=np.int64)

    def _live(self, pos: np.ndarray) -> np.ndarray:
        if self.dead is None or pos.size == 0:
            return pos
        return pos[~self.dead[pos]]

    # -- mutations allowed by the model --------------------------------------
    def set_column(self, name: str, pos, values) -> None:
        self.columns[name][pos] = values

    def set_etype(self, pos, values) -> None:
        """Paper §4.1.1: edge-type change is the one allowed in-place edit."""
        self.etype[pos] = values

    def tombstone(self, pos) -> None:
        """Tombstone positions. Copy-on-write once a manifest publication
        sealed the current `dead` array (core/manifest.py): lock-free
        readers pinned to an older manifest keep the pre-delete array, so a
        delete can never tear a published view's structure."""
        if self.dead is None:
            dead = np.zeros(self.n_edges, dtype=bool)
        elif self._dead_sealed:
            dead = self.dead.copy()
        else:
            dead = self.dead
        dead[pos] = True
        self.dead = dead
        self._dead_sealed = False

    # -- PSW sliding window (paper §6.1) --------------------------------------
    def window(self, interval: Tuple[int, int]) -> Tuple[int, int]:
        """Contiguous edge-array range whose sources fall in `interval`.

        This is the paper's sliding window: because the partition is
        source-sorted, the out-edges of any vertex interval form one
        contiguous run.
        """
        lo, hi = interval
        a = int(np.searchsorted(self.src, lo, side="left"))
        b = int(np.searchsorted(self.src, hi, side="left"))
        return a, b

    # -- attribute → edge reverse lookup (paper §4.3) -------------------------
    def edge_at(self, pos: int) -> Tuple[int, int, int]:
        """Recover (src, dst, type) from an edge-array position: dst/type are
        stored at the position; src via pointer-array search (paper does the
        same binary search)."""
        j = int(np.searchsorted(self.src_ptr, pos, side="right")) - 1
        return int(self.src_vertices[j]), int(self.dst[pos]), int(self.etype[pos])


def build_partition(
    interval: Tuple[int, int],
    src: np.ndarray,
    dst: np.ndarray,
    etype: Optional[np.ndarray] = None,
    columns: Optional[Dict[str, np.ndarray]] = None,
    presorted: bool = False,
) -> EdgePartition:
    """Bulk-build an immutable edge partition (sort by (src, dst), index)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    etype = (
        np.zeros(src.shape[0], dtype=np.int8)
        if etype is None
        else np.asarray(etype, dtype=np.int8)
    )
    columns = dict(columns or {})
    if not presorted and src.size:
        order = np.lexsort((dst, src))
        src, dst, etype = src[order], dst[order], etype[order]
        columns = {k: np.asarray(v)[order] for k, v in columns.items()}

    src_vertices, first = np.unique(src, return_index=True)
    src_ptr = np.concatenate([first, [src.shape[0]]]).astype(np.int64)

    dst_perm = np.argsort(dst, kind="stable").astype(np.int64)
    dst_sorted = dst[dst_perm]
    dst_vertices, dfirst = np.unique(dst_sorted, return_index=True)
    dst_ptr = np.concatenate([dfirst, [dst.shape[0]]]).astype(np.int64)

    return EdgePartition(
        interval=interval,
        src=src,
        dst=dst,
        etype=etype,
        src_vertices=src_vertices,
        src_ptr=src_ptr,
        dst_perm=dst_perm,
        dst_vertices=dst_vertices,
        dst_ptr=dst_ptr,
        columns=columns,
    )


# ---------------------------------------------------------------------------
# Linear-time sorted merges (LSM write path, DESIGN.md §6)
# ---------------------------------------------------------------------------
# A partition's edge-array is (src, dst)-sorted, and boolean-masked subsets
# of it stay sorted. Merging a partition with an incoming run therefore
# never needs to re-sort the big side: sort only the small run, compute the
# interleave permutation with two binary searches, and rebuild every index
# array (CSR over sources, CSC perm over destinations) from that
# permutation in O(n) — no fresh `unique` / `argsort` over the merged data.

#: Largest vertex-ID bound for which (src, dst) packs into one int64 key.
_MAX_PACKED_BOUND = 3_037_000_499  # isqrt(2**63 - 1)


def sorted_run_index(sorted_vals: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Sparse CSR (vertices, ptr) over an already-sorted key array in O(n) —
    the linear replacement for `np.unique(..., return_index=True)` on data
    whose order is known. Bitwise-identical to the unique-based build."""
    n = int(sorted_vals.shape[0])
    if n == 0:
        return sorted_vals[:0].astype(np.int64), np.zeros(1, np.int64)
    starts = np.concatenate(
        [[0], np.flatnonzero(sorted_vals[1:] != sorted_vals[:-1]) + 1]
    ).astype(np.int64)
    vertices = sorted_vals[starts].astype(np.int64)
    ptr = np.concatenate([starts, [n]]).astype(np.int64)
    return vertices, ptr


def merge_sorted_runs(
    a_src: np.ndarray, a_dst: np.ndarray,
    b_src: np.ndarray, b_dst: np.ndarray,
    key_bound: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Stable two-way merge of two (src, dst)-sorted edge runs in O(nA+nB).

    Returns `(pos_a, pos_b)`: the merged-array positions of A's and B's
    elements, with A before B on equal keys — exactly the order
    `np.lexsort((dst, src))` would give the concatenation [A, B], computed
    from two `searchsorted` passes instead of an O(n log n) sort.

    Requires `0 <= src, dst < key_bound <= _MAX_PACKED_BOUND` so the pair
    packs losslessly into one monotone int64 key.
    """
    ka = _pack_keys(a_src, a_dst, key_bound)
    kbq = _pack_keys(b_src, b_dst, key_bound)
    return _merge_positions(ka, kbq)


def _pack_keys(src: np.ndarray, dst: np.ndarray, bound: int) -> np.ndarray:
    k = src * np.int64(bound)
    k += dst  # in place: one temporary instead of two
    return k


_ARANGE_SCRATCH = np.empty(0, np.int64)


def _arange(n: int) -> np.ndarray:
    """Read-only view of [0, n) from a grow-only scratch — the merge path
    needs consecutive-integer vectors constantly and never mutates them.
    The scratch is marked non-writable so a view escaping through a public
    return value (merge_sorted_runs' disjoint fast path) cannot be mutated
    into corrupting later merges."""
    global _ARANGE_SCRATCH
    if _ARANGE_SCRATCH.shape[0] < n:
        _ARANGE_SCRATCH = np.arange(max(n, 2 * _ARANGE_SCRATCH.shape[0]),
                                    dtype=np.int64)
        _ARANGE_SCRATCH.flags.writeable = False
    return _ARANGE_SCRATCH[:n]


def _merge_positions(ka: np.ndarray, kbq: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Merged positions of two sorted key arrays (A before B on ties). Only
    the small side is binary-searched; the big side's shifts come from a
    bincount + cumsum over the small side's insertion ranks — sequential
    passes instead of nA random binary searches."""
    nA, nB = ka.shape[0], kbq.shape[0]
    if nA == 0 or nB == 0 or ka[-1] <= kbq[0]:  # disjoint: A wholly first
        return _arange(nA), nA + _arange(nB)
    if kbq[-1] < ka[0]:  # disjoint: B wholly first
        return nB + _arange(nA), _arange(nB)
    rank_b = np.searchsorted(ka, kbq, side="right")  # #{a <= b} per b
    pos_b = rank_b + _arange(nB)
    # b precedes a[i] iff rank_b <= i: a[i]'s shift is a step function that
    # climbs at each insertion rank — expand it by run lengths, then add
    # i in place (two big temporaries total, not five)
    lengths = np.empty(nB + 1, np.int64)
    lengths[0] = rank_b[0]
    np.subtract(rank_b[1:], rank_b[:-1], out=lengths[1:nB])
    lengths[nB] = nA - rank_b[-1]
    pos_a = np.repeat(_arange(nB + 1), lengths)
    pos_a += _arange(nA)
    return pos_a, pos_b


@dataclasses.dataclass
class SortedRun:
    """A (src, dst)-sorted edge run plus its stable dst-sort order — the
    unit consumed by `merge_runs_into_partition`."""

    src: np.ndarray                 # (n,) int64, (src, dst)-ascending
    dst: np.ndarray                 # (n,) int64
    etype: np.ndarray               # (n,) int8
    columns: Dict[str, np.ndarray]  # positional
    dst_order: np.ndarray           # (n,) stable argsort of dst
    dst_sorted: Optional[np.ndarray] = None  # dst[dst_order], if already built

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])


def run_from_arrays(
    src: np.ndarray,
    dst: np.ndarray,
    etype: Optional[np.ndarray] = None,
    columns: Optional[Dict[str, np.ndarray]] = None,
    presorted: bool = False,
    key_bound: Optional[int] = None,
) -> SortedRun:
    """Sort a small incoming run (the only sort on the merge path). With
    `presorted=True` (push-down merges: masked subsets of a sorted partition
    stay sorted) the lexsort is skipped entirely; with `key_bound` set the
    two-key lexsort collapses into one packed-key argsort."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    n = int(src.shape[0])
    etype = (np.zeros(n, np.int8) if etype is None
             else np.asarray(etype, dtype=np.int8))
    columns = dict(columns or {})
    if presorted or n == 0:
        dst_order = np.argsort(dst, kind="stable").astype(np.int64)
        return SortedRun(src=src, dst=dst, etype=etype, columns=columns,
                         dst_order=dst_order)
    if key_bound is not None and key_bound * key_bound * (n + 1) < 2 ** 63:
        # (src, dst, position) packs into one int64, making every key
        # unique: a plain value sort (no stable argsort, no index array)
        # recovers both the stable (src, dst) order and — with the roles
        # swapped — the stable dst order of the sorted run
        k3 = _pack_keys(src, dst, key_bound) * np.int64(n)
        k3 += _arange(n)
        k4 = _pack_keys(dst, src, key_bound) * np.int64(n)
        k4 += _arange(n)
        k3.sort()
        k4.sort()
        order = k3 % n                      # original pos, (src, dst)-sorted
        inv = np.empty(n, np.int64)
        inv[order] = _arange(n)
        dst_order = inv[k4 % n]             # ties resolved by (src, insertion)
    else:
        order = np.lexsort((dst, src))
        dst_order = None
    src, dst, etype = src[order], dst[order], etype[order]
    columns = {k: np.asarray(v)[order] for k, v in columns.items()}
    if dst_order is None:
        dst_order = np.argsort(dst, kind="stable").astype(np.int64)
    return SortedRun(src=src, dst=dst, etype=etype, columns=columns,
                     dst_order=dst_order)


def run_from_partition(
    part: "EdgePartition",
    live: Optional[np.ndarray] = None,
    columns: Optional[Sequence[str]] = None,
) -> SortedRun:
    """View a partition's live edges as a SortedRun, reusing the stored
    `dst_perm` instead of re-sorting: a masked subset of a (src, dst)-sorted
    array stays sorted, and its stable dst order is the stored perm filtered
    to live positions and renumbered — all O(n)."""
    names = part.columns.keys() if columns is None else columns
    if live is None:
        cols = {k: part.columns[k] for k in names if k in part.columns}
        return SortedRun(src=part.src, dst=part.dst, etype=part.etype,
                         columns=cols,
                         dst_order=np.asarray(part.dst_perm, np.int64))
    new_pos = np.cumsum(live) - 1
    keep = live[part.dst_perm]
    dst_order = np.asarray(new_pos[part.dst_perm[keep]], np.int64)
    cols = {k: part.columns[k][live] for k in names if k in part.columns}
    return SortedRun(src=part.src[live], dst=part.dst[live],
                     etype=part.etype[live], columns=cols,
                     dst_order=dst_order)


def merge_runs(a: SortedRun, b: SortedRun, key_bound: int,
               column_dtypes: Optional[Dict[str, np.dtype]] = None) -> SortedRun:
    """O(n) stable merge of two sorted runs into one SortedRun (A before B
    on ties) — used when a flush overflows its partition and the combined
    edges go straight to the children without materializing the partition."""
    nA, nB = a.n_edges, b.n_edges
    n = nA + nB
    column_dtypes = dict(column_dtypes or {})
    pos_a, pos_b = merge_sorted_runs(a.src, a.dst, b.src, b.dst, key_bound)

    def scatter(xa, xb, dtype):
        out = np.empty(n, dtype)
        out[pos_a] = xa
        out[pos_b] = xb
        return out

    columns = {}
    for k, dt in column_dtypes.items():
        xa = a.columns.get(k)
        xb = b.columns.get(k)
        columns[k] = scatter(
            xa if xa is not None else np.zeros(nA, dt),
            xb if xb is not None else np.zeros(nB, dt), dt)
    # dst-sorted streams of each run, expressed in merged positions; keys
    # (dst, merged position) are strictly increasing within each stream and
    # globally distinct, so one more merge pass orders them. The merged
    # dst_order is bitwise identical to np.argsort(dst, kind="stable").
    ma = pos_a[a.dst_order]
    mb = pos_b[b.dst_order]
    da = a.dst[a.dst_order]
    db = b.dst[b.dst_order]
    qa, qb = _merge_positions(_pack_keys(da, ma, n), _pack_keys(db, mb, n))
    dst_order = np.empty(n, np.int64)
    dst_order[qa] = ma
    dst_order[qb] = mb
    # merged dst-sorted values by monotone scatter (no random gather)
    d_sorted = np.empty(n, np.int64)
    d_sorted[qa] = da
    d_sorted[qb] = db
    return SortedRun(
        src=scatter(a.src, b.src, np.int64),
        dst=scatter(a.dst, b.dst, np.int64),
        etype=scatter(a.etype, b.etype, np.int8),
        columns=columns,
        dst_order=dst_order,
        dst_sorted=d_sorted,
    )


def partition_from_run(
    interval: Tuple[int, int],
    run: SortedRun,
    column_dtypes: Optional[Dict[str, np.dtype]] = None,
) -> EdgePartition:
    """Build a partition straight from a SortedRun (the empty-target merge
    fast path) — indexes in O(n) off the run's existing order. The run's
    arrays must be freshly owned (not views of a live buffer/partition)."""
    n = run.n_edges
    column_dtypes = dict(column_dtypes or {})
    src_vertices, src_ptr = sorted_run_index(run.src)
    d_sorted = (run.dst[run.dst_order] if run.dst_sorted is None
                else run.dst_sorted)
    dst_vertices, dst_ptr = sorted_run_index(d_sorted)
    columns = {}
    for k, dt in column_dtypes.items():
        col = run.columns.get(k)
        columns[k] = np.asarray(col, dt) if col is not None else np.zeros(n, dt)
    return EdgePartition(
        interval=interval,
        src=run.src,
        dst=run.dst,
        etype=run.etype,
        src_vertices=src_vertices,
        src_ptr=src_ptr,
        dst_perm=run.dst_order,
        dst_vertices=dst_vertices,
        dst_ptr=dst_ptr,
        columns=columns,
    )


def merge_runs_into_partition(
    interval: Tuple[int, int],
    a: SortedRun,
    b: SortedRun,
    key_bound: int,
    column_dtypes: Optional[Dict[str, np.dtype]] = None,
) -> EdgePartition:
    """O(n) merge of two sorted runs into a NEW immutable partition.

    The edge-array is the stable (src, dst) interleave of A then B
    (`merge_runs`); the CSR source index comes from run boundaries of the
    merged (already sorted) src array and the CSC dst permutation from the
    merged dst order (`partition_from_run`) — bitwise identical to a
    from-scratch `build_partition`, without sorting.
    """
    return partition_from_run(
        interval, merge_runs(a, b, key_bound, column_dtypes), column_dtypes)


# ---------------------------------------------------------------------------
# The full PAL graph
# ---------------------------------------------------------------------------
class GraphPAL:
    """P destination-interval partitions + per-interval vertex columns.

    IDs handed to the public API are *original* IDs; the reversible hash is
    applied at the boundary (paper §7.2).
    """

    def __init__(self, intervals: IntervalMap, partitions: List[EdgePartition],
                 vertex_columns: Optional[Dict[str, List[np.ndarray]]] = None):
        assert len(partitions) == intervals.n_partitions
        self.intervals = intervals
        self.partitions = partitions
        # vertex columns: name -> list of per-interval arrays (positional)
        self.vertex_columns: Dict[str, List[np.ndarray]] = vertex_columns or {}
        self._engine = None

    def storage_engine(self):
        """Vectorized set-at-a-time read interface (engine.py, DESIGN.md §5)."""
        if self._engine is None:
            from .engine import PALEngine
            self._engine = PALEngine(self)
        return self._engine

    # -- construction ---------------------------------------------------------
    @classmethod
    def from_edges(
        cls,
        src,
        dst,
        n_partitions: int = 8,
        max_id: Optional[int] = None,
        etype=None,
        columns: Optional[Dict[str, np.ndarray]] = None,
    ) -> "GraphPAL":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if max_id is None:
            max_id = int(max(src.max(initial=0), dst.max(initial=0)))
        iv = IntervalMap.for_capacity(max_id, n_partitions)
        isrc, idst = iv.to_internal(src), iv.to_internal(dst)
        part_of = iv.interval_of(idst)
        etype = None if etype is None else np.asarray(etype, dtype=np.int8)
        columns = columns or {}
        parts: List[EdgePartition] = []
        for i in range(n_partitions):
            m = part_of == i
            cols = {k: np.asarray(v)[m] for k, v in columns.items()}
            et = None if etype is None else etype[m]
            parts.append(build_partition(iv.interval_range(i), isrc[m], idst[m], et, cols))
        return cls(iv, parts)

    # -- stats ----------------------------------------------------------------
    @property
    def n_edges(self) -> int:
        return sum(p.n_edges for p in self.partitions)

    @property
    def n_live_edges(self) -> int:
        return sum(p.n_live_edges for p in self.partitions)

    def nbytes(self) -> int:
        n = sum(p.nbytes() for p in self.partitions)
        for col in self.vertex_columns.values():
            n += sum(a.nbytes for a in col)
        return n

    # -- vertex columns (paper §4.4: positional, O(1)) --------------------------
    def add_vertex_column(self, name: str, dtype, fill=0) -> None:
        ell = self.intervals.interval_len
        self.vertex_columns[name] = [
            np.full(ell, fill, dtype=dtype) for _ in range(self.intervals.n_partitions)
        ]

    def vertex_get(self, name: str, orig_ids):
        intern = self.intervals.to_internal(orig_ids)
        part = self.intervals.interval_of(intern)
        off = self.intervals.local_offset(intern)
        col = self.vertex_columns[name]
        out = np.empty(np.shape(intern), dtype=col[0].dtype)
        flat_p, flat_o = np.ravel(part), np.ravel(off)
        flat_out = out.reshape(-1)
        for i in np.unique(flat_p):
            m = flat_p == i
            flat_out[m] = col[int(i)][flat_o[m]]
        return out

    def vertex_set(self, name: str, orig_ids, values) -> None:
        intern = self.intervals.to_internal(orig_ids)
        part = self.intervals.interval_of(intern)
        off = self.intervals.local_offset(intern)
        col = self.vertex_columns[name]
        values = np.asarray(values)
        flat_p, flat_o = np.ravel(part), np.ravel(off)
        flat_v = values.reshape(flat_p.shape[0], *values.shape[len(np.shape(intern)):])
        for i in np.unique(flat_p):
            m = flat_p == i
            col[int(i)][flat_o[m]] = flat_v[m]

    # -- edge queries (original-ID API; paper §4.2) ----------------------------
    def out_edges(self, v: int) -> List[Tuple[int, int]]:
        """All (partition_idx, edge_pos) of v's out-edges. A vertex can have
        out-edges in every partition (paper: min(P, outdeg) random accesses)."""
        vi = int(self.intervals.to_internal(v))
        hits: List[Tuple[int, int]] = []
        for pi, part in enumerate(self.partitions):
            for pos in part.out_edges(vi):
                hits.append((pi, int(pos)))
        return hits

    def in_edges(self, v: int) -> List[Tuple[int, int]]:
        """All (partition_idx, edge_pos) of v's in-edges — exactly one
        partition owns them (paper: the interval containing v)."""
        vi = int(self.intervals.to_internal(v))
        pi = int(self.intervals.interval_of(vi))
        return [(pi, int(pos)) for pos in self.partitions[pi].in_edges(vi)]

    def out_neighbors(self, v: int) -> np.ndarray:
        vi = int(self.intervals.to_internal(v))
        chunks = []
        for part in self.partitions:
            pos = part.out_edges(vi)
            if pos.size:
                chunks.append(part.dst[pos])
        if not chunks:
            return np.empty(0, dtype=np.int64)
        return np.asarray(self.intervals.to_original(np.concatenate(chunks)))

    def in_neighbors(self, v: int) -> np.ndarray:
        vi = int(self.intervals.to_internal(v))
        pi = int(self.intervals.interval_of(vi))
        part = self.partitions[pi]
        pos = part.in_edges(vi)
        if pos.size == 0:
            return np.empty(0, dtype=np.int64)
        return np.asarray(self.intervals.to_original(part.src[pos]))

    def out_neighbors_batch(self, vs: Sequence[int]) -> List[np.ndarray]:
        """Batched out-neighbor query, one array per queried vertex (legacy
        shape; the flat CSR-grouped form lives on `storage_engine()`)."""
        vals, offsets = self.storage_engine().out_neighbors_batch(vs)
        return [vals[offsets[i]:offsets[i + 1]] for i in range(len(offsets) - 1)]

    # -- exports ----------------------------------------------------------------
    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) in original IDs, live edges only, partition order."""
        ss, dd = [], []
        for part in self.partitions:
            live = (
                np.ones(part.n_edges, dtype=bool) if part.dead is None else ~part.dead
            )
            ss.append(part.src[live])
            dd.append(part.dst[live])
        s = np.concatenate(ss) if ss else np.empty(0, np.int64)
        d = np.concatenate(dd) if dd else np.empty(0, np.int64)
        return (np.asarray(self.intervals.to_original(s)),
                np.asarray(self.intervals.to_original(d)))

    def partition_sizes(self) -> np.ndarray:
        return np.asarray([p.n_edges for p in self.partitions])
