"""Partitioned Adjacency Lists (PAL) — the paper's core data structure.

Faithful to GraphChi-DB (Kyrola & Guestrin, 2014) §4 with the TPU adaptation
documented in DESIGN.md §2:

  * the vertex-ID range is split into P intervals; edge-partition(i) stores
    every edge whose *destination* lies in interval(i), sorted by *source*;
  * each edge is stored exactly once, both directions are queryable;
  * the paper's in-edge linked list (next-with-same-dst offsets) is replaced
    by an immutable dst-sort permutation + dst pointer array (CSC within the
    partition) — pointer chasing has no TPU analogue;
  * edge attributes are columnar and positional: the edge's index in the
    edge-array is the key into every column (paper §4.3);
  * vertex attributes are columnar per interval with O(1) positional access
    (paper §4.4);
  * interval balancing uses the paper's reversible hash (§7.2).

Construction and queries are host-side numpy (this is the database layer);
`device_arrays()` exports immutable jnp views for the compute layer (PSW,
GNN message passing, Pallas kernels).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "IntervalMap",
    "EdgePartition",
    "GraphPAL",
    "build_partition",
]


# ---------------------------------------------------------------------------
# Intervals + reversible hash (paper §4.1, §7.2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class IntervalMap:
    """P equal-length vertex intervals over internal IDs [0, P*L).

    The paper's reversible hash maps original IDs to internal IDs so that
    consecutive original IDs land in *different* intervals, balancing
    power-law edge distributions without dynamic interval management:

        intern = (orig mod P) * L + (orig div P)
        orig   = (intern mod L) * P + (intern div L)

    (The paper's §7.2 decode line swaps div/mod — an apparent typo; the
    formula above is the true inverse of its encode, verified by the
    round-trip property test.)
    """

    n_partitions: int
    interval_len: int

    @property
    def max_vertices(self) -> int:
        return self.n_partitions * self.interval_len

    @classmethod
    def for_capacity(cls, max_id: int, n_partitions: int) -> "IntervalMap":
        interval_len = -(-int(max_id + 1) // n_partitions)  # ceil div
        return cls(n_partitions=n_partitions, interval_len=interval_len)

    # -- reversible hash -----------------------------------------------------
    def to_internal(self, orig):
        orig = np.asarray(orig, dtype=np.int64)
        p, ell = self.n_partitions, self.interval_len
        return (orig % p) * ell + (orig // p)

    def to_original(self, intern):
        intern = np.asarray(intern, dtype=np.int64)
        p, ell = self.n_partitions, self.interval_len
        return (intern % ell) * p + (intern // ell)

    # -- interval lookup (O(1), "mathematically", paper §7.2) ----------------
    def interval_of(self, intern):
        return np.asarray(intern, dtype=np.int64) // self.interval_len

    def interval_range(self, i: int) -> Tuple[int, int]:
        lo = i * self.interval_len
        return lo, lo + self.interval_len

    def local_offset(self, intern):
        """Offset within owning interval — positional vertex-column key."""
        return np.asarray(intern, dtype=np.int64) % self.interval_len


# ---------------------------------------------------------------------------
# Edge partition (paper §4.1.1, with CSC-perm adaptation)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class EdgePartition:
    """Immutable destination-interval edge partition.

    Edge order (the 'edge-array'): sorted by (src, dst). Attribute columns
    are positional w.r.t. this order. The only permitted in-place mutation
    mirrors the paper: edge-type change, attribute-column writes, and
    tombstoning (§5.3) — none of which reorder or resize the arrays.
    """

    interval: Tuple[int, int]  # [lo, hi) of internal destination IDs
    src: np.ndarray            # (E,) int64, ascending
    dst: np.ndarray            # (E,) int64, within interval
    etype: np.ndarray          # (E,) int8  (paper: 4-bit type)
    # sparse CSR over sources (paper's pointer-array; sparse format §4.1.1)
    src_vertices: np.ndarray   # (S,) unique sources, ascending
    src_ptr: np.ndarray        # (S+1,) offsets into edge-array
    # dst access (replaces the in-edge linked list; DESIGN.md §2)
    dst_perm: np.ndarray       # (E,) permutation sorting edges by dst
    dst_vertices: np.ndarray   # (D,) unique destinations, ascending
    dst_ptr: np.ndarray        # (D+1,) offsets into dst_perm
    # columnar edge attributes, positional (paper §4.3)
    columns: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    # tombstones (paper §5.3): permanent removal happens at merge time
    dead: Optional[np.ndarray] = None  # (E,) bool or None

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_live_edges(self) -> int:
        if self.dead is None:
            return self.n_edges
        return int(self.n_edges - self.dead.sum())

    def nbytes(self) -> int:
        n = self.src.nbytes + self.dst.nbytes + self.etype.nbytes
        n += self.src_vertices.nbytes + self.src_ptr.nbytes
        n += self.dst_perm.nbytes + self.dst_vertices.nbytes + self.dst_ptr.nbytes
        for c in self.columns.values():
            n += c.nbytes
        return n

    # -- primitive queries (paper §4.2) --------------------------------------
    def out_edge_range(self, v: int) -> Tuple[int, int]:
        """Edge-array range [a, b) of v's out-edges (binary search on the
        pointer-array, paper §4.2.1). Empty range if none."""
        i = np.searchsorted(self.src_vertices, v)
        if i < self.src_vertices.shape[0] and self.src_vertices[i] == v:
            return int(self.src_ptr[i]), int(self.src_ptr[i + 1])
        return 0, 0

    def out_edges(self, v: int) -> np.ndarray:
        """Positions in the edge-array of v's live out-edges."""
        a, b = self.out_edge_range(v)
        pos = np.arange(a, b, dtype=np.int64)
        return self._live(pos)

    def in_edges(self, v: int) -> np.ndarray:
        """Positions in the edge-array of v's live in-edges (via dst-perm —
        the paper walks the linked list; we take one contiguous perm slice)."""
        i = np.searchsorted(self.dst_vertices, v)
        if i < self.dst_vertices.shape[0] and self.dst_vertices[i] == v:
            pos = self.dst_perm[self.dst_ptr[i]:self.dst_ptr[i + 1]]
            return self._live(np.asarray(pos, dtype=np.int64))
        return np.empty(0, dtype=np.int64)

    def _live(self, pos: np.ndarray) -> np.ndarray:
        if self.dead is None or pos.size == 0:
            return pos
        return pos[~self.dead[pos]]

    # -- mutations allowed by the model --------------------------------------
    def set_column(self, name: str, pos, values) -> None:
        self.columns[name][pos] = values

    def set_etype(self, pos, values) -> None:
        """Paper §4.1.1: edge-type change is the one allowed in-place edit."""
        self.etype[pos] = values

    def tombstone(self, pos) -> None:
        if self.dead is None:
            self.dead = np.zeros(self.n_edges, dtype=bool)
        self.dead[pos] = True

    # -- PSW sliding window (paper §6.1) --------------------------------------
    def window(self, interval: Tuple[int, int]) -> Tuple[int, int]:
        """Contiguous edge-array range whose sources fall in `interval`.

        This is the paper's sliding window: because the partition is
        source-sorted, the out-edges of any vertex interval form one
        contiguous run.
        """
        lo, hi = interval
        a = int(np.searchsorted(self.src, lo, side="left"))
        b = int(np.searchsorted(self.src, hi, side="left"))
        return a, b

    # -- attribute → edge reverse lookup (paper §4.3) -------------------------
    def edge_at(self, pos: int) -> Tuple[int, int, int]:
        """Recover (src, dst, type) from an edge-array position: dst/type are
        stored at the position; src via pointer-array search (paper does the
        same binary search)."""
        j = int(np.searchsorted(self.src_ptr, pos, side="right")) - 1
        return int(self.src_vertices[j]), int(self.dst[pos]), int(self.etype[pos])


def build_partition(
    interval: Tuple[int, int],
    src: np.ndarray,
    dst: np.ndarray,
    etype: Optional[np.ndarray] = None,
    columns: Optional[Dict[str, np.ndarray]] = None,
    presorted: bool = False,
) -> EdgePartition:
    """Bulk-build an immutable edge partition (sort by (src, dst), index)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    etype = (
        np.zeros(src.shape[0], dtype=np.int8)
        if etype is None
        else np.asarray(etype, dtype=np.int8)
    )
    columns = dict(columns or {})
    if not presorted and src.size:
        order = np.lexsort((dst, src))
        src, dst, etype = src[order], dst[order], etype[order]
        columns = {k: np.asarray(v)[order] for k, v in columns.items()}

    src_vertices, first = np.unique(src, return_index=True)
    src_ptr = np.concatenate([first, [src.shape[0]]]).astype(np.int64)

    dst_perm = np.argsort(dst, kind="stable").astype(np.int64)
    dst_sorted = dst[dst_perm]
    dst_vertices, dfirst = np.unique(dst_sorted, return_index=True)
    dst_ptr = np.concatenate([dfirst, [dst.shape[0]]]).astype(np.int64)

    return EdgePartition(
        interval=interval,
        src=src,
        dst=dst,
        etype=etype,
        src_vertices=src_vertices,
        src_ptr=src_ptr,
        dst_perm=dst_perm,
        dst_vertices=dst_vertices,
        dst_ptr=dst_ptr,
        columns=columns,
    )


# ---------------------------------------------------------------------------
# The full PAL graph
# ---------------------------------------------------------------------------
class GraphPAL:
    """P destination-interval partitions + per-interval vertex columns.

    IDs handed to the public API are *original* IDs; the reversible hash is
    applied at the boundary (paper §7.2).
    """

    def __init__(self, intervals: IntervalMap, partitions: List[EdgePartition],
                 vertex_columns: Optional[Dict[str, List[np.ndarray]]] = None):
        assert len(partitions) == intervals.n_partitions
        self.intervals = intervals
        self.partitions = partitions
        # vertex columns: name -> list of per-interval arrays (positional)
        self.vertex_columns: Dict[str, List[np.ndarray]] = vertex_columns or {}
        self._engine = None

    def storage_engine(self):
        """Vectorized set-at-a-time read interface (engine.py, DESIGN.md §5)."""
        if self._engine is None:
            from .engine import PALEngine
            self._engine = PALEngine(self)
        return self._engine

    # -- construction ---------------------------------------------------------
    @classmethod
    def from_edges(
        cls,
        src,
        dst,
        n_partitions: int = 8,
        max_id: Optional[int] = None,
        etype=None,
        columns: Optional[Dict[str, np.ndarray]] = None,
    ) -> "GraphPAL":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if max_id is None:
            max_id = int(max(src.max(initial=0), dst.max(initial=0)))
        iv = IntervalMap.for_capacity(max_id, n_partitions)
        isrc, idst = iv.to_internal(src), iv.to_internal(dst)
        part_of = iv.interval_of(idst)
        etype = None if etype is None else np.asarray(etype, dtype=np.int8)
        columns = columns or {}
        parts: List[EdgePartition] = []
        for i in range(n_partitions):
            m = part_of == i
            cols = {k: np.asarray(v)[m] for k, v in columns.items()}
            et = None if etype is None else etype[m]
            parts.append(build_partition(iv.interval_range(i), isrc[m], idst[m], et, cols))
        return cls(iv, parts)

    # -- stats ----------------------------------------------------------------
    @property
    def n_edges(self) -> int:
        return sum(p.n_edges for p in self.partitions)

    @property
    def n_live_edges(self) -> int:
        return sum(p.n_live_edges for p in self.partitions)

    def nbytes(self) -> int:
        n = sum(p.nbytes() for p in self.partitions)
        for col in self.vertex_columns.values():
            n += sum(a.nbytes for a in col)
        return n

    # -- vertex columns (paper §4.4: positional, O(1)) --------------------------
    def add_vertex_column(self, name: str, dtype, fill=0) -> None:
        ell = self.intervals.interval_len
        self.vertex_columns[name] = [
            np.full(ell, fill, dtype=dtype) for _ in range(self.intervals.n_partitions)
        ]

    def vertex_get(self, name: str, orig_ids):
        intern = self.intervals.to_internal(orig_ids)
        part = self.intervals.interval_of(intern)
        off = self.intervals.local_offset(intern)
        col = self.vertex_columns[name]
        out = np.empty(np.shape(intern), dtype=col[0].dtype)
        flat_p, flat_o = np.ravel(part), np.ravel(off)
        flat_out = out.reshape(-1)
        for i in np.unique(flat_p):
            m = flat_p == i
            flat_out[m] = col[int(i)][flat_o[m]]
        return out

    def vertex_set(self, name: str, orig_ids, values) -> None:
        intern = self.intervals.to_internal(orig_ids)
        part = self.intervals.interval_of(intern)
        off = self.intervals.local_offset(intern)
        col = self.vertex_columns[name]
        values = np.asarray(values)
        flat_p, flat_o = np.ravel(part), np.ravel(off)
        flat_v = values.reshape(flat_p.shape[0], *values.shape[len(np.shape(intern)):])
        for i in np.unique(flat_p):
            m = flat_p == i
            col[int(i)][flat_o[m]] = flat_v[m]

    # -- edge queries (original-ID API; paper §4.2) ----------------------------
    def out_edges(self, v: int) -> List[Tuple[int, int]]:
        """All (partition_idx, edge_pos) of v's out-edges. A vertex can have
        out-edges in every partition (paper: min(P, outdeg) random accesses)."""
        vi = int(self.intervals.to_internal(v))
        hits: List[Tuple[int, int]] = []
        for pi, part in enumerate(self.partitions):
            for pos in part.out_edges(vi):
                hits.append((pi, int(pos)))
        return hits

    def in_edges(self, v: int) -> List[Tuple[int, int]]:
        """All (partition_idx, edge_pos) of v's in-edges — exactly one
        partition owns them (paper: the interval containing v)."""
        vi = int(self.intervals.to_internal(v))
        pi = int(self.intervals.interval_of(vi))
        return [(pi, int(pos)) for pos in self.partitions[pi].in_edges(vi)]

    def out_neighbors(self, v: int) -> np.ndarray:
        vi = int(self.intervals.to_internal(v))
        chunks = []
        for part in self.partitions:
            pos = part.out_edges(vi)
            if pos.size:
                chunks.append(part.dst[pos])
        if not chunks:
            return np.empty(0, dtype=np.int64)
        return np.asarray(self.intervals.to_original(np.concatenate(chunks)))

    def in_neighbors(self, v: int) -> np.ndarray:
        vi = int(self.intervals.to_internal(v))
        pi = int(self.intervals.interval_of(vi))
        part = self.partitions[pi]
        pos = part.in_edges(vi)
        if pos.size == 0:
            return np.empty(0, dtype=np.int64)
        return np.asarray(self.intervals.to_original(part.src[pos]))

    def out_neighbors_batch(self, vs: Sequence[int]) -> List[np.ndarray]:
        """Batched out-neighbor query, one array per queried vertex (legacy
        shape; the flat CSR-grouped form lives on `storage_engine()`)."""
        vals, offsets = self.storage_engine().out_neighbors_batch(vs)
        return [vals[offsets[i]:offsets[i + 1]] for i in range(len(offsets) - 1)]

    # -- exports ----------------------------------------------------------------
    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) in original IDs, live edges only, partition order."""
        ss, dd = [], []
        for part in self.partitions:
            live = (
                np.ones(part.n_edges, dtype=bool) if part.dead is None else ~part.dead
            )
            ss.append(part.src[live])
            dd.append(part.dst[live])
        s = np.concatenate(ss) if ss else np.empty(0, np.int64)
        d = np.concatenate(dd) if dd else np.empty(0, np.int64)
        return (np.asarray(self.intervals.to_original(s)),
                np.asarray(self.intervals.to_original(d)))

    def partition_sizes(self) -> np.ndarray:
        return np.asarray([p.n_edges for p in self.partitions])
