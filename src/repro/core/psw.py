"""Parallel Sliding Windows (paper §6) — host-faithful and TPU-distributed.

Two execution engines:

1. `psw_sweep_host` / `pagerank_host`: Algorithm 2 verbatim — sweep the P
   vertex intervals; for interval i load the subgraph (in-edges = the whole
   owner partition, out-edges = one contiguous *window* per partition, found
   via the source-sorted order), run the vertex update, write back. This is
   the paper's engine and is what the paper-table benchmarks run.

2. `DeviceGraph` + `edge_centric_sweep`: the TPU adaptation (DESIGN.md §2).
   Each mesh device owns one vertex interval and its destination partition.
   A sweep needs source-vertex state that lives on other devices; the paper's
   Θ(P²) window *seeks* become ONE `all_to_all` of precomputed window rows
   (`mode="psw_windows"`), or an `all_gather` of the full vertex state for
   small state (`mode="dense_gather"`, the paper's §6.1.1 edge-centric model
   that keeps O(V) state in memory).

The pure-jnp "virtual device" path (`plan.n_devices == 1`) computes the
identical math with transposes standing in for the collectives, so all of it
is testable on CPU; `shard_map` wiring is exercised by the dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .lsm import LSMTree
from .pal import GraphPAL, IntervalMap

GraphLike = Union[GraphPAL, LSMTree]


def _host_partitions(g: GraphLike) -> list:
    """Every physical partition of the store (all LSM levels, the PAL
    partition list, or a pinned ManifestView's partition proxies) —
    duck-typed, no storage-class branching. A `ManifestView`
    (core/manifest.py) satisfies the whole contract this module needs
    (`all_partitions` with stable `dead` refs, `buffers` as frozen staging
    shims, `to_coo`, `intervals`), so out-of-core PSW streaming and
    DeviceGraph compilation run against one epoch-pinned state while the
    writer and maintenance keep going (ISSUE 5)."""
    all_parts = getattr(g, "all_partitions", None)
    return list(all_parts()) if all_parts is not None else list(g.partitions)


__all__ = [
    "DeviceGraph",
    "build_device_graph",
    "edge_centric_sweep",
    "pagerank_device",
    "pagerank_out_of_core",
    "psw_sweep_host",
    "pagerank_host",
    "stream_interval_buckets",
]


# ---------------------------------------------------------------------------
# Host-side PSW (Algorithm 2)
# ---------------------------------------------------------------------------
def psw_sweep_host(
    g: GraphLike,
    update_interval: Callable[..., None],
) -> int:
    """One PSW iteration (paper Alg. 2). For each interval i the callback gets:

        update_interval(i, owner_partition, in_pos, windows)

    where `in_pos` are the dst-sorted edge positions of the owner partition
    and `windows` is a list of (partition, a, b) contiguous out-edge ranges —
    the sliding windows. Returns the number of random accesses a disk would
    have issued (Θ(P²)), for the benchmark I/O-proxy.
    """
    iv = g.intervals
    # PAL: one owner partition per interval; LSM: one owner per level +
    # windows from every partition (duck-typed on the partition layout)
    parts = g.partitions if not hasattr(g, "all_partitions") else None
    seeks = 0
    for i in range(iv.n_partitions):
        lo, hi = iv.interval_range(i)
        if parts is not None:
            owner = parts[i]
            all_parts = parts
        else:
            all_parts = g.all_partitions()
            owner = None
        windows = []
        for part in all_parts:
            a, b = part.window((lo, hi))
            windows.append((part, a, b))
            seeks += 1  # one seek per window (paper §6.1)
        if parts is not None:
            update_interval(i, owner, windows)
            seeks += 1  # owner partition sequential load
        else:
            owners = [
                p for p in all_parts if p.interval[0] <= lo < p.interval[1]
            ]
            update_interval(i, owners, windows)
            seeks += len(owners)
    return seeks


def pagerank_host(g: GraphLike, n_iters: int = 5, damping: float = 0.85) -> np.ndarray:
    """Vertex-centric PageRank with PSW, state on edges (paper §6.1).

    The edge state rank(src)/outdeg(src) lives in a fresh per-partition
    OVERLAY keyed by partition identity — the store's attribute columns are
    never written (they used to be clobbered in place, the ROADMAP-flagged
    wart; tests/test_psw_query.py now pins source columns bitwise). Each
    sweep computes an interval's new ranks from its in-edge state and
    refreshes its out-edge state through the sliding windows. Returns ranks
    indexed by internal ID.
    """
    iv = g.intervals
    n = iv.max_vertices
    # PSW windows only cover partitions, so an LSM store merges its buffers
    # first (read-only analytics use snapshot() instead)
    flush_all = getattr(g, "flush_all", None)
    if flush_all is not None:
        flush_all()
    parts = _host_partitions(g)

    # out-degree (global pass)
    outdeg = np.zeros(n, dtype=np.int64)
    for p in parts:
        if p.n_edges:
            live = np.ones(p.n_edges, bool) if p.dead is None else ~p.dead
            np.add.at(outdeg, p.src[live], 1)
    ranks = np.full(n, 1.0, dtype=np.float64)
    # `parts` (and the window partitions psw_sweep_host hands back) are the
    # store's own stable partition objects, so identity keys are stable for
    # the whole run; `parts` holds them alive
    pr = {}
    for p in parts:
        if p.n_edges:
            pr[id(p)] = ranks[p.src] / np.maximum(outdeg[p.src], 1)
        else:
            pr[id(p)] = np.zeros(0, dtype=np.float64)

    def sweep(i, owner, windows):
        lo, hi = iv.interval_range(i)
        owners = owner if isinstance(owner, list) else [owner]
        acc = np.zeros(hi - lo, dtype=np.float64)
        for p in owners:
            if p.n_edges == 0:
                continue
            live = np.ones(p.n_edges, bool) if p.dead is None else ~p.dead
            sel = live & (p.dst >= lo) & (p.dst < hi)
            np.add.at(acc, p.dst[sel] - lo, pr[id(p)][sel])
        new_rank = (1 - damping) + damping * acc
        ranks[lo:hi] = new_rank
        # refresh out-edge state through the windows
        for p, a, b in windows:
            if b > a:
                s = p.src[a:b]
                pr[id(p)][a:b] = ranks[s] / np.maximum(outdeg[s], 1)

    for _ in range(n_iters):
        psw_sweep_host(g, sweep)
    return ranks


# ---------------------------------------------------------------------------
# Out-of-core PSW (disk tier, paper §6.1): stream buckets, never materialize
# ---------------------------------------------------------------------------
def stream_interval_buckets(g: GraphLike, evict_each: bool = False):
    """Yield `(i, src, dst)` per destination interval, internal IDs,
    canonically (dst, src)-sorted — exactly the rows `build_device_graph`
    would pack, produced ONE interval at a time so the whole edge set is
    never resident.

    Per interval, each owning partition contributes one contiguous slice of
    its dst-sorted permutation (read from mmap if the partition is
    disk-backed), buffers contribute a masked scan, and one small stable
    lexsort canonicalizes the bucket. Chunk concatenation follows the
    `to_coo` order, so the per-bucket sort is bit-identical to the global
    lexsort restricted to the bucket (property-tested). With `evict_each`,
    disk partitions drop their mappings after every bucket, bounding
    resident memory by one bucket + the pinned indexes.
    """
    iv = g.intervals
    parts = _host_partitions(g)
    buffers = getattr(g, "buffers", None) or []
    for i in range(iv.n_partitions):
        lo, hi = iv.interval_range(i)
        chunks_s: list = []
        chunks_d: list = []
        for part in parts:
            plo, phi = part.interval
            if phi <= lo or plo >= hi or part.n_edges == 0:
                continue
            # disk partitions resolve the bucket's perm range against the
            # compressed resident index; RAM partitions use the arrays
            bounds = getattr(part, "dst_ptr_bounds", None)
            res = bounds(lo, hi) if bounds is not None else None
            if res is not None:
                pa, pb = res
            else:
                dv = part.dst_vertices
                a = int(np.searchsorted(dv, lo, side="left"))
                b = int(np.searchsorted(dv, hi, side="left"))
                pa, pb = int(part.dst_ptr[a]), int(part.dst_ptr[b])
            if pb == pa:
                continue
            # perm slice → ascending edge-array positions = to_coo order
            pos = np.sort(np.asarray(part.dst_perm[pa:pb], np.int64))
            if part.dead is not None:
                pos = pos[~part.dead[pos]]
            if pos.size:
                chunks_s.append(np.asarray(part.src[pos], np.int64))
                chunks_d.append(np.asarray(part.dst[pos], np.int64))
        for buf in buffers:
            if len(buf):
                st = buf.staging()
                m = (st.dst >= lo) & (st.dst < hi)
                if m.any():
                    chunks_s.append(st.src[m].astype(np.int64))
                    chunks_d.append(st.dst[m].astype(np.int64))
        if chunks_s:
            s = np.concatenate(chunks_s)
            d = np.concatenate(chunks_d)
            order = np.lexsort((s, d))
            s, d = s[order], d[order]
        else:
            s = np.empty(0, np.int64)
            d = np.empty(0, np.int64)
        yield i, s, d
        if evict_each:
            for part in parts:
                # a swept bucket's pages won't be re-read this pass: hint
                # the kernel to drop them (madvise DONTNEED) so streaming
                # the store doesn't churn hotter data out of the page
                # cache, then unmap
                advise = getattr(part, "advise_dontneed", None)
                if advise is not None:
                    advise()
                ev = getattr(part, "evict", None)
                if ev is not None:
                    ev()


def pagerank_out_of_core(g: GraphLike, n_iters: int = 5,
                         damping: float = 0.85,
                         evict_each: bool = True) -> np.ndarray:
    """Edge-centric PageRank streaming one destination-interval bucket at a
    time from the store — the paper's §6.1.1 model executed out-of-core:
    O(V) vertex state resident, one bucket of edges in flight, everything
    else on disk. Same synchronous iteration as `pagerank_device` (verified
    to agree in the tests). Returns ranks indexed by internal ID."""
    iv = g.intervals
    n = iv.max_vertices
    outdeg = np.zeros(n, np.int64)
    for i, s, d in stream_interval_buckets(g, evict_each=evict_each):
        if s.size:
            outdeg += np.bincount(s, minlength=n)
    ranks = np.ones(n, np.float64)
    inv_deg = 1.0 / np.maximum(outdeg, 1)
    for _ in range(n_iters):
        contrib = ranks * inv_deg
        acc = np.zeros(n, np.float64)
        for i, s, d in stream_interval_buckets(g, evict_each=evict_each):
            if s.size:
                lo, hi = iv.interval_range(i)
                acc[lo:hi] = np.bincount(d - lo, weights=contrib[s],
                                         minlength=hi - lo)
        ranks = (1.0 - damping) + damping * acc
    return ranks


# ---------------------------------------------------------------------------
# Device PSW (TPU adaptation)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DeviceGraph:
    """Interval-sharded immutable graph arrays (struct-of-arrays, padded).

    Leading axis P = number of intervals = mesh shards. Edges of partition i
    are dst-sorted (so segment ops see monotone ids) and padded to E_max.
    """

    n_partitions: int
    interval_len: int
    n_edges: int
    src: jnp.ndarray        # (P, E) int32 global internal source IDs
    dst_local: jnp.ndarray  # (P, E) int32 local destination offsets
    mask: jnp.ndarray       # (P, E) bool  (False = padding)
    outdeg: jnp.ndarray     # (P, L) int32 out-degree of owned vertices
    # PSW window-exchange plan (None until build_window_plan)
    send_idx: Optional[jnp.ndarray] = None   # (P, P, W) owner-local rows
    edge_owner: Optional[jnp.ndarray] = None  # (P, E) src owner interval
    edge_slot: Optional[jnp.ndarray] = None   # (P, E) row in recv buffer

    @property
    def window_width(self) -> int:
        return 0 if self.send_idx is None else int(self.send_idx.shape[-1])


def build_device_graph(g: GraphLike, with_window_plan: bool = True) -> DeviceGraph:
    iv = g.intervals
    P, L = iv.n_partitions, iv.interval_len
    src_o, dst_o = g.to_coo()
    src = np.asarray(iv.to_internal(src_o))
    dst = np.asarray(iv.to_internal(dst_o))
    # ONE global (dst, src) lexsort canonically orders every bucket at once:
    # sorting by dst groups the destination intervals contiguously and
    # ascending, and within a bucket (dst, src)-order equals the per-bucket
    # sort — bit-identical to sorting each bucket separately, so an
    # LSMTree.snapshot() (which feeds the live staging views through
    # `to_coo`) stays bit-identical to a bulk-built GraphPAL's DeviceGraph.
    order = np.lexsort((src, dst))
    s_sorted, d_sorted = src[order], dst[order]
    counts = np.bincount(d_sorted // L, minlength=P)
    bounds = np.concatenate([[0], np.cumsum(counts)])
    e_max = max(1, int(counts.max(initial=0)))
    # round up to a lane-friendly multiple (TPU tiles are 128-wide)
    e_max = -(-e_max // 128) * 128
    S = np.zeros((P, e_max), np.int32)
    D = np.zeros((P, e_max), np.int32)
    M = np.zeros((P, e_max), bool)
    for i in range(P):
        a, b = int(bounds[i]), int(bounds[i + 1])
        S[i, : b - a] = s_sorted[a:b]
        D[i, : b - a] = d_sorted[a:b] - i * L
        M[i, : b - a] = True
    outdeg = np.zeros(P * L, np.int32)
    np.add.at(outdeg, src, 1)
    dg = DeviceGraph(
        n_partitions=P, interval_len=L, n_edges=int(src.shape[0]),
        src=jnp.asarray(S), dst_local=jnp.asarray(D), mask=jnp.asarray(M),
        outdeg=jnp.asarray(outdeg.reshape(P, L)),
    )
    if with_window_plan:
        _build_window_plan(dg, S, M)
    return dg


def _build_window_plan(dg: DeviceGraph, S: np.ndarray, M: np.ndarray) -> None:
    """Precompute the PSW window exchange: which owner rows each consumer
    needs (unique srcs per (owner, consumer) pair), and per-edge slots into
    the receive buffer. Host-side, immutable alongside the partitions."""
    P, L = dg.n_partitions, dg.interval_len
    uniq: Dict[Tuple[int, int], np.ndarray] = {}
    w_max = 1
    for j in range(P):  # consumer partition j
        s = S[j][M[j]]
        owner = s // L
        for i in range(P):
            u = np.unique(s[owner == i])
            uniq[(i, j)] = u
            w_max = max(w_max, u.shape[0])
    w_max = -(-w_max // 128) * 128
    send_idx = np.zeros((P, P, w_max), np.int32)
    for (i, j), u in uniq.items():
        send_idx[i, j, : u.shape[0]] = (u - i * L).astype(np.int32)
    edge_owner = np.zeros_like(S)
    edge_slot = np.zeros_like(S)
    for j in range(P):
        s = S[j]
        own = s // L
        edge_owner[j] = own
        for i in range(P):
            m = (own == i) & M[j]
            if m.any():
                edge_slot[j][m] = np.searchsorted(uniq[(i, j)], s[m]).astype(np.int32)
    dg.send_idx = jnp.asarray(send_idx)
    dg.edge_owner = jnp.asarray(edge_owner.astype(np.int32))
    dg.edge_slot = jnp.asarray(edge_slot.astype(np.int32))


# -- collectives with a pure-jnp virtual-device fallback ----------------------
def _exchange_windows(x: jnp.ndarray, send_idx: jnp.ndarray,
                      axis_name: Optional[str]) -> jnp.ndarray:
    """PSW window exchange.

    x: (P_local, L, d) owner-local vertex state; send_idx: (P_local, P, W)
    owner-local rows destined for each global consumer. Returns
    recv: (P_local, P, W, d) with recv[b, o] = x_owner_o[send_idx_o[·, this]].
    Under shard_map this is ONE all_to_all — the TPU sliding window; without
    an axis name it is the same math via a transpose (virtual devices).
    """
    send = jnp.take_along_axis(x[:, None], send_idx[..., None], axis=2)
    # send: (P_local owner, P consumer, W, d)
    if axis_name is None:
        return jnp.swapaxes(send, 0, 1)  # (P consumer, P owner, W, d)
    out = jax.lax.all_to_all(send, axis_name, split_axis=1, concat_axis=0)
    # out: (P global owner, P_local consumer, W, d)
    return jnp.swapaxes(out, 0, 1)


def _gather_all(x: jnp.ndarray, axis_name: Optional[str]) -> jnp.ndarray:
    if axis_name is None:
        return x.reshape(-1, *x.shape[2:])
    return jax.lax.all_gather(x, axis_name).reshape(-1, *x.shape[2:])


def edge_centric_sweep_arrays(
    src: jnp.ndarray,          # (Pl, E) global src IDs
    dst_local: jnp.ndarray,    # (Pl, E)
    mask: jnp.ndarray,         # (Pl, E)
    interval_len: int,
    x: jnp.ndarray,            # (Pl, L, d) vertex state (owner-local rows)
    msg_fn: Callable[[jnp.ndarray], jnp.ndarray],
    mode: str = "psw_windows",
    axis_name: Optional[str] = None,
    send_idx: Optional[jnp.ndarray] = None,     # (Pl, P, W)
    edge_owner: Optional[jnp.ndarray] = None,   # (Pl, E)
    edge_slot: Optional[jnp.ndarray] = None,    # (Pl, E)
) -> jnp.ndarray:
    """One edge-centric PSW sweep over per-shard arrays: gather source state
    (via all_gather or the PSW window all_to_all), apply `msg_fn`,
    segment-sum into local destinations. Returns (Pl, L, d') sums."""
    L = interval_len
    if x.ndim == 2:
        x = x[..., None]
    if mode == "dense_gather":
        x_all = _gather_all(x, axis_name)            # (P*L, d)
        src_state = x_all[src]                       # (Pl, E, d)
    elif mode == "psw_windows":
        assert send_idx is not None, "window plan not built"
        recv = _exchange_windows(x, send_idx, axis_name)  # (Pl, P, W, d)
        w = recv.shape[2]
        flat = recv.reshape(recv.shape[0], -1, x.shape[-1])  # (Pl, P*W, d)
        idx = edge_owner * w + edge_slot
        src_state = jnp.take_along_axis(flat, idx[..., None], axis=1)
    else:
        raise ValueError(mode)
    msgs = msg_fn(src_state) * mask[..., None]
    # dst-sorted per partition → segment_sum with monotone ids
    seg = jax.vmap(lambda m, d: jax.ops.segment_sum(m, d, num_segments=L))(
        msgs, dst_local
    )
    return seg


def edge_centric_sweep(
    dg: DeviceGraph,
    x: jnp.ndarray,
    msg_fn: Callable[[jnp.ndarray], jnp.ndarray],
    mode: str = "psw_windows",
    axis_name: Optional[str] = None,
) -> jnp.ndarray:
    """Sweep over the whole DeviceGraph (virtual devices, or pass axis_name
    under shard_map with pre-sliced arrays — see launch/sharding.py)."""
    return edge_centric_sweep_arrays(
        dg.src, dg.dst_local, dg.mask, dg.interval_len, x, msg_fn,
        mode=mode, axis_name=axis_name, send_idx=dg.send_idx,
        edge_owner=dg.edge_owner, edge_slot=dg.edge_slot,
    )


def pagerank_device(dg: DeviceGraph, n_iters: int = 5, damping: float = 0.85,
                    mode: str = "psw_windows",
                    axis_name: Optional[str] = None) -> jnp.ndarray:
    """PageRank with the device PSW engine. Returns (P, L) ranks."""
    P, L = dg.n_partitions, dg.interval_len
    inv_deg = 1.0 / jnp.maximum(dg.outdeg.astype(jnp.float32), 1.0)

    def body(r, _):
        contrib = (r * inv_deg)[..., None]           # (P, L, 1)
        acc = edge_centric_sweep(dg, contrib, lambda s: s, mode, axis_name)
        r_new = (1.0 - damping) + damping * acc[..., 0]
        return r_new, None

    r0 = jnp.ones((P, L), jnp.float32)
    r, _ = jax.lax.scan(body, r0, None, length=n_iters)
    return r
