"""Graph queries over PAL / LSM storage (paper §4.2, §7.4, §8.4).

Implements the paper's query set:
  * out-edge / in-edge primitive queries (on GraphPAL and LSMTree),
  * friends-of-friends (FoF) with the frontier-batched out-edge strategy,
  * frontier traversal with the direction-optimizing top-down/bottom-up
    switch of Beamer et al. that the paper adopts in §7.4,
  * depth-limited unweighted shortest path (one- or two-sided BFS, §8.4).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from .lsm import LSMTree
from .pal import GraphPAL

GraphLike = Union[GraphPAL, LSMTree]

__all__ = ["Frontier", "friends_of_friends", "bfs", "shortest_path", "traverse_out"]


class Frontier:
    """A set of vertices (original IDs) flowing through traversal operators —
    the paper's Scala-API frontier (§7.4)."""

    def __init__(self, ids: Sequence[int]):
        self.ids = np.unique(np.asarray(list(ids), dtype=np.int64))

    def __len__(self) -> int:
        return int(self.ids.shape[0])

    def has_vertex(self, v: int) -> bool:
        i = np.searchsorted(self.ids, v)
        return bool(i < self.ids.shape[0] and self.ids[i] == v)


def _out_neighbors_batch(g: GraphLike, vs: np.ndarray) -> np.ndarray:
    """Union of out-neighborhoods (top-down step)."""
    if isinstance(g, GraphPAL):
        chunks = g.out_neighbors_batch(vs)
        if not chunks:
            return np.empty(0, np.int64)
        return np.concatenate([c for c in chunks if c.size] or
                              [np.empty(0, np.int64)])
    chunks = [g.out_neighbors(int(v)) for v in vs]
    chunks = [c for c in chunks if c.size]
    return np.concatenate(chunks) if chunks else np.empty(0, np.int64)


def _bottom_up_step(g: GraphLike, frontier_mask: np.ndarray,
                    iv) -> np.ndarray:
    """Bottom-up sweep (paper §7.4 / Beamer): stream ALL edges once and emit
    destinations whose source is in the frontier. Cost O(|E|/B) sequential —
    cheaper than per-vertex queries when the frontier is a large fraction of V."""
    parts = g.partitions if isinstance(g, GraphPAL) else g.all_partitions()
    next_ids = []
    for part in parts:
        if part.n_edges == 0:
            continue
        live = np.ones(part.n_edges, bool) if part.dead is None else ~part.dead
        src_orig = np.asarray(iv.to_original(part.src), dtype=np.int64)
        m = live & frontier_mask[src_orig]
        if m.any():
            next_ids.append(np.asarray(iv.to_original(part.dst[m]), np.int64))
    if isinstance(g, LSMTree):
        for buf in g.buffers:
            if len(buf):
                s = np.asarray(iv.to_original(np.asarray(buf.src, np.int64)))
                d = np.asarray(iv.to_original(np.asarray(buf.dst, np.int64)))
                m = frontier_mask[s]
                if m.any():
                    next_ids.append(d[m])
    return np.concatenate(next_ids) if next_ids else np.empty(0, np.int64)


def traverse_out(g: GraphLike, frontier: Frontier,
                 bottom_up_threshold: float = 0.05) -> Frontier:
    """One traversal hop with the direction-optimizing switch (paper §7.4):
    if the frontier exceeds a fraction of |V|, sweep bottom-up over all
    edges instead of issuing per-vertex out-edge queries."""
    iv = g.intervals
    n_vert = iv.max_vertices
    if len(frontier) > bottom_up_threshold * n_vert:
        mask = np.zeros(n_vert + 1, dtype=bool)
        mask[np.minimum(frontier.ids, n_vert)] = True
        nbrs = _bottom_up_step(g, mask, iv)
    else:
        nbrs = _out_neighbors_batch(g, frontier.ids)
    return Frontier(nbrs)


def friends_of_friends(g: GraphLike, v: int,
                       max_friends: Optional[int] = None) -> np.ndarray:
    """Paper §8.4: W = {w : ∃u, (v,u) ∈ E, (u,w) ∈ E}, excluding the friends
    themselves (and v). Out-edges of all friends are queried in one batch."""
    friends = g.out_neighbors(v) if isinstance(g, GraphPAL) else g.out_neighbors(v)
    friends = np.unique(friends)
    if max_friends is not None and friends.shape[0] > max_friends:
        friends = friends[:max_friends]
    if friends.size == 0:
        return np.empty(0, np.int64)
    fof = _out_neighbors_batch(g, friends)
    fof = np.unique(fof)
    # exclude friends and the query vertex (paper's selectOut filter)
    return np.setdiff1d(fof, np.concatenate([friends, [v]]), assume_unique=False)


def bfs(g: GraphLike, source: int, max_depth: int = 5,
        bottom_up_threshold: float = 0.05) -> dict:
    """Direction-optimizing BFS; returns {vertex: depth} for reached vertices."""
    depth = {int(source): 0}
    frontier = Frontier([source])
    for d in range(1, max_depth + 1):
        nxt = traverse_out(g, frontier, bottom_up_threshold)
        fresh = [int(u) for u in nxt.ids if int(u) not in depth]
        if not fresh:
            break
        for u in fresh:
            depth[u] = d
        frontier = Frontier(fresh)
    return depth


def shortest_path(g: GraphLike, s: int, t: int, max_depth: int = 5,
                  two_sided: bool = True) -> Optional[int]:
    """Depth-limited unweighted shortest path (paper §8.4). Two-sided search
    expands the smaller frontier each round; the backward side uses
    in-neighbors."""
    if s == t:
        return 0
    if not two_sided:
        d = bfs(g, s, max_depth)
        return d.get(int(t))

    fwd = {int(s): 0}
    bwd = {int(t): 0}
    f_front, b_front = Frontier([s]), Frontier([t])
    for _ in range(max_depth):
        if len(f_front) == 0 and len(b_front) == 0:
            return None
        expand_fwd = len(f_front) <= len(b_front) and len(f_front) > 0
        if expand_fwd or len(b_front) == 0:
            nxt = traverse_out(g, f_front)
            fresh = []
            base = max(fwd.values())
            for u in nxt.ids:
                u = int(u)
                if u in bwd:
                    return base + 1 + bwd[u]
                if u not in fwd:
                    fwd[u] = base + 1
                    fresh.append(u)
            f_front = Frontier(fresh)
        else:
            # backward hop over in-neighbors
            chunks = [g.in_neighbors(int(v)) for v in b_front.ids]
            chunks = [c for c in chunks if c.size]
            nbrs = np.unique(np.concatenate(chunks)) if chunks else np.empty(0, np.int64)
            fresh = []
            base = max(bwd.values())
            for u in nbrs:
                u = int(u)
                if u in fwd:
                    return fwd[u] + 1 + base
                if u not in bwd:
                    bwd[u] = base + 1
                    fresh.append(u)
            b_front = Frontier(fresh)
        total = max(fwd.values()) + max(bwd.values())
        if total >= max_depth and len(f_front) == 0 and len(b_front) == 0:
            break
    return None
