"""Graph queries over any StorageEngine (paper §4.2, §7.4, §8.4).

Implements the paper's query set:
  * friends-of-friends (FoF) with the frontier-batched out-edge strategy,
  * frontier traversal with the direction-optimizing top-down/bottom-up
    switch of Beamer et al. that the paper adopts in §7.4,
  * depth-limited unweighted shortest path (one- or two-sided BFS, §8.4).

Since ISSUE 6 the public operators are thin facades over the columnar
multi-hop layer (core/multihop.py, DESIGN.md §10): per-hop dedup, visited
sets, and meets are packed-key sort/unique/searchsorted, never Python
loops over vertices. The pre-ISSUE-6 per-hop implementations are kept as
`*_perhop` — they are the measured baselines in benchmarks/bench_multihop
and the reference oracles in tests/test_multihop.py; their answers are
bitwise-identical to the columnar path.

Every operator speaks only the vectorized set-at-a-time `StorageEngine`
interface (engine.py, DESIGN.md §5) — the same code path serves a bulk-built
`GraphPAL`, a live `LSMTree` (all levels + in-memory buffers), an on-disk
`GraphDB`, and a lock-free `ManifestView`, with no storage-class branching
anywhere in this module.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Sequence

import numpy as np

from . import multihop as mh
from .engine import StorageEngine, as_engine

# a StorageEngine, or any store exposing storage_engine() — duck-typed via
# as_engine(), deliberately not a Union over concrete storage classes
GraphLike = Any

__all__ = [
    "Frontier",
    "bfs",
    "bfs_perhop",
    "consistent_engine",
    "dedup_frontier",
    "friends_of_friends",
    "friends_of_friends_perhop",
    "shortest_path",
    "shortest_path_perhop",
    "traverse_out",
]


class Frontier:
    """A set of vertices (original IDs) flowing through traversal operators —
    the paper's Scala-API frontier (§7.4)."""

    def __init__(self, ids: Sequence[int]):
        self.ids = np.unique(np.asarray(list(ids), dtype=np.int64))

    def __len__(self) -> int:
        return int(self.ids.shape[0])

    def has_vertex(self, v: int) -> bool:
        i = np.searchsorted(self.ids, v)
        return bool(i < self.ids.shape[0] and self.ids[i] == v)


def dedup_frontier(g: GraphLike, ids, visited=None,
                   degree_order: bool = False) -> np.ndarray:
    """Compact a raw neighbor batch into the next frontier: sorted-unique,
    minus the already-visited set, so repeated hops never re-expand a
    duplicate or settled vertex. With `degree_order`, the survivors are
    reordered by DESCENDING live out-degree (one no-gather degree batch):
    heavy hitters go first, which is the order truncated traversals keep
    and the order that fills slab ranges widest-first."""
    ids = np.unique(np.asarray(ids, np.int64).ravel())
    if visited is not None:
        vis = np.unique(np.asarray(list(visited), np.int64).ravel())
        if vis.shape[0]:
            ids = ids[~mh.semijoin(ids, vis)]
    if degree_order and ids.shape[0]:
        deg = as_engine(g).out_degree_batch(ids)
        ids = ids[np.argsort(-deg, kind="stable")]
    return ids


def _bottom_up_step(eng: StorageEngine, frontier_ids: np.ndarray,
                    visited=None) -> np.ndarray:
    """Bottom-up sweep (paper §7.4 / Beamer): stream ALL edges once and emit
    destinations whose source is in the frontier. Cost O(|E|/B) sequential —
    cheaper than per-vertex queries when the frontier is a large fraction of
    V. The frontier is compacted first (dedup_frontier) so the membership
    mask is built from distinct, still-unexpanded vertices only."""
    ids = dedup_frontier(eng, frontier_ids, visited=visited)
    n_vert = eng.n_internal_vertices
    mask = np.zeros(n_vert + 1, dtype=bool)
    mask[np.minimum(ids, n_vert)] = True
    iv = eng.intervals
    next_ids = []
    for chunk in eng.edge_chunks():
        src_orig = np.asarray(iv.to_original(chunk.src), dtype=np.int64)
        m = mask[src_orig]
        if m.any():
            next_ids.append(np.asarray(iv.to_original(chunk.dst[m]), np.int64))
    return np.concatenate(next_ids) if next_ids else np.empty(0, np.int64)


def traverse_out(g: GraphLike, frontier: Frontier,
                 bottom_up_threshold: float = 0.05,
                 visited=None) -> Frontier:
    """One traversal hop with the direction-optimizing switch (paper §7.4):
    if the frontier exceeds a fraction of |V|, sweep bottom-up over all
    edges instead of issuing batched out-edge queries. `visited` vertices
    are dropped from the frontier before expansion — a repeated hop never
    re-expands them."""
    eng = as_engine(g)
    ids = dedup_frontier(eng, frontier.ids, visited=visited)
    n_vert = eng.n_internal_vertices
    if (ids.shape[0] > bottom_up_threshold * n_vert
            and "stream" in getattr(eng, "supported_hop_modes",
                                    ("sparse", "stream", "kernel"))):
        # engines that cannot stream the whole edge set (the sharded
        # scatter/gather engine, ISSUE 8) stay on the batched probe path
        nbrs = _bottom_up_step(eng, ids)
    else:
        nbrs, _ = eng.out_neighbors_batch(ids)
    return Frontier(nbrs)


@contextlib.contextmanager
def consistent_engine(g: GraphLike):
    """One pinned StorageEngine for a multi-op read session, uniform over
    every tier (ISSUE 8): a `ServiceDB` yields its lock-free epoch view's
    engine, a `ShardRouter` pins one manifest in EVERY shard worker and
    yields the scatter/gather engine over those pins, and anything else
    (GraphPAL, LSMTree, GraphDB, ManifestView, Snapshot) passes through
    `as_engine` unchanged. The pin — single- or multi-process — is released
    on exit, so traversals composed of many engine calls (khop, FoF, BFS)
    read one frozen state per store regardless of concurrent writers."""
    pin_view = getattr(g, "pin_view", None)       # ShardRouter
    read_view = getattr(g, "read_view", None)     # ServiceDB / GraphDB
    if pin_view is not None:
        with pin_view() as view:
            yield view.storage_engine()
    elif read_view is not None:
        with read_view() as view:
            yield view.storage_engine()
    else:
        yield as_engine(g)


# ---------------------------------------------------------------------------
# Columnar operators (the public path, ISSUE 6)
# ---------------------------------------------------------------------------
def friends_of_friends(g: GraphLike, v: int,
                       max_friends: Optional[int] = None) -> np.ndarray:
    """Paper §8.4: W = {w : ∃u, (v,u) ∈ E, (u,w) ∈ E}, excluding the friends
    themselves (and v). One columnar 2-hop (multihop.two_hop_counts) —
    bitwise the per-hop answer, including the sorted-first-`max_friends`
    truncation."""
    res = mh.two_hop_counts(g, np.asarray([v], np.int64),
                            max_friends=max_friends)
    return res.ids[:int(res.offsets[1])]


def bfs(g: GraphLike, source: int, max_depth: int = 5,
        bottom_up_threshold: float = 0.05) -> dict:
    """Direction-optimizing BFS; returns {vertex: depth} for reached
    vertices. Levels come from the columnar k-hop operator — visited-set
    subtraction is a packed-key semijoin per hop, and dense frontiers take
    the bottom-up stream (or a memoized kernel plan) per the §10.3
    heuristic; only the final dict is materialized per vertex."""
    res = mh.khop(g, [source], max_depth,
                  dense_threshold=bottom_up_threshold)
    depth = {}
    for d, level in enumerate(res.levels):
        for u in level.tolist():
            depth[u] = d
    return depth


def _lookup_sorted(ids: np.ndarray, dep: np.ndarray,
                   keys: np.ndarray) -> np.ndarray:
    """Depths of `keys` (all present) in the sorted id/depth columns."""
    return dep[np.searchsorted(ids, keys)]


def shortest_path(g: GraphLike, s: int, t: int, max_depth: int = 5,
                  two_sided: bool = True) -> Optional[int]:
    """Depth-limited unweighted shortest path (paper §8.4). Two-sided search
    expands the smaller frontier each round (backward over the batched
    in-neighbor primitive); meets are columnar: one semijoin of the new
    level against the other side's visited column, with the MINIMUM over
    all meeting vertices (the per-hop baseline settled for the first meet
    in id order). Search stops once no future meet can beat the best."""
    eng = as_engine(g)
    if s == t:
        return 0
    if not two_sided:
        return bfs(eng, s, max_depth).get(int(t))

    f_ids = np.asarray([s], np.int64)
    f_dep = np.zeros(1, np.int64)
    b_ids = np.asarray([t], np.int64)
    b_dep = np.zeros(1, np.int64)
    f_lev, b_lev = f_ids, b_ids
    df = db = 0
    best = None
    while df + db < max_depth and (f_lev.shape[0] or b_lev.shape[0]):
        fwd = f_lev.shape[0] > 0 and (b_lev.shape[0] == 0
                                      or f_lev.shape[0] <= b_lev.shape[0])
        if fwd:
            _, nb = eng.expand_frontier(f_lev, "out")
            df += 1
            nxt = np.unique(nb)
            met = nxt[mh.semijoin(nxt, b_ids)]
            if met.shape[0]:
                cand = df + int(_lookup_sorted(b_ids, b_dep, met).min())
                best = cand if best is None else min(best, cand)
            f_lev = nxt[~mh.semijoin(nxt, f_ids)]
            pos = np.searchsorted(f_ids, f_lev)
            f_ids = np.insert(f_ids, pos, f_lev)
            f_dep = np.insert(f_dep, pos, df)
        else:
            _, nb = eng.expand_frontier(b_lev, "in")
            db += 1
            nxt = np.unique(nb)
            met = nxt[mh.semijoin(nxt, f_ids)]
            if met.shape[0]:
                cand = int(_lookup_sorted(f_ids, f_dep, met).min()) + db
                best = cand if best is None else min(best, cand)
            b_lev = nxt[~mh.semijoin(nxt, b_ids)]
            pos = np.searchsorted(b_ids, b_lev)
            b_ids = np.insert(b_ids, pos, b_lev)
            b_dep = np.insert(b_dep, pos, db)
        if best is not None and best <= df + db:
            break
    if best is not None and best <= max_depth:
        return best
    return None


# ---------------------------------------------------------------------------
# Per-hop baselines (pre-ISSUE-6 implementations, kept verbatim for the
# bench_multihop speedup gates and as test oracles)
# ---------------------------------------------------------------------------
def friends_of_friends_perhop(g: GraphLike, v: int,
                              max_friends: Optional[int] = None) -> np.ndarray:
    """Per-hop FoF: two grouped batch calls glued by Python (the PR-1-era
    strategy the columnar operator is benchmarked against)."""
    eng = as_engine(g)
    friends, _ = eng.out_neighbors_batch(np.asarray([v], dtype=np.int64))
    friends = np.unique(friends)
    if max_friends is not None and friends.shape[0] > max_friends:
        friends = friends[:max_friends]
    if friends.size == 0:
        return np.empty(0, np.int64)
    fof, _ = eng.out_neighbors_batch(friends)
    fof = np.unique(fof)
    # exclude friends and the query vertex (paper's selectOut filter)
    return np.setdiff1d(fof, np.concatenate([friends, [v]]), assume_unique=False)


def bfs_perhop(g: GraphLike, source: int, max_depth: int = 5,
               bottom_up_threshold: float = 0.05) -> dict:
    """Per-hop BFS: one batched hop per level, visited-set management in a
    Python dict — the interpreter-bound loop bench_multihop measures."""
    eng = as_engine(g)
    depth = {int(source): 0}
    frontier = Frontier([source])
    for d in range(1, max_depth + 1):
        nxt = traverse_out(eng, frontier, bottom_up_threshold)
        fresh = [int(u) for u in nxt.ids if int(u) not in depth]
        if not fresh:
            break
        for u in fresh:
            depth[u] = d
        frontier = Frontier(fresh)
    return depth


def shortest_path_perhop(g: GraphLike, s: int, t: int, max_depth: int = 5,
                         two_sided: bool = True) -> Optional[int]:
    """Per-hop two-sided search; settles for the FIRST meeting vertex in id
    order (not necessarily the minimum over the meet set — the columnar
    path fixes that)."""
    eng = as_engine(g)
    if s == t:
        return 0
    if not two_sided:
        d = bfs_perhop(eng, s, max_depth)
        return d.get(int(t))

    fwd = {int(s): 0}
    bwd = {int(t): 0}
    f_front, b_front = Frontier([s]), Frontier([t])
    for _ in range(max_depth):
        if len(f_front) == 0 and len(b_front) == 0:
            return None
        expand_fwd = len(f_front) <= len(b_front) and len(f_front) > 0
        if expand_fwd or len(b_front) == 0:
            nxt = traverse_out(eng, f_front)
            fresh = []
            base = max(fwd.values())
            for u in nxt.ids:
                u = int(u)
                if u in bwd:
                    return base + 1 + bwd[u]
                if u not in fwd:
                    fwd[u] = base + 1
                    fresh.append(u)
            f_front = Frontier(fresh)
        else:
            # backward hop over in-neighbors, one batched query
            nbrs, _ = eng.in_neighbors_batch(b_front.ids)
            nbrs = np.unique(nbrs)
            fresh = []
            base = max(bwd.values())
            for u in nbrs:
                u = int(u)
                if u in fwd:
                    return fwd[u] + 1 + base
                if u not in bwd:
                    bwd[u] = base + 1
                    fresh.append(u)
            b_front = Frontier(fresh)
        total = max(fwd.values()) + max(bwd.values())
        if total >= max_depth and len(f_front) == 0 and len(b_front) == 0:
            break
    return None
