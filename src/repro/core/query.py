"""Graph queries over any StorageEngine (paper §4.2, §7.4, §8.4).

Implements the paper's query set:
  * friends-of-friends (FoF) with the frontier-batched out-edge strategy,
  * frontier traversal with the direction-optimizing top-down/bottom-up
    switch of Beamer et al. that the paper adopts in §7.4,
  * depth-limited unweighted shortest path (one- or two-sided BFS, §8.4).

Every operator speaks only the vectorized set-at-a-time `StorageEngine`
interface (engine.py, DESIGN.md §5) — the same code path serves a bulk-built
`GraphPAL` and a live `LSMTree` (all levels + in-memory buffers), with no
storage-class branching anywhere in this module.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import numpy as np

from .engine import StorageEngine, as_engine

# a StorageEngine, or any store exposing storage_engine() — duck-typed via
# as_engine(), deliberately not a Union over concrete storage classes
GraphLike = Any

__all__ = ["Frontier", "friends_of_friends", "bfs", "shortest_path", "traverse_out"]


class Frontier:
    """A set of vertices (original IDs) flowing through traversal operators —
    the paper's Scala-API frontier (§7.4)."""

    def __init__(self, ids: Sequence[int]):
        self.ids = np.unique(np.asarray(list(ids), dtype=np.int64))

    def __len__(self) -> int:
        return int(self.ids.shape[0])

    def has_vertex(self, v: int) -> bool:
        i = np.searchsorted(self.ids, v)
        return bool(i < self.ids.shape[0] and self.ids[i] == v)


def _bottom_up_step(eng: StorageEngine, frontier_mask: np.ndarray) -> np.ndarray:
    """Bottom-up sweep (paper §7.4 / Beamer): stream ALL edges once and emit
    destinations whose source is in the frontier. Cost O(|E|/B) sequential —
    cheaper than per-vertex queries when the frontier is a large fraction of
    V. Streams the engine's edge chunks (partitions of every level AND live
    buffers) instead of branching on the storage class."""
    iv = eng.intervals
    next_ids = []
    for chunk in eng.edge_chunks():
        src_orig = np.asarray(iv.to_original(chunk.src), dtype=np.int64)
        m = frontier_mask[src_orig]
        if m.any():
            next_ids.append(np.asarray(iv.to_original(chunk.dst[m]), np.int64))
    return np.concatenate(next_ids) if next_ids else np.empty(0, np.int64)


def traverse_out(g: GraphLike, frontier: Frontier,
                 bottom_up_threshold: float = 0.05) -> Frontier:
    """One traversal hop with the direction-optimizing switch (paper §7.4):
    if the frontier exceeds a fraction of |V|, sweep bottom-up over all
    edges instead of issuing batched out-edge queries."""
    eng = as_engine(g)
    n_vert = eng.n_internal_vertices
    if len(frontier) > bottom_up_threshold * n_vert:
        mask = np.zeros(n_vert + 1, dtype=bool)
        mask[np.minimum(frontier.ids, n_vert)] = True
        nbrs = _bottom_up_step(eng, mask)
    else:
        nbrs, _ = eng.out_neighbors_batch(frontier.ids)
    return Frontier(nbrs)


def friends_of_friends(g: GraphLike, v: int,
                       max_friends: Optional[int] = None) -> np.ndarray:
    """Paper §8.4: W = {w : ∃u, (v,u) ∈ E, (u,w) ∈ E}, excluding the friends
    themselves (and v). Out-edges of all friends are queried in one batch."""
    eng = as_engine(g)
    friends, _ = eng.out_neighbors_batch(np.asarray([v], dtype=np.int64))
    friends = np.unique(friends)
    if max_friends is not None and friends.shape[0] > max_friends:
        friends = friends[:max_friends]
    if friends.size == 0:
        return np.empty(0, np.int64)
    fof, _ = eng.out_neighbors_batch(friends)
    fof = np.unique(fof)
    # exclude friends and the query vertex (paper's selectOut filter)
    return np.setdiff1d(fof, np.concatenate([friends, [v]]), assume_unique=False)


def bfs(g: GraphLike, source: int, max_depth: int = 5,
        bottom_up_threshold: float = 0.05) -> dict:
    """Direction-optimizing BFS; returns {vertex: depth} for reached vertices."""
    eng = as_engine(g)
    depth = {int(source): 0}
    frontier = Frontier([source])
    for d in range(1, max_depth + 1):
        nxt = traverse_out(eng, frontier, bottom_up_threshold)
        fresh = [int(u) for u in nxt.ids if int(u) not in depth]
        if not fresh:
            break
        for u in fresh:
            depth[u] = d
        frontier = Frontier(fresh)
    return depth


def shortest_path(g: GraphLike, s: int, t: int, max_depth: int = 5,
                  two_sided: bool = True) -> Optional[int]:
    """Depth-limited unweighted shortest path (paper §8.4). Two-sided search
    expands the smaller frontier each round; the backward side uses the
    batched in-neighbor primitive."""
    eng = as_engine(g)
    if s == t:
        return 0
    if not two_sided:
        d = bfs(eng, s, max_depth)
        return d.get(int(t))

    fwd = {int(s): 0}
    bwd = {int(t): 0}
    f_front, b_front = Frontier([s]), Frontier([t])
    for _ in range(max_depth):
        if len(f_front) == 0 and len(b_front) == 0:
            return None
        expand_fwd = len(f_front) <= len(b_front) and len(f_front) > 0
        if expand_fwd or len(b_front) == 0:
            nxt = traverse_out(eng, f_front)
            fresh = []
            base = max(fwd.values())
            for u in nxt.ids:
                u = int(u)
                if u in bwd:
                    return base + 1 + bwd[u]
                if u not in fwd:
                    fwd[u] = base + 1
                    fresh.append(u)
            f_front = Frontier(fresh)
        else:
            # backward hop over in-neighbors, one batched query
            nbrs, _ = eng.in_neighbors_batch(b_front.ids)
            nbrs = np.unique(nbrs)
            fresh = []
            base = max(bwd.values())
            for u in nbrs:
                u = int(u)
                if u in fwd:
                    return fwd[u] + 1 + base
                if u not in bwd:
                    bwd[u] = base + 1
                    fresh.append(u)
            b_front = Frontier(fresh)
        total = max(fwd.values()) + max(bwd.values())
        if total >= max_depth and len(f_front) == 0 and len(b_front) == 0:
            break
    return None
