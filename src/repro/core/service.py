"""The concurrent service tier: lock-free live reads, snapshot-isolated
reader sessions, and a parallel maintenance pipeline over a live GraphDB
(ISSUE 4 + ISSUE 5; paper §1, §5 — an *online* graph database serves
queries and fast insertions concurrently).

Three read/write surfaces:

  * **Lock-free live reads** (`read_view`, ISSUE 5). Every mutation batch
    and merge commit publishes an immutable `LevelManifest`
    (core/manifest.py); a reader pins the current one under an epoch guard
    and runs point queries, batched engine slabs, FoF/BFS, and PSW
    streaming against it without EVER taking the service lock — read
    latency no longer spikes when the writer appends or a merge runs.
    Superseded manifests (and the partition files they reference) are
    reclaimed only once no epoch pins them.

  * `Snapshot` — a read-only, self-contained session directory produced by
    `GraphDB.pin_snapshot`: hard links to the pinned manifest's immutable
    partition files (+ dead sidecars) and to the WAL segments covering
    [manifest.wal_offset, pinned_offset). Opening one rebuilds the exact
    logical state at the pinned WAL offset; the decoded tail records are
    shared across opens at the same pinned offset through a small
    process-wide cache (ISSUE 5 satellite), so the Nth session of a pin
    skips the decode entirely. Sessions are directory-addressed: any
    number of reader threads or *processes* can `Snapshot.open(path)` the
    same pin concurrently.

  * `ServiceDB` — the single-writer front end. One lock serializes
    mutations, snapshot pinning, and maintenance COMMITS; the insert path
    only appends to the WAL and the in-memory buffers (`LSMTree.auto_flush`
    is off). Maintenance is a pipeline (ISSUE 5): a scheduler thread
    dispatches independent top-level buffer merges to a small worker pool —
    each flush drains its buffer under the service lock (cheap), runs the
    merge + partition-sink persistence under only its top-interval lock
    (expensive, concurrent across intervals), and commits + publishes under
    the service lock again (cheap). Checkpoints overlap in-flight merges:
    phase A persists RAM/dirty partitions with NO locks held; phase B takes
    a short exclusive window (all interval locks + the service lock — which
    blocks writers briefly, never readers) for the residual flush, manifest
    write, epoch-aware store GC, and WAL compaction. Reader-latency
    feedback steers cadence: a WAL tail over `wal_tail_budget_bytes`, or a
    `begin_snapshot` whose session rebuild exceeded
    `snapshot_open_budget_s`, schedules a checkpoint early so tail replays
    stay short. The dirty set is bounded: once buffered + in-flight edges
    exceed `backpressure_edges`, writers block until the pipeline drains
    below the high-water mark.

Maintenance pipeline (DESIGN.md §9):

    scheduler --buffered > cap----> worker pool: FLUSH(j)   [interval lock j]
       |                            FLUSH(k) runs CONCURRENTLY  [lock k]
       |--ops/WAL-tail/feedback---> CHECKPOINT: phase A (no locks) overlaps
       |                            the flushes; phase B brief exclusive
       '--close()-----------------> drain pool, final checkpoint, exit

Lock order (deadlock-free): interval locks in ascending index, THEN the
service lock. Deletes/column updates take their one interval lock first for
the same reason. Readers take neither.
"""
from __future__ import annotations

import dataclasses
import errno
import itertools
import json
import os
import shutil
import threading
import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import numpy as np

from . import telemetry
from .disk import DiskPartition, GraphDB, open_partition_file, replay_ops
from .failpoints import failpoint
from .integrity import ReadOnlyError
from .lsm import LSMTree
from .pal import IntervalMap
from .walog import SegmentedWAL

__all__ = ["ServiceDB", "Snapshot", "ServiceStats", "tail_cache_stats"]


# ---------------------------------------------------------------------------
# Shared replayed-WAL-tail cache (ISSUE 5 satellite)
# ---------------------------------------------------------------------------
# Decoded tail records keyed by the *inode identity* of the segments plus
# the [offset, end) window. Session directories of the same pin hard-link
# the same segment inodes, so every `Snapshot.open` at one pinned offset —
# from any thread, over any session dir — hits the same entry and skips the
# decode. Records are numpy views over immutable segment bytes; applying
# them into each session's private tree copies, so sharing is safe.
_TAIL_CACHE_MAX = 4
_TAIL_CACHE: "OrderedDict[tuple, list]" = OrderedDict()
_TAIL_CACHE_LOCK = threading.Lock()
_TAIL_CACHE_STATS = {"hits": 0, "misses": 0}
_M_TAIL_HITS = telemetry.counter("service.tail_cache.hits")
_M_TAIL_MISSES = telemetry.counter("service.tail_cache.misses")
_M_WAL_TAIL = telemetry.gauge("service.wal_tail_bytes")
_M_BACKLOG = telemetry.gauge("service.backlog_edges")
_M_JOB_S = telemetry.histogram("service.job.seconds")


def tail_cache_stats() -> Dict[str, int]:
    with _TAIL_CACHE_LOCK:
        return dict(_TAIL_CACHE_STATS)


def _cached_tail_ops(wal: SegmentedWAL, offset: int, end: int) -> list:
    key = wal.segment_identity(offset, end)
    with _TAIL_CACHE_LOCK:
        ops = _TAIL_CACHE.get(key)
        if ops is not None:
            _TAIL_CACHE.move_to_end(key)
            _TAIL_CACHE_STATS["hits"] += 1
            _M_TAIL_HITS.inc()
            return ops
        _TAIL_CACHE_STATS["misses"] += 1
        _M_TAIL_MISSES.inc()
    # strict_head: a session dir is a CLOSED set of hard links — a missing
    # first segment is loss (someone deleted a link), never compaction
    ops = list(wal.replay(offset=offset, end=end, strict_head=True))
    with _TAIL_CACHE_LOCK:
        _TAIL_CACHE[key] = ops
        while len(_TAIL_CACHE) > _TAIL_CACHE_MAX:
            _TAIL_CACHE.popitem(last=False)
    return ops


# ---------------------------------------------------------------------------
# Snapshot — a pinned, read-only, process-shareable session
# ---------------------------------------------------------------------------
class Snapshot:
    """A consistent read-only view of a GraphDB at one WAL offset.

    Built from a session directory written by `GraphDB.pin_snapshot`. The
    reconstruction is exactly the recovery path: open the pinned manifest's
    partition files (mmap-backed, shared page cache across sessions), then
    replay the typed WAL records in [wal_offset, pinned_offset) into
    private in-memory state. Mutating methods are deliberately absent."""

    def __init__(self, directory: str, doc: Optional[Dict[str, Any]] = None):
        # resolve once, against the CALLER's cwd: every store path below is
        # derived from the session dir (SNAPSHOT.json stores only digests,
        # never absolute paths), so a session dir can be renamed, moved, or
        # handed to another process and opened there. The abspath matters
        # because partition mmaps open lazily — a relative path captured
        # here would break on the first read after any chdir (ISSUE 8).
        directory = os.path.abspath(directory)
        self.dir = directory
        if doc is None:
            with open(os.path.join(directory, GraphDB.SNAPSHOT)) as f:
                doc = json.load(f)
        self.doc = doc
        self.pinned_offset = int(doc["pinned_offset"])
        config = doc["config"]
        iv = IntervalMap(n_partitions=config["n_partitions"],
                         interval_len=config["interval_len"])
        column_dtypes = {k: np.dtype(s)
                         for k, s in config["column_dtypes"].items()}
        tree = LSMTree(
            iv, n_levels=config["n_levels"], branching=config["branching"],
            buffer_cap=config["buffer_cap"],
            max_partition_edges=config["max_partition_edges"],
            column_dtypes=column_dtypes, durable=False)
        for li, level in enumerate(doc["levels"]):
            for pi, entry in enumerate(level):
                if entry is None:
                    continue
                part = open_partition_file(
                    os.path.join(directory, f"part_{entry['digest']}.pal"))
                # sessions carry no residency budget: decode pointer
                # indexes once and keep them (repeat-query speed)
                part.index_resident = True
                dead = os.path.join(directory,
                                    f"part_{entry['digest']}.dead.npy")
                if entry.get("dead") and os.path.exists(dead):
                    part.dead = np.load(dead)
                tree.levels[li][pi] = part
        wal = SegmentedWAL(os.path.join(directory, "wal"), readonly=True)
        replay_ops(tree, _cached_tail_ops(wal, int(doc["wal_offset"]),
                                          self.pinned_offset))
        tree.publish()  # cover the directly-installed pinned partitions
        self.tree = tree
        self._engine = None

    @classmethod
    def open(cls, directory: str) -> "Snapshot":
        """Open an existing session directory — the cross-process entry
        point (reader processes share nothing but the immutable files)."""
        return cls(directory)

    # -- read surface ---------------------------------------------------------
    @property
    def intervals(self) -> IntervalMap:
        return self.tree.intervals

    @property
    def n_edges(self) -> int:
        return self.tree.n_edges

    def storage_engine(self):
        if self._engine is None:
            from .engine import SnapshotEngine
            self._engine = SnapshotEngine(self.tree)
        return self._engine

    def out_neighbors(self, v: int) -> np.ndarray:
        return self.tree.out_neighbors(v)

    def in_neighbors(self, v: int) -> np.ndarray:
        return self.tree.in_neighbors(v)

    def to_coo(self):
        return self.tree.to_coo()

    def all_partitions(self):
        return self.tree.all_partitions()

    def snapshot(self, **kw):
        """Compile the pinned state into a DeviceGraph for PSW analytics."""
        return self.tree.snapshot(**kw)

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        """Drop mappings and decoded caches; the session dir stays openable."""
        for part in self.tree.all_partitions():
            ev = getattr(part, "evict", None)
            if ev is not None:
                ev()

    def release(self) -> None:
        """Close AND delete the session directory — the last hard link to
        any GC'd partition file or compacted WAL segment drops here."""
        self.close()
        shutil.rmtree(self.dir, ignore_errors=True)


# ---------------------------------------------------------------------------
# ServiceDB — single writer, parallel maintenance pipeline, lock-free reads
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServiceStats:
    flushes: int = 0          # committed buffer drains (merges + sink)
    checkpoints: int = 0      # maintenance checkpoints (manifest + GC)
    snapshots: int = 0        # sessions pinned
    backpressure_waits: int = 0  # insert calls that blocked on the bound
    feedback_checkpoints: int = 0  # checkpoints scheduled by reader feedback
    max_concurrent_flushes: int = 0  # peak in-flight flush jobs (pipeline)
    job_retries: int = 0      # supervised job failures that were retried
    poisoned_jobs: int = 0    # jobs quarantined after repeated failure
    read_only_entries: int = 0   # times the service shed to read-only
    read_only_exits: int = 0     # times auto-recovery cleared it
    scrubs: int = 0           # background integrity scrub passes


# registry names for the ServiceStats collector (ISSUE 9): the dataclass
# stays the live state its `+=` sites mutate under the service lock;
# telemetry.snapshot() reads it through a weakref at aggregation time
_SERVICE_STATS_METRICS = {
    "flushes": "service.flushes",
    "checkpoints": "service.checkpoints",
    "snapshots": "service.snapshots",
    "backpressure_waits": "service.backpressure_waits",
    "feedback_checkpoints": "service.feedback_checkpoints",
    "max_concurrent_flushes": "service.max_concurrent_flushes",
    "job_retries": "service.job_retries",
    "poisoned_jobs": "service.poisoned_jobs",
    "read_only_entries": "service.read_only_entries",
    "read_only_exits": "service.read_only_exits",
    "scrubs": "service.scrubs",
}


# __init__ kwargs that ServiceDB.create must keep for itself rather than
# forward to GraphDB.create
_SUPERVISION_KW = ("max_job_failures", "backoff_base_s", "backoff_max_s",
                   "recovery_probe_s", "scrub_interval_s", "scrub_limit")


class ServiceDB:
    """Concurrent front end over a durable GraphDB.

    Writer methods (insert/delete/update) append to the WAL + buffers under
    the service lock and return; merges, partition persistence, checkpoint
    GC, and WAL compaction run on the maintenance pipeline. Live reads go
    through `read_view()` — epoch-pinned manifests, NO lock shared with any
    of the above. `begin_snapshot` pins the current logical state into a
    session directory and returns a `Snapshot` any number of readers can
    query (or re-open by path from other processes).

    `pipeline=True` (default) runs the ISSUE-5 parallel pipeline: flush
    merges of distinct top-level intervals proceed concurrently on
    `maintenance_workers` threads, and checkpoints overlap them.
    `pipeline=False` keeps the PR-4 serial loop (one thread, every step
    under the service lock) — the in-run baseline `bench_service.py`'s
    contended-read benchmark measures against."""

    def __init__(self, db: GraphDB,
                 checkpoint_interval_ops: int = 500_000,
                 backpressure_edges: Optional[int] = None,
                 maintenance: bool = True,
                 pipeline: bool = True,
                 maintenance_workers: Optional[int] = None,
                 wal_tail_budget_bytes: int = 64 << 20,
                 snapshot_open_budget_s: float = 1.0,
                 max_job_failures: int = 3,
                 backoff_base_s: float = 0.05,
                 backoff_max_s: float = 5.0,
                 recovery_probe_s: float = 0.5,
                 scrub_interval_s: Optional[float] = None,
                 scrub_limit: Optional[int] = None):
        if db.tree.wal is None:
            raise ValueError("ServiceDB needs a durable GraphDB")
        self.db = db
        self.tree = db.tree
        self.tree.auto_flush = False  # inserts never merge on their thread
        self.checkpoint_interval_ops = int(checkpoint_interval_ops)
        self.backpressure_edges = int(backpressure_edges
                                      if backpressure_edges is not None
                                      else 4 * self.tree.buffer_cap)
        self.pipeline = bool(pipeline)
        self.maintenance_workers = int(
            maintenance_workers if maintenance_workers is not None
            else max(2, min(4, (os.cpu_count() or 2) - 1)))
        self.wal_tail_budget_bytes = int(wal_tail_budget_bytes)
        self.snapshot_open_budget_s = float(snapshot_open_budget_s)
        self.stats = ServiceStats()
        telemetry.register_stats(self.stats, _SERVICE_STATS_METRICS)
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._drained = threading.Condition(self._lock)
        self._closing = False
        self._ops_since_ckpt = 0
        self._snap_ids = itertools.count()
        self.maintenance_error: Optional[BaseException] = None
        # -- supervision (ISSUE 7): maintenance jobs are retried with
        # exponential backoff, quarantined ("poisoned") after K failures,
        # and persist-path failure sheds the service to READ-ONLY mode —
        # writes raise ReadOnlyError, epoch reads and snapshots stay live,
        # and a periodic probe auto-recovers once the condition clears
        self.max_job_failures = int(max_job_failures)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_max_s = float(backoff_max_s)
        self.recovery_probe_s = float(recovery_probe_s)
        self.scrub_interval_s = scrub_interval_s
        self.scrub_limit = scrub_limit
        self._job_failures: Dict[str, int] = {}
        self._job_backoff: Dict[str, float] = {}   # key -> monotonic deadline
        self._poisoned: set = set()
        self.read_only = False
        self.read_only_reason: Optional[str] = None
        self._next_probe = 0.0
        self._last_scrub = time.monotonic()
        self._scrubbing = False
        # merge slots: one lock per top-level destination interval. A flush
        # job owns its subtree for the whole merge; deletes/column updates
        # take the one slot their destination maps to. Lock ORDER: interval
        # locks (ascending index) strictly before the service lock. RLocks,
        # so a caller may pre-acquire a slot (in order) around a compound
        # operation that itself takes it.
        self._interval_locks = [threading.RLock() for _ in self.tree.buffers]
        self._flushing: set = set()       # top indexes with a job in flight
        self._ckpt_running = False
        self._ckpt_requested = False      # reader-feedback checkpoint ask
        # the tail budget measures what a new session must REPLAY, i.e.
        # bytes past the manifest-covered offset — a store reopened with a
        # big pre-existing tail must count it (initializing to the current
        # tail would report 0 until new writes accrue)
        try:
            self._last_ckpt_offset = int(
                db._read_manifest().get("wal_offset", 0))
        except OSError:
            self._last_ckpt_offset = self.tree.wal.tail_offset()
        self.last_snapshot_open_s = 0.0
        self._thread: Optional[threading.Thread] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        if maintenance:
            if self.pipeline:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.maintenance_workers,
                    thread_name_prefix="graphdb-mw")
                target = self._scheduler_loop
            else:
                target = self._maintenance_loop
            self._thread = threading.Thread(
                target=target, name="graphdb-maintenance", daemon=True)
            self._thread.start()

    # -- lifecycle -------------------------------------------------------------
    @classmethod
    def create(cls, directory: str, max_id: int,
               checkpoint_interval_ops: int = 500_000,
               backpressure_edges: Optional[int] = None,
               maintenance: bool = True, pipeline: bool = True,
               maintenance_workers: Optional[int] = None,
               wal_tail_budget_bytes: int = 64 << 20,
               snapshot_open_budget_s: float = 1.0,
               **graphdb_kw) -> "ServiceDB":
        graphdb_kw.setdefault("durable", True)
        service_kw = {k: graphdb_kw.pop(k) for k in _SUPERVISION_KW
                      if k in graphdb_kw}
        db = GraphDB.create(directory, max_id=max_id, **graphdb_kw)
        return cls(db, checkpoint_interval_ops=checkpoint_interval_ops,
                   backpressure_edges=backpressure_edges,
                   maintenance=maintenance, pipeline=pipeline,
                   maintenance_workers=maintenance_workers,
                   wal_tail_budget_bytes=wal_tail_budget_bytes,
                   snapshot_open_budget_s=snapshot_open_budget_s,
                   **service_kw)

    @classmethod
    def open(cls, directory: str, **service_kw) -> "ServiceDB":
        return cls(GraphDB.open(directory), **service_kw)

    def close(self) -> None:
        with self._lock:
            self._closing = True
            self._work.notify_all()
            self._drained.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._pool is not None:
            self._pool.shutdown(wait=True)  # in-flight jobs finish cleanly
            self._pool = None
        with self._lock:
            self.db.close()  # final checkpoint + WAL close

    # -- writer surface --------------------------------------------------------
    def _check_writable(self) -> None:
        """Caller holds the lock. Raises BEFORE the mutation is applied."""
        if self.read_only:
            raise ReadOnlyError(self.read_only_reason or "degraded")
        if self.maintenance_error is not None:
            raise RuntimeError("maintenance thread died") \
                from self.maintenance_error

    def _after_mutation(self, n_ops: int) -> None:
        """Caller holds the lock. Account ops, wake maintenance, apply
        backpressure: block while the dirty set (buffered + in-flight
        drained edges) exceeds the bound."""
        self._ops_since_ckpt += n_ops
        if telemetry.enabled():
            _M_WAL_TAIL.set(int(self.wal_tail_bytes()))
            _M_BACKLOG.set(int(self.tree.total_buffered()
                               + self.tree.inflight_edges()))
        if self._pending_work():
            self._work.notify()
        waited = False
        while (self.tree.total_buffered() + self.tree.inflight_edges()
               > self.backpressure_edges
               and not self._closing and not self.read_only
               and self.maintenance_error is None
               and self._thread is not None
               and self._thread.is_alive()):
            waited = True
            self._work.notify()
            self._drained.wait(timeout=1.0)
        if waited:
            self.stats.backpressure_waits += 1
        if self.read_only:
            # the pipeline degraded while this writer waited: the mutation
            # IS applied (buffered + WAL) but the writer must learn the
            # service stopped accepting more
            raise ReadOnlyError(self.read_only_reason or "degraded")
        if self.maintenance_error is not None:
            # a dead maintenance thread would leave backpressure waiting
            # forever — surface its failure to the writer instead
            raise RuntimeError("maintenance thread died") \
                from self.maintenance_error

    def insert_edge(self, src: int, dst: int, etype: int = 0, **cols) -> None:
        with self._lock:
            self._check_writable()
            self.tree.insert_edge(src, dst, etype=etype, **cols)
            self._after_mutation(1)

    def insert_edges(self, src, dst, etype=None, columns=None) -> None:
        n = int(np.asarray(src).shape[0])
        with self._lock:
            self._check_writable()
            self.tree.insert_edges(src, dst, etype=etype, columns=columns)
            self._after_mutation(n)

    def _merge_slot_of(self, dst: int) -> threading.Lock:
        """The interval lock owning `dst`'s top-level subtree. Structural
        partition mutations (tombstones, in-place column writes) must hold
        it so they serialize with an in-flight merge of the same subtree —
        otherwise the merge's rebuilt partitions would drop a tombstone
        landed mid-merge. Acquired BEFORE the service lock (lock order)."""
        idst = int(self.tree.intervals.to_internal_scalar(dst))
        return self._interval_locks[self.tree._top_index_of(idst)]

    def delete_edge(self, src: int, dst: int) -> bool:
        with self._merge_slot_of(dst):
            with self._lock:
                self._check_writable()
                found = self.tree.delete_edge(src, dst)
                self._after_mutation(1)
                return found

    def update_edge_column(self, src: int, dst: int, name: str, value) -> bool:
        with self._merge_slot_of(dst):
            with self._lock:
                self._check_writable()
                ok = self.tree.update_edge_column(src, dst, name, value)
                self._after_mutation(1)
                return ok

    def _all_merge_slots(self):
        """Context acquiring every interval lock in index order — the brief
        exclusive window of checkpoint phase B (writers blocked, epoch
        readers unaffected)."""
        class _All:
            def __init__(_s, locks):
                _s.locks = locks

            def __enter__(_s):
                for lk in _s.locks:
                    lk.acquire()

            def __exit__(_s, *exc):
                for lk in reversed(_s.locks):
                    lk.release()

        return _All(self._interval_locks)

    def checkpoint(self) -> Dict[str, Any]:
        with self._all_merge_slots():
            with self._lock:
                manifest = self.db.checkpoint()
                self._ops_since_ckpt = 0
                self._last_ckpt_offset = self.tree.wal.tail_offset()
                return manifest

    # -- snapshot sessions -----------------------------------------------------
    def begin_snapshot(self, view=None) -> Snapshot:
        """Pin the current logical state and return a read-only session.
        The pin (hard links + SNAPSHOT.json) happens under the lock — a
        few syscalls, no data copy; the session rebuild (mmap + WAL tail
        replay) happens outside it, off the writer's critical path.

        With `view` (a pinned `ManifestView`), the session is pinned at the
        view's logical offset instead of the current tail: the rebuilt
        state is bitwise the view's state, which is how an in-process epoch
        crosses the process boundary (shard workers export their pinned
        epoch this way — core/shardrouter.py)."""
        offset = None if view is None else int(view.wal_tail)
        with self._lock:
            base = os.path.join(self.db.dir, "snapshots")
            os.makedirs(base, exist_ok=True)
            while True:
                # the counter restarts per instance and pids recycle, so a
                # reopened ServiceDB can land on a still-live session name —
                # skip collisions instead of crashing
                sid = f"snap_{os.getpid()}_{next(self._snap_ids):06d}"
                dest = os.path.join(base, sid)
                try:
                    doc = self.db.pin_snapshot(dest, pinned_offset=offset)
                    break
                except FileExistsError:
                    continue
            self.stats.snapshots += 1
        t0 = time.perf_counter()
        snap = Snapshot(dest, doc=doc)
        open_s = time.perf_counter() - t0
        self.last_snapshot_open_s = open_s
        if open_s > self.snapshot_open_budget_s:
            # reader-latency feedback: the session rebuild (mmap + tail
            # replay) is getting slow — a checkpoint shrinks the tail
            with self._lock:
                if not self._ckpt_requested:
                    self._ckpt_requested = True
                    self.stats.feedback_checkpoints += 1
                self._work.notify()
        return snap

    # -- live reads (lock-free: epoch-pinned manifests, ISSUE 5) ---------------
    def read_view(self):
        """Pin the current published manifest and return a read-only store
        view (core/manifest.py). The whole query session on one view —
        point lookups, batched engine slabs, FoF/BFS, PSW streaming — runs
        against a single frozen state and NEVER takes the service lock, so
        read latency is flat while the writer appends and merges run.
        Release the view (context manager) when done."""
        return self.tree.read_view()

    def out_neighbors(self, v: int) -> np.ndarray:
        with self.read_view() as view:
            return view.out_neighbors(v)

    def in_neighbors(self, v: int) -> np.ndarray:
        with self.read_view() as view:
            return view.in_neighbors(v)

    @property
    def n_edges(self) -> int:
        with self.read_view() as view:
            return view.n_edges

    @property
    def intervals(self) -> IntervalMap:
        return self.tree.intervals

    def storage_engine(self):
        """The LIVE engine — only safe while no concurrent writer runs
        (e.g. single-thread benchmarking). Concurrent readers should use
        `read_view().storage_engine()` (lock-free, one consistent manifest)
        or `begin_snapshot().storage_engine()` (process-shareable)."""
        return self.db.storage_engine()

    def health(self) -> Dict[str, Any]:
        """One liveness/progress probe, cheap enough to poll: what a shard
        router's supervisor (core/shardrouter.py) uses to decide a worker
        is alive and making progress, and what `bench_shard.py` records
        per shard. Taken without the service lock — every field is a
        single read of published state (approximate by design)."""
        with self.read_view() as view:
            n_edges = view.n_edges
            epoch = view.version
        tail = int(self.wal_tail_bytes())
        backlog = int(self.tree.total_buffered()
                      + self.tree.inflight_edges())
        alive = bool(self._thread is not None and self._thread.is_alive())
        poisoned = sorted(self._poisoned)
        # metric-derived readiness (ISSUE 9 satellite): ready means "a new
        # request will be served promptly AND durably" — not read-only, a
        # live maintenance pipeline, the WAL tail within its replay budget,
        # backlog under the backpressure bound, and no quarantined jobs
        wal_tail_ok = tail <= self.wal_tail_budget_bytes
        backlog_ok = backlog <= self.backpressure_edges
        return {
            "pid": os.getpid(),
            "n_edges": int(n_edges),
            "epoch": int(epoch),
            "read_only": bool(self.read_only),
            "read_only_reason": self.read_only_reason,
            "wal_tail_bytes": tail,
            "wal_tail_budget_bytes": int(self.wal_tail_budget_bytes),
            "wal_tail_ok": bool(wal_tail_ok),
            "buffered": int(self.tree.total_buffered()),
            "backlog_edges": backlog,
            "backlog_ok": bool(backlog_ok),
            "poisoned_jobs": poisoned,
            "poisoned_count": len(poisoned),
            "maintenance_alive": alive,
            "ready": bool(not self.read_only and alive and wal_tail_ok
                          and backlog_ok and not poisoned),
            "io": self.db.io.snapshot(),
        }

    def admission_state(self) -> Dict[str, Any]:
        """The three facts front-end admission control (core/frontdesk.py)
        polls before queueing a WRITE: read-only degradation (shed now —
        the write would only fail later, typed the same), and how close
        the dirty set is to the backpressure bound (a front desk sheds
        instead of letting its dispatcher block inside `insert_edges`).
        Lock-free single reads, cheap enough for the admission fast path.
        """
        backlog = int(self.tree.total_buffered()
                      + self.tree.inflight_edges())
        return {
            "read_only": bool(self.read_only),
            "read_only_reason": self.read_only_reason,
            "backlog_edges": backlog,
            "backpressure_edges": int(self.backpressure_edges),
            "accepting_writes": bool(not self.read_only
                                     and backlog <= self.backpressure_edges),
        }

    def metrics_snapshot(self) -> Dict[str, Any]:
        """This process's aggregated telemetry (ISSUE 9): every registry
        counter/gauge/histogram summed across threads, legacy stats bags
        folded in. JSON-safe."""
        return telemetry.snapshot()

    def prometheus_text(self) -> str:
        return telemetry.prometheus_text()

    # -- maintenance -----------------------------------------------------------
    def wal_tail_bytes(self) -> int:
        """Un-checkpointed WAL bytes — what a new session must replay."""
        return self.tree.wal.tail_offset() - self._last_ckpt_offset

    def _checkpoint_due(self) -> bool:
        return (self._ops_since_ckpt >= self.checkpoint_interval_ops
                or self._ckpt_requested
                or self.wal_tail_bytes() >= self.wal_tail_budget_bytes)

    def _pending_work(self) -> bool:
        return (self.tree.total_buffered() > self.tree.buffer_cap
                or self._checkpoint_due())

    # -- the PR-4 serial loop (pipeline=False: the measured baseline) ----------
    def _maintenance_loop(self) -> None:
        try:
            self._maintenance_steps()
        except BaseException as e:
            # don't die silently: record the failure so the next writer
            # call raises it instead of hanging in the backpressure wait
            with self._lock:
                self.maintenance_error = e
                self._drained.notify_all()

    def _maintenance_steps(self) -> None:
        while True:
            # one lock acquisition per transition: the lock is actually
            # free between a flush and the next flush/checkpoint, so
            # writers interleave with a sustained drain instead of
            # stalling behind the whole backlog
            with self._lock:
                while (not self._pending_work() and not self._closing
                       and not self.read_only):
                    self._work.wait(timeout=0.5)
                if self._closing:
                    return  # close() checkpoints what remains
                if self.read_only:
                    self._probe_recovery()
                    if self.read_only:
                        self._work.wait(timeout=self.recovery_probe_s)
                    continue
                if (self.tree.total_buffered() > self.tree.buffer_cap
                        and self._backoff_ready("flush")):
                    # FLUSH: one whole buffer per merge — back-to-back
                    # small flushes of the same top partition batch into
                    # one rewrite instead of many
                    try:
                        self.tree.flush_fullest_buffer()
                    except BaseException as e:
                        self._job_failed("flush", e)
                    else:
                        self._job_ok("flush")
                        self.stats.flushes += 1
                elif self._checkpoint_due() and self._backoff_ready(
                        "checkpoint"):
                    # CHECKPOINT: persist + manifest + store GC + WAL
                    # segment compaction
                    try:
                        self.db.checkpoint()
                    except BaseException as e:
                        self._job_failed("checkpoint", e)
                    else:
                        self._job_ok("checkpoint")
                        self._ops_since_ckpt = 0
                        self._last_ckpt_offset = self.tree.wal.tail_offset()
                        self._ckpt_requested = False
                        self.stats.checkpoints += 1
                else:
                    # pending work, but every step is backing off
                    self._work.wait(timeout=0.1)
                self._drained.notify_all()

    # -- the ISSUE-5 pipeline (pipeline=True) ----------------------------------
    def _scheduler_loop(self) -> None:
        """Dispatch flush jobs (one per top-level interval, concurrent
        across intervals) and checkpoint jobs to the worker pool. Holds the
        service lock only to inspect state and enqueue; all heavy work runs
        on the workers."""
        try:
            with self._lock:
                while True:
                    while (not self._pending_work() and not self._closing
                           and not self.read_only
                           and not self._scrub_due()):
                        self._work.wait(timeout=0.5)
                    if self._closing:
                        return  # close() drains the pool + final checkpoint
                    if self.read_only:
                        # degraded: no new jobs; probe for recovery
                        self._probe_recovery()
                        if self.read_only:
                            self._work.wait(timeout=self.recovery_probe_s)
                        continue
                    submitted = self._schedule_flushes()
                    if (self._checkpoint_due() and not self._ckpt_running
                            and self._backoff_ready("checkpoint")):
                        self._ckpt_running = True
                        self._pool.submit(self._run_job, "checkpoint",
                                          self._checkpoint_job,
                                          ctx=telemetry.current_context())
                        submitted = True
                    if self._scrub_due():
                        self._scrubbing = True
                        self._pool.submit(self._run_job, "scrub",
                                          self._scrub_job,
                                          ctx=telemetry.current_context())
                        submitted = True
                    if not submitted:
                        # work is pending but every eligible job is already
                        # in flight (or backing off) — wait for a commit or
                        # a backoff expiry to change the state
                        self._work.wait(timeout=0.2)
        except BaseException as e:
            with self._lock:
                self.maintenance_error = e
                self._drained.notify_all()

    def _schedule_flushes(self) -> bool:
        """Caller holds the lock. Submit flush jobs for the fullest
        buffers not already in flight while the drainable backlog exceeds
        the cap — independent intervals drain CONCURRENTLY."""
        if self.tree.total_buffered() <= self.tree.buffer_cap:
            return False
        sizes = [(len(b), j) for j, b in enumerate(self.tree.buffers)
                 if len(b) and j not in self._flushing
                 and self._backoff_ready(f"flush:{j}")]
        sizes.sort(reverse=True)
        submitted = False
        remaining = self.tree.total_buffered()
        for n, j in sizes:
            if len(self._flushing) >= self.maintenance_workers:
                break
            self._flushing.add(j)
            self.stats.max_concurrent_flushes = max(
                self.stats.max_concurrent_flushes, len(self._flushing))
            self._pool.submit(self._run_job, f"flush:{j}",
                              self._flush_job, j,
                              ctx=telemetry.current_context())
            submitted = True
            remaining -= n
            if remaining <= self.tree.buffer_cap:
                break
        return submitted

    # -- supervision (ISSUE 7) -------------------------------------------------
    def _job_ok(self, key: str) -> None:
        with self._lock:
            self._job_failures.pop(key, None)
            self._job_backoff.pop(key, None)

    def _job_failed(self, key: str, exc: BaseException) -> None:
        """Supervisor policy: exponential-backoff retry; poison-quarantine
        the job after `max_job_failures`; ENOSPC or a poisoned persist-path
        job sheds the whole service to read-only (writes rejected typed,
        epoch reads + snapshot sessions stay live; auto-recovery probes)."""
        with self._lock:
            n = self._job_failures.get(key, 0) + 1
            self._job_failures[key] = n
            is_enospc = (isinstance(exc, OSError)
                         and exc.errno == errno.ENOSPC)
            poisoned = n >= self.max_job_failures
            if poisoned and key not in self._poisoned:
                self._poisoned.add(key)
                self.stats.poisoned_jobs += 1
            if not poisoned:
                self.stats.job_retries += 1
                delay = min(self.backoff_max_s,
                            self.backoff_base_s * (2 ** (n - 1)))
                self._job_backoff[key] = time.monotonic() + delay
            if (is_enospc or poisoned) and not key.startswith("scrub"):
                # persist-path degradation: record the fault (legacy
                # `maintenance_error` surface) and shed to read-only
                self.maintenance_error = exc
                self._enter_read_only(
                    "ENOSPC" if is_enospc
                    else f"maintenance job {key!r} failed {n}x: {exc}")
            self._drained.notify_all()
            self._work.notify_all()

    def _enter_read_only(self, reason: str) -> None:
        """Caller holds the lock."""
        if not self.read_only:
            self.read_only = True
            self.read_only_reason = reason
            self.stats.read_only_entries += 1
            self._next_probe = time.monotonic() + self.recovery_probe_s

    def _exit_read_only(self) -> None:
        """Caller holds the lock. Clears degradation state entirely: the
        poisoned jobs get a fresh supervisor ledger — if the fault is
        still there they re-fail and the service re-degrades."""
        self.read_only = False
        self.read_only_reason = None
        self.maintenance_error = None
        self._job_failures.clear()
        self._job_backoff.clear()
        self._poisoned.clear()
        self.stats.read_only_exits += 1
        self._drained.notify_all()
        self._work.notify_all()

    def _probe_recovery(self) -> None:
        """Caller holds the lock, service is read-only. Probe the cheapest
        operation resembling the persist path (create + fsync + publish a
        tiny file); success clears read-only and un-poisons every job."""
        now = time.monotonic()
        if now < self._next_probe:
            return
        self._next_probe = now + self.recovery_probe_s
        probe = os.path.join(self.db.dir, ".recovery_probe.tmp")
        try:
            failpoint("part.write.fsync")
            with open(probe, "wb") as f:
                f.write(b"probe")
                f.flush()
                os.fsync(f.fileno())
            os.remove(probe)
        except OSError:
            return  # still degraded; probe again later
        self._exit_read_only()

    def _backoff_ready(self, key: str) -> bool:
        """Caller holds the lock: job not poisoned and past its backoff."""
        if key in self._poisoned:
            return False
        until = self._job_backoff.get(key)
        return until is None or time.monotonic() >= until

    def _scrub_due(self) -> bool:
        """Caller holds the lock."""
        return (self.scrub_interval_s is not None
                and not self._scrubbing
                and self._backoff_ready("scrub")
                and (time.monotonic() - self._last_scrub
                     >= self.scrub_interval_s))

    def _scrub_job(self) -> None:
        """Idle-cadence background scrub (worker pool): re-verify section
        CRCs + content digests of live partition files; corrupt ones are
        quarantined under the exclusive window, readers keep flowing from
        the surviving levels."""
        try:
            failpoint("service.scrub")
            with self._all_merge_slots():
                with self._lock:
                    self.db.scrub(limit=self.scrub_limit)
            with self._lock:
                self.stats.scrubs += 1
        finally:
            with self._lock:
                self._scrubbing = False
                self._last_scrub = time.monotonic()

    def _run_job(self, key: str, fn, *args, ctx=None) -> None:
        """Worker-pool entry point. `ctx` is the submitter's ambient
        [trace_id, span_id] (ISSUE 9): the job's span joins the submitting
        request's trace, so a write that triggered a flush shows the flush
        inside its own trace."""
        with telemetry.attach(ctx), \
                telemetry.span("service.job", job=key) as sp:
            t0 = time.perf_counter()
            try:
                fn(*args)
            except BaseException as e:
                self._job_failed(key, e)
                with self._lock:
                    sp.tag(error=type(e).__name__,
                           retries=self._job_failures.get(key, 0),
                           poisoned=key in self._poisoned,
                           read_only=self.read_only)
            else:
                self._job_ok(key)
            _M_JOB_S.observe(time.perf_counter() - t0,
                             label=key.split(":", 1)[0])

    def _flush_job(self, j: int) -> None:
        """One pipelined flush: drain under the service lock (cheap —
        detach staging views, publish), merge + persist under ONLY the
        interval lock (the expensive part, concurrent with other intervals'
        flushes, the writer, and every reader), commit + publish under the
        service lock again (cheap pointer swaps)."""
        try:
            with self._interval_locks[j]:
                with self._lock:
                    st = self.tree.drain_buffer(j)
                if st is None:
                    return
                failpoint("service.flush.merge")
                txn = self.tree.build_flush_txn(j, st)  # off the service lock
                with self._lock:
                    self.tree.commit_txn(txn)
                    self.stats.flushes += 1
        finally:
            with self._lock:
                self._flushing.discard(j)
                self._drained.notify_all()
                self._work.notify()

    def _checkpoint_job(self) -> None:
        """Checkpoint overlapping in-flight merges. Phase A persists every
        RAM/dirty partition with NO locks held (content-addressed puts are
        idempotent; a partition a concurrent merge replaces becomes an
        unreferenced file the next GC removes). Phase B takes all interval
        locks + the service lock for the residual buffer flush, manifest
        write, epoch-aware GC, and WAL compaction — by then phase A has
        already written the bulk of the bytes, so the exclusive window
        stays short. Writers stall only for phase B; readers never."""
        try:
            with self._lock:
                candidates = [
                    part for lv in self.tree.levels for part in lv
                    if part.n_edges
                    and (not isinstance(part, DiskPartition) or part.dirty)
                ]
            failpoint("service.ckpt.phaseA")
            for part in candidates:  # phase A: no locks, overlaps merges
                self.db.store.put(part)
            with self._all_merge_slots():  # phase B: brief exclusive window
                with self._lock:
                    failpoint("service.ckpt.phaseB")
                    self.db.checkpoint()
                    self._ops_since_ckpt = 0
                    self._last_ckpt_offset = self.tree.wal.tail_offset()
                    self.stats.checkpoints += 1
        finally:
            with self._lock:
                self._ckpt_running = False
                self._ckpt_requested = False
                self._drained.notify_all()
