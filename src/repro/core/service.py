"""The concurrent service tier: snapshot-isolated reader sessions over a
live GraphDB, with background maintenance (ISSUE 4; paper §1, §5 — an
*online* graph database serves queries and fast insertions concurrently).

Two classes:

  * `Snapshot` — a read-only, self-contained session directory produced by
    `GraphDB.pin_snapshot`: hard links to the pinned manifest's immutable
    partition files (+ dead sidecars) and to the WAL segments covering
    [manifest.wal_offset, pinned_offset). Opening one rebuilds the exact
    logical state at the pinned WAL offset — manifest partitions + typed
    tail replay (inserts with columns, tombstones, column writes) — so a
    session answers queries bitwise-identical to a serial replay of its
    prefix, forever, regardless of writer progress, compaction, store GC,
    or WAL segment deletion (the links keep every needed inode alive).
    Sessions are directory-addressed: any number of reader threads or
    *processes* can `Snapshot.open(path)` the same pin concurrently.

  * `ServiceDB` — the single-writer front end. One lock serializes
    mutations, snapshot pinning, and maintenance; the insert path only
    appends to the WAL and the in-memory buffers (`LSMTree.auto_flush` is
    off), while a maintenance thread drains buffers (running the merges
    and the partition-sink persistence), takes periodic checkpoints, and
    GCs — all off the caller's thread. The dirty set is bounded: once
    buffered edges exceed `backpressure_edges`, writers block until the
    maintenance thread drains below the high-water mark.

Maintenance thread state machine (DESIGN.md §8):

    IDLE --buffered > cap--------------> FLUSH  (drain fullest buffer:
      ^                                          merge + sink persistence)
      |--ops since ckpt >= interval----> CHECKPOINT (persist + manifest +
      |                                          store GC + WAL compaction)
      '--close()-----------------------> final checkpoint, exit

Every transition runs under the service lock; between transitions the lock
is free for writers. Readers never take the lock after `begin_snapshot`
returns — isolation comes from immutability, not locking.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import numpy as np

from .disk import GraphDB, open_partition_file, replay_ops
from .lsm import LSMTree
from .pal import IntervalMap
from .walog import SegmentedWAL

__all__ = ["ServiceDB", "Snapshot", "ServiceStats"]


# ---------------------------------------------------------------------------
# Snapshot — a pinned, read-only, process-shareable session
# ---------------------------------------------------------------------------
class Snapshot:
    """A consistent read-only view of a GraphDB at one WAL offset.

    Built from a session directory written by `GraphDB.pin_snapshot`. The
    reconstruction is exactly the recovery path: open the pinned manifest's
    partition files (mmap-backed, shared page cache across sessions), then
    replay the typed WAL records in [wal_offset, pinned_offset) into
    private in-memory state. Mutating methods are deliberately absent."""

    def __init__(self, directory: str, doc: Optional[Dict[str, Any]] = None):
        self.dir = directory
        if doc is None:
            with open(os.path.join(directory, GraphDB.SNAPSHOT)) as f:
                doc = json.load(f)
        self.doc = doc
        self.pinned_offset = int(doc["pinned_offset"])
        config = doc["config"]
        iv = IntervalMap(n_partitions=config["n_partitions"],
                         interval_len=config["interval_len"])
        column_dtypes = {k: np.dtype(s)
                         for k, s in config["column_dtypes"].items()}
        tree = LSMTree(
            iv, n_levels=config["n_levels"], branching=config["branching"],
            buffer_cap=config["buffer_cap"],
            max_partition_edges=config["max_partition_edges"],
            column_dtypes=column_dtypes, durable=False)
        for li, level in enumerate(doc["levels"]):
            for pi, entry in enumerate(level):
                if entry is None:
                    continue
                part = open_partition_file(
                    os.path.join(directory, f"part_{entry['digest']}.pal"))
                dead = os.path.join(directory,
                                    f"part_{entry['digest']}.dead.npy")
                if entry.get("dead") and os.path.exists(dead):
                    part.dead = np.load(dead)
                tree.levels[li][pi] = part
        wal = SegmentedWAL(os.path.join(directory, "wal"), readonly=True)
        replay_ops(tree, wal.replay(offset=int(doc["wal_offset"]),
                                    end=self.pinned_offset))
        self.tree = tree
        self._engine = None

    @classmethod
    def open(cls, directory: str) -> "Snapshot":
        """Open an existing session directory — the cross-process entry
        point (reader processes share nothing but the immutable files)."""
        return cls(directory)

    # -- read surface ---------------------------------------------------------
    @property
    def intervals(self) -> IntervalMap:
        return self.tree.intervals

    @property
    def n_edges(self) -> int:
        return self.tree.n_edges

    def storage_engine(self):
        if self._engine is None:
            from .engine import SnapshotEngine
            self._engine = SnapshotEngine(self.tree)
        return self._engine

    def out_neighbors(self, v: int) -> np.ndarray:
        return self.tree.out_neighbors(v)

    def in_neighbors(self, v: int) -> np.ndarray:
        return self.tree.in_neighbors(v)

    def to_coo(self):
        return self.tree.to_coo()

    def all_partitions(self):
        return self.tree.all_partitions()

    def snapshot(self, **kw):
        """Compile the pinned state into a DeviceGraph for PSW analytics."""
        return self.tree.snapshot(**kw)

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        """Drop mappings and decoded caches; the session dir stays openable."""
        for part in self.tree.all_partitions():
            ev = getattr(part, "evict", None)
            if ev is not None:
                ev()

    def release(self) -> None:
        """Close AND delete the session directory — the last hard link to
        any GC'd partition file or compacted WAL segment drops here."""
        self.close()
        shutil.rmtree(self.dir, ignore_errors=True)


# ---------------------------------------------------------------------------
# ServiceDB — single writer, background maintenance, snapshot hand-out
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServiceStats:
    flushes: int = 0          # maintenance buffer drains (merges + sink)
    checkpoints: int = 0      # maintenance checkpoints (manifest + GC)
    snapshots: int = 0        # sessions pinned
    backpressure_waits: int = 0  # insert calls that blocked on the bound


class ServiceDB:
    """Concurrent front end over a durable GraphDB.

    Writer methods (insert/delete/update) append to the WAL + buffers under
    the service lock and return; merges, partition persistence, checkpoint
    GC, and WAL compaction run on the maintenance thread. `begin_snapshot`
    pins the current logical state into a session directory and returns a
    `Snapshot` any number of readers can query (or re-open by path from
    other processes) without ever contending with the writer."""

    def __init__(self, db: GraphDB,
                 checkpoint_interval_ops: int = 500_000,
                 backpressure_edges: Optional[int] = None,
                 maintenance: bool = True):
        if db.tree.wal is None:
            raise ValueError("ServiceDB needs a durable GraphDB")
        self.db = db
        self.tree = db.tree
        self.tree.auto_flush = False  # inserts never merge on their thread
        self.checkpoint_interval_ops = int(checkpoint_interval_ops)
        self.backpressure_edges = int(backpressure_edges
                                      if backpressure_edges is not None
                                      else 4 * self.tree.buffer_cap)
        self.stats = ServiceStats()
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._drained = threading.Condition(self._lock)
        self._closing = False
        self._ops_since_ckpt = 0
        self._snap_ids = itertools.count()
        self.maintenance_error: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None
        if maintenance:
            self._thread = threading.Thread(
                target=self._maintenance_loop, name="graphdb-maintenance",
                daemon=True)
            self._thread.start()

    # -- lifecycle -------------------------------------------------------------
    @classmethod
    def create(cls, directory: str, max_id: int,
               checkpoint_interval_ops: int = 500_000,
               backpressure_edges: Optional[int] = None,
               maintenance: bool = True, **graphdb_kw) -> "ServiceDB":
        graphdb_kw.setdefault("durable", True)
        db = GraphDB.create(directory, max_id=max_id, **graphdb_kw)
        return cls(db, checkpoint_interval_ops=checkpoint_interval_ops,
                   backpressure_edges=backpressure_edges,
                   maintenance=maintenance)

    @classmethod
    def open(cls, directory: str, **service_kw) -> "ServiceDB":
        return cls(GraphDB.open(directory), **service_kw)

    def close(self) -> None:
        with self._lock:
            self._closing = True
            self._work.notify_all()
            self._drained.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            self.db.close()  # final checkpoint + WAL close

    # -- writer surface --------------------------------------------------------
    def _after_mutation(self, n_ops: int) -> None:
        """Caller holds the lock. Account ops, wake maintenance, apply
        backpressure: block while the dirty set exceeds the bound."""
        if self.maintenance_error is not None:
            # a dead maintenance thread would leave backpressure waiting
            # forever — surface its failure to the writer instead
            raise RuntimeError("maintenance thread died") \
                from self.maintenance_error
        self._ops_since_ckpt += n_ops
        if self._pending_work():
            self._work.notify()
        waited = False
        while (self.tree.total_buffered() > self.backpressure_edges
               and not self._closing and self._thread is not None
               and self._thread.is_alive()):
            waited = True
            self._work.notify()
            self._drained.wait(timeout=1.0)
        if waited:
            self.stats.backpressure_waits += 1

    def insert_edge(self, src: int, dst: int, etype: int = 0, **cols) -> None:
        with self._lock:
            self.tree.insert_edge(src, dst, etype=etype, **cols)
            self._after_mutation(1)

    def insert_edges(self, src, dst, etype=None, columns=None) -> None:
        n = int(np.asarray(src).shape[0])
        with self._lock:
            self.tree.insert_edges(src, dst, etype=etype, columns=columns)
            self._after_mutation(n)

    def delete_edge(self, src: int, dst: int) -> bool:
        with self._lock:
            found = self.tree.delete_edge(src, dst)
            self._after_mutation(1)
            return found

    def update_edge_column(self, src: int, dst: int, name: str, value) -> bool:
        with self._lock:
            ok = self.tree.update_edge_column(src, dst, name, value)
            self._after_mutation(1)
            return ok

    def checkpoint(self) -> Dict[str, Any]:
        with self._lock:
            manifest = self.db.checkpoint()
            self._ops_since_ckpt = 0
            return manifest

    # -- snapshot sessions -----------------------------------------------------
    def begin_snapshot(self) -> Snapshot:
        """Pin the current logical state and return a read-only session.
        The pin (hard links + SNAPSHOT.json) happens under the lock — a
        few syscalls, no data copy; the session rebuild (mmap + WAL tail
        replay) happens outside it, off the writer's critical path."""
        with self._lock:
            base = os.path.join(self.db.dir, "snapshots")
            os.makedirs(base, exist_ok=True)
            while True:
                # the counter restarts per instance and pids recycle, so a
                # reopened ServiceDB can land on a still-live session name —
                # skip collisions instead of crashing
                sid = f"snap_{os.getpid()}_{next(self._snap_ids):06d}"
                dest = os.path.join(base, sid)
                try:
                    doc = self.db.pin_snapshot(dest)
                    break
                except FileExistsError:
                    continue
            self.stats.snapshots += 1
        return Snapshot(dest, doc=doc)

    # -- live reads (serialized with the writer) -------------------------------
    def out_neighbors(self, v: int) -> np.ndarray:
        with self._lock:
            return self.db.out_neighbors(v)

    def in_neighbors(self, v: int) -> np.ndarray:
        with self._lock:
            return self.db.in_neighbors(v)

    @property
    def n_edges(self) -> int:
        with self._lock:
            return self.tree.n_edges

    @property
    def intervals(self) -> IntervalMap:
        return self.tree.intervals

    def storage_engine(self):
        """The LIVE engine — only safe while no concurrent writer runs
        (e.g. single-thread benchmarking). Concurrent readers should use
        `begin_snapshot().storage_engine()` instead."""
        return self.db.storage_engine()

    # -- maintenance -----------------------------------------------------------
    def _pending_work(self) -> bool:
        return (self.tree.total_buffered() > self.tree.buffer_cap
                or self._ops_since_ckpt >= self.checkpoint_interval_ops)

    def _maintenance_loop(self) -> None:
        try:
            self._maintenance_steps()
        except BaseException as e:
            # don't die silently: record the failure so the next writer
            # call raises it instead of hanging in the backpressure wait
            with self._lock:
                self.maintenance_error = e
                self._drained.notify_all()

    def _maintenance_steps(self) -> None:
        while True:
            # one lock acquisition per transition: the lock is actually
            # free between a flush and the next flush/checkpoint, so
            # writers and live reads interleave with a sustained drain
            # instead of stalling behind the whole backlog
            with self._lock:
                while not self._pending_work() and not self._closing:
                    self._work.wait(timeout=0.5)
                if self._closing:
                    return  # close() checkpoints what remains
                if self.tree.total_buffered() > self.tree.buffer_cap:
                    # FLUSH: one whole buffer per merge — back-to-back
                    # small flushes of the same top partition batch into
                    # one rewrite instead of many
                    self.tree.flush_fullest_buffer()
                    self.stats.flushes += 1
                elif self._ops_since_ckpt >= self.checkpoint_interval_ops:
                    # CHECKPOINT: persist + manifest + store GC + WAL
                    # segment compaction
                    self.db.checkpoint()
                    self._ops_since_ckpt = 0
                    self.stats.checkpoints += 1
                self._drained.notify_all()
