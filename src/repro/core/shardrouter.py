"""Shared-nothing interval sharding (ISSUE 8, DESIGN.md §12).

The single-process engine is GIL-bound: epoch views, merges, and query
glue all share one interpreter, so reader threads scale at ~1.26x for 2
readers (BENCH_service). This module splits the vertex-interval space
across N *shard worker processes* — each running its own full `ServiceDB`
(own WAL, own partition store, own maintenance pipeline, own
epoch-published manifests) — fronted by a `ShardRouter` that:

  * routes single-shard ops (insert, out_neighbors, per-source range
    reads) by interval ownership,
  * scatter/gathers batched frontier expansions: `expand_frontier`
    slices the frontier by owner shard, ships each slice over a binary
    length-prefixed IPC protocol (checksummed with the existing wsum32,
    failpoint-instrumented), and fans the flat (owner, neighbor) results
    back into the columnar operator layer (core/multihop.py) unchanged,
  * maintains per-shard manifest epochs: a `ShardedView` pins one
    published manifest in every worker, so a cross-shard read is a vector
    of per-shard snapshot pins (the consistency model in DESIGN.md §12).

Ownership
---------
A vertex's owner shard is a pure function of its id:

    owner(v) = interval_of(to_internal(v)) % n_shards == (v % P) % n_shards

(`P` = n_partitions; the equality holds because the reversible hash puts
`v` into interval `v % P` — paper §7.2). Edges live on the shard owning
their SOURCE: `out_neighbors`/insert/source-range ops touch exactly one
shard, while in-direction ops broadcast to all shards and merge. With
`P % n_shards == 0` (enforced) the hash spreads consecutive original ids
uniformly across shards, so hot id ranges don't pile onto one worker.

Wire protocol
-------------
Frames over an AF_UNIX stream socket (one listener per worker, one
connection per router thread — the connection is the epoch-pin scope):

    header  <IIII  = magic "SHRD", payload length, wsum32(payload), status
    payload <I     = meta length, then meta JSON, then raw ndarray bytes

`meta["arrays"]` lists (name, dtype, shape) for the concatenated array
blobs — numpy buffers cross the boundary as raw bytes, never pickled.
status 0 = request, 1 = ok, 2 = typed error (re-raised router-side).
Failpoint sites: `shard.rpc.send`, `shard.rpc.recv`, `shard.worker.op`,
`shard.worker.serve` — all in the closed CATALOG, all reachable from
tests and the torture harness via `GRAPHDB_FAILPOINTS` (spawned workers
inherit the environment).

Failure / restart
-----------------
Workers are supervised: a dead worker (crash failpoint, OOM-kill, bug) is
respawned by the router *on the same durable directory* — recovery is the
ordinary manifest + WAL-replay open. Reads retry transparently (with
exponential backoff + jitter) after a respawn — they are idempotent
against the recovered state; writes never auto-retry (the WAL may or may
not have acknowledged the mutation — the caller must decide). Epoch pins
die with their connection: a `ShardedView` spanning a restart raises
`ShardEpochLost` rather than silently serving a different epoch.

Request lifecycle (ISSUE 10, DESIGN.md §14)
-------------------------------------------
Every RPC can carry a `Deadline` (explicit argument or the thread's
ambient `deadline_scope`): the remaining budget rides in frame meta, the
router derives each socket timeout from it, retry sleeps never outrun it,
and the worker re-checks it before dispatching — an op whose caller
already gave up is shed with a typed `DeadlineExceeded`, not executed.
A read retried across a worker respawn re-checks the *remaining* budget
at every stage, so a respawn that outlives the deadline surfaces as
`DeadlineExceeded`, never as a silent multi-second stall.

Slowness (the gray failure crashes don't model) is handled two ways:

  * **Hedging** — live (non-view) reads re-issue a sub-request that has
    not answered within the hedge delay (a latency-histogram quantile of
    `shard.rpc.seconds`, floored and capped) on a FRESH connection;
    first response wins. The worker serves each connection on its own
    handler thread, so a hedge genuinely overtakes a stalled request.
    Pinned `ShardedView` reads are never hedged: epoch pins are scoped
    to one connection, and a hedge on another connection would answer
    from a different epoch.
  * **Circuit breakers** — one per shard, fed by transport failures,
    deadline-derived timeouts, and histogram-classified slow calls.
    An open breaker fails calls fast with `ShardOverloadError` instead
    of queueing more work onto a sick worker; after a cool-down one
    probe (health checks always qualify) decides whether to close it.
"""
from __future__ import annotations

import atexit
import dataclasses
import json
import multiprocessing as mp
import os
import random
import socket
import struct
import threading
import time
from concurrent.futures import (FIRST_COMPLETED, ThreadPoolExecutor,
                                TimeoutError as _FutTimeout,
                                wait as _fut_wait)
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import telemetry
from .deadline import (CircuitBreaker, Deadline, backoff_delays,
                       current_deadline, deadline_scope)
from .engine import StorageEngine
from .failpoints import failpoint, fp_clear, fp_set
from .integrity import (DeadlineExceeded, GraphDBError, OverloadError,
                        checksum32)
from .pal import IntervalMap

__all__ = [
    "ShardConfig",
    "ShardEpochLost",
    "ShardOverloadError",
    "ShardProtocolError",
    "ShardRemoteError",
    "ShardRouter",
    "ShardUnavailable",
    "ShardedEngine",
    "ShardedView",
    "shard_of",
]


# ---------------------------------------------------------------------------
# errors
# ---------------------------------------------------------------------------
class ShardProtocolError(GraphDBError):
    """Bytes on a shard socket disagree with the framing contract (bad
    magic, checksum mismatch, truncated frame). The connection that saw it
    is poisoned and torn down — frames after a framing error cannot be
    trusted to be aligned."""


class ShardUnavailable(GraphDBError):
    """A shard worker could not serve the request and the router did not
    (or must not) retry: writes after a worker death, or a worker that
    stayed dead through a respawn attempt."""

    def __init__(self, shard: int, detail: str):
        super().__init__(f"shard {shard}: {detail}")
        self.shard = shard


class ShardRemoteError(GraphDBError):
    """A typed error raised inside a shard worker, carried back over the
    wire. `kind` is the worker-side exception class name."""

    def __init__(self, shard: int, kind: str, message: str):
        super().__init__(f"shard {shard}: {kind}: {message}")
        self.shard = shard
        self.kind = kind

    def to_payload(self) -> Dict[str, Any]:
        return {"kind": self.kind, "message": str(self)}


class ShardEpochLost(ShardUnavailable):
    """The worker holding a ShardedView's epoch pin restarted (or the pin's
    connection dropped): the pinned manifest is gone and the view cannot
    answer consistently. Callers open a fresh view."""

    def __init__(self, shard: int):
        super().__init__(shard, "pinned epoch lost (worker restarted)")


class ShardOverloadError(OverloadError):
    """A shard-scoped overload shed: the shard's circuit breaker is open
    (the router fails fast rather than queueing more work onto a worker
    that is failing or pathologically slow), or the worker itself shed the
    request. Subtype of `OverloadError` so front-end admission control and
    callers handle both with one except clause."""

    def __init__(self, shard: int, reason: str = "breaker_open",
                 detail: str = ""):
        super().__init__(reason, detail=f"shard {shard}"
                         + (f": {detail}" if detail else ""))
        self.shard = shard


# ---------------------------------------------------------------------------
# ownership
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardConfig:
    """The sharding geometry every participant derives routing from. All
    shards share ONE internal id space (same IntervalMap), so internal ids,
    packed multihop keys, and engine outputs are identical across shards
    and bitwise-comparable with an unsharded store of the same config."""

    n_shards: int
    n_partitions: int
    interval_len: int
    max_id: int

    @property
    def intervals(self) -> IntervalMap:
        return IntervalMap(n_partitions=self.n_partitions,
                           interval_len=self.interval_len)

    def shard_of(self, vs) -> np.ndarray:
        return shard_of(vs, self.n_partitions, self.n_shards)


def shard_of(vs, n_partitions: int, n_shards: int) -> np.ndarray:
    """Owner shard of each ORIGINAL vertex id — `(v % P) % n_shards`,
    which equals `interval_of(to_internal(v)) % n_shards` for every id the
    store can hold (the reversible hash maps v into interval `v % P`;
    tests/test_shard.py asserts the equivalence)."""
    vs = np.asarray(vs, dtype=np.int64)
    return (vs % np.int64(n_partitions)) % np.int64(n_shards)


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------
_MAGIC = 0x53485244  # "SHRD"
_HEADER = struct.Struct("<IIII")  # magic, payload_len, wsum32, status
ST_REQUEST, ST_OK, ST_ERROR = 0, 1, 2
_MAX_FRAME = 1 << 31

_M_RPC_REQS = telemetry.counter("shard.rpc.requests")
_M_RPC_S = telemetry.histogram("shard.rpc.seconds")
_M_RPC_TX = telemetry.counter("shard.rpc.bytes_sent")
_M_RPC_RX = telemetry.counter("shard.rpc.bytes_recv")
_M_RPC_INFLIGHT = telemetry.counter("shard.rpc.inflight")
_M_RESTARTS = telemetry.counter("shard.restarts")
_M_RPC_RETRIES = telemetry.counter("shard.rpc.retries")
_M_DEADLINE = telemetry.counter("request.deadline_exceeded")
_M_HEDGES_SENT = telemetry.counter("shard.hedges.sent")
_M_HEDGES_WON = telemetry.counter("shard.hedges.won")
_M_BREAKER_TRIPS = telemetry.counter("shard.breaker.trips")
_M_BREAKER_FF = telemetry.counter("shard.breaker.fastfail")
_M_BREAKER_OPEN = telemetry.gauge("shard.breaker.open")


def encode_payload(meta: Dict[str, Any],
                   arrays: Optional[Dict[str, np.ndarray]] = None) -> bytes:
    """meta JSON + raw C-contiguous array bytes, self-describing via
    meta["arrays"]. Arrays are never pickled: the receiver re-views the
    exact dtype/shape over the wire bytes."""
    arrays = arrays or {}
    meta = dict(meta)
    specs, blobs = [], []
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        specs.append([name, arr.dtype.str, list(arr.shape)])
        blobs.append(arr.tobytes())
    meta["arrays"] = specs
    mbytes = json.dumps(meta, separators=(",", ":")).encode()
    return b"".join([struct.pack("<I", len(mbytes)), mbytes] + blobs)


def decode_payload(buf: bytes) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
    (mlen,) = struct.unpack_from("<I", buf, 0)
    meta = json.loads(buf[4:4 + mlen].decode())
    arrays: Dict[str, np.ndarray] = {}
    off = 4 + mlen
    for name, dtype, shape in meta.pop("arrays", []):
        dt = np.dtype(dtype)
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        end = off + n * dt.itemsize
        arrays[name] = np.frombuffer(buf[off:end], dtype=dt).reshape(shape)
        off = end
    return meta, arrays


def _send_all(sock: socket.socket, data: bytes) -> None:
    """Write every byte or raise — an explicit bounded loop instead of
    `sendall` so a signal landing mid-write (EINTR) resumes at the right
    offset and a closed peer surfaces as a typed ConnectionError, never a
    silent partial frame (ISSUE 10 satellite). The loop is bounded: every
    iteration either makes progress or raises."""
    view = memoryview(data)
    sent = 0
    total = len(view)
    while sent < total:
        try:
            n = sock.send(view[sent:])
        except InterruptedError:
            continue  # EINTR: nothing was written, retry the same slice
        if n <= 0:
            raise ConnectionError("shard connection closed mid-send")
        sent += n


def send_frame(sock: socket.socket, status: int, meta: Dict[str, Any],
               arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
    payload = encode_payload(meta, arrays)
    failpoint("shard.rpc.send")
    _M_RPC_TX.inc(len(payload))
    _send_all(sock, _HEADER.pack(_MAGIC, len(payload), checksum32(payload),
                                 status) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly n bytes or raise. Bounded: each iteration either
    receives at least one byte, retries a signal interruption (EINTR), or
    raises — a dribbling peer (1 byte per segment) therefore costs at most
    n iterations and can never yield a silent short read."""
    chunks = []
    while n:
        try:
            b = sock.recv(min(n, 1 << 20))
        except InterruptedError:
            continue
        if not b:
            raise ConnectionError("shard connection closed mid-frame")
        chunks.append(b)
        n -= len(b)
    return b"".join(chunks)


def recv_frame(sock: socket.socket
               ) -> Tuple[int, Dict[str, Any], Dict[str, np.ndarray]]:
    head = _recv_exact(sock, _HEADER.size)
    magic, length, cksum, status = _HEADER.unpack(head)
    failpoint("shard.rpc.recv")
    if magic != _MAGIC or length > _MAX_FRAME:
        raise ShardProtocolError(
            f"bad frame header (magic {magic:#x}, length {length})")
    payload = _recv_exact(sock, length)
    _M_RPC_RX.inc(int(length))
    if checksum32(payload) != cksum:
        raise ShardProtocolError(
            f"frame checksum mismatch over {length} payload bytes")
    meta, arrays = decode_payload(payload)
    return status, meta, arrays


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------
def _predicate_from(d: Optional[Dict[str, Any]]):
    if d is None:
        return None
    from .multihop import EdgePredicate
    return EdgePredicate(**d)


class _WorkerState:
    """Per-process state of one shard worker: the shard's ServiceDB plus
    the accept loop's stop flag."""

    def __init__(self, shard_id: int, svc):
        self.shard_id = shard_id
        self.svc = svc
        self.stop = threading.Event()


class _Connection:
    """One router connection served by one worker thread. The connection
    is the epoch-pin scope: pinned views die (and are released) with it,
    which is what makes 'pin lost after restart' detectable instead of
    silently re-pinning a different epoch."""

    def __init__(self, state: _WorkerState, sock: socket.socket):
        self.state = state
        self.sock = sock
        self.views: Dict[int, Any] = {}
        self._next_view = 0

    # -- op handlers ---------------------------------------------------------
    def _store(self, kw: Dict[str, Any]):
        """The read target: a pinned epoch view when the request names one,
        the live tree otherwise (single-op reads pin their own view)."""
        token = kw.get("epoch")
        if token is None:
            return None
        view = self.views.get(int(token))
        if view is None:
            raise KeyError(f"unknown epoch token {token} (pin lost?)")
        return view

    def handle(self, meta: Dict[str, Any],
               arrays: Dict[str, np.ndarray]
               ) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        op = meta["op"]
        kw = meta.get("kw", {})
        svc = self.state.svc
        if op == "ping":
            return {"shard": self.state.shard_id, **svc.health()}, {}
        if op == "insert_edges":
            cols = {n[4:]: a for n, a in arrays.items()
                    if n.startswith("col:")}
            svc.insert_edges(arrays["src"], arrays["dst"],
                             etype=arrays.get("etype"),
                             columns=cols or None)
            return {"n": int(arrays["src"].shape[0])}, {}
        if op == "delete_edge":
            return {"found": bool(svc.delete_edge(kw["src"], kw["dst"]))}, {}
        if op == "pin_epoch":
            view = svc.read_view()
            token = self._next_view
            self._next_view += 1
            self.views[token] = view
            return {"epoch": token, "version": int(view.version),
                    "n_edges": int(view.n_edges)}, {}
        if op == "release_epoch":
            view = self.views.pop(int(kw["epoch"]), None)
            if view is not None:
                view.release()
            return {"released": view is not None}, {}
        if op == "snapshot":
            view = self._store(kw)
            snap = svc.begin_snapshot(view=view)
            snap.close()  # the worker keeps no mapping; the dir is the API
            return {"dir": snap.dir}, {}
        if op == "checkpoint":
            svc.checkpoint()
            return {"ok": True}, {}
        if op == "io_stats":
            return dict(svc.db.io.snapshot()), {}
        if op == "telemetry":
            # worker-side observability surface: this process's metric
            # snapshot (exact-mergeable router-side) and, on request, its
            # buffered Chrome trace events — both JSON, both ride in meta
            doc: Dict[str, Any] = {"metrics": telemetry.snapshot()}
            if kw.get("trace"):
                doc["trace"] = telemetry.trace_events(
                    clear=bool(kw.get("clear")))
            return doc, {}
        if op == "failpoint":
            # per-shard fault arming (ISSUE 10): the GRAPHDB_FAILPOINTS
            # env channel is inherited by EVERY spawned worker, so a chaos
            # harness that wants exactly ONE slow shard arms it here over
            # the wire instead (seeded prob → reproducible latency faults)
            if kw.get("clear"):
                fp_clear(kw.get("site"))
                return {"ok": True}, {}
            fp_set(kw["site"], kw["action"], after=int(kw.get("after", 0)),
                   count=kw.get("count", 1), prob=kw.get("prob"),
                   seed=kw.get("seed"))
            return {"ok": True}, {}

        # -- reads: answered from the pinned epoch (or a private pin) -------
        view = self._store(kw)
        owns_pin = view is None
        if owns_pin:
            view = svc.read_view()
        try:
            eng = view.storage_engine()
            if op == "out_neighbors":
                return {}, {"nb": view.out_neighbors(int(kw["v"]))}
            if op == "in_neighbors":
                return {}, {"nb": view.in_neighbors(int(kw["v"]))}
            if op == "expand":
                owner, nb = eng.expand_frontier(
                    arrays["vs"], kw.get("direction", "out"),
                    _predicate_from(kw.get("predicate")))
                return {}, {"owner": owner, "nb": nb}
            if op == "degree_batch":
                deg = eng._degree_batch(arrays["vs"],
                                        kw.get("direction", "out"))
                return {}, {"deg": deg}
            if op == "coo":
                s, d = view.to_coo()
                return {}, {"src": np.asarray(s, np.int64),
                            "dst": np.asarray(d, np.int64)}
            if op == "n_edges":
                return {"n_edges": int(view.n_edges)}, {}
        finally:
            if owns_pin:
                view.release()
        raise ValueError(f"unknown shard op {op!r}")

    def serve(self) -> None:
        try:
            while not self.state.stop.is_set():
                try:
                    status, meta, arrays = recv_frame(self.sock)
                except (ConnectionError, OSError):
                    return
                if status != ST_REQUEST:
                    raise ShardProtocolError(
                        f"worker received non-request status {status}")
                if meta.get("op") == "shutdown":
                    send_frame(self.sock, ST_OK, {"ok": True})
                    self.state.stop.set()
                    return
                try:
                    # rebuild the budget BEFORE the failpoint so an
                    # injected stall (modeling queueing delay inside the
                    # worker) consumes it; the re-check after means an op
                    # whose caller's budget is already gone is shed typed,
                    # not executed — the router maps the kind back to a
                    # local DeadlineExceeded
                    bdl = Deadline.from_budget(meta.get("deadline"))
                    failpoint("shard.worker.op")
                    if bdl is not None and bdl.expired():
                        _M_DEADLINE.inc(label="worker")
                        raise DeadlineExceeded(
                            f"shard {self.state.shard_id} "
                            f"{meta.get('op', '?')} (shed pre-dispatch)",
                            -bdl.remaining())
                    # the router's trace context rides in meta["trace"];
                    # attaching it here is what stitches worker spans into
                    # the router-side trace (same trace id across processes)
                    with telemetry.attach(meta.get("trace")), \
                            telemetry.span("shard.op",
                                           op=meta.get("op", "?"),
                                           shard=self.state.shard_id), \
                            deadline_scope(bdl):
                        rmeta, rarrays = self.handle(meta, arrays)
                    send_frame(self.sock, ST_OK, rmeta, rarrays)
                except BrokenPipeError:
                    return
                except Exception as exc:  # typed errors cross the wire
                    try:
                        send_frame(self.sock, ST_ERROR,
                                   {"kind": type(exc).__name__,
                                    "message": str(exc)})
                    except OSError:
                        return
        finally:
            for view in self.views.values():
                try:
                    view.release()
                except Exception:
                    pass
            self.views.clear()
            try:
                self.sock.close()
            except OSError:
                pass


def _worker_main(shard_id: int, directory: str, sock_path: str,
                 db_kw: Dict[str, Any]) -> None:
    """Entry point of a spawned shard worker: open (or create) the shard's
    ServiceDB on its own durable directory, bind the shard socket, and
    serve router connections until told to shut down. Crash-restart safe:
    a respawn on the same directory is the ordinary WAL-replay open."""
    from .service import ServiceDB
    from .disk import GraphDB
    if os.path.exists(os.path.join(directory, GraphDB.MANIFEST)):
        svc = ServiceDB.open(directory)
    else:
        svc = ServiceDB.create(directory, **db_kw)
    state = _WorkerState(shard_id, svc)
    try:
        os.unlink(sock_path)  # a stale socket from a crashed predecessor
    except FileNotFoundError:
        pass
    listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    listener.bind(sock_path)
    listener.listen(16)
    listener.settimeout(0.25)
    failpoint("shard.worker.serve")
    threads: List[threading.Thread] = []
    try:
        while not state.stop.is_set():
            try:
                conn, _ = listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=_Connection(state, conn).serve,
                                 name=f"shard{shard_id}-conn", daemon=True)
            t.start()
            threads.append(t)
    finally:
        listener.close()
        try:
            os.unlink(sock_path)
        except OSError:
            pass
        for t in threads:
            t.join(timeout=2.0)
        svc.close()


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------
class _ShardProc:
    """Router-side handle of one worker: process, socket path, and a
    generation counter — bumped on every respawn so threads' cached
    connections (and the epoch pins living on them) detect the restart."""

    def __init__(self, shard_id: int, directory: str, sock_path: str):
        self.shard_id = shard_id
        self.dir = directory
        self.sock_path = sock_path
        self.proc: Optional[mp.process.BaseProcess] = None
        self.generation = 0
        self.lock = threading.Lock()  # serializes respawns, not requests


class ShardRouter:
    """Front end over N shard worker processes (module docstring). Thread
    safe: each router thread keeps one connection per shard (the worker
    runs one handler thread per connection), so concurrent reader threads
    fan out to genuinely parallel workers without sharing sockets."""

    CONFIG = "SHARDS.json"
    SPAWN_TIMEOUT_S = 120.0  # worker import (numpy+jax) + recovery replay

    def __init__(self, directory: str, config: ShardConfig,
                 db_kw: Dict[str, Any], start: bool = True,
                 op_timeout_s: float = 60.0,
                 read_retries: int = 2,
                 backoff_base_s: float = 0.01,
                 backoff_cap_s: float = 0.25,
                 hedge: bool = True,
                 hedge_quantile: float = 0.95,
                 hedge_floor_s: float = 0.002,
                 hedge_cap_s: float = 0.05,
                 hedge_default_s: float = 0.010,
                 hedge_min_samples: int = 64,
                 breaker_failures: int = 8,
                 breaker_open_s: float = 1.0,
                 breaker_slow_floor_s: float = 0.25,
                 breaker_slow_mult: float = 16.0,
                 rpc_pool_size: Optional[int] = None):
        self.dir = os.path.abspath(directory)
        self.config = config
        self.intervals = config.intervals
        self.db_kw = dict(db_kw)
        self._ctx = mp.get_context("spawn")
        self._tls = threading.local()
        self._closed = False
        self.restarts = 0
        # -- request-lifecycle configuration (ISSUE 10) --
        self.op_timeout_s = float(op_timeout_s)   # no-deadline socket cap
        self.read_retries = int(read_retries)     # extra attempts for reads
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.hedge = bool(hedge)
        self.hedge_quantile = float(hedge_quantile)
        self.hedge_floor_s = float(hedge_floor_s)
        self.hedge_cap_s = float(hedge_cap_s)
        self.hedge_default_s = float(hedge_default_s)
        self.hedge_min_samples = int(hedge_min_samples)
        self.breaker_slow_floor_s = float(breaker_slow_floor_s)
        self.breaker_slow_mult = float(breaker_slow_mult)
        self.rpc_pool_size = rpc_pool_size
        self.breakers = [CircuitBreaker(breaker_failures, breaker_open_s)
                        for _ in range(config.n_shards)]
        self._retry_rng = random.Random()
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pool_lock = threading.Lock()
        self._hedge_cache = (-1e9, float(hedge_default_s))
        self._slow_cache = (-1e9, None)
        # every live socket the router ever opened, across ALL threads —
        # close() drains this so threads that exited with cached
        # connections cannot leak fds (ISSUE 10 satellite)
        self._socks: set = set()
        self._socks_lock = threading.Lock()
        self.shards = [
            _ShardProc(i, os.path.join(self.dir, f"shard_{i:02d}"),
                       os.path.join(self.dir, f"shard_{i:02d}.sock"))
            for i in range(config.n_shards)
        ]
        # a router abandoned without close() must not leave worker
        # processes behind at interpreter exit; close() unregisters
        atexit.register(self.close)
        if start:
            for sp in self.shards:
                self._spawn(sp)
            for sp in self.shards:
                self._wait_ready(sp)

    # -- lifecycle -------------------------------------------------------------
    @classmethod
    def create(cls, directory: str, max_id: int, n_shards: int,
               router_kw: Optional[Dict[str, Any]] = None,
               **db_kw) -> "ShardRouter":
        """Create a sharded store: N empty per-shard ServiceDBs under
        `directory`, all sharing one internal id space. `db_kw` forwards
        to `ServiceDB.create` in every worker (identical config per shard
        — routing and bitwise comparability depend on it); `router_kw`
        forwards to `ShardRouter.__init__` (timeouts, hedging, breaker
        tuning — router policy, never persisted)."""
        n_partitions = int(db_kw.get("n_partitions", 8))
        if n_partitions % n_shards:
            raise ValueError(
                f"n_partitions ({n_partitions}) must be a multiple of "
                f"n_shards ({n_shards}) for balanced interval ownership")
        db_kw.setdefault("n_partitions", n_partitions)
        db_kw["max_id"] = int(max_id)
        # workers on a 1-core box each default to multiple maintenance
        # threads; one per worker process keeps N shards from oversubscribing
        db_kw.setdefault("maintenance_workers", 1)
        os.makedirs(directory, exist_ok=True)
        iv = IntervalMap.for_capacity(max_id, n_partitions)
        config = ShardConfig(n_shards=n_shards, n_partitions=iv.n_partitions,
                             interval_len=iv.interval_len, max_id=int(max_id))
        doc = {"n_shards": n_shards, "n_partitions": iv.n_partitions,
               "interval_len": iv.interval_len, "max_id": int(max_id),
               "db_kw": {k: v for k, v in db_kw.items()
                         if isinstance(v, (int, float, str, bool,
                                           type(None)))}}
        with open(os.path.join(directory, cls.CONFIG), "w") as f:
            json.dump(doc, f, indent=1)
        return cls(directory, config, db_kw, **(router_kw or {}))

    @classmethod
    def open(cls, directory: str,
             **router_kw) -> "ShardRouter":
        with open(os.path.join(directory, cls.CONFIG)) as f:
            doc = json.load(f)
        config = ShardConfig(n_shards=doc["n_shards"],
                             n_partitions=doc["n_partitions"],
                             interval_len=doc["interval_len"],
                             max_id=doc["max_id"])
        return cls(directory, config, doc.get("db_kw", {}), **router_kw)

    def close(self) -> None:
        """Shut the cluster down and release EVERY router-held resource.
        Idempotent (close-twice is a no-op), atexit-registered (an
        abandoned router cannot leave worker processes behind), and safe
        to call while other threads are mid-request — their blocked recvs
        are unblocked by the socket close and surface as typed
        `ShardUnavailable("router closed")`, never a hang."""
        if self._closed:
            return
        self._closed = True
        atexit.unregister(self.close)
        # 1. polite shutdown, on a fresh connection per shard (best
        #    effort; a cached one may be generation-stale or mid-frame)
        for sp in self.shards:
            try:
                conn = self._connect(sp, force=True)
                conn.settimeout(5.0)
                send_frame(conn, ST_REQUEST, {"op": "shutdown"})
                recv_frame(conn)
                self._close_sock(conn)
            except (GraphDBError, OSError, ConnectionError):
                pass
        # 2. stop feeding the hedge pool (pending hedges are cancelled;
        #    in-flight ones fail typed once their sockets close below)
        with self._pool_lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=False, cancel_futures=True)
        # 3. reap worker processes — no zombies survive close()
        for sp in self.shards:
            if sp.proc is not None:
                sp.proc.join(timeout=30.0)
                if sp.proc.is_alive():
                    sp.proc.terminate()
                    sp.proc.join(timeout=5.0)
                sp.proc = None
        # 4. close every socket the router ever opened, including ones
        #    cached in OTHER threads' connection maps (fd-leak guard)
        with self._socks_lock:
            socks, self._socks = list(self._socks), set()
        for s in socks:
            try:
                s.close()
            except OSError:
                pass
        # 5. remove leftover socket files from terminated workers (a
        #    clean worker exit unlinks its own)
        for sp in self.shards:
            try:
                os.unlink(sp.sock_path)
            except OSError:
                pass

    def __enter__(self) -> "ShardRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- supervision -----------------------------------------------------------
    def _spawn(self, sp: _ShardProc) -> None:
        sp.proc = self._ctx.Process(
            target=_worker_main,
            args=(sp.shard_id, sp.dir, sp.sock_path, self.db_kw),
            name=f"graphdb-shard-{sp.shard_id}", daemon=True)
        sp.proc.start()

    def _wait_ready(self, sp: _ShardProc,
                    deadline: Optional[Deadline] = None) -> None:
        """Poll a spawning worker until it answers a ping. Bounded by
        SPAWN_TIMEOUT_S — and, when the caller carries a `Deadline`, by
        its REMAINING budget: a read retried across a respawn must raise
        `DeadlineExceeded` when the budget runs out mid-recovery, not
        block for the full spawn timeout (ISSUE 10 satellite)."""
        give_up = time.monotonic() + self.SPAWN_TIMEOUT_S
        while True:
            if sp.proc is not None and not sp.proc.is_alive():
                raise ShardUnavailable(
                    sp.shard_id,
                    f"worker died during startup "
                    f"(exit code {sp.proc.exitcode})")
            try:
                conn = self._connect(sp)
                conn.settimeout(self.SPAWN_TIMEOUT_S)
                send_frame(conn, ST_REQUEST, {"op": "ping"})
                status, meta, _ = recv_frame(conn)
                if status == ST_OK:
                    conn.settimeout(None)
                    self._cache_conn(sp, conn)
                    return
            except (OSError, ConnectionError):
                pass
            if deadline is not None and deadline.expired():
                _M_DEADLINE.inc(label="rpc")
                raise DeadlineExceeded(
                    f"shard {sp.shard_id} respawn wait",
                    -deadline.remaining())
            if time.monotonic() > give_up:
                raise ShardUnavailable(sp.shard_id, "worker never came up")
            time.sleep(0.05)

    def restart_shard(self, shard_id: int,
                      deadline: Optional[Deadline] = None) -> None:
        """Respawn a dead worker on its durable directory (WAL-replay
        recovery) and bump the generation so every thread's cached
        connection — and the epoch pins living on them — is invalidated.
        With a `Deadline`, every wait (the respawn lock, the ready poll)
        is bounded by the remaining budget and expiry surfaces typed."""
        sp = self.shards[shard_id]
        if self._closed:
            raise ShardUnavailable(shard_id, "router closed")
        if deadline is None:
            sp.lock.acquire()
        elif not sp.lock.acquire(timeout=max(0.0, deadline.remaining())):
            _M_DEADLINE.inc(label="rpc")
            raise DeadlineExceeded(f"shard {shard_id} respawn lock wait",
                                   -deadline.remaining())
        try:
            if sp.proc is not None and sp.proc.is_alive():
                # alive: the failure was a broken connection, not a dead
                # worker — a fresh connect (new generation) is enough
                try:
                    conn = self._connect(sp)
                    self._close_sock(conn)
                    sp.generation += 1
                    return
                except (OSError, ConnectionError):
                    sp.proc.terminate()
                    sp.proc.join(timeout=10.0)
            self.restarts += 1
            _M_RESTARTS.inc()
            sp.generation += 1
            self._spawn(sp)
            self._wait_ready(sp, deadline)
        finally:
            sp.lock.release()

    def health(self) -> List[Dict[str, Any]]:
        """Ping every shard; a dead shard reports {"alive": False} instead
        of raising (supervisors poll this). Pings are breaker PROBES: they
        bypass an open breaker — a recovered worker's successful health
        ping is exactly the evidence that closes its breaker again."""
        out = []
        for sp in self.shards:
            try:
                meta, _ = self._call(sp.shard_id, "ping", {}, retry=False,
                                     probe=True)
                meta["alive"] = True
            except (GraphDBError, OSError, ConnectionError) as exc:
                meta = {"shard": sp.shard_id, "alive": False,
                        "error": str(exc)}
            out.append(meta)
        return out

    def arm_failpoint(self, shard_id: int, site: str,
                      action: Optional[str] = None, after: int = 0,
                      count: Optional[int] = 1, prob: Optional[float] = None,
                      seed: Optional[int] = None, clear: bool = False
                      ) -> None:
        """Arm (or clear) a failpoint inside ONE shard worker over the
        wire — the chaos harness's per-shard fault channel (the env var
        channel is inherited by every spawned worker and cannot single
        out a shard). A probe call: it bypasses the breaker so faults can
        be cleared even while the breaker they caused is open."""
        kw: Dict[str, Any] = {"site": site, "clear": bool(clear)}
        if not clear:
            kw.update(action=action, after=int(after), count=count,
                      prob=prob, seed=seed)
        self._call(shard_id, "failpoint", kw, retry=False, probe=True)

    # -- per-thread connections ------------------------------------------------
    def _connect(self, sp: _ShardProc, force: bool = False) -> socket.socket:
        conn = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            conn.connect(sp.sock_path)
        except OSError:
            conn.close()
            raise
        with self._socks_lock:
            self._socks.add(conn)
        if self._closed and not force:
            # raced with close(): its registry drain may already have run
            self._close_sock(conn)
            raise ShardUnavailable(sp.shard_id, "router closed")
        return conn

    def _close_sock(self, conn: socket.socket) -> None:
        with self._socks_lock:
            self._socks.discard(conn)
        try:
            conn.close()
        except OSError:
            pass

    def _cache_conn(self, sp: _ShardProc, conn: socket.socket) -> None:
        cache = getattr(self._tls, "conns", None)
        if cache is None:
            cache = self._tls.conns = {}
        old = cache.get(sp.shard_id)
        if old is not None:
            self._close_sock(old[0])
        cache[sp.shard_id] = (conn, sp.generation)

    def _conn(self, sp: _ShardProc) -> socket.socket:
        cache = getattr(self._tls, "conns", None)
        if cache is not None:
            entry = cache.get(sp.shard_id)
            if entry is not None and entry[1] == sp.generation:
                return entry[0]
        conn = self._connect(sp)
        self._cache_conn(sp, conn)
        return conn

    def _drop_conn(self, sp: _ShardProc) -> None:
        cache = getattr(self._tls, "conns", None)
        if cache is not None:
            entry = cache.pop(sp.shard_id, None)
            if entry is not None:
                self._close_sock(entry[0])

    # -- breaker + hedging plumbing --------------------------------------------
    def _breaker_failure(self, shard_id: int) -> None:
        if self.breakers[shard_id].record_failure():
            _M_BREAKER_TRIPS.inc(label=str(shard_id))
        self._breaker_gauge()

    def _breaker_gauge(self) -> None:
        _M_BREAKER_OPEN.set(sum(1 for b in self.breakers
                                if b.state != CircuitBreaker.CLOSED))

    def _slow_threshold(self) -> Optional[float]:
        """The latency above which a SUCCESSFUL call still counts as a
        breaker failure — fed back from the `shard.rpc.seconds` histogram
        (a multiple of its p99, floored so ordinary jitter never trips),
        None until enough samples exist. Cached briefly: quantile() merges
        every thread cell and must not run per call."""
        now = time.monotonic()
        if now - self._slow_cache[0] > 0.25:
            p = _M_RPC_S.quantile(0.99, min_count=self.hedge_min_samples)
            self._slow_cache = (
                now, None if p is None else
                max(self.breaker_slow_floor_s, self.breaker_slow_mult * p))
        return self._slow_cache[1]

    def _hedge_delay(self) -> float:
        """How long a primary sub-request may stay unanswered before a
        hedge is issued: the observed `shard.rpc.seconds` quantile
        (default p95), floored (hedging under normal jitter doubles load
        for nothing) and capped (the whole point is beating a 50ms stall),
        with a fixed default until the histogram has enough samples."""
        now = time.monotonic()
        if now - self._hedge_cache[0] > 0.25:
            p = _M_RPC_S.quantile(self.hedge_quantile,
                                  min_count=self.hedge_min_samples)
            d = self.hedge_default_s if p is None else p
            self._hedge_cache = (
                now, min(self.hedge_cap_s, max(self.hedge_floor_s, d)))
        return self._hedge_cache[1]

    def _rpc_pool(self) -> ThreadPoolExecutor:
        with self._pool_lock:
            if self._pool is None:
                n = self.rpc_pool_size or max(8, 4 * len(self.shards))
                self._pool = ThreadPoolExecutor(
                    max_workers=n, thread_name_prefix="graphdb-rpc")
            return self._pool

    def _remote_error(self, shard_id: int, meta: Dict[str, Any]):
        """Map a worker-side ST_ERROR frame back to a LOCAL typed error
        where the lifecycle depends on the type crossing the wire; every
        other kind stays a ShardRemoteError carrying the kind string."""
        kind = meta.get("kind", "Error")
        message = meta.get("message", "")
        if kind == "DeadlineExceeded":
            _M_DEADLINE.inc(label="rpc")
            return DeadlineExceeded(f"shard {shard_id}: {message}")
        if kind in ("OverloadError", "ShardOverloadError"):
            return ShardOverloadError(shard_id, "remote", message)
        return ShardRemoteError(shard_id, kind, message)

    def _call(self, shard_id: int, op: str, kw: Dict[str, Any],
              arrays: Optional[Dict[str, np.ndarray]] = None,
              retry: bool = True,
              deadline: Optional[Deadline] = None,
              probe: bool = False
              ) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        """One request/response exchange with a shard, under the full
        request lifecycle (module docstring):

          * the deadline (explicit, else the thread's ambient scope) is
            checked before every attempt, rides in frame meta, and caps
            the socket timeout, the retry sleeps, and any respawn wait;
          * reads (`retry=True`) survive worker death — supervised
            respawn, then exponential-backoff-with-jitter retries (they
            are idempotent against the recovered state); writes
            (`retry=False`) raise `ShardUnavailable` because the WAL may
            or may not have acknowledged the mutation, and replaying it
            blindly could double-apply;
          * a socket timeout poisons the CONNECTION only (frame alignment
            is unknown) — the worker is presumed alive-but-slow, so no
            respawn and no generation bump (other threads' pins survive);
          * the shard's circuit breaker fails non-probe calls fast with
            `ShardOverloadError` while open, and every attempt's outcome
            (including histogram-classified slow successes) feeds it.
        """
        sp = self.shards[shard_id]
        if self._closed:
            raise ShardUnavailable(shard_id, "router closed")
        dl = deadline if deadline is not None else current_deadline()
        br = self.breakers[shard_id]
        if not probe and not br.allow():
            _M_BREAKER_FF.inc(label=str(shard_id))
            raise ShardOverloadError(shard_id, "breaker_open",
                                     f"fast-failed {op}")
        request: Dict[str, Any] = {"op": op, "kw": kw}
        if telemetry.enabled():
            # the caller's trace context (if any) crosses the process
            # boundary in frame meta — a retried read after a respawn
            # re-sends it, so the restarted worker joins the same trace
            request["trace"] = telemetry.current_context()
        t0 = time.perf_counter()
        _M_RPC_INFLIGHT.inc()
        try:
            with telemetry.span("shard.rpc", shard=shard_id, op=op):
                return self._call_attempts(sp, op, request, arrays, retry,
                                           dl)
        finally:
            _M_RPC_INFLIGHT.inc(-1)
            _M_RPC_REQS.inc(label=op)
            _M_RPC_S.observe(time.perf_counter() - t0, label=str(shard_id))

    def _call_attempts(self, sp: _ShardProc, op: str,
                       request: Dict[str, Any],
                       arrays: Optional[Dict[str, np.ndarray]],
                       retry: bool, dl: Optional[Deadline]
                       ) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        shard_id = sp.shard_id
        attempts = (self.read_retries + 1) if retry else 1
        pacing = backoff_delays(self.backoff_base_s, self.backoff_cap_s,
                                attempts, self._retry_rng)
        last_exc: Optional[BaseException] = None
        for attempt in range(attempts):
            if dl is not None:
                try:
                    dl.check(f"shard {shard_id} {op}")
                except DeadlineExceeded:
                    _M_DEADLINE.inc(label="rpc")
                    raise
                request["deadline"] = dl.to_budget()
            timed_out = False
            a0 = time.perf_counter()
            try:
                conn = self._conn(sp)
                conn.settimeout(dl.timeout(cap=self.op_timeout_s)
                                if dl is not None else self.op_timeout_s)
                send_frame(conn, ST_REQUEST, request, arrays)
                status, meta, rarrays = recv_frame(conn)
            except socket.timeout as exc:
                # frame alignment on this connection is now unknown —
                # poison it; the worker is presumed alive-but-slow
                self._drop_conn(sp)
                timed_out = True
                last_exc = exc
            except ShardProtocolError:
                # a misframed stream is unrecoverable
                self._drop_conn(sp)
                raise
            except (OSError, ConnectionError) as exc:
                self._drop_conn(sp)
                last_exc = exc
            else:
                # the worker ANSWERED: transport is healthy. A response
                # slower than the histogram-derived threshold still feeds
                # the breaker as a failure (gray workers answer, late).
                slow = self._slow_threshold()
                if slow is not None and (time.perf_counter() - a0) > slow:
                    self._breaker_failure(shard_id)
                else:
                    self.breakers[shard_id].record_success()
                    self._breaker_gauge()
                if status == ST_ERROR:
                    raise self._remote_error(shard_id, meta)
                return meta, rarrays
            # -- transport failure or timeout ------------------------------
            self._breaker_failure(shard_id)
            if self._closed:
                raise ShardUnavailable(shard_id, "router closed")
            if dl is not None and dl.expired():
                # the remaining budget decides the TYPE: a retry that no
                # longer fits raises DeadlineExceeded, not ShardUnavailable
                _M_DEADLINE.inc(label="rpc")
                raise DeadlineExceeded(
                    f"shard {shard_id} {op} (after {attempt + 1} "
                    f"attempt{'s' if attempt else ''})",
                    -dl.remaining()) from last_exc
            if not retry or attempt == attempts - 1:
                raise ShardUnavailable(
                    shard_id, f"{op} failed: {last_exc}") from last_exc
            if not timed_out:
                # the worker looks dead — supervised respawn (bounded by
                # the remaining budget when a deadline is carried)
                self.restart_shard(shard_id, deadline=dl)
            _M_RPC_RETRIES.inc(label=op)
            delay = next(pacing)
            if dl is not None:
                delay = min(delay, max(0.0, dl.remaining()))
            if delay > 0.0:
                time.sleep(delay)
        raise ShardUnavailable(shard_id, f"{op}: retry exhausted")

    # -- hedged fan-out --------------------------------------------------------
    def _gather(self, calls: Sequence[Tuple[int, str, Dict[str, Any],
                                            Optional[Dict[str, np.ndarray]]]],
                deadline: Optional[Deadline] = None) -> List[Tuple]:
        """Issue `(shard_id, op, kw, arrays)` calls concurrently with
        hedging, returning results IN CALL ORDER (gather order must be
        deterministic — bitwise comparability of scatter/gather reads
        depends on it, not on completion order). Each primary that has
        not answered within the hedge delay of its submit gets ONE hedge
        on a fresh pool thread (fresh connection); first response wins.
        Live (non-view) reads only — epoch pins are connection-scoped.
        Falls back to plain sequential calls when hedging is off."""
        dl = deadline if deadline is not None else current_deadline()
        if not self.hedge or self._closed:
            return [self._call(s, op, kw, arr, deadline=dl)
                    for s, op, kw, arr in calls]
        pool = self._rpc_pool()
        ctx = telemetry.current_context() if telemetry.enabled() else None

        def attempt(c):
            s, op, kw, arr = c

            def run():
                with telemetry.attach(ctx):
                    return self._call(s, op, kw, arr, retry=True,
                                      deadline=dl)
            return run

        primaries = [pool.submit(attempt(c)) for c in calls]
        t0 = time.monotonic()
        hd = self._hedge_delay()
        out: List[Tuple] = []
        for c, prim in zip(calls, primaries):
            try:
                out.append(prim.result(
                    timeout=max(0.0, t0 + hd - time.monotonic())))
                continue
            except _FutTimeout:
                pass
            _M_HEDGES_SENT.inc(label=str(c[0]))
            hedge = pool.submit(attempt(c))
            out.append(self._first_response(c[0], prim, hedge))
        return out

    @staticmethod
    def _first_response(shard_id: int, primary, hedge):
        """First SUCCESS of {primary, hedge} wins; if both fail, surface
        the primary's error (the hedge raced the same fault)."""
        pending = {primary, hedge}
        while pending:
            done, pending = _fut_wait(pending,
                                      return_when=FIRST_COMPLETED)
            for f in done:
                if f.exception() is None:
                    if f is hedge:
                        _M_HEDGES_WON.inc(label=str(shard_id))
                    return f.result()
        return primary.result()  # re-raises the primary's exception

    # -- write surface ---------------------------------------------------------
    def insert_edges(self, src, dst, etype=None, columns=None) -> None:
        """Scatter a batch to its owner shards (by SOURCE vertex). The
        batch is atomic per shard, not across shards: a concurrent view
        may see one shard's slice before another's (DESIGN.md §12)."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        owner = self.config.shard_of(src)
        for s in np.unique(owner):
            idx = np.flatnonzero(owner == s)
            arrays = {"src": src[idx], "dst": dst[idx]}
            if etype is not None:
                arrays["etype"] = np.asarray(etype)[idx]
            for name, col in (columns or {}).items():
                arrays[f"col:{name}"] = np.asarray(col)[idx]
            self._call(int(s), "insert_edges", {}, arrays, retry=False)

    def insert_edge(self, src: int, dst: int, etype: int = 0, **cols) -> None:
        self.insert_edges([src], [dst], etype=[etype],
                          columns={k: [v] for k, v in cols.items()} or None)

    def delete_edge(self, src: int, dst: int) -> bool:
        s = int(self.config.shard_of([src])[0])
        meta, _ = self._call(s, "delete_edge",
                             {"src": int(src), "dst": int(dst)}, retry=False)
        return bool(meta["found"])

    def checkpoint_all(self) -> None:
        for sp in self.shards:
            self._call(sp.shard_id, "checkpoint", {}, retry=False)

    # -- read surface ----------------------------------------------------------
    def out_neighbors(self, v: int) -> np.ndarray:
        """Single-shard routed read (the owner holds ALL of v's out-edges).
        Hedged: a stalled owner's sub-request is re-issued after the hedge
        delay, first response wins."""
        s = int(self.config.shard_of([v])[0])
        _, arrays = self._gather([(s, "out_neighbors",
                                   {"v": int(v)}, None)])[0]
        return arrays["nb"]

    def in_neighbors(self, v: int) -> np.ndarray:
        """Hedged broadcast + merge (in-edges of v are scattered across
        every shard's stores). Returned SORTED — the canonical cross-shard
        order; per-slab order would depend on each shard's private merge
        history (and, now, on which of primary/hedge answered first)."""
        calls = [(sp.shard_id, "in_neighbors", {"v": int(v)}, None)
                 for sp in self.shards]
        parts = [arrays["nb"] for _, arrays in self._gather(calls)]
        return np.sort(np.concatenate(parts)) if parts else \
            np.empty(0, np.int64)

    @property
    def n_edges(self) -> int:
        return sum(self._call(sp.shard_id, "n_edges", {})[0]["n_edges"]
                   for sp in self.shards)

    def io_stats(self) -> List[Dict[str, Any]]:
        """Per-shard block-read accounting (bench_shard.py's evidence that
        scatter/gather actually partitions the work)."""
        return [self._call(sp.shard_id, "io_stats", {})[0]
                for sp in self.shards]

    # -- observability ---------------------------------------------------------
    def metrics_snapshot(self) -> Dict[str, Any]:
        """Router-process metrics plus every reachable worker's, with an
        exact cross-process aggregate (histograms merge bucket-wise,
        counters sum — telemetry.merge_snapshots). A dead shard is simply
        absent from `shards`; it still counts in `aggregate` only through
        whatever the router itself recorded about it."""
        router = telemetry.snapshot()
        shards = []
        for sp in self.shards:
            try:
                meta, _ = self._call(sp.shard_id, "telemetry", {})
                shards.append(meta["metrics"])
            except (GraphDBError, OSError, ConnectionError):
                pass
        return {"router": router, "shards": shards,
                "aggregate": telemetry.merge_snapshots([router] + shards)}

    def trace_export(self, path: Optional[str] = None) -> Dict[str, Any]:
        """One Chrome-trace-event document stitching the router's spans
        with every worker's. Span timestamps are epoch microseconds, so
        events from different processes align on a common axis; a query's
        trace id ties its router-side span to the worker spans it caused
        (they attached the context from frame meta). Loadable in
        Perfetto / chrome://tracing."""
        events = list(telemetry.trace_events())
        for sp in self.shards:
            try:
                meta, _ = self._call(sp.shard_id, "telemetry",
                                     {"trace": True})
                events.extend(meta.get("trace", []))
            except (GraphDBError, OSError, ConnectionError):
                pass
        return telemetry.trace_export(events=events, path=path)

    def health_summary(self) -> Dict[str, Any]:
        """Cluster-level readiness folded over per-shard health(): ready
        iff every worker is alive and itself ready (WAL tail within
        budget, backlog under backpressure, nothing poisoned, writable)."""
        per = self.health()
        alive = [h for h in per if h.get("alive")]
        return {
            "n_shards": len(per),
            "alive": len(alive),
            "ready": (len(alive) == len(per)
                      and all(h.get("ready", False) for h in alive)),
            "restarts": int(self.restarts),
            "poisoned_count": sum(int(h.get("poisoned_count", 0))
                                  for h in alive),
            "backlog_edges": sum(int(h.get("backlog_edges", 0))
                                 for h in alive),
            "shards": per,
        }

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        ss, dd = [], []
        for sp in self.shards:
            _, arrays = self._call(sp.shard_id, "coo", {})
            ss.append(arrays["src"])
            dd.append(arrays["dst"])
        return np.concatenate(ss), np.concatenate(dd)

    # -- epochs ----------------------------------------------------------------
    def pin_view(self) -> "ShardedView":
        """Pin one published manifest in every shard and return the
        cross-shard view. The pins live on THIS thread's connections, so a
        view must be used and released by the thread that created it (the
        same discipline as ManifestView's pin slot)."""
        return ShardedView(self)

    def storage_engine(self) -> "ShardedEngine":
        """An engine over ad-hoc per-op pins (each scatter/gather op pins
        and releases inside every worker). For a multi-op consistent read,
        use `pin_view().storage_engine()`."""
        return ShardedEngine(self, view=None)


# ---------------------------------------------------------------------------
# sharded view + engine
# ---------------------------------------------------------------------------
class ShardedView:
    """A vector of per-shard epoch pins: shard i answers every read from
    its pinned manifest, so a multi-op query (k-hop, FoF) sees N frozen
    per-shard states. Cross-shard consistency model: per-shard prefix
    (DESIGN.md §12) — quiesced (no concurrent writer), it equals the
    unsharded store exactly."""

    def __init__(self, router: ShardRouter):
        self.router = router
        self.epochs: Dict[int, int] = {}
        self.versions: Dict[int, int] = {}
        self._released = False
        self._thread = threading.get_ident()
        try:
            # pinning is an idempotent read: it may transparently respawn a
            # dead worker (the fresh pin then covers the recovered state)
            for sp in router.shards:
                meta, _ = router._call(sp.shard_id, "pin_epoch", {})
                self.epochs[sp.shard_id] = int(meta["epoch"])
                self.versions[sp.shard_id] = int(meta["version"])
        except GraphDBError:
            self.release()
            raise

    def _epoch_kw(self, shard_id: int) -> Dict[str, Any]:
        if self._released:
            raise ShardEpochLost(shard_id)
        return {"epoch": self.epochs[shard_id]}

    def call(self, shard_id: int, op: str, kw: Dict[str, Any],
             arrays: Optional[Dict[str, np.ndarray]] = None):
        """A read against this view's pin on `shard_id`. Never auto-retries
        across a worker restart: the pin died with the worker and a silent
        re-pin would splice two different epochs into one 'view'."""
        kw = {**kw, **self._epoch_kw(shard_id)}
        try:
            return self.router._call(shard_id, op, kw, arrays, retry=False)
        except ShardRemoteError as exc:
            if "epoch token" in str(exc):
                raise ShardEpochLost(shard_id) from exc
            raise
        except ShardUnavailable as exc:
            raise ShardEpochLost(shard_id) from exc

    # -- store duck type (as_engine dispatches through this) ------------------
    @property
    def intervals(self) -> IntervalMap:
        return self.router.intervals

    @property
    def n_edges(self) -> int:
        return sum(self.call(sp.shard_id, "n_edges", {})[0]["n_edges"]
                   for sp in self.router.shards)

    def out_neighbors(self, v: int) -> np.ndarray:
        s = int(self.router.config.shard_of([v])[0])
        return self.call(s, "out_neighbors", {"v": int(v)})[1]["nb"]

    def in_neighbors(self, v: int) -> np.ndarray:
        parts = [self.call(sp.shard_id, "in_neighbors", {"v": int(v)})[1]
                 ["nb"] for sp in self.router.shards]
        return np.sort(np.concatenate(parts))

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        ss, dd = [], []
        for sp in self.router.shards:
            _, arrays = self.call(sp.shard_id, "coo", {})
            ss.append(arrays["src"])
            dd.append(arrays["dst"])
        return np.concatenate(ss), np.concatenate(dd)

    def begin_snapshot_dirs(self) -> List[str]:
        """Export every shard's pinned epoch as an on-disk session dir
        (`ServiceDB.begin_snapshot(view=...)` inside the worker): any
        process may `Snapshot.open` them and read state bitwise-equal to
        this view's pins — the hard-link machinery crossing the shard
        boundary."""
        return [self.call(sp.shard_id, "snapshot", {})[0]["dir"]
                for sp in self.router.shards]

    def storage_engine(self) -> "ShardedEngine":
        return ShardedEngine(self.router, view=self)

    # -- lifecycle -------------------------------------------------------------
    def release(self) -> None:
        if self._released:
            return
        self._released = True
        for shard_id, token in self.epochs.items():
            try:
                self.router._call(shard_id, "release_epoch",
                                  {"epoch": token}, retry=False)
            except (GraphDBError, OSError, ConnectionError):
                pass  # a dead worker already dropped the pin

    close = release

    def __enter__(self) -> "ShardedView":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class ShardedEngine(StorageEngine):
    """StorageEngine whose slab probes happen inside shard workers.

    Scatter/gather: out-direction ops slice the query vertices by owner
    shard and ship only each shard's slice; in-direction ops broadcast the
    whole batch. Results come back as flat (owner, neighbor) pairs with
    owner indices mapped to the caller's positions, so the columnar
    operators in core/multihop.py consume them unchanged. Only the
    "sparse" hop mode is supported (`supported_hop_modes`): stream/kernel
    modes need the whole edge set, which must not cross the wire per hop.
    """

    supported_hop_modes = ("sparse",)

    def __init__(self, router: ShardRouter, view: Optional[ShardedView]):
        super().__init__(view if view is not None else router)
        self.router = router
        self.view = view

    # -- plumbing --------------------------------------------------------------
    @property
    def intervals(self) -> IntervalMap:
        return self.router.intervals

    @property
    def n_internal_vertices(self) -> int:
        return self.router.intervals.max_vertices

    def _slabs(self):
        raise NotImplementedError(
            "sharded engines have no local slabs: reads are scattered to "
            "shard workers (open a per-shard Snapshot for slab access)")

    def cache_token(self):
        return None  # plans are never built router-side (sparse-only)

    def _shard_call(self, shard_id: int, op: str, kw, arrays):
        if self.view is not None:
            return self.view.call(shard_id, op, kw, arrays)
        return self.router._call(shard_id, op, kw, arrays)

    def _scatter(self, vs: np.ndarray, direction: str, op: str,
                 kw: Dict[str, Any]):
        """Yield (global index array, response arrays) per shard:
        out-direction scatters owner slices, in-direction broadcasts.
        Live (view-less) reads fan out through the router's hedged gather
        — sub-requests run concurrently and a stalled shard's is re-issued
        after the hedge delay; pinned-view reads stay sequential on the
        calling thread (epoch pins are connection-scoped, and a hedge on
        another connection would answer from a different epoch). Either
        way results are yielded in deterministic shard order, so gather
        output is independent of completion order (bitwise gates)."""
        cfg = self.router.config
        if direction == "out":
            owner = cfg.shard_of(vs)
            shards = [int(s) for s in np.unique(owner)]
            idxs = [np.flatnonzero(owner == s) for s in shards]
            payloads = [{"vs": vs[i]} for i in idxs]
        else:
            shards = [sp.shard_id for sp in self.router.shards]
            idx = np.arange(vs.shape[0], dtype=np.int64)
            idxs = [idx] * len(shards)
            payloads = [{"vs": vs}] * len(shards)
        if self.view is not None:
            for s, i, p in zip(shards, idxs, payloads):
                yield i, self.view.call(s, op, kw, p)[1]
        else:
            calls = [(s, op, kw, p) for s, p in zip(shards, payloads)]
            for i, (_, arrays) in zip(idxs, self.router._gather(calls)):
                yield i, arrays

    # -- the scatter/gather read surface --------------------------------------
    def expand_frontier(self, vs, direction: str = "out", predicate=None,
                        ) -> Tuple[np.ndarray, np.ndarray]:
        vs = np.asarray(vs, dtype=np.int64).ravel()
        if vs.shape[0] == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        kw = {"direction": direction,
              "predicate": (dataclasses.asdict(predicate)
                            if predicate is not None else None)}
        owners, vals = [], []
        for idx, arrays in self._scatter(vs, direction, "expand", kw):
            if arrays["owner"].shape[0]:
                owners.append(idx[arrays["owner"]])
                vals.append(arrays["nb"])
        if not vals:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        return np.concatenate(owners), np.concatenate(vals)

    def _neighbors_batch(self, vs, direction: str):
        from .multihop import _csr_offsets
        vs = np.asarray(vs, dtype=np.int64).ravel()
        owner, nb = self.expand_frontier(vs, direction)
        order = np.argsort(owner, kind="stable")
        return nb[order], _csr_offsets(owner[order], vs.shape[0])

    def _degree_batch(self, vs, direction: str) -> np.ndarray:
        vs = np.asarray(vs, dtype=np.int64).ravel()
        deg = np.zeros(vs.shape[0], np.int64)
        for idx, arrays in self._scatter(vs, direction, "degree_batch",
                                         {"direction": direction}):
            deg[idx] += arrays["deg"]
        return deg

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        g = self.graph
        return g.to_coo()
