"""Shared-nothing interval sharding (ISSUE 8, DESIGN.md §12).

The single-process engine is GIL-bound: epoch views, merges, and query
glue all share one interpreter, so reader threads scale at ~1.26x for 2
readers (BENCH_service). This module splits the vertex-interval space
across N *shard worker processes* — each running its own full `ServiceDB`
(own WAL, own partition store, own maintenance pipeline, own
epoch-published manifests) — fronted by a `ShardRouter` that:

  * routes single-shard ops (insert, out_neighbors, per-source range
    reads) by interval ownership,
  * scatter/gathers batched frontier expansions: `expand_frontier`
    slices the frontier by owner shard, ships each slice over a binary
    length-prefixed IPC protocol (checksummed with the existing wsum32,
    failpoint-instrumented), and fans the flat (owner, neighbor) results
    back into the columnar operator layer (core/multihop.py) unchanged,
  * maintains per-shard manifest epochs: a `ShardedView` pins one
    published manifest in every worker, so a cross-shard read is a vector
    of per-shard snapshot pins (the consistency model in DESIGN.md §12).

Ownership
---------
A vertex's owner shard is a pure function of its id:

    owner(v) = interval_of(to_internal(v)) % n_shards == (v % P) % n_shards

(`P` = n_partitions; the equality holds because the reversible hash puts
`v` into interval `v % P` — paper §7.2). Edges live on the shard owning
their SOURCE: `out_neighbors`/insert/source-range ops touch exactly one
shard, while in-direction ops broadcast to all shards and merge. With
`P % n_shards == 0` (enforced) the hash spreads consecutive original ids
uniformly across shards, so hot id ranges don't pile onto one worker.

Wire protocol
-------------
Frames over an AF_UNIX stream socket (one listener per worker, one
connection per router thread — the connection is the epoch-pin scope):

    header  <IIII  = magic "SHRD", payload length, wsum32(payload), status
    payload <I     = meta length, then meta JSON, then raw ndarray bytes

`meta["arrays"]` lists (name, dtype, shape) for the concatenated array
blobs — numpy buffers cross the boundary as raw bytes, never pickled.
status 0 = request, 1 = ok, 2 = typed error (re-raised router-side).
Failpoint sites: `shard.rpc.send`, `shard.rpc.recv`, `shard.worker.op`,
`shard.worker.serve` — all in the closed CATALOG, all reachable from
tests and the torture harness via `GRAPHDB_FAILPOINTS` (spawned workers
inherit the environment).

Failure / restart
-----------------
Workers are supervised: a dead worker (crash failpoint, OOM-kill, bug) is
respawned by the router *on the same durable directory* — recovery is the
ordinary manifest + WAL-replay open. Reads retry transparently once after
a respawn (they are idempotent against the recovered state); writes never
auto-retry (the WAL may or may not have acknowledged the mutation — the
caller must decide). Epoch pins die with their connection: a `ShardedView`
spanning a restart raises `ShardEpochLost` rather than silently serving a
different epoch.
"""
from __future__ import annotations

import dataclasses
import json
import multiprocessing as mp
import os
import socket
import struct
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import telemetry
from .engine import StorageEngine
from .failpoints import failpoint
from .integrity import GraphDBError, checksum32
from .pal import IntervalMap

__all__ = [
    "ShardConfig",
    "ShardEpochLost",
    "ShardProtocolError",
    "ShardRemoteError",
    "ShardRouter",
    "ShardUnavailable",
    "ShardedEngine",
    "ShardedView",
    "shard_of",
]


# ---------------------------------------------------------------------------
# errors
# ---------------------------------------------------------------------------
class ShardProtocolError(GraphDBError):
    """Bytes on a shard socket disagree with the framing contract (bad
    magic, checksum mismatch, truncated frame). The connection that saw it
    is poisoned and torn down — frames after a framing error cannot be
    trusted to be aligned."""


class ShardUnavailable(GraphDBError):
    """A shard worker could not serve the request and the router did not
    (or must not) retry: writes after a worker death, or a worker that
    stayed dead through a respawn attempt."""

    def __init__(self, shard: int, detail: str):
        super().__init__(f"shard {shard}: {detail}")
        self.shard = shard


class ShardRemoteError(GraphDBError):
    """A typed error raised inside a shard worker, carried back over the
    wire. `kind` is the worker-side exception class name."""

    def __init__(self, shard: int, kind: str, message: str):
        super().__init__(f"shard {shard}: {kind}: {message}")
        self.shard = shard
        self.kind = kind

    def to_payload(self) -> Dict[str, Any]:
        return {"kind": self.kind, "message": str(self)}


class ShardEpochLost(ShardUnavailable):
    """The worker holding a ShardedView's epoch pin restarted (or the pin's
    connection dropped): the pinned manifest is gone and the view cannot
    answer consistently. Callers open a fresh view."""

    def __init__(self, shard: int):
        super().__init__(shard, "pinned epoch lost (worker restarted)")


# ---------------------------------------------------------------------------
# ownership
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardConfig:
    """The sharding geometry every participant derives routing from. All
    shards share ONE internal id space (same IntervalMap), so internal ids,
    packed multihop keys, and engine outputs are identical across shards
    and bitwise-comparable with an unsharded store of the same config."""

    n_shards: int
    n_partitions: int
    interval_len: int
    max_id: int

    @property
    def intervals(self) -> IntervalMap:
        return IntervalMap(n_partitions=self.n_partitions,
                           interval_len=self.interval_len)

    def shard_of(self, vs) -> np.ndarray:
        return shard_of(vs, self.n_partitions, self.n_shards)


def shard_of(vs, n_partitions: int, n_shards: int) -> np.ndarray:
    """Owner shard of each ORIGINAL vertex id — `(v % P) % n_shards`,
    which equals `interval_of(to_internal(v)) % n_shards` for every id the
    store can hold (the reversible hash maps v into interval `v % P`;
    tests/test_shard.py asserts the equivalence)."""
    vs = np.asarray(vs, dtype=np.int64)
    return (vs % np.int64(n_partitions)) % np.int64(n_shards)


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------
_MAGIC = 0x53485244  # "SHRD"
_HEADER = struct.Struct("<IIII")  # magic, payload_len, wsum32, status
ST_REQUEST, ST_OK, ST_ERROR = 0, 1, 2
_MAX_FRAME = 1 << 31

_M_RPC_REQS = telemetry.counter("shard.rpc.requests")
_M_RPC_S = telemetry.histogram("shard.rpc.seconds")
_M_RPC_TX = telemetry.counter("shard.rpc.bytes_sent")
_M_RPC_RX = telemetry.counter("shard.rpc.bytes_recv")
_M_RPC_INFLIGHT = telemetry.counter("shard.rpc.inflight")
_M_RESTARTS = telemetry.counter("shard.restarts")


def encode_payload(meta: Dict[str, Any],
                   arrays: Optional[Dict[str, np.ndarray]] = None) -> bytes:
    """meta JSON + raw C-contiguous array bytes, self-describing via
    meta["arrays"]. Arrays are never pickled: the receiver re-views the
    exact dtype/shape over the wire bytes."""
    arrays = arrays or {}
    meta = dict(meta)
    specs, blobs = [], []
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        specs.append([name, arr.dtype.str, list(arr.shape)])
        blobs.append(arr.tobytes())
    meta["arrays"] = specs
    mbytes = json.dumps(meta, separators=(",", ":")).encode()
    return b"".join([struct.pack("<I", len(mbytes)), mbytes] + blobs)


def decode_payload(buf: bytes) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
    (mlen,) = struct.unpack_from("<I", buf, 0)
    meta = json.loads(buf[4:4 + mlen].decode())
    arrays: Dict[str, np.ndarray] = {}
    off = 4 + mlen
    for name, dtype, shape in meta.pop("arrays", []):
        dt = np.dtype(dtype)
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        end = off + n * dt.itemsize
        arrays[name] = np.frombuffer(buf[off:end], dtype=dt).reshape(shape)
        off = end
    return meta, arrays


def send_frame(sock: socket.socket, status: int, meta: Dict[str, Any],
               arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
    payload = encode_payload(meta, arrays)
    failpoint("shard.rpc.send")
    _M_RPC_TX.inc(len(payload))
    sock.sendall(_HEADER.pack(_MAGIC, len(payload), checksum32(payload),
                              status) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        b = sock.recv(min(n, 1 << 20))
        if not b:
            raise ConnectionError("shard connection closed mid-frame")
        chunks.append(b)
        n -= len(b)
    return b"".join(chunks)


def recv_frame(sock: socket.socket
               ) -> Tuple[int, Dict[str, Any], Dict[str, np.ndarray]]:
    head = _recv_exact(sock, _HEADER.size)
    magic, length, cksum, status = _HEADER.unpack(head)
    failpoint("shard.rpc.recv")
    if magic != _MAGIC or length > _MAX_FRAME:
        raise ShardProtocolError(
            f"bad frame header (magic {magic:#x}, length {length})")
    payload = _recv_exact(sock, length)
    _M_RPC_RX.inc(int(length))
    if checksum32(payload) != cksum:
        raise ShardProtocolError(
            f"frame checksum mismatch over {length} payload bytes")
    meta, arrays = decode_payload(payload)
    return status, meta, arrays


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------
def _predicate_from(d: Optional[Dict[str, Any]]):
    if d is None:
        return None
    from .multihop import EdgePredicate
    return EdgePredicate(**d)


class _WorkerState:
    """Per-process state of one shard worker: the shard's ServiceDB plus
    the accept loop's stop flag."""

    def __init__(self, shard_id: int, svc):
        self.shard_id = shard_id
        self.svc = svc
        self.stop = threading.Event()


class _Connection:
    """One router connection served by one worker thread. The connection
    is the epoch-pin scope: pinned views die (and are released) with it,
    which is what makes 'pin lost after restart' detectable instead of
    silently re-pinning a different epoch."""

    def __init__(self, state: _WorkerState, sock: socket.socket):
        self.state = state
        self.sock = sock
        self.views: Dict[int, Any] = {}
        self._next_view = 0

    # -- op handlers ---------------------------------------------------------
    def _store(self, kw: Dict[str, Any]):
        """The read target: a pinned epoch view when the request names one,
        the live tree otherwise (single-op reads pin their own view)."""
        token = kw.get("epoch")
        if token is None:
            return None
        view = self.views.get(int(token))
        if view is None:
            raise KeyError(f"unknown epoch token {token} (pin lost?)")
        return view

    def handle(self, meta: Dict[str, Any],
               arrays: Dict[str, np.ndarray]
               ) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        op = meta["op"]
        kw = meta.get("kw", {})
        svc = self.state.svc
        if op == "ping":
            return {"shard": self.state.shard_id, **svc.health()}, {}
        if op == "insert_edges":
            cols = {n[4:]: a for n, a in arrays.items()
                    if n.startswith("col:")}
            svc.insert_edges(arrays["src"], arrays["dst"],
                             etype=arrays.get("etype"),
                             columns=cols or None)
            return {"n": int(arrays["src"].shape[0])}, {}
        if op == "delete_edge":
            return {"found": bool(svc.delete_edge(kw["src"], kw["dst"]))}, {}
        if op == "pin_epoch":
            view = svc.read_view()
            token = self._next_view
            self._next_view += 1
            self.views[token] = view
            return {"epoch": token, "version": int(view.version),
                    "n_edges": int(view.n_edges)}, {}
        if op == "release_epoch":
            view = self.views.pop(int(kw["epoch"]), None)
            if view is not None:
                view.release()
            return {"released": view is not None}, {}
        if op == "snapshot":
            view = self._store(kw)
            snap = svc.begin_snapshot(view=view)
            snap.close()  # the worker keeps no mapping; the dir is the API
            return {"dir": snap.dir}, {}
        if op == "checkpoint":
            svc.checkpoint()
            return {"ok": True}, {}
        if op == "io_stats":
            return dict(svc.db.io.snapshot()), {}
        if op == "telemetry":
            # worker-side observability surface: this process's metric
            # snapshot (exact-mergeable router-side) and, on request, its
            # buffered Chrome trace events — both JSON, both ride in meta
            doc: Dict[str, Any] = {"metrics": telemetry.snapshot()}
            if kw.get("trace"):
                doc["trace"] = telemetry.trace_events(
                    clear=bool(kw.get("clear")))
            return doc, {}

        # -- reads: answered from the pinned epoch (or a private pin) -------
        view = self._store(kw)
        owns_pin = view is None
        if owns_pin:
            view = svc.read_view()
        try:
            eng = view.storage_engine()
            if op == "out_neighbors":
                return {}, {"nb": view.out_neighbors(int(kw["v"]))}
            if op == "in_neighbors":
                return {}, {"nb": view.in_neighbors(int(kw["v"]))}
            if op == "expand":
                owner, nb = eng.expand_frontier(
                    arrays["vs"], kw.get("direction", "out"),
                    _predicate_from(kw.get("predicate")))
                return {}, {"owner": owner, "nb": nb}
            if op == "degree_batch":
                deg = eng._degree_batch(arrays["vs"],
                                        kw.get("direction", "out"))
                return {}, {"deg": deg}
            if op == "coo":
                s, d = view.to_coo()
                return {}, {"src": np.asarray(s, np.int64),
                            "dst": np.asarray(d, np.int64)}
            if op == "n_edges":
                return {"n_edges": int(view.n_edges)}, {}
        finally:
            if owns_pin:
                view.release()
        raise ValueError(f"unknown shard op {op!r}")

    def serve(self) -> None:
        try:
            while not self.state.stop.is_set():
                try:
                    status, meta, arrays = recv_frame(self.sock)
                except (ConnectionError, OSError):
                    return
                if status != ST_REQUEST:
                    raise ShardProtocolError(
                        f"worker received non-request status {status}")
                if meta.get("op") == "shutdown":
                    send_frame(self.sock, ST_OK, {"ok": True})
                    self.state.stop.set()
                    return
                try:
                    failpoint("shard.worker.op")
                    # the router's trace context rides in meta["trace"];
                    # attaching it here is what stitches worker spans into
                    # the router-side trace (same trace id across processes)
                    with telemetry.attach(meta.get("trace")), \
                            telemetry.span("shard.op",
                                           op=meta.get("op", "?"),
                                           shard=self.state.shard_id):
                        rmeta, rarrays = self.handle(meta, arrays)
                    send_frame(self.sock, ST_OK, rmeta, rarrays)
                except BrokenPipeError:
                    return
                except Exception as exc:  # typed errors cross the wire
                    try:
                        send_frame(self.sock, ST_ERROR,
                                   {"kind": type(exc).__name__,
                                    "message": str(exc)})
                    except OSError:
                        return
        finally:
            for view in self.views.values():
                try:
                    view.release()
                except Exception:
                    pass
            self.views.clear()
            try:
                self.sock.close()
            except OSError:
                pass


def _worker_main(shard_id: int, directory: str, sock_path: str,
                 db_kw: Dict[str, Any]) -> None:
    """Entry point of a spawned shard worker: open (or create) the shard's
    ServiceDB on its own durable directory, bind the shard socket, and
    serve router connections until told to shut down. Crash-restart safe:
    a respawn on the same directory is the ordinary WAL-replay open."""
    from .service import ServiceDB
    from .disk import GraphDB
    if os.path.exists(os.path.join(directory, GraphDB.MANIFEST)):
        svc = ServiceDB.open(directory)
    else:
        svc = ServiceDB.create(directory, **db_kw)
    state = _WorkerState(shard_id, svc)
    try:
        os.unlink(sock_path)  # a stale socket from a crashed predecessor
    except FileNotFoundError:
        pass
    listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    listener.bind(sock_path)
    listener.listen(16)
    listener.settimeout(0.25)
    failpoint("shard.worker.serve")
    threads: List[threading.Thread] = []
    try:
        while not state.stop.is_set():
            try:
                conn, _ = listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=_Connection(state, conn).serve,
                                 name=f"shard{shard_id}-conn", daemon=True)
            t.start()
            threads.append(t)
    finally:
        listener.close()
        try:
            os.unlink(sock_path)
        except OSError:
            pass
        for t in threads:
            t.join(timeout=2.0)
        svc.close()


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------
class _ShardProc:
    """Router-side handle of one worker: process, socket path, and a
    generation counter — bumped on every respawn so threads' cached
    connections (and the epoch pins living on them) detect the restart."""

    def __init__(self, shard_id: int, directory: str, sock_path: str):
        self.shard_id = shard_id
        self.dir = directory
        self.sock_path = sock_path
        self.proc: Optional[mp.process.BaseProcess] = None
        self.generation = 0
        self.lock = threading.Lock()  # serializes respawns, not requests


class ShardRouter:
    """Front end over N shard worker processes (module docstring). Thread
    safe: each router thread keeps one connection per shard (the worker
    runs one handler thread per connection), so concurrent reader threads
    fan out to genuinely parallel workers without sharing sockets."""

    CONFIG = "SHARDS.json"
    SPAWN_TIMEOUT_S = 120.0  # worker import (numpy+jax) + recovery replay

    def __init__(self, directory: str, config: ShardConfig,
                 db_kw: Dict[str, Any], start: bool = True):
        self.dir = os.path.abspath(directory)
        self.config = config
        self.intervals = config.intervals
        self.db_kw = dict(db_kw)
        self._ctx = mp.get_context("spawn")
        self._tls = threading.local()
        self._closed = False
        self.restarts = 0
        self.shards = [
            _ShardProc(i, os.path.join(self.dir, f"shard_{i:02d}"),
                       os.path.join(self.dir, f"shard_{i:02d}.sock"))
            for i in range(config.n_shards)
        ]
        if start:
            for sp in self.shards:
                self._spawn(sp)
            for sp in self.shards:
                self._wait_ready(sp)

    # -- lifecycle -------------------------------------------------------------
    @classmethod
    def create(cls, directory: str, max_id: int, n_shards: int,
               **db_kw) -> "ShardRouter":
        """Create a sharded store: N empty per-shard ServiceDBs under
        `directory`, all sharing one internal id space. `db_kw` forwards
        to `ServiceDB.create` in every worker (identical config per shard
        — routing and bitwise comparability depend on it)."""
        n_partitions = int(db_kw.get("n_partitions", 8))
        if n_partitions % n_shards:
            raise ValueError(
                f"n_partitions ({n_partitions}) must be a multiple of "
                f"n_shards ({n_shards}) for balanced interval ownership")
        db_kw.setdefault("n_partitions", n_partitions)
        db_kw["max_id"] = int(max_id)
        # workers on a 1-core box each default to multiple maintenance
        # threads; one per worker process keeps N shards from oversubscribing
        db_kw.setdefault("maintenance_workers", 1)
        os.makedirs(directory, exist_ok=True)
        iv = IntervalMap.for_capacity(max_id, n_partitions)
        config = ShardConfig(n_shards=n_shards, n_partitions=iv.n_partitions,
                             interval_len=iv.interval_len, max_id=int(max_id))
        doc = {"n_shards": n_shards, "n_partitions": iv.n_partitions,
               "interval_len": iv.interval_len, "max_id": int(max_id),
               "db_kw": {k: v for k, v in db_kw.items()
                         if isinstance(v, (int, float, str, bool,
                                           type(None)))}}
        with open(os.path.join(directory, cls.CONFIG), "w") as f:
            json.dump(doc, f, indent=1)
        return cls(directory, config, db_kw)

    @classmethod
    def open(cls, directory: str) -> "ShardRouter":
        with open(os.path.join(directory, cls.CONFIG)) as f:
            doc = json.load(f)
        config = ShardConfig(n_shards=doc["n_shards"],
                             n_partitions=doc["n_partitions"],
                             interval_len=doc["interval_len"],
                             max_id=doc["max_id"])
        return cls(directory, config, doc.get("db_kw", {}))

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for sp in self.shards:
            try:
                conn = self._conn(sp)
                send_frame(conn, ST_REQUEST, {"op": "shutdown"})
                recv_frame(conn)
            except (GraphDBError, OSError, ConnectionError):
                pass
        for sp in self.shards:
            if sp.proc is not None:
                sp.proc.join(timeout=30.0)
                if sp.proc.is_alive():
                    sp.proc.terminate()
                    sp.proc.join(timeout=5.0)
                sp.proc = None

    def __enter__(self) -> "ShardRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- supervision -----------------------------------------------------------
    def _spawn(self, sp: _ShardProc) -> None:
        sp.proc = self._ctx.Process(
            target=_worker_main,
            args=(sp.shard_id, sp.dir, sp.sock_path, self.db_kw),
            name=f"graphdb-shard-{sp.shard_id}", daemon=True)
        sp.proc.start()

    def _wait_ready(self, sp: _ShardProc) -> None:
        deadline = time.monotonic() + self.SPAWN_TIMEOUT_S
        while True:
            if sp.proc is not None and not sp.proc.is_alive():
                raise ShardUnavailable(
                    sp.shard_id,
                    f"worker died during startup "
                    f"(exit code {sp.proc.exitcode})")
            try:
                conn = self._connect(sp)
                send_frame(conn, ST_REQUEST, {"op": "ping"})
                status, meta, _ = recv_frame(conn)
                if status == ST_OK:
                    self._cache_conn(sp, conn)
                    return
            except (OSError, ConnectionError):
                pass
            if time.monotonic() > deadline:
                raise ShardUnavailable(sp.shard_id, "worker never came up")
            time.sleep(0.05)

    def restart_shard(self, shard_id: int) -> None:
        """Respawn a dead worker on its durable directory (WAL-replay
        recovery) and bump the generation so every thread's cached
        connection — and the epoch pins living on them — is invalidated."""
        sp = self.shards[shard_id]
        with sp.lock:
            if sp.proc is not None and sp.proc.is_alive():
                # alive: the failure was a broken connection, not a dead
                # worker — a fresh connect (new generation) is enough
                try:
                    conn = self._connect(sp)
                    conn.close()
                    sp.generation += 1
                    return
                except (OSError, ConnectionError):
                    sp.proc.terminate()
                    sp.proc.join(timeout=10.0)
            self.restarts += 1
            _M_RESTARTS.inc()
            sp.generation += 1
            self._spawn(sp)
            self._wait_ready(sp)

    def health(self) -> List[Dict[str, Any]]:
        """Ping every shard; a dead shard reports {"alive": False} instead
        of raising (supervisors poll this)."""
        out = []
        for sp in self.shards:
            try:
                meta, _ = self._call(sp.shard_id, "ping", {}, retry=False)
                meta["alive"] = True
            except (GraphDBError, OSError, ConnectionError) as exc:
                meta = {"shard": sp.shard_id, "alive": False,
                        "error": str(exc)}
            out.append(meta)
        return out

    # -- per-thread connections ------------------------------------------------
    def _connect(self, sp: _ShardProc) -> socket.socket:
        conn = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        conn.connect(sp.sock_path)
        return conn

    def _cache_conn(self, sp: _ShardProc, conn: socket.socket) -> None:
        cache = getattr(self._tls, "conns", None)
        if cache is None:
            cache = self._tls.conns = {}
        old = cache.get(sp.shard_id)
        if old is not None:
            try:
                old[0].close()
            except OSError:
                pass
        cache[sp.shard_id] = (conn, sp.generation)

    def _conn(self, sp: _ShardProc) -> socket.socket:
        cache = getattr(self._tls, "conns", None)
        if cache is not None:
            entry = cache.get(sp.shard_id)
            if entry is not None and entry[1] == sp.generation:
                return entry[0]
        conn = self._connect(sp)
        self._cache_conn(sp, conn)
        return conn

    def _drop_conn(self, sp: _ShardProc) -> None:
        cache = getattr(self._tls, "conns", None)
        if cache is not None:
            entry = cache.pop(sp.shard_id, None)
            if entry is not None:
                try:
                    entry[0].close()
                except OSError:
                    pass

    def _call(self, shard_id: int, op: str, kw: Dict[str, Any],
              arrays: Optional[Dict[str, np.ndarray]] = None,
              retry: bool = True
              ) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        """One request/response exchange with a shard. On transport failure:
        reads (`retry=True`) respawn the worker and retry ONCE — they are
        idempotent against the recovered state; writes (`retry=False`) raise
        `ShardUnavailable` because the WAL may or may not have acknowledged
        the mutation, and replaying it blindly could double-apply."""
        sp = self.shards[shard_id]
        request = {"op": op, "kw": kw}
        if telemetry.enabled():
            # the caller's trace context (if any) crosses the process
            # boundary in frame meta — a retried read after a respawn
            # re-sends it, so the restarted worker joins the same trace
            request["trace"] = telemetry.current_context()
        t0 = time.perf_counter()
        _M_RPC_INFLIGHT.inc()
        try:
            with telemetry.span("shard.rpc", shard=shard_id, op=op):
                for attempt in (0, 1):
                    try:
                        conn = self._conn(sp)
                        send_frame(conn, ST_REQUEST, request, arrays)
                        status, meta, rarrays = recv_frame(conn)
                    except (OSError, ConnectionError) as exc:
                        self._drop_conn(sp)
                        if not retry or attempt:
                            raise ShardUnavailable(
                                shard_id, f"{op} failed: {exc}") from exc
                        self.restart_shard(shard_id)
                        continue
                    except ShardProtocolError:
                        # a misframed stream is unrecoverable
                        self._drop_conn(sp)
                        raise
                    if status == ST_ERROR:
                        raise ShardRemoteError(shard_id,
                                               meta.get("kind", "Error"),
                                               meta.get("message", ""))
                    return meta, rarrays
                raise ShardUnavailable(shard_id, f"{op}: retry exhausted")
        finally:
            _M_RPC_INFLIGHT.inc(-1)
            _M_RPC_REQS.inc(label=op)
            _M_RPC_S.observe(time.perf_counter() - t0, label=str(shard_id))

    # -- write surface ---------------------------------------------------------
    def insert_edges(self, src, dst, etype=None, columns=None) -> None:
        """Scatter a batch to its owner shards (by SOURCE vertex). The
        batch is atomic per shard, not across shards: a concurrent view
        may see one shard's slice before another's (DESIGN.md §12)."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        owner = self.config.shard_of(src)
        for s in np.unique(owner):
            idx = np.flatnonzero(owner == s)
            arrays = {"src": src[idx], "dst": dst[idx]}
            if etype is not None:
                arrays["etype"] = np.asarray(etype)[idx]
            for name, col in (columns or {}).items():
                arrays[f"col:{name}"] = np.asarray(col)[idx]
            self._call(int(s), "insert_edges", {}, arrays, retry=False)

    def insert_edge(self, src: int, dst: int, etype: int = 0, **cols) -> None:
        self.insert_edges([src], [dst], etype=[etype],
                          columns={k: [v] for k, v in cols.items()} or None)

    def delete_edge(self, src: int, dst: int) -> bool:
        s = int(self.config.shard_of([src])[0])
        meta, _ = self._call(s, "delete_edge",
                             {"src": int(src), "dst": int(dst)}, retry=False)
        return bool(meta["found"])

    def checkpoint_all(self) -> None:
        for sp in self.shards:
            self._call(sp.shard_id, "checkpoint", {}, retry=False)

    # -- read surface ----------------------------------------------------------
    def out_neighbors(self, v: int) -> np.ndarray:
        """Single-shard routed read (the owner holds ALL of v's out-edges)."""
        s = int(self.config.shard_of([v])[0])
        _, arrays = self._call(s, "out_neighbors", {"v": int(v)})
        return arrays["nb"]

    def in_neighbors(self, v: int) -> np.ndarray:
        """Broadcast + merge (in-edges of v are scattered across every
        shard's stores). Returned SORTED — the canonical cross-shard order;
        per-slab order would depend on each shard's private merge history."""
        parts = [self._call(sp.shard_id, "in_neighbors", {"v": int(v)})[1]
                 ["nb"] for sp in self.shards]
        return np.sort(np.concatenate(parts)) if parts else \
            np.empty(0, np.int64)

    @property
    def n_edges(self) -> int:
        return sum(self._call(sp.shard_id, "n_edges", {})[0]["n_edges"]
                   for sp in self.shards)

    def io_stats(self) -> List[Dict[str, Any]]:
        """Per-shard block-read accounting (bench_shard.py's evidence that
        scatter/gather actually partitions the work)."""
        return [self._call(sp.shard_id, "io_stats", {})[0]
                for sp in self.shards]

    # -- observability ---------------------------------------------------------
    def metrics_snapshot(self) -> Dict[str, Any]:
        """Router-process metrics plus every reachable worker's, with an
        exact cross-process aggregate (histograms merge bucket-wise,
        counters sum — telemetry.merge_snapshots). A dead shard is simply
        absent from `shards`; it still counts in `aggregate` only through
        whatever the router itself recorded about it."""
        router = telemetry.snapshot()
        shards = []
        for sp in self.shards:
            try:
                meta, _ = self._call(sp.shard_id, "telemetry", {})
                shards.append(meta["metrics"])
            except (GraphDBError, OSError, ConnectionError):
                pass
        return {"router": router, "shards": shards,
                "aggregate": telemetry.merge_snapshots([router] + shards)}

    def trace_export(self, path: Optional[str] = None) -> Dict[str, Any]:
        """One Chrome-trace-event document stitching the router's spans
        with every worker's. Span timestamps are epoch microseconds, so
        events from different processes align on a common axis; a query's
        trace id ties its router-side span to the worker spans it caused
        (they attached the context from frame meta). Loadable in
        Perfetto / chrome://tracing."""
        events = list(telemetry.trace_events())
        for sp in self.shards:
            try:
                meta, _ = self._call(sp.shard_id, "telemetry",
                                     {"trace": True})
                events.extend(meta.get("trace", []))
            except (GraphDBError, OSError, ConnectionError):
                pass
        return telemetry.trace_export(events=events, path=path)

    def health_summary(self) -> Dict[str, Any]:
        """Cluster-level readiness folded over per-shard health(): ready
        iff every worker is alive and itself ready (WAL tail within
        budget, backlog under backpressure, nothing poisoned, writable)."""
        per = self.health()
        alive = [h for h in per if h.get("alive")]
        return {
            "n_shards": len(per),
            "alive": len(alive),
            "ready": (len(alive) == len(per)
                      and all(h.get("ready", False) for h in alive)),
            "restarts": int(self.restarts),
            "poisoned_count": sum(int(h.get("poisoned_count", 0))
                                  for h in alive),
            "backlog_edges": sum(int(h.get("backlog_edges", 0))
                                 for h in alive),
            "shards": per,
        }

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        ss, dd = [], []
        for sp in self.shards:
            _, arrays = self._call(sp.shard_id, "coo", {})
            ss.append(arrays["src"])
            dd.append(arrays["dst"])
        return np.concatenate(ss), np.concatenate(dd)

    # -- epochs ----------------------------------------------------------------
    def pin_view(self) -> "ShardedView":
        """Pin one published manifest in every shard and return the
        cross-shard view. The pins live on THIS thread's connections, so a
        view must be used and released by the thread that created it (the
        same discipline as ManifestView's pin slot)."""
        return ShardedView(self)

    def storage_engine(self) -> "ShardedEngine":
        """An engine over ad-hoc per-op pins (each scatter/gather op pins
        and releases inside every worker). For a multi-op consistent read,
        use `pin_view().storage_engine()`."""
        return ShardedEngine(self, view=None)


# ---------------------------------------------------------------------------
# sharded view + engine
# ---------------------------------------------------------------------------
class ShardedView:
    """A vector of per-shard epoch pins: shard i answers every read from
    its pinned manifest, so a multi-op query (k-hop, FoF) sees N frozen
    per-shard states. Cross-shard consistency model: per-shard prefix
    (DESIGN.md §12) — quiesced (no concurrent writer), it equals the
    unsharded store exactly."""

    def __init__(self, router: ShardRouter):
        self.router = router
        self.epochs: Dict[int, int] = {}
        self.versions: Dict[int, int] = {}
        self._released = False
        self._thread = threading.get_ident()
        try:
            # pinning is an idempotent read: it may transparently respawn a
            # dead worker (the fresh pin then covers the recovered state)
            for sp in router.shards:
                meta, _ = router._call(sp.shard_id, "pin_epoch", {})
                self.epochs[sp.shard_id] = int(meta["epoch"])
                self.versions[sp.shard_id] = int(meta["version"])
        except GraphDBError:
            self.release()
            raise

    def _epoch_kw(self, shard_id: int) -> Dict[str, Any]:
        if self._released:
            raise ShardEpochLost(shard_id)
        return {"epoch": self.epochs[shard_id]}

    def call(self, shard_id: int, op: str, kw: Dict[str, Any],
             arrays: Optional[Dict[str, np.ndarray]] = None):
        """A read against this view's pin on `shard_id`. Never auto-retries
        across a worker restart: the pin died with the worker and a silent
        re-pin would splice two different epochs into one 'view'."""
        kw = {**kw, **self._epoch_kw(shard_id)}
        try:
            return self.router._call(shard_id, op, kw, arrays, retry=False)
        except ShardRemoteError as exc:
            if "epoch token" in str(exc):
                raise ShardEpochLost(shard_id) from exc
            raise
        except ShardUnavailable as exc:
            raise ShardEpochLost(shard_id) from exc

    # -- store duck type (as_engine dispatches through this) ------------------
    @property
    def intervals(self) -> IntervalMap:
        return self.router.intervals

    @property
    def n_edges(self) -> int:
        return sum(self.call(sp.shard_id, "n_edges", {})[0]["n_edges"]
                   for sp in self.router.shards)

    def out_neighbors(self, v: int) -> np.ndarray:
        s = int(self.router.config.shard_of([v])[0])
        return self.call(s, "out_neighbors", {"v": int(v)})[1]["nb"]

    def in_neighbors(self, v: int) -> np.ndarray:
        parts = [self.call(sp.shard_id, "in_neighbors", {"v": int(v)})[1]
                 ["nb"] for sp in self.router.shards]
        return np.sort(np.concatenate(parts))

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        ss, dd = [], []
        for sp in self.router.shards:
            _, arrays = self.call(sp.shard_id, "coo", {})
            ss.append(arrays["src"])
            dd.append(arrays["dst"])
        return np.concatenate(ss), np.concatenate(dd)

    def begin_snapshot_dirs(self) -> List[str]:
        """Export every shard's pinned epoch as an on-disk session dir
        (`ServiceDB.begin_snapshot(view=...)` inside the worker): any
        process may `Snapshot.open` them and read state bitwise-equal to
        this view's pins — the hard-link machinery crossing the shard
        boundary."""
        return [self.call(sp.shard_id, "snapshot", {})[0]["dir"]
                for sp in self.router.shards]

    def storage_engine(self) -> "ShardedEngine":
        return ShardedEngine(self.router, view=self)

    # -- lifecycle -------------------------------------------------------------
    def release(self) -> None:
        if self._released:
            return
        self._released = True
        for shard_id, token in self.epochs.items():
            try:
                self.router._call(shard_id, "release_epoch",
                                  {"epoch": token}, retry=False)
            except (GraphDBError, OSError, ConnectionError):
                pass  # a dead worker already dropped the pin

    close = release

    def __enter__(self) -> "ShardedView":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class ShardedEngine(StorageEngine):
    """StorageEngine whose slab probes happen inside shard workers.

    Scatter/gather: out-direction ops slice the query vertices by owner
    shard and ship only each shard's slice; in-direction ops broadcast the
    whole batch. Results come back as flat (owner, neighbor) pairs with
    owner indices mapped to the caller's positions, so the columnar
    operators in core/multihop.py consume them unchanged. Only the
    "sparse" hop mode is supported (`supported_hop_modes`): stream/kernel
    modes need the whole edge set, which must not cross the wire per hop.
    """

    supported_hop_modes = ("sparse",)

    def __init__(self, router: ShardRouter, view: Optional[ShardedView]):
        super().__init__(view if view is not None else router)
        self.router = router
        self.view = view

    # -- plumbing --------------------------------------------------------------
    @property
    def intervals(self) -> IntervalMap:
        return self.router.intervals

    @property
    def n_internal_vertices(self) -> int:
        return self.router.intervals.max_vertices

    def _slabs(self):
        raise NotImplementedError(
            "sharded engines have no local slabs: reads are scattered to "
            "shard workers (open a per-shard Snapshot for slab access)")

    def cache_token(self):
        return None  # plans are never built router-side (sparse-only)

    def _shard_call(self, shard_id: int, op: str, kw, arrays):
        if self.view is not None:
            return self.view.call(shard_id, op, kw, arrays)
        return self.router._call(shard_id, op, kw, arrays)

    def _scatter(self, vs: np.ndarray, direction: str, op: str,
                 kw: Dict[str, Any]):
        """Yield (global index array, response arrays) per shard:
        out-direction scatters owner slices, in-direction broadcasts."""
        cfg = self.router.config
        if direction == "out":
            owner = cfg.shard_of(vs)
            for s in np.unique(owner):
                idx = np.flatnonzero(owner == s)
                yield idx, self._shard_call(int(s), op, kw,
                                            {"vs": vs[idx]})[1]
        else:
            idx = np.arange(vs.shape[0], dtype=np.int64)
            for sp in self.router.shards:
                yield idx, self._shard_call(sp.shard_id, op, kw,
                                            {"vs": vs})[1]

    # -- the scatter/gather read surface --------------------------------------
    def expand_frontier(self, vs, direction: str = "out", predicate=None,
                        ) -> Tuple[np.ndarray, np.ndarray]:
        vs = np.asarray(vs, dtype=np.int64).ravel()
        if vs.shape[0] == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        kw = {"direction": direction,
              "predicate": (dataclasses.asdict(predicate)
                            if predicate is not None else None)}
        owners, vals = [], []
        for idx, arrays in self._scatter(vs, direction, "expand", kw):
            if arrays["owner"].shape[0]:
                owners.append(idx[arrays["owner"]])
                vals.append(arrays["nb"])
        if not vals:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        return np.concatenate(owners), np.concatenate(vals)

    def _neighbors_batch(self, vs, direction: str):
        from .multihop import _csr_offsets
        vs = np.asarray(vs, dtype=np.int64).ravel()
        owner, nb = self.expand_frontier(vs, direction)
        order = np.argsort(owner, kind="stable")
        return nb[order], _csr_offsets(owner[order], vs.shape[0])

    def _degree_batch(self, vs, direction: str) -> np.ndarray:
        vs = np.asarray(vs, dtype=np.int64).ravel()
        deg = np.zeros(vs.shape[0], np.int64)
        for idx, arrays in self._scatter(vs, direction, "degree_batch",
                                         {"direction": direction}):
            deg[idx] += arrays["deg"]
        return deg

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray]:
        g = self.graph
        return g.to_coo()
