"""Unified telemetry: metrics registry + cross-process trace spans (ISSUE 9).

One process-global registry (the Prometheus default-registry model) serves
every engine instance in the process; shard workers are separate processes
whose snapshots the router fetches over RPC and merges exactly
(`merge_snapshots`), so aggregation composes the same way the shards do.

Design constraints, in order:

  1. **The disabled path must be near-free** — `set_enabled(False)` turns
     every `inc`/`observe`/`span` into a single module-global check, the
     same discipline as `failpoints.failpoint`. The observability bench
     section gates the *enabled* path at <3% on insert and contended read.
  2. **No locks on the hot path.** Counters and histograms write to
     per-thread cells (registered once per thread under a lock); the only
     synchronization on `inc`/`observe` is the GIL. `snapshot()` sums the
     cells — aggregation cost is paid by the reader, never the writer.
  3. **Exact histogram merge.** Latency histograms are 64 power-of-two
     nanosecond buckets held as int64 numpy arrays; merging two histograms
     (across threads or across processes) is integer bucket addition, so a
     router-side aggregate is bit-identical to observing every sample in
     one process.
  4. **Closed catalog.** Every metric/span name must be declared in
     `CATALOG` (linted both ways by `scripts/check_metrics.py`, the
     `check_failpoints.py` pattern). Names starting with ``x.`` are the
     caller-owned escape hatch (tests, experiments) and bypass the
     catalog — they never appear in `src/`.

Spans are Chrome-trace complete events (`ph: "X"`): wall-clock `ts` in
microseconds (epoch-based, so router and worker processes align on one
Perfetto timeline), `dur` from a monotonic clock, `pid`/`tid` real OS ids,
and `args` carrying `trace`/`span`/`parent` ids plus caller tags. Context
propagates through a thread-local stack; `current_context()` exports the
ambient (trace, span) pair as a JSON-safe list that rides in shard RPC
frame metadata and into maintenance-pool submissions, and `attach()`
re-establishes it on the far side — one trace stitches a router-side query
through every shard worker it touched.

Legacy counter bags (`ServiceStats`, `LSMStats`, `codec.block_reads`, …)
keep their plain attributes; `register_stats` adds a read-side *collector*
(a weakref + an explicit field→metric-name map) so `snapshot()` folds them
into the same namespace without taxing their write paths at all.
"""
from __future__ import annotations

import json
import os
import threading
import time
import weakref
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

__all__ = [
    "CATALOG", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "REGISTRY", "SpanHandle", "attach", "chrome_trace", "counter",
    "current_context", "enabled", "gauge", "histogram", "merge_snapshots",
    "prometheus_text", "register_stats", "reset", "set_enabled", "snapshot",
    "span", "trace_events", "trace_export",
]

# ---------------------------------------------------------------------------
# catalog
# ---------------------------------------------------------------------------
# name -> (kind, help). Kinds: counter | gauge | histogram | span.
# The registry rejects undeclared names at creation time (typos fail fast,
# exactly like failpoints.fp_set) and scripts/check_metrics.py lints that
# the catalog and the src/ call sites agree in both directions.
CATALOG: Dict[str, Tuple[str, str]] = {
    # --- WAL (core/walog.py) ---
    "wal.appends": ("counter", "records appended to the segmented WAL"),
    "wal.append.bytes": ("counter", "payload bytes appended to the WAL"),
    "wal.append.seconds": ("histogram", "WAL append latency (lock to tail)"),
    "wal.fsyncs": ("counter", "WAL fsync calls"),
    "wal.fsync.seconds": ("histogram", "WAL fsync latency"),
    # --- epoch guard / manifests (core/manifest.py) ---
    "manifest.publishes": ("counter", "LevelManifest publications"),
    "manifest.pins": ("counter", "epoch pins taken by readers"),
    "manifest.retires": ("counter", "retired manifests reclaimed by trim"),
    "manifest.epoch": ("gauge", "version of the currently published manifest"),
    "manifest.pin_lag": ("gauge",
                         "published version minus oldest pinned version"),
    # --- disk tier (core/disk.py, core/engine.py) ---
    "disk.block_reads": ("counter", "modeled block reads (IOStats)"),
    "disk.bytes_read": ("counter", "modeled bytes read (IOStats)"),
    "disk.gathers": ("counter", "gather operations accounted by IOStats"),
    "disk.interval.read_edges": ("counter",
                                 "edges gathered from disk slabs, by "
                                 "interval label lo:hi (read heat)"),
    # --- compressed index accounting (core/codec.py, core/disk.py) ---
    "codec.block_reads": ("counter",
                          "sparse/raw index block probes (RAM or disk)"),
    "codec.chunk_decodes": ("counter", "gamma chunk decodes"),
    "codec.block_decodes": ("counter", "blocked-gamma pointer block decodes"),
    # --- service tier (core/service.py) ---
    "service.flushes": ("counter", "buffer flush merges committed"),
    "service.checkpoints": ("counter", "checkpoints completed"),
    "service.snapshots": ("counter", "snapshot sessions exported"),
    "service.backpressure_waits": ("counter", "writer backpressure stalls"),
    "service.feedback_checkpoints": ("counter",
                                     "checkpoints forced by reader feedback"),
    "service.max_concurrent_flushes": ("counter",
                                       "high-water concurrent flush merges"),
    "service.job_retries": ("counter", "maintenance job retries"),
    "service.poisoned_jobs": ("counter", "maintenance jobs poisoned"),
    "service.read_only_entries": ("counter", "entries into read-only mode"),
    "service.read_only_exits": ("counter", "exits from read-only mode"),
    "service.scrubs": ("counter", "scrub passes completed"),
    "service.tail_cache.hits": ("counter", "decoded-WAL-tail cache hits"),
    "service.tail_cache.misses": ("counter", "decoded-WAL-tail cache misses"),
    "service.wal_tail_bytes": ("gauge", "WAL bytes past the last checkpoint"),
    "service.backlog_edges": ("gauge", "buffered + in-flight edges"),
    "service.job.seconds": ("histogram",
                            "maintenance job latency, by job label"),
    "service.job": ("span", "one maintenance job (flush/checkpoint/scrub)"),
    # --- LSM (core/lsm.py) ---
    "lsm.inserts": ("counter", "edges inserted into the LSM"),
    "lsm.buffer_flushes": ("counter", "buffer drains flushed into levels"),
    "lsm.pushdown_merges": ("counter", "level pushdown merges"),
    "lsm.edges_rewritten": ("counter", "edges rewritten during merges"),
    "lsm.splits": ("counter", "partition splits"),
    "lsm.deletes": ("counter", "edge deletions applied"),
    "lsm.purged_tombstones": ("counter", "tombstones purged by merges"),
    # --- multihop (core/multihop.py) ---
    "multihop.hops": ("counter", "frontier expansions, by mode label"),
    "multihop.hop.seconds": ("histogram", "single-hop expansion latency"),
    "multihop.hop": ("span", "one k-hop frontier expansion"),
    "multihop.two_hop": ("span", "one batched FoF (two_hop_counts) call"),
    # --- shard runtime (core/shardrouter.py) ---
    "shard.rpc.requests": ("counter", "router-side RPC calls, by op label"),
    "shard.rpc.seconds": ("histogram",
                          "router-side RPC round-trip latency, by shard"),
    "shard.rpc.bytes_sent": ("counter", "frame payload bytes sent"),
    "shard.rpc.bytes_recv": ("counter", "frame payload bytes received"),
    "shard.rpc.inflight": ("counter",
                           "RPCs currently in flight (inc/dec; the router's "
                           "queue depth)"),
    "shard.restarts": ("counter", "shard worker restarts"),
    "shard.rpc": ("span", "one router-side shard RPC"),
    "shard.op": ("span", "one worker-side op execution"),
    # --- request lifecycle (core/deadline.py wiring, ISSUE 10) ---
    "request.deadline_exceeded": ("counter",
                                  "requests whose budget ran out, by "
                                  "surface label (rpc/worker/frontdesk)"),
    "shard.rpc.retries": ("counter",
                          "idempotent-read retries after a transport "
                          "failure or deadline-derived socket timeout"),
    "shard.hedges.sent": ("counter",
                          "hedge sub-requests issued after the "
                          "histogram-derived hedge delay"),
    "shard.hedges.won": ("counter",
                         "hedges whose response beat the primary's"),
    "shard.breaker.trips": ("counter",
                            "circuit-breaker open transitions, by shard"),
    "shard.breaker.fastfail": ("counter",
                               "calls failed fast by an open breaker, "
                               "by shard"),
    "shard.breaker.open": ("gauge",
                           "shards whose circuit breaker is currently "
                           "open or probing"),
    # --- serving front end (core/frontdesk.py) ---
    "frontdesk.requests": ("counter", "admitted requests, by op label"),
    "frontdesk.sheds": ("counter",
                        "requests shed by admission control, by reason "
                        "label (queue_full/queue_delay/backpressure/"
                        "read_only)"),
    "frontdesk.batches": ("counter",
                          "engine dispatches, each coalescing >= 1 "
                          "queued requests, by op label"),
    "frontdesk.batched_ops": ("counter",
                              "requests served through coalesced "
                              "dispatches, by op label"),
    "frontdesk.queue.seconds": ("histogram",
                                "request queue delay, enqueue to batch "
                                "start"),
    "frontdesk.depth": ("gauge", "requests queued at the front desk now"),
}

_SPAN_NAMES = frozenset(n for n, (k, _) in CATALOG.items() if k == "span")

ESCAPE_PREFIX = "x."  # caller-owned namespace: bypasses the catalog

_ENABLED = True


def set_enabled(on: bool) -> None:
    """Global kill-switch: the telemetry-off arm of the overhead bench."""
    global _ENABLED
    _ENABLED = bool(on)


def enabled() -> bool:
    return _ENABLED


def _check(name: str, kind: str) -> None:
    if name.startswith(ESCAPE_PREFIX):
        return
    ent = CATALOG.get(name)
    if ent is None:
        raise KeyError(f"telemetry name not in CATALOG: {name!r}")
    if ent[0] != kind:
        raise KeyError(f"telemetry name {name!r} is a {ent[0]}, not a {kind}")


# ---------------------------------------------------------------------------
# metric primitives — per-thread cells, summed at snapshot time
# ---------------------------------------------------------------------------
class _CCell:
    __slots__ = ("v", "labels")

    def __init__(self):
        self.v = 0
        self.labels: Dict[str, int] = {}


class Counter:
    """Monotonic (or up/down, for queue depths) counter.

    `inc()` touches only a thread-local cell — no lock, no allocation after
    the first call per thread. `inc(n, label)` keeps a per-label tally in
    the same cell (read heat by interval, hops by mode, RPCs by op)."""

    __slots__ = ("name", "_tls", "_cells", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._tls = threading.local()
        self._cells: List[_CCell] = []
        self._lock = threading.Lock()

    def _cell(self) -> _CCell:
        c = getattr(self._tls, "c", None)
        if c is None:
            c = self._tls.c = _CCell()
            with self._lock:
                self._cells.append(c)
        return c

    def inc(self, n: int = 1, label: Optional[str] = None) -> None:
        if not _ENABLED:
            return
        c = self._cell()
        if label is None:
            c.v += n
        else:
            c.labels[label] = c.labels.get(label, 0) + n

    def value(self):
        """Total (int) or, if any label was ever used, {label: int} with
        the unlabeled remainder under ''. Cells of exited threads are kept:
        totals must include their contribution."""
        with self._lock:
            cells = list(self._cells)
        total = 0
        labels: Dict[str, int] = {}
        for c in cells:
            total += c.v
            for k, v in c.labels.items():
                labels[k] = labels.get(k, 0) + v
        if not labels:
            return int(total)
        if total:
            labels[""] = labels.get("", 0) + int(total)
        return {k: int(v) for k, v in labels.items()}

    def _zero(self) -> None:
        with self._lock:
            for c in self._cells:
                c.v = 0
                c.labels.clear()


class Gauge:
    """Last-write-wins scalar. A plain attribute store: CPython makes the
    write atomic, and a gauge's only contract is 'recent'."""

    __slots__ = ("name", "_v")

    def __init__(self, name: str):
        self.name = name
        self._v = 0

    def set(self, v) -> None:
        if not _ENABLED:
            return
        self._v = v

    def value(self):
        return self._v

    def _zero(self) -> None:
        self._v = 0


N_BUCKETS = 64  # bucket b holds samples with ns.bit_length() == b (2^63 cap)


class _HCell:
    __slots__ = ("buckets", "sum")

    def __init__(self):
        self.buckets = np.zeros(N_BUCKETS, np.int64)
        self.sum = 0.0


class Histogram:
    """Power-of-two-bucket latency histogram.

    `observe(seconds)` buckets the nanosecond value by bit length into a
    per-thread int64 numpy array; merging across threads/processes is
    exact integer bucket addition. Optional `label` keeps one array per
    label (per-shard RPC latency) in the same cell."""

    __slots__ = ("name", "_tls", "_cells", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._tls = threading.local()
        self._cells: List[Dict[str, _HCell]] = []
        self._lock = threading.Lock()

    def _cell(self, label: str) -> _HCell:
        d = getattr(self._tls, "d", None)
        if d is None:
            d = self._tls.d = {}
            with self._lock:
                self._cells.append(d)
        h = d.get(label)
        if h is None:
            h = d[label] = _HCell()
        return h

    def observe(self, seconds: float, label: str = "") -> None:
        if not _ENABLED:
            return
        ns = int(seconds * 1e9)
        b = ns.bit_length() if ns > 0 else 0
        if b >= N_BUCKETS:
            b = N_BUCKETS - 1
        h = self._cell(label)
        h.buckets[b] += 1
        h.sum += seconds

    def value(self) -> Dict[str, Dict[str, Any]]:
        """{label: {count, sum, buckets{str(b): n}, p50_us, p99_us}}."""
        with self._lock:
            cells = list(self._cells)
        merged: Dict[str, Tuple[np.ndarray, float]] = {}
        for d in cells:
            for label, h in list(d.items()):
                if label in merged:
                    b, s = merged[label]
                    merged[label] = (b + h.buckets, s + h.sum)
                else:
                    merged[label] = (h.buckets.copy(), h.sum)
        return {label: _hist_dict(b, s) for label, (b, s) in merged.items()}

    def quantile(self, q: float, label: Optional[str] = None,
                 min_count: int = 1) -> Optional[float]:
        """The `q`-quantile in SECONDS (bucket upper bound — conservative),
        merged across threads and, with `label=None`, across labels. None
        until at least `min_count` samples exist. This is what feeds
        hedge-delay and breaker slow-call thresholds back from observed
        latency (ISSUE 10): a control input, not just an export."""
        with self._lock:
            cells = list(self._cells)
        buckets = np.zeros(N_BUCKETS, np.int64)
        for d in cells:
            for lb, h in list(d.items()):
                if label is None or lb == label:
                    buckets += h.buckets
        count = int(buckets.sum())
        if count < max(1, int(min_count)):
            return None
        cum = np.cumsum(buckets)
        b = int(np.searchsorted(cum, q * count))
        return float(1 << min(b, N_BUCKETS - 1)) / 1e9

    def _zero(self) -> None:
        with self._lock:
            for d in self._cells:
                for h in d.values():
                    h.buckets[:] = 0
                    h.sum = 0.0


def _hist_dict(buckets: np.ndarray, total: float) -> Dict[str, Any]:
    count = int(buckets.sum())
    nz = np.flatnonzero(buckets)
    out = {"count": count, "sum": float(total),
           "buckets": {str(int(b)): int(buckets[b]) for b in nz}}
    if count:
        cum = np.cumsum(buckets[nz])
        for q, key in ((0.5, "p50_us"), (0.99, "p99_us")):
            b = int(nz[int(np.searchsorted(cum, q * count))])
            out[key] = (1 << b) / 1000.0  # bucket upper bound, ns -> us
    return out


# ---------------------------------------------------------------------------
# trace spans — thread-local context, Chrome-trace complete events
# ---------------------------------------------------------------------------
_ctx = threading.local()


def _new_id() -> str:
    return os.urandom(8).hex()


def current_context() -> Optional[List[str]]:
    """Ambient [trace_id, span_id] or None — JSON-safe, ships in RPC meta
    and maintenance-pool submissions."""
    stack = getattr(_ctx, "stack", None)
    if not stack:
        return None
    return list(stack[-1])


class SpanHandle:
    __slots__ = ("name", "trace", "span", "parent", "tags")

    def __init__(self, name, trace, span_id, parent, tags):
        self.name = name
        self.trace = trace
        self.span = span_id
        self.parent = parent
        self.tags = tags

    def tag(self, **kw) -> None:
        self.tags.update(kw)


_NULL_SPAN = SpanHandle("", None, None, None, {})
_JSON_SCALARS = (str, int, float, bool, type(None))


def _safe_tags(tags: Dict[str, Any]) -> Dict[str, Any]:
    return {k: (v if isinstance(v, _JSON_SCALARS) else str(v))
            for k, v in tags.items()}


@contextmanager
def attach(ctx: Optional[Iterable]):
    """Re-establish a remote caller's [trace_id, span_id] as the ambient
    context (shard worker serving an RPC, maintenance job running a
    submission). `None` is a no-op, so call sites stay unconditional."""
    if ctx is None or not _ENABLED:
        yield
        return
    trace_id, span_id = ctx[0], ctx[1]
    stack = getattr(_ctx, "stack", None)
    if stack is None:
        stack = _ctx.stack = []
    stack.append((trace_id, span_id))
    try:
        yield
    finally:
        stack.pop()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
class MetricsRegistry:
    def __init__(self, max_events: int = 16384):
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()
        # (weakref to stats object, {attr: metric name}) — read-side
        # collectors for legacy counter bags; dead refs pruned at snapshot
        self._collectors: List[Tuple[weakref.ref, Dict[str, str]]] = []
        self._events: deque = deque(maxlen=max_events)

    # -- metric accessors (create-or-get; catalog-checked) --
    def _get(self, name: str, kind: str, cls):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise KeyError(f"telemetry name {name!r} already registered "
                               f"as {type(m).__name__}")
            return m
        _check(name, kind)
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter", Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge", Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, "histogram", Histogram)

    def register_stats(self, obj, fields: Dict[str, str]) -> None:
        """Fold a legacy stats object into snapshots: `fields` maps its
        attribute names to catalog counter names. Values from live
        instances with the same metric name are SUMMED (many LSMTree /
        Snapshot instances per process is normal)."""
        for attr, name in fields.items():
            _check(name, "counter")
            getattr(obj, attr)  # fail fast on a bad attribute name
        with self._lock:
            self._collectors.append((weakref.ref(obj), dict(fields)))

    def _collect(self) -> Dict[str, int]:
        with self._lock:
            live = [(r, f) for r, f in self._collectors if r() is not None]
            self._collectors = live
            pairs = list(live)
        out: Dict[str, int] = {}
        for ref, fields in pairs:
            obj = ref()
            if obj is None:
                continue
            for attr, name in fields.items():
                try:
                    v = int(getattr(obj, attr))
                except (AttributeError, TypeError, ValueError):
                    continue
                out[name] = out.get(name, 0) + v
        return out

    # -- spans --
    def record_event(self, ev: Dict[str, Any]) -> None:
        self._events.append(ev)  # deque.append is atomic under the GIL

    def trace_events(self, clear: bool = False) -> List[Dict[str, Any]]:
        with self._lock:
            evs = list(self._events)
            if clear:
                self._events.clear()
        return evs

    # -- export --
    def snapshot(self) -> Dict[str, Any]:
        """JSON-safe aggregate of every metric across all threads, plus
        the registered legacy collectors. Safe to call concurrently with
        writers: cells only grow, and reads of stale values are bounded
        by one in-flight increment."""
        with self._lock:
            metrics = dict(self._metrics)
        counters: Dict[str, Any] = {}
        gauges: Dict[str, Any] = {}
        hists: Dict[str, Any] = {}
        for name, m in sorted(metrics.items()):
            if isinstance(m, Counter):
                counters[name] = m.value()
            elif isinstance(m, Gauge):
                gauges[name] = m.value()
            else:
                hists[name] = m.value()
        for name, v in self._collect().items():
            if isinstance(counters.get(name), dict):
                d = counters[name]
                d[""] = d.get("", 0) + v
            else:
                counters[name] = counters.get(name, 0) + v
        return {"pid": os.getpid(), "counters": counters, "gauges": gauges,
                "histograms": hists}

    def prometheus_text(self) -> str:
        snap = self.snapshot()
        lines: List[str] = []

        def pname(name):
            return "graphdb_" + name.replace(".", "_").replace("-", "_")

        for name, v in snap["counters"].items():
            p = pname(name)
            lines.append(f"# TYPE {p} counter")
            if isinstance(v, dict):
                for label, n in sorted(v.items()):
                    lines.append(f'{p}{{label="{label}"}} {n}')
            else:
                lines.append(f"{p} {v}")
        for name, v in snap["gauges"].items():
            p = pname(name)
            lines.append(f"# TYPE {p} gauge")
            lines.append(f"{p} {v}")
        for name, labels in snap["histograms"].items():
            p = pname(name)
            lines.append(f"# TYPE {p} histogram")
            for label, h in sorted(labels.items()):
                sel = f'label="{label}",' if label else ""
                cum = 0
                for b in sorted(h["buckets"], key=int):
                    cum += h["buckets"][b]
                    le = (1 << int(b)) / 1e9
                    lines.append(f'{p}_bucket{{{sel}le="{le:g}"}} {cum}')
                lines.append(f'{p}_bucket{{{sel}le="+Inf"}} {h["count"]}')
                sel2 = f'{{label="{label}"}}' if label else ""
                lines.append(f'{p}_sum{sel2} {h["sum"]:g}')
                lines.append(f'{p}_count{sel2} {h["count"]}')
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Zero every metric IN PLACE (module-level handles stay valid)
        and drop buffered trace events. Test/bench isolation only."""
        with self._lock:
            metrics = list(self._metrics.values())
            self._events.clear()
        for m in metrics:
            m._zero()


REGISTRY = MetricsRegistry()


# ---------------------------------------------------------------------------
# module-level convenience API (what instrumented modules import)
# ---------------------------------------------------------------------------
def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def register_stats(obj, fields: Dict[str, str]) -> None:
    REGISTRY.register_stats(obj, fields)


def snapshot() -> Dict[str, Any]:
    return REGISTRY.snapshot()


def prometheus_text() -> str:
    return REGISTRY.prometheus_text()


def trace_events(clear: bool = False) -> List[Dict[str, Any]]:
    return REGISTRY.trace_events(clear=clear)


def reset() -> None:
    REGISTRY.reset()


@contextmanager
def span(name: str, **tags):
    """Record a Chrome-trace complete event around the body.

    Joins the ambient trace if one exists (same thread via the context
    stack, or a remote one re-established by `attach`); otherwise roots a
    new trace. Yields a `SpanHandle` — `handle.tag(k=v)` adds tags
    mid-span (retry counts, poison state), `handle.trace` is the trace id
    tests assert stitching on."""
    if not _ENABLED:
        yield _NULL_SPAN
        return
    if name not in _SPAN_NAMES and not name.startswith(ESCAPE_PREFIX):
        raise KeyError(f"span name not in CATALOG: {name!r}")
    stack = getattr(_ctx, "stack", None)
    if stack is None:
        stack = _ctx.stack = []
    if stack:
        trace_id, parent = stack[-1]
    else:
        trace_id, parent = _new_id(), None
    span_id = _new_id()
    handle = SpanHandle(name, trace_id, span_id, parent, dict(tags))
    stack.append((trace_id, span_id))
    ts_us = time.time_ns() // 1000
    t0 = time.perf_counter_ns()
    try:
        yield handle
    finally:
        dur_us = (time.perf_counter_ns() - t0) // 1000
        stack.pop()
        args = _safe_tags(handle.tags)
        args["trace"] = trace_id
        args["span"] = span_id
        if parent is not None:
            args["parent"] = parent
        REGISTRY.record_event({
            "name": name, "cat": "graphdb", "ph": "X", "ts": ts_us,
            "dur": dur_us, "pid": os.getpid(),
            "tid": threading.get_native_id(), "args": args})


def chrome_trace(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Wrap events in the Chrome trace-event JSON envelope Perfetto and
    chrome://tracing load directly."""
    return {"traceEvents": list(events), "displayTimeUnit": "ms"}


def trace_export(events: Optional[Iterable[Dict[str, Any]]] = None,
                 path: Optional[str] = None) -> Dict[str, Any]:
    """This process's buffered spans as a Chrome trace document (pass
    `events` to wrap an externally merged list, e.g. router + workers).
    Optionally also write it to `path`."""
    doc = chrome_trace(REGISTRY.trace_events() if events is None else events)
    if path is not None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f)
    return doc


# ---------------------------------------------------------------------------
# exact cross-process aggregation
# ---------------------------------------------------------------------------
def _merge_counter(a, b):
    if isinstance(a, dict) or isinstance(b, dict):
        out = dict(a) if isinstance(a, dict) else ({"": a} if a else {})
        for k, v in (b.items() if isinstance(b, dict) else [("", b)]):
            out[k] = out.get(k, 0) + v
        return out
    return a + b


def _merge_hist(a: Dict[str, Any], b: Dict[str, Any]) -> Dict[str, Any]:
    buckets = dict(a["buckets"])
    for k, v in b["buckets"].items():
        buckets[k] = buckets.get(k, 0) + v
    arr = np.zeros(N_BUCKETS, np.int64)
    for k, v in buckets.items():
        arr[int(k)] = v
    return _hist_dict(arr, a["sum"] + b["sum"])


def merge_snapshots(snaps: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Exact aggregate of per-process snapshots: counters sum, histograms
    merge bucket-wise (identical to having observed every sample in one
    registry), gauges keep the last snapshot's value."""
    out: Dict[str, Any] = {"pids": [], "counters": {}, "gauges": {},
                           "histograms": {}}
    for s in snaps:
        if not s:
            continue
        if "pid" in s:
            out["pids"].append(s["pid"])
        for name, v in s.get("counters", {}).items():
            cur = out["counters"].get(name)
            out["counters"][name] = v if cur is None else _merge_counter(cur, v)
        out["gauges"].update(s.get("gauges", {}))
        for name, labels in s.get("histograms", {}).items():
            dst = out["histograms"].setdefault(name, {})
            for label, h in labels.items():
                dst[label] = h if label not in dst else _merge_hist(dst[label], h)
    return out
