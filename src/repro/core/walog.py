"""Segmented durable write-ahead log (ISSUE 4, ROADMAP "WAL compaction",
"Mutation durability", "Columns in the WAL").

The single append-only `wal.log` of PR 3 had three gaps: it only grew (the
manifest recorded covered offsets but bytes were never reclaimed), it only
recorded `(src, dst, etype)` (buffered attribute columns and all deletes /
column writes were lost between checkpoints), and a reader could not pin a
stable prefix while a writer kept appending. `SegmentedWAL` closes all
three:

  * **Segments.** The log is a directory of `seg_<base>.wal` files, rotated
    once a segment's record bytes exceed `segment_bytes`. Offsets handed to
    callers are *global logical* offsets over the concatenated record
    stream (headers excluded), so they survive rotation; `<base>` in the
    file name is the segment's first record's global offset. Segments
    wholly below a checkpoint's covered offset are deleted by
    `compact(covered)` — on-disk WAL bytes shrink instead of growing
    forever. Rotation fsyncs the sealed segment.
  * **Typed records with a declared column schema.** Each segment header
    carries the schema (sorted column name → dtype); insert records store
    the columns positionally after the edge triples, so crash recovery
    restores attribute values buffered since the last checkpoint. Deletes
    (tombstones) and in-place column writes are record types of their own —
    *every* mutation is durable between checkpoints, not just inserts.
  * **Pinnable prefixes.** Segment files are append-only and never
    rewritten, so hard-linking them into a session directory pins the
    bytes; `replay(offset, end)` caps at `end`, giving a snapshot a
    bitwise-stable view of the record stream even while the writer keeps
    appending to the shared inode (core/service.py).

Record stream grammar (little-endian):

    INSERT  = 0x01  u32 n  n×(i64 src, i64 dst, i8 etype)
                    then, per schema column in schema order, n×itemsize
    DELETE  = 0x02  i64 src, i64 dst                (internal IDs)
    COLUMN  = 0x03  u16 schema_index, i64 src, i64 dst, itemsize value

Segments whose header declares `"crc": 1` (every segment written since
ISSUE 7) append a u32 CRC-32 over the record bytes after EVERY record;
older segments parse exactly as before. The CRC turns silent bit rot into
a typed failure (`WALCorruptionError`) instead of garbage edges:

  * a bad record in a SEALED segment — or followed by further valid bytes
    — is corruption of acknowledged history and raises, carrying the
    global offset of the durable prefix before it;
  * a bad or length-torn record at the very tail of the LAST segment is a
    torn write (crash mid-append, possibly spanning a filesystem-section
    boundary): it was never acknowledged-and-synced, so replay drops it
    and opening for append truncates back to the last whole record.

Replay also verifies the segment CHAIN: each segment must begin exactly
where its predecessor ended (`WALGapError` otherwise), so a missing or
header-torn middle segment — e.g. a snapshot dir that lost a hard link —
fails typed instead of silently skipping acknowledged mutations.
"""
from __future__ import annotations

import json
import os
import struct
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from . import telemetry
from .failpoints import failpoint
from .integrity import (
    CKSUM_ALGO,
    CRC_ALGO,
    WALCorruptionError,
    WALGapError,
    crc32,
    fsync_dir,
    record_checksum,
)

__all__ = ["SegmentedWAL", "REC_INSERT", "REC_DELETE", "REC_COLUMN"]

_MAGIC = b"GCDBWAL1"
REC_INSERT = 1
REC_DELETE = 2
REC_COLUMN = 3

_M_APPENDS = telemetry.counter("wal.appends")
_M_APPEND_BYTES = telemetry.counter("wal.append.bytes")
_M_APPEND_S = telemetry.histogram("wal.append.seconds")
_M_FSYNCS = telemetry.counter("wal.fsyncs")
_M_FSYNC_S = telemetry.histogram("wal.fsync.seconds")

_EDGE_DT = np.dtype([("s", "<i8"), ("d", "<i8"), ("t", "i1")])
_INSERT_HDR = struct.Struct("<BI")
_DELETE_REC = struct.Struct("<Bqq")
_COLUMN_HDR = struct.Struct("<BHqq")


class SegmentedWAL:
    """Rotating segmented WAL over a directory. One writer; any number of
    readers via `replay` (including read-only instances over a directory of
    hard-linked segments). All appends are thread-safe behind one lock."""

    def __init__(self, directory: str,
                 column_dtypes: Optional[Dict[str, Any]] = None,
                 sync: str = "commit", segment_bytes: int = 4 << 20,
                 readonly: bool = False, crc: bool = True):
        assert sync in ("always", "commit", "close"), sync
        self.dir = directory
        self.sync = sync
        self.segment_bytes = int(segment_bytes)
        self.readonly = readonly
        # new segments carry per-record checksums; the int is the
        # record-checksum VERSION (2 = record_checksum: crc32 small /
        # wsum32 bulk; 1 = plain crc32, still replayable)
        self.crc = 2 if crc else 0
        self._lock = threading.Lock()
        self._f = None
        os.makedirs(directory, exist_ok=True)
        segs = self._scan()
        # quarantine a torn-HEADER tail segment (crash during rotation,
        # before the header's fsync): it was created but never held an
        # acknowledged record — appends only start after the header is on
        # disk — so dropping it loses nothing. Only the newest segment can
        # be in this state; an unreadable earlier segment is corruption.
        # A writer deletes the file; a readonly session just ignores it.
        while segs and _try_header(segs[-1][1]) is None:
            base, path = segs.pop()
            if not readonly:
                os.remove(path)
        if segs:
            # schema is immutable per WAL: read it back from any header
            hdr = _read_header(segs[-1][1])
            self.schema: List[Tuple[str, np.dtype]] = [
                (name, np.dtype(s)) for name, s in hdr["schema"]]
            if column_dtypes is not None:
                declared = sorted((k, np.dtype(v).str)
                                  for k, v in column_dtypes.items())
                assert declared == [(n, dt.str) for n, dt in self.schema], (
                    "WAL column schema mismatch: "
                    f"{declared} vs {hdr['schema']}")
        else:
            self.schema = sorted(
                (k, np.dtype(v)) for k, v in (column_dtypes or {}).items())
        self._names = [n for n, _ in self.schema]
        if readonly:
            self._base = self._tail = self._end_of(segs)
            return
        if segs:
            base, path = segs[-1]
            self._base = base
            # truncate a torn tail so appends resume at a record boundary;
            # in a CRC segment a fully-written record whose bytes were only
            # partially persisted (torn page across a section boundary)
            # also fails here and is truncated with it
            self._seg_crc = int(_read_header(path).get("crc", 0))
            body_len = os.path.getsize(path) - _header_len(path)
            good = _parse_len(_read_body(path), self.schema, self._seg_crc)
            if good < body_len:
                with open(path, "r+b") as f:
                    f.truncate(_header_len(path) + good)
            self._tail = base + good
            self._seg_bytes = good
            self._f = open(path, "ab", buffering=1 << 20)
        else:
            self._base = self._tail = 0
            self._open_segment(0)

    # -- segment bookkeeping ---------------------------------------------------
    def _scan(self) -> List[Tuple[int, str]]:
        segs = []
        for fname in os.listdir(self.dir):
            if fname.startswith("seg_") and fname.endswith(".wal"):
                segs.append((int(fname[4:-4]),
                             os.path.join(self.dir, fname)))
        return sorted(segs)

    def _end_of(self, segs) -> int:
        for base, path in reversed(segs):
            if _try_header(path) is not None:
                return base + os.path.getsize(path) - _header_len(path)
        return 0

    def _open_segment(self, base: int) -> None:
        path = os.path.join(self.dir, f"seg_{base:020d}.wal")
        doc = {"base": base,
               "schema": [[n, dt.str] for n, dt in self.schema]}
        if self.crc:
            doc["crc"] = self.crc
            doc["crc_algo"] = (CRC_ALGO if self.crc == 1 else
                               f"{CRC_ALGO}<1KiB/{CKSUM_ALGO}")
        header = json.dumps(doc, sort_keys=True).encode()
        failpoint("wal.segment.create")
        with open(path, "wb") as f:
            f.write(_MAGIC)
            f.write(struct.pack("<I", len(header)))
            f.write(header)
            f.flush()
            os.fsync(f.fileno())
        # the segment's directory entry must be durable before any record
        # in it is acknowledged (rename-without-dir-fsync loses the file)
        fsync_dir(self.dir)
        self._f = open(path, "ab", buffering=1 << 20)
        self._base = base
        self._seg_bytes = 0
        self._seg_crc = self.crc

    def _rotate(self) -> None:
        failpoint("wal.segment.rotate")
        self._f.flush()
        os.fsync(self._f.fileno())  # seal: a sealed segment is fully durable
        self._f.close()
        self._open_segment(self._tail)

    # -- appends ---------------------------------------------------------------
    def _append(self, payload: bytes) -> None:
        assert not self.readonly, "read-only WAL"
        t0 = time.perf_counter()
        with self._lock:
            if self._seg_crc:
                ck = (crc32 if self._seg_crc == 1
                      else record_checksum)(payload)
                payload += struct.pack("<I", ck)
            failpoint("wal.append.write")
            self._f.write(payload)
            self._tail += len(payload)
            self._seg_bytes += len(payload)
            if self.sync == "commit":
                self._f.flush()
            elif self.sync == "always":
                self._f.flush()
                failpoint("wal.append.fsync")
                ts = time.perf_counter()
                os.fsync(self._f.fileno())
                _M_FSYNCS.inc()
                _M_FSYNC_S.observe(time.perf_counter() - ts)
            if self._seg_bytes >= self.segment_bytes:
                self._rotate()
        _M_APPENDS.inc()
        _M_APPEND_BYTES.inc(len(payload))
        _M_APPEND_S.observe(time.perf_counter() - t0)

    def append_inserts(self, isrc, idst, etype,
                       columns: Optional[Dict[str, Any]] = None) -> None:
        """ONE group-commit record for a whole insert batch, columns
        included (internal IDs)."""
        isrc = np.ascontiguousarray(isrc, np.int64).ravel()
        n = int(isrc.shape[0])
        if n == 0:
            return
        rec = np.empty(n, _EDGE_DT)
        rec["s"] = isrc
        rec["d"] = np.asarray(idst, np.int64).ravel()
        rec["t"] = np.asarray(etype, np.int8).ravel()
        parts = [_INSERT_HDR.pack(REC_INSERT, n), rec.tobytes()]
        columns = columns or {}
        for name, dt in self.schema:
            v = columns.get(name)
            if v is None:
                arr = np.zeros(n, dt)
            else:
                arr = np.broadcast_to(np.asarray(v, dt), (n,))
            parts.append(np.ascontiguousarray(arr).tobytes())
        self._append(b"".join(parts))

    def append_delete(self, isrc: int, idst: int) -> None:
        self._append(_DELETE_REC.pack(REC_DELETE, int(isrc), int(idst)))

    def append_column(self, name: str, isrc: int, idst: int, value) -> None:
        ci = self._names.index(name)
        dt = self.schema[ci][1]
        self._append(_COLUMN_HDR.pack(REC_COLUMN, ci, int(isrc), int(idst))
                     + np.asarray(value, dt).tobytes())

    # -- durability ------------------------------------------------------------
    def flush(self, fsync: bool = False) -> None:
        if self.readonly or self._f is None:
            return
        with self._lock:
            self._f.flush()
            if fsync:
                failpoint("wal.append.fsync")
                ts = time.perf_counter()
                os.fsync(self._f.fileno())
                _M_FSYNCS.inc()
                _M_FSYNC_S.observe(time.perf_counter() - ts)

    def tail_offset(self) -> int:
        with self._lock:
            return self._tail

    def close(self) -> None:
        if self._f is not None:
            self.flush(fsync=True)
            self._f.close()
            self._f = None

    # -- segment lifecycle -----------------------------------------------------
    def segments(self) -> List[Tuple[int, int, str]]:
        """(base_offset, end_offset, path) per readable segment, ascending
        (a torn-header tail segment holds no acked records and is skipped)."""
        out = []
        for base, path in self._scan():
            if _try_header(path) is not None:
                out.append((base, base + os.path.getsize(path)
                            - _header_len(path), path))
        return out

    def compact(self, covered_offset: int) -> int:
        """Delete segments wholly below the covered offset (checkpointed
        state supersedes them). The active segment is never deleted — it is
        rotated first if it too is fully covered, so the next segment
        starts exactly at the covered boundary."""
        if self.readonly:
            return 0  # a pinned session dir never reclaims its links
        removed = 0
        with self._lock:
            if (self._f is not None
                    and self._tail <= covered_offset and self._seg_bytes > 0):
                self._rotate()
        for base, end, path in self.segments():
            if end <= covered_offset and base != self._base:
                failpoint("wal.compact.unlink")
                os.remove(path)
                removed += 1
        return removed

    def on_disk_bytes(self) -> int:
        return sum(os.path.getsize(p) for _, _, p in self.segments())

    def segment_identity(self, offset: int, end: int) -> Tuple:
        """Hashable identity of the record window [offset, end): the
        (st_dev, st_ino, base) of every segment overlapping it, plus the
        window itself. Hard-linked copies of the segments (snapshot session
        dirs pinning the same offset) share inodes and therefore the same
        identity — the key of the shared replayed-tail cache
        (core/service.py, ISSUE 5 satellite)."""
        parts = []
        for base, seg_end, path in self.segments():
            if seg_end > offset and base < end:
                st = os.stat(path)
                parts.append((st.st_dev, st.st_ino, base))
        return (tuple(parts), int(offset), int(end))

    # -- replay ----------------------------------------------------------------
    def replay(self, offset: int = 0, end: Optional[int] = None,
               strict_head: bool = False) -> Iterator[Tuple]:
        """Decode records whose global offsets lie in [offset, end). Yields
        ("insert", src, dst, etype, columns) | ("delete", s, d) |
        ("column", name, s, d, value), in log order. `offset`/`end` must be
        record boundaries the WAL handed out (tail offsets); a torn
        trailing record is dropped. Failure is TYPED, never silent: a hole
        BETWEEN available segments raises `WALGapError` (acknowledged
        mutations would silently vanish); a CRC-failed record that is not
        the torn tail raises `WALCorruptionError` carrying the offset of
        the durable prefix before it. A hole before the FIRST available
        segment is compaction (only whole leading segments are ever
        deleted) and is skipped — unless `strict_head` is set, for readers
        of a pinned session dir where the first segment must cover
        `offset` and a missing link is loss, not compaction."""
        self.flush()
        segs = [(base, path, _try_header(path)) for base, path in self._scan()]
        # a crash during rotation leaves torn-header files only at the TAIL
        # (possibly several from a crash loop): they hold no acked records
        # and are skipped. An unreadable segment with a readable one after
        # it is a hole in acked history — typed failure below.
        while segs and segs[-1][2] is None:
            segs.pop()
        if strict_head and end is not None and end > offset and not segs:
            # a pinned dir whose [offset, end) window is non-empty must
            # hold at least the segment covering `offset`
            raise WALGapError(self.dir, int(offset), int(end))
        pos: Optional[int] = None  # None until the first readable segment
        for i, (base, path, hdr) in enumerate(segs):
            if end is not None and base >= end:
                break
            if hdr is None:
                raise WALGapError(self.dir,
                                  base if pos is None else pos,
                                  segs[i + 1][0])
            body = _read_body(path)
            seg_end = base + len(body)
            if seg_end <= offset:
                pos = max(pos or 0, seg_end)
                continue
            if pos is None:
                if strict_head and base > offset:
                    raise WALGapError(self.dir, int(offset), base)
            elif base > pos:
                raise WALGapError(self.dir, pos, base)
            lo = max(0, offset - base)
            hi = len(body) if end is None else min(len(body), end - base)
            schema = [(n, np.dtype(s)) for n, s in hdr["schema"]]
            crc = int(hdr.get("crc", 0))
            window = body[lo:hi]
            good = _parse_len(window, schema, crc)
            if good < len(window):
                # bytes past the last whole valid record: a torn tail is
                # droppable, anything else is corruption of acked history
                tail_of_log = (i == len(segs) - 1 and hi == len(body))
                if not tail_of_log:
                    raise WALCorruptionError(
                        path, base + lo + good,
                        "WAL record failed CRC / framing mid-stream")
            yield from _parse(window[:good], schema, crc)
            pos = seg_end


# ---------------------------------------------------------------------------
# Segment parsing (shared by replay, torn-tail recovery)
# ---------------------------------------------------------------------------
def _read_header(path: str) -> Dict[str, Any]:
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != _MAGIC:
            raise ValueError(f"{path}: not a WAL segment")
        (hlen,) = struct.unpack("<I", f.read(4))
        return json.loads(f.read(hlen))


def _try_header(path: str) -> Optional[Dict[str, Any]]:
    """Header, or None for an empty/torn-header segment file."""
    try:
        return _read_header(path)
    except (ValueError, struct.error, json.JSONDecodeError, KeyError):
        return None


def _header_len(path: str) -> int:
    with open(path, "rb") as f:
        f.seek(8)
        (hlen,) = struct.unpack("<I", f.read(4))
    return 12 + hlen


def _read_body(path: str) -> bytes:
    with open(path, "rb") as f:
        data = f.read()
    hlen = struct.unpack("<I", data[8:12])[0]
    return data[12 + hlen:]


def _record_span(buf: bytes, p: int, schema) -> int:
    """Byte length of the record starting at p, or -1 if torn/unknown."""
    kind = buf[p]
    if kind == REC_INSERT:
        if p + _INSERT_HDR.size > len(buf):
            return -1
        (_, n) = _INSERT_HDR.unpack_from(buf, p)
        span = _INSERT_HDR.size + n * _EDGE_DT.itemsize
        for _, dt in schema:
            span += n * dt.itemsize
        return span
    if kind == REC_DELETE:
        return _DELETE_REC.size
    if kind == REC_COLUMN:
        if p + _COLUMN_HDR.size > len(buf):
            return -1
        (_, ci, _, _) = _COLUMN_HDR.unpack_from(buf, p)
        if ci >= len(schema):
            return -1
        return _COLUMN_HDR.size + schema[ci][1].itemsize
    return -1  # unknown kind: treat as torn


def _rec_at(buf: bytes, p: int, schema, crc: int) -> int:
    """Total stream span of the record at p (CRC trailer included), after
    verifying the trailer when the segment carries one. -1 = torn/bad."""
    span = _record_span(buf, p, schema)
    if span < 0:
        return -1
    total = span + 4 if crc else span
    if p + total > len(buf):
        return -1
    if crc:
        (want,) = struct.unpack_from("<I", buf, p + span)
        body = memoryview(buf)[p:p + span]
        got = crc32(body) if crc == 1 else record_checksum(body)
        if got != want:
            return -1
    return total


def _parse_len(buf: bytes, schema, crc: int = 0) -> int:
    """Length of the longest valid whole-record prefix of buf (in a CRC
    segment, "valid" includes the checksum)."""
    p = 0
    while p < len(buf):
        total = _rec_at(buf, p, schema, crc)
        if total < 0:
            break
        p += total
    return p


def _parse(buf: bytes, schema, crc: int = 0) -> Iterator[Tuple]:
    p = 0
    while p < len(buf):
        total = _rec_at(buf, p, schema, crc)
        if total < 0:
            break  # torn trailing record
        kind = buf[p]
        if kind == REC_INSERT:
            (_, n) = _INSERT_HDR.unpack_from(buf, p)
            q = p + _INSERT_HDR.size
            rec = np.frombuffer(buf, _EDGE_DT, count=n, offset=q)
            q += n * _EDGE_DT.itemsize
            cols = {}
            for name, dt in schema:
                cols[name] = np.frombuffer(buf, dt, count=n, offset=q)
                q += n * dt.itemsize
            yield ("insert", rec["s"], rec["d"], rec["t"], cols)
        elif kind == REC_DELETE:
            (_, s, d) = _DELETE_REC.unpack_from(buf, p)
            yield ("delete", s, d)
        else:
            (_, ci, s, d) = _COLUMN_HDR.unpack_from(buf, p)
            name, dt = schema[ci]
            val = np.frombuffer(buf, dt, count=1,
                                offset=p + _COLUMN_HDR.size)[0]
            yield ("column", name, s, d, val)
        p += total
