"""Segmented durable write-ahead log (ISSUE 4, ROADMAP "WAL compaction",
"Mutation durability", "Columns in the WAL").

The single append-only `wal.log` of PR 3 had three gaps: it only grew (the
manifest recorded covered offsets but bytes were never reclaimed), it only
recorded `(src, dst, etype)` (buffered attribute columns and all deletes /
column writes were lost between checkpoints), and a reader could not pin a
stable prefix while a writer kept appending. `SegmentedWAL` closes all
three:

  * **Segments.** The log is a directory of `seg_<base>.wal` files, rotated
    once a segment's record bytes exceed `segment_bytes`. Offsets handed to
    callers are *global logical* offsets over the concatenated record
    stream (headers excluded), so they survive rotation; `<base>` in the
    file name is the segment's first record's global offset. Segments
    wholly below a checkpoint's covered offset are deleted by
    `compact(covered)` — on-disk WAL bytes shrink instead of growing
    forever. Rotation fsyncs the sealed segment.
  * **Typed records with a declared column schema.** Each segment header
    carries the schema (sorted column name → dtype); insert records store
    the columns positionally after the edge triples, so crash recovery
    restores attribute values buffered since the last checkpoint. Deletes
    (tombstones) and in-place column writes are record types of their own —
    *every* mutation is durable between checkpoints, not just inserts.
  * **Pinnable prefixes.** Segment files are append-only and never
    rewritten, so hard-linking them into a session directory pins the
    bytes; `replay(offset, end)` caps at `end`, giving a snapshot a
    bitwise-stable view of the record stream even while the writer keeps
    appending to the shared inode (core/service.py).

Record stream grammar (little-endian):

    INSERT  = 0x01  u32 n  n×(i64 src, i64 dst, i8 etype)
                    then, per schema column in schema order, n×itemsize
    DELETE  = 0x02  i64 src, i64 dst                (internal IDs)
    COLUMN  = 0x03  u16 schema_index, i64 src, i64 dst, itemsize value

A torn trailing record (crash mid-write) is detected by length and dropped;
opening for append truncates the active segment back to the last whole
record so new records never follow garbage.
"""
from __future__ import annotations

import json
import os
import struct
import threading
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

__all__ = ["SegmentedWAL", "REC_INSERT", "REC_DELETE", "REC_COLUMN"]

_MAGIC = b"GCDBWAL1"
REC_INSERT = 1
REC_DELETE = 2
REC_COLUMN = 3

_EDGE_DT = np.dtype([("s", "<i8"), ("d", "<i8"), ("t", "i1")])
_INSERT_HDR = struct.Struct("<BI")
_DELETE_REC = struct.Struct("<Bqq")
_COLUMN_HDR = struct.Struct("<BHqq")


class SegmentedWAL:
    """Rotating segmented WAL over a directory. One writer; any number of
    readers via `replay` (including read-only instances over a directory of
    hard-linked segments). All appends are thread-safe behind one lock."""

    def __init__(self, directory: str,
                 column_dtypes: Optional[Dict[str, Any]] = None,
                 sync: str = "commit", segment_bytes: int = 4 << 20,
                 readonly: bool = False):
        assert sync in ("always", "commit", "close"), sync
        self.dir = directory
        self.sync = sync
        self.segment_bytes = int(segment_bytes)
        self.readonly = readonly
        self._lock = threading.Lock()
        self._f = None
        os.makedirs(directory, exist_ok=True)
        segs = self._scan()
        # quarantine a torn-HEADER tail segment (crash during rotation,
        # before the header's fsync): it was created but never held an
        # acknowledged record — appends only start after the header is on
        # disk — so dropping it loses nothing. Only the newest segment can
        # be in this state; an unreadable earlier segment is corruption.
        # A writer deletes the file; a readonly session just ignores it.
        while segs and _try_header(segs[-1][1]) is None:
            base, path = segs.pop()
            if not readonly:
                os.remove(path)
        if segs:
            # schema is immutable per WAL: read it back from any header
            hdr = _read_header(segs[-1][1])
            self.schema: List[Tuple[str, np.dtype]] = [
                (name, np.dtype(s)) for name, s in hdr["schema"]]
            if column_dtypes is not None:
                declared = sorted((k, np.dtype(v).str)
                                  for k, v in column_dtypes.items())
                assert declared == [(n, dt.str) for n, dt in self.schema], (
                    "WAL column schema mismatch: "
                    f"{declared} vs {hdr['schema']}")
        else:
            self.schema = sorted(
                (k, np.dtype(v)) for k, v in (column_dtypes or {}).items())
        self._names = [n for n, _ in self.schema]
        if readonly:
            self._base = self._tail = self._end_of(segs)
            return
        if segs:
            base, path = segs[-1]
            self._base = base
            # truncate a torn tail so appends resume at a record boundary
            body_len = os.path.getsize(path) - _header_len(path)
            good = _parse_len(_read_body(path), self.schema)
            if good < body_len:
                with open(path, "r+b") as f:
                    f.truncate(_header_len(path) + good)
            self._tail = base + good
            self._seg_bytes = good
            self._f = open(path, "ab", buffering=1 << 20)
        else:
            self._base = self._tail = 0
            self._open_segment(0)

    # -- segment bookkeeping ---------------------------------------------------
    def _scan(self) -> List[Tuple[int, str]]:
        segs = []
        for fname in os.listdir(self.dir):
            if fname.startswith("seg_") and fname.endswith(".wal"):
                segs.append((int(fname[4:-4]),
                             os.path.join(self.dir, fname)))
        return sorted(segs)

    def _end_of(self, segs) -> int:
        for base, path in reversed(segs):
            if _try_header(path) is not None:
                return base + os.path.getsize(path) - _header_len(path)
        return 0

    def _open_segment(self, base: int) -> None:
        path = os.path.join(self.dir, f"seg_{base:020d}.wal")
        header = json.dumps({
            "base": base,
            "schema": [[n, dt.str] for n, dt in self.schema],
        }, sort_keys=True).encode()
        with open(path, "wb") as f:
            f.write(_MAGIC)
            f.write(struct.pack("<I", len(header)))
            f.write(header)
            f.flush()
            os.fsync(f.fileno())
        self._f = open(path, "ab", buffering=1 << 20)
        self._base = base
        self._seg_bytes = 0

    def _rotate(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())  # seal: a sealed segment is fully durable
        self._f.close()
        self._open_segment(self._tail)

    # -- appends ---------------------------------------------------------------
    def _append(self, payload: bytes) -> None:
        assert not self.readonly, "read-only WAL"
        with self._lock:
            self._f.write(payload)
            self._tail += len(payload)
            self._seg_bytes += len(payload)
            if self.sync == "commit":
                self._f.flush()
            elif self.sync == "always":
                self._f.flush()
                os.fsync(self._f.fileno())
            if self._seg_bytes >= self.segment_bytes:
                self._rotate()

    def append_inserts(self, isrc, idst, etype,
                       columns: Optional[Dict[str, Any]] = None) -> None:
        """ONE group-commit record for a whole insert batch, columns
        included (internal IDs)."""
        isrc = np.ascontiguousarray(isrc, np.int64).ravel()
        n = int(isrc.shape[0])
        if n == 0:
            return
        rec = np.empty(n, _EDGE_DT)
        rec["s"] = isrc
        rec["d"] = np.asarray(idst, np.int64).ravel()
        rec["t"] = np.asarray(etype, np.int8).ravel()
        parts = [_INSERT_HDR.pack(REC_INSERT, n), rec.tobytes()]
        columns = columns or {}
        for name, dt in self.schema:
            v = columns.get(name)
            if v is None:
                arr = np.zeros(n, dt)
            else:
                arr = np.broadcast_to(np.asarray(v, dt), (n,))
            parts.append(np.ascontiguousarray(arr).tobytes())
        self._append(b"".join(parts))

    def append_delete(self, isrc: int, idst: int) -> None:
        self._append(_DELETE_REC.pack(REC_DELETE, int(isrc), int(idst)))

    def append_column(self, name: str, isrc: int, idst: int, value) -> None:
        ci = self._names.index(name)
        dt = self.schema[ci][1]
        self._append(_COLUMN_HDR.pack(REC_COLUMN, ci, int(isrc), int(idst))
                     + np.asarray(value, dt).tobytes())

    # -- durability ------------------------------------------------------------
    def flush(self, fsync: bool = False) -> None:
        if self.readonly or self._f is None:
            return
        with self._lock:
            self._f.flush()
            if fsync:
                os.fsync(self._f.fileno())

    def tail_offset(self) -> int:
        with self._lock:
            return self._tail

    def close(self) -> None:
        if self._f is not None:
            self.flush(fsync=True)
            self._f.close()
            self._f = None

    # -- segment lifecycle -----------------------------------------------------
    def segments(self) -> List[Tuple[int, int, str]]:
        """(base_offset, end_offset, path) per readable segment, ascending
        (a torn-header tail segment holds no acked records and is skipped)."""
        out = []
        for base, path in self._scan():
            if _try_header(path) is not None:
                out.append((base, base + os.path.getsize(path)
                            - _header_len(path), path))
        return out

    def compact(self, covered_offset: int) -> int:
        """Delete segments wholly below the covered offset (checkpointed
        state supersedes them). The active segment is never deleted — it is
        rotated first if it too is fully covered, so the next segment
        starts exactly at the covered boundary."""
        if self.readonly:
            return 0  # a pinned session dir never reclaims its links
        removed = 0
        with self._lock:
            if (self._f is not None
                    and self._tail <= covered_offset and self._seg_bytes > 0):
                self._rotate()
        for base, end, path in self.segments():
            if end <= covered_offset and base != self._base:
                os.remove(path)
                removed += 1
        return removed

    def on_disk_bytes(self) -> int:
        return sum(os.path.getsize(p) for _, _, p in self.segments())

    def segment_identity(self, offset: int, end: int) -> Tuple:
        """Hashable identity of the record window [offset, end): the
        (st_dev, st_ino, base) of every segment overlapping it, plus the
        window itself. Hard-linked copies of the segments (snapshot session
        dirs pinning the same offset) share inodes and therefore the same
        identity — the key of the shared replayed-tail cache
        (core/service.py, ISSUE 5 satellite)."""
        parts = []
        for base, seg_end, path in self.segments():
            if seg_end > offset and base < end:
                st = os.stat(path)
                parts.append((st.st_dev, st.st_ino, base))
        return (tuple(parts), int(offset), int(end))

    # -- replay ----------------------------------------------------------------
    def replay(self, offset: int = 0,
               end: Optional[int] = None) -> Iterator[Tuple]:
        """Decode records whose global offsets lie in [offset, end). Yields
        ("insert", src, dst, etype, columns) | ("delete", s, d) |
        ("column", name, s, d, value), in log order. `offset`/`end` must be
        record boundaries the WAL handed out (tail offsets); a torn
        trailing record is dropped."""
        self.flush()
        for base, path in self._scan():
            if end is not None and base >= end:
                break
            hdr = _try_header(path)
            if hdr is None:
                continue  # torn-header tail segment: holds no acked records
            body = _read_body(path)
            seg_end = base + len(body)
            if seg_end <= offset:
                continue
            lo = max(0, offset - base)
            hi = len(body) if end is None else min(len(body), end - base)
            schema = [(n, np.dtype(s)) for n, s in hdr["schema"]]
            yield from _parse(body[lo:hi], schema)


# ---------------------------------------------------------------------------
# Segment parsing (shared by replay, torn-tail recovery)
# ---------------------------------------------------------------------------
def _read_header(path: str) -> Dict[str, Any]:
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != _MAGIC:
            raise ValueError(f"{path}: not a WAL segment")
        (hlen,) = struct.unpack("<I", f.read(4))
        return json.loads(f.read(hlen))


def _try_header(path: str) -> Optional[Dict[str, Any]]:
    """Header, or None for an empty/torn-header segment file."""
    try:
        return _read_header(path)
    except (ValueError, struct.error, json.JSONDecodeError, KeyError):
        return None


def _header_len(path: str) -> int:
    with open(path, "rb") as f:
        f.seek(8)
        (hlen,) = struct.unpack("<I", f.read(4))
    return 12 + hlen


def _read_body(path: str) -> bytes:
    with open(path, "rb") as f:
        data = f.read()
    hlen = struct.unpack("<I", data[8:12])[0]
    return data[12 + hlen:]


def _record_span(buf: bytes, p: int, schema) -> int:
    """Byte length of the record starting at p, or -1 if torn/unknown."""
    kind = buf[p]
    if kind == REC_INSERT:
        if p + _INSERT_HDR.size > len(buf):
            return -1
        (_, n) = _INSERT_HDR.unpack_from(buf, p)
        span = _INSERT_HDR.size + n * _EDGE_DT.itemsize
        for _, dt in schema:
            span += n * dt.itemsize
        return span
    if kind == REC_DELETE:
        return _DELETE_REC.size
    if kind == REC_COLUMN:
        if p + _COLUMN_HDR.size > len(buf):
            return -1
        (_, ci, _, _) = _COLUMN_HDR.unpack_from(buf, p)
        if ci >= len(schema):
            return -1
        return _COLUMN_HDR.size + schema[ci][1].itemsize
    return -1  # unknown kind: treat as torn


def _parse_len(buf: bytes, schema) -> int:
    """Length of the longest whole-record prefix of buf."""
    p = 0
    while p < len(buf):
        span = _record_span(buf, p, schema)
        if span < 0 or p + span > len(buf):
            break
        p += span
    return p


def _parse(buf: bytes, schema) -> Iterator[Tuple]:
    p = 0
    while p < len(buf):
        span = _record_span(buf, p, schema)
        if span < 0 or p + span > len(buf):
            break  # torn trailing record
        kind = buf[p]
        if kind == REC_INSERT:
            (_, n) = _INSERT_HDR.unpack_from(buf, p)
            q = p + _INSERT_HDR.size
            rec = np.frombuffer(buf, _EDGE_DT, count=n, offset=q)
            q += n * _EDGE_DT.itemsize
            cols = {}
            for name, dt in schema:
                cols[name] = np.frombuffer(buf, dt, count=n, offset=q)
                q += n * dt.itemsize
            yield ("insert", rec["s"], rec["d"], rec["t"], cols)
        elif kind == REC_DELETE:
            (_, s, d) = _DELETE_REC.unpack_from(buf, p)
            yield ("delete", s, d)
        else:
            (_, ci, s, d) = _COLUMN_HDR.unpack_from(buf, p)
            name, dt = schema[ci]
            val = np.frombuffer(buf, dt, count=1,
                                offset=p + _COLUMN_HDR.size)[0]
            yield ("column", name, s, d, val)
        p += span
