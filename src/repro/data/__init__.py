from .linkbench import LinkBenchConfig, LinkBenchWorkload, REQUEST_MIX
from .pipeline import GraphStream, TokenStream, TokenStreamConfig
