"""LinkBench-style workload generator (Armstrong et al., SIGMOD'13 — the
benchmark the paper uses in §8.2).

Generates a request mix over a growing social-graph-like store: node get /
insert / update, edge insert-or-update / delete / update, out-neighbor and
time-range queries — with the paper-noted quirk that LinkBench assigns
neighbor IDs near the source (locality), which we optionally randomize.
Request frequencies follow the published LinkBench mix.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

__all__ = ["LinkBenchConfig", "LinkBenchWorkload", "REQUEST_MIX"]

# Published LinkBench operation mix (fractions of total requests).
REQUEST_MIX = {
    "node_get": 0.129,
    "node_insert": 0.026,
    "node_update": 0.074,
    "edge_insert_or_update": 0.12,
    "edge_delete": 0.03,
    "edge_update": 0.08,
    "edge_getrange": 0.006,
    "edge_outnbrs": 0.535,
}


@dataclasses.dataclass(frozen=True)
class LinkBenchConfig:
    n_vertices: int = 100_000
    edges_per_vertex: float = 5.0
    zipf_alpha: float = 1.6
    payload_bytes: int = 16
    realistic_ids: bool = True   # scatter neighbor ids (paper's critique)
    seed: int = 0


class LinkBenchWorkload:
    def __init__(self, cfg: LinkBenchConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        ops, probs = zip(*REQUEST_MIX.items())
        self._ops = list(ops)
        self._probs = np.asarray(probs) / sum(probs)

    def initial_graph(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, timestamps) of the pre-benchmark bulk load."""
        n = self.cfg.n_vertices
        e = int(n * self.cfg.edges_per_vertex)
        src = (self.rng.zipf(self.cfg.zipf_alpha, e) - 1) % n
        if self.cfg.realistic_ids:
            dst = self.rng.integers(0, n, e)
        else:
            dst = (src + self.rng.integers(1, 100, e)) % n  # LinkBench locality
        ts = np.sort(self.rng.integers(0, 2**31, e))
        return src, dst, ts

    def _vertex(self) -> int:
        return int((self.rng.zipf(self.cfg.zipf_alpha) - 1) % self.cfg.n_vertices)

    def requests(self, n_requests: int) -> Iterator[dict]:
        choices = self.rng.choice(len(self._ops), n_requests, p=self._probs)
        for c in choices:
            op = self._ops[c]
            req = {"op": op, "u": self._vertex()}
            if op.startswith("edge"):
                req["v"] = self._vertex()
                req["ts"] = int(self.rng.integers(0, 2**31))
            if op in ("node_update", "edge_update", "edge_insert_or_update",
                      "node_insert"):
                req["payload"] = float(self.rng.random())
            yield req
