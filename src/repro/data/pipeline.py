"""Data pipeline: synthetic token stream + LSM-segment shuffle buffer.

The token pipeline mirrors the paper's ingestion discipline: data arrives in
IMMUTABLE segments (the LSM level-0 analogue); a bounded shuffle buffer merges
segments; batches are deterministic functions of (seed, step) so a restarted
job reproduces the exact stream from any checkpointed step — the data-side
half of fault tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["TokenStreamConfig", "TokenStream", "GraphStream"]


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0


class TokenStream:
    """Deterministic synthetic LM batches; `batch_at(step)` is random-access
    (restart-safe — no iterator state to lose)."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.cfg.seed << 32) ^ step)
        # zipf-ish marginal over the vocab = realistic token frequencies
        z = rng.zipf(1.3, size=(self.cfg.batch, self.cfg.seq_len + 1))
        toks = (z - 1) % self.cfg.vocab_size
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class GraphStream:
    """Power-law edge stream (preferential-attachment-flavoured) for the
    online-insert benchmarks and incremental PageRank — the paper's twitter-
    2010-like ingestion workload, at configurable scale."""

    def __init__(self, n_vertices: int, alpha: float = 1.8, seed: int = 0):
        self.n = n_vertices
        self.alpha = alpha
        self.rng = np.random.default_rng(seed)

    def next_edges(self, k: int):
        """Returns (src, dst): sources uniform, destinations zipf-hot."""
        src = self.rng.integers(0, self.n, k)
        dst = (self.rng.zipf(self.alpha, k) - 1) % self.n
        # hash the hot head across the id space (paper's graphs have hot ids
        # scattered, not concentrated at 0)
        dst = (dst * 2654435761) % self.n
        return src, dst
