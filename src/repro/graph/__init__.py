"""Message-passing substrate built on PAL storage."""
from .segment_ops import (
    aggregate_multi,
    degree,
    edge_softmax,
    gather_src,
    scatter_max,
    scatter_mean,
    scatter_min,
    scatter_std,
    scatter_sum,
)
from .sampler import NeighborSampler, SampledSubgraph
from .padding import pad_to_ell, bucket_edges_by_block

__all__ = [
    "aggregate_multi", "degree", "edge_softmax", "gather_src",
    "scatter_max", "scatter_mean", "scatter_min", "scatter_std", "scatter_sum",
    "NeighborSampler", "SampledSubgraph", "pad_to_ell", "bucket_edges_by_block",
]
