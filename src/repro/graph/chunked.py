"""Edge-chunked message passing (PSW discipline for XLA-native GNNs).

Big PAL partitions are processed in edge chunks inside a `lax.scan`, holding
only (E/chunks)-sized per-edge transients. Aggregators fold across chunks:
sum/mean/std via (sum, sumsq, count) moments; max/min via elementwise fold
with ±inf identities (masked edges contribute the identity, fixing the
mask-as-zero bias a naive `segment_max(msgs * mask)` has).
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..sharding import constrain

__all__ = ["multi_aggregate_chunked", "fold_aggregate"]

NEG = -1e30
POS = 1e30


def _chunk(arr, nc):
    out = arr.reshape(nc, arr.shape[0] // nc, *arr.shape[1:])
    # keep chunks edge-sharded (reshape would otherwise let SPMD replicate)
    return constrain(out, None, "edges", *([None] * (arr.ndim - 1)))


def multi_aggregate_chunked(
    msg_fn: Callable[..., jnp.ndarray],
    edge_arrays: Dict[str, jnp.ndarray],   # chunked along edges, incl. 'dst',
                                           # 'mask'
    n_nodes: int,
    d_msg: int,
    aggregators: Sequence[str] = ("mean", "max", "min", "std"),
    chunks: int = 1,
) -> Dict[str, jnp.ndarray]:
    """Fold segment aggregations over edge chunks.

    msg_fn(**chunk_arrays) -> (Ec, d) messages. Returns the dict of raw
    moments {sum, sumsq, max, min, count}; finalize with `fold_aggregate`.
    """
    need_sq = "std" in aggregators
    need_max = "max" in aggregators
    need_min = "min" in aggregators

    def one_chunk(acc, chunk):
        dst = chunk["dst"]
        mask = chunk["mask"]
        msgs = msg_fn(**{k: v for k, v in chunk.items()
                         if k not in ("dst", "mask")})
        m = mask.astype(msgs.dtype)[:, None]
        acc["sum"] = acc["sum"] + jax.ops.segment_sum(
            msgs * m, dst, num_segments=n_nodes)
        acc["count"] = acc["count"] + jax.ops.segment_sum(
            m[:, 0], dst, num_segments=n_nodes)
        if need_sq:
            acc["sumsq"] = acc["sumsq"] + jax.ops.segment_sum(
                msgs * msgs * m, dst, num_segments=n_nodes)
        if need_max:
            mx = jax.ops.segment_max(jnp.where(m > 0, msgs, NEG), dst,
                                     num_segments=n_nodes)
            acc["max"] = jnp.maximum(acc["max"], mx)
        if need_min:
            mn = jax.ops.segment_min(jnp.where(m > 0, msgs, POS), dst,
                                     num_segments=n_nodes)
            acc["min"] = jnp.minimum(acc["min"], mn)
        acc = {k: constrain(v, "nodes", *([None] * (v.ndim - 1)))
               for k, v in acc.items()}
        return acc

    acc = {
        "sum": jnp.zeros((n_nodes, d_msg)),
        "count": jnp.zeros((n_nodes,)),
    }
    if need_sq:
        acc["sumsq"] = jnp.zeros((n_nodes, d_msg))
    if need_max:
        acc["max"] = jnp.full((n_nodes, d_msg), NEG)
    if need_min:
        acc["min"] = jnp.full((n_nodes, d_msg), POS)
    acc = {k: constrain(v, "nodes", *([None] * (v.ndim - 1)))
           for k, v in acc.items()}

    if chunks == 1:
        return one_chunk(acc, edge_arrays)

    chunked = {k: _chunk(v, chunks) for k, v in edge_arrays.items()}
    acc, _ = jax.lax.scan(
        lambda a, c: (jax.checkpoint(one_chunk)(a, c), None), acc, chunked)
    return acc


def fold_aggregate(acc: Dict[str, jnp.ndarray],
                   aggregators: Sequence[str], eps: float = 1e-5):
    """Finalize moments into the stacked (N, A*d) aggregate."""
    cnt = jnp.maximum(acc["count"], 1.0)[:, None]
    has = (acc["count"] > 0)[:, None]
    outs = []
    for a in aggregators:
        if a == "sum":
            outs.append(acc["sum"])
        elif a == "mean":
            outs.append(acc["sum"] / cnt)
        elif a == "std":
            mean = acc["sum"] / cnt
            var = jnp.maximum(acc["sumsq"] / cnt - mean * mean, 0.0)
            outs.append(jnp.sqrt(var + eps))
        elif a == "max":
            outs.append(jnp.where(has, acc["max"], 0.0))
        elif a == "min":
            outs.append(jnp.where(has, acc["min"], 0.0))
        else:
            raise ValueError(a)
    return jnp.concatenate(outs, axis=-1)
