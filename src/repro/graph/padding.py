"""Padding / bucketing helpers for device-ready graph layouts."""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["pad_to_ell", "bucket_edges_by_block"]


def pad_to_ell(src: np.ndarray, dst: np.ndarray, n_nodes: int,
               max_degree: int) -> Tuple[np.ndarray, np.ndarray]:
    """ELL layout: (n_nodes, max_degree) source-index matrix + validity mask.
    Edges beyond max_degree per destination are dropped (caller picks the cap;
    PAL's |E|/P constraint from the paper bounds it)."""
    order = np.argsort(dst, kind="stable")
    s, d = src[order], dst[order]
    idx = np.zeros((n_nodes, max_degree), np.int32)
    mask = np.zeros((n_nodes, max_degree), bool)
    counts = np.zeros(n_nodes, np.int64)
    for i in range(s.shape[0]):
        v = d[i]
        c = counts[v]
        if c < max_degree:
            idx[v, c] = s[i]
            mask[v, c] = True
            counts[v] = c + 1
    return idx, mask


def bucket_edges_by_block(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                          block: int) -> Tuple[np.ndarray, np.ndarray]:
    """Group edges into (dst_block, src_block) tiles; returns the list of
    active tile coordinates and a dense per-tile adjacency stack — the
    block-sparse layout consumed by the psw_spmm kernel."""
    bs = (src // block).astype(np.int64)
    bd = (dst // block).astype(np.int64)
    keys = bd * (-(-n_nodes // block)) + bs
    uniq, inv = np.unique(keys, return_inverse=True)
    n_blocks_side = -(-n_nodes // block)
    coords = np.stack([uniq // n_blocks_side, uniq % n_blocks_side], axis=1)
    tiles = np.zeros((uniq.shape[0], block, block), np.float32)
    np.add.at(tiles, (inv, dst % block, src % block), 1.0)  # multigraph-safe
    return coords.astype(np.int32), tiles
