"""Distributed PSW operators (shard_map): the paper's sliding windows on TPU.

GraphChi streams each partition's windows sequentially through RAM; here the
node-state shards stream around the device ring via collective-permute. One
full revolution delivers every remote source row exactly once — an
all-gather's bytes with an x-shard-sized memory footprint (DESIGN.md §2).

Ops (all differentiable; ring_gather has a custom VJP whose backward is a
REVERSE grad-ring, so nothing is checkpointed per step):

  ring_gather(x, idx)        x row-sharded, idx arbitrary global rows
  local_gather(x, idx)       idx guaranteed local to the shard (PAL dst!)
  local_scatter_sum(v, idx)  scatter into shard-local rows
  local_edge_softmax(s, idx) softmax grouped by shard-local destination

`ring_mesh(mesh)` reshapes any production mesh into the 1-D ring view these
ops use (same devices, flattened order).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..jax_compat import mesh_axis_types, pvary, shard_map

__all__ = ["ring_mesh", "ring_gather", "ring_scatter_sum", "local_gather",
           "local_scatter_sum", "local_edge_softmax"]


def ring_mesh(mesh: Mesh) -> Mesh:
    """1-D view of a production mesh (same devices, flattened)."""
    return Mesh(mesh.devices.reshape(-1), ("ring",), **mesh_axis_types(1))


def _expand(sel, ndim):
    return sel.reshape(sel.shape + (1,) * (ndim - 1))


# ---------------------------------------------------------------------------
# ring gather with reverse-ring VJP
# ---------------------------------------------------------------------------
def _ring_fwd_local(x_loc, idx_loc, *, P_size: int, n_loc: int):
    my = jax.lax.axis_index("ring")
    fwd_perm = [(j, (j + 1) % P_size) for j in range(P_size)]
    out0 = pvary(
        jnp.zeros((idx_loc.shape[0],) + x_loc.shape[1:], x_loc.dtype),
        ("ring",))

    def step(carry, s):
        x_rot, out = carry
        owner = jax.lax.rem(my - s + P_size, P_size)
        sel = (idx_loc // n_loc) == owner
        local_row = jnp.clip(idx_loc - owner * n_loc, 0, n_loc - 1)
        rows = jnp.take(x_rot, local_row, axis=0)
        out = out + jnp.where(_expand(sel, rows.ndim), rows, 0)
        x_rot = jax.lax.ppermute(x_rot, "ring", fwd_perm)
        return (x_rot, out), None

    (_, out), _ = jax.lax.scan(step, (x_loc, out0), jnp.arange(P_size))
    return out


def _ring_bwd_local(idx_loc, g_loc, *, P_size: int, n_loc: int,
                    feat_shape, dtype):
    """Reverse grad-ring: a per-shard gradient buffer circulates backward;
    each device scatter-adds its contribution when the owner's buffer is
    resident; after P steps every buffer is home, fully accumulated."""
    my = jax.lax.axis_index("ring")
    bwd_perm = [(j, (j - 1) % P_size) for j in range(P_size)]
    g32 = g_loc.astype(jnp.float32)

    def step(gbuf, s):
        owner = jax.lax.rem(my + s, P_size)
        sel = (idx_loc // n_loc) == owner
        local_row = jnp.clip(idx_loc - owner * n_loc, 0, n_loc - 1)
        contrib = jax.ops.segment_sum(
            jnp.where(_expand(sel, g32.ndim), g32, 0), local_row,
            num_segments=n_loc)
        gbuf = gbuf + contrib
        gbuf = jax.lax.ppermute(gbuf, "ring", bwd_perm)
        return gbuf, None

    gbuf0 = pvary(jnp.zeros((n_loc,) + feat_shape, jnp.float32),
                  ("ring",))
    gbuf, _ = jax.lax.scan(step, gbuf0, jnp.arange(P_size))
    return gbuf.astype(dtype)


def ring_gather(x: jnp.ndarray, idx: jnp.ndarray, mesh: Mesh) -> jnp.ndarray:
    """x: (N, ...) row-sharded over the ring; idx: (E,) global row ids,
    edge-sharded. Returns x[idx], edge-sharded. N and E must divide the ring."""
    rmesh = ring_mesh(mesh)
    P_size = rmesh.devices.size
    n_loc = x.shape[0] // P_size
    spec = P("ring")
    feat_shape, x_dtype = x.shape[1:], x.dtype  # static, captured in closure

    @jax.custom_vjp
    def _gather(x, idx):
        f = functools.partial(_ring_fwd_local, P_size=P_size, n_loc=n_loc)
        return shard_map(f, mesh=rmesh, in_specs=(spec, spec),
                         out_specs=spec)(x, idx)

    def _fwd(x, idx):
        return _gather(x, idx), idx

    def _bwd(idx, g):
        b = functools.partial(_ring_bwd_local, P_size=P_size, n_loc=n_loc,
                              feat_shape=feat_shape, dtype=x_dtype)
        gx = shard_map(b, mesh=rmesh, in_specs=(spec, spec),
                       out_specs=spec)(idx, g)
        return gx, None

    _gather.defvjp(_fwd, _bwd)
    return _gather(x, idx)


def ring_scatter_sum(vals: jnp.ndarray, idx: jnp.ndarray, n: int,
                     mesh: Mesh) -> jnp.ndarray:
    """Transpose of ring_gather: scatter-add rows `vals` (edge-sharded) into
    global rows idx of an (n, ...) output (row-sharded over the ring), via
    the reverse grad-ring — never materializing a replicated (n, ...) array.
    VJP is a ring_gather of the cotangent."""
    rmesh = ring_mesh(mesh)
    P_size = rmesh.devices.size
    n_loc = n // P_size
    spec = P("ring")
    feat_shape, v_dtype = vals.shape[1:], vals.dtype

    @jax.custom_vjp
    def _scatter(vals, idx):
        def f(v_loc, idx_loc):
            return _ring_bwd_local(idx_loc, v_loc, P_size=P_size, n_loc=n_loc,
                                   feat_shape=feat_shape, dtype=v_dtype)
        return shard_map(f, mesh=rmesh, in_specs=(spec, spec),
                         out_specs=spec)(vals, idx)

    def _fwd(vals, idx):
        return _scatter(vals, idx), idx

    def _bwd(idx, g):
        f = functools.partial(_ring_fwd_local, P_size=P_size, n_loc=n_loc)
        gv = shard_map(f, mesh=rmesh, in_specs=(spec, spec),
                       out_specs=spec)(g, idx)
        return gv.astype(v_dtype), None

    _scatter.defvjp(_fwd, _bwd)
    return _scatter(vals, idx)


# ---------------------------------------------------------------------------
# shard-local ops (PAL guarantees destination locality)
# ---------------------------------------------------------------------------
def local_gather(x: jnp.ndarray, idx: jnp.ndarray, mesh: Mesh) -> jnp.ndarray:
    """x[idx] where every idx is owned by the same shard as the edge —
    exactly the PAL property for destination rows. Zero communication."""
    rmesh = ring_mesh(mesh)
    P_size = rmesh.devices.size
    n_loc = x.shape[0] // P_size
    spec = P("ring")

    def f(x_loc, idx_loc):
        my = jax.lax.axis_index("ring")
        return jnp.take(x_loc, jnp.clip(idx_loc - my * n_loc, 0, n_loc - 1),
                        axis=0)

    return shard_map(f, mesh=rmesh, in_specs=(spec, spec), out_specs=spec)(x, idx)


def local_scatter_sum(vals: jnp.ndarray, idx: jnp.ndarray, n: int,
                      mesh: Mesh) -> jnp.ndarray:
    """segment-sum into shard-local destination rows. Zero communication."""
    rmesh = ring_mesh(mesh)
    P_size = rmesh.devices.size
    n_loc = n // P_size
    spec = P("ring")

    def f(v_loc, idx_loc):
        my = jax.lax.axis_index("ring")
        return jax.ops.segment_sum(
            v_loc, jnp.clip(idx_loc - my * n_loc, 0, n_loc - 1),
            num_segments=n_loc)

    return shard_map(f, mesh=rmesh, in_specs=(spec, spec), out_specs=spec)(
        vals, idx)


def local_edge_softmax(scores: jnp.ndarray, idx: jnp.ndarray, n: int,
                       mesh: Mesh) -> jnp.ndarray:
    """edge_softmax grouped by shard-local destinations."""
    from .segment_ops import edge_softmax
    rmesh = ring_mesh(mesh)
    P_size = rmesh.devices.size
    n_loc = n // P_size
    spec = P("ring")

    def f(s_loc, idx_loc):
        my = jax.lax.axis_index("ring")
        loc = jnp.clip(idx_loc - my * n_loc, 0, n_loc - 1)
        if s_loc.ndim == 1:
            return edge_softmax(s_loc, loc, n_loc)
        return jax.vmap(lambda col: edge_softmax(col, loc, n_loc),
                        in_axes=1, out_axes=1)(s_loc)

    return shard_map(f, mesh=rmesh, in_specs=(spec, spec), out_specs=spec)(
        scores, idx)
