"""Fanout neighbor sampler over PAL-CSR (minibatch_lg requires a REAL sampler).

Host-side, numpy. Samples k-hop in-neighborhoods ("who influences me") with
per-hop fanouts (e.g. 15-10 = GraphSAGE-style), reading PAL's dst-perm CSC —
exactly the structure the paper builds for in-edge queries. Produces padded,
device-ready subgraph arrays with local re-indexing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.lsm import LSMTree
from ..core.pal import GraphPAL

GraphLike = Union[GraphPAL, LSMTree]

__all__ = ["SampledSubgraph", "NeighborSampler"]


@dataclasses.dataclass
class SampledSubgraph:
    """Padded minibatch subgraph with local indices.

    nodes: (N_pad,) original vertex IDs (first n_seeds = the seed batch)
    node_mask: (N_pad,) valid-node mask
    src, dst: (E_pad,) local indices into `nodes`
    edge_mask: (E_pad,) valid-edge mask
    n_seeds: number of seed (output) nodes
    """

    nodes: np.ndarray
    node_mask: np.ndarray
    src: np.ndarray
    dst: np.ndarray
    edge_mask: np.ndarray
    n_seeds: int


class NeighborSampler:
    """Uniform fanout sampler over a PAL graph's in-edges (CSC direction).

    The sampler consolidates the graph into flat CSC arrays once (a PSW-style
    full pass), then serves minibatches with O(batch · prod(fanouts)) work.
    """

    def __init__(self, g: GraphLike, seed: int = 0):
        self.iv = g.intervals
        if isinstance(g, LSMTree):
            g.flush_all()
            parts = g.all_partitions()
        else:
            parts = g.partitions
        # consolidate: in-neighbor CSC over internal ids
        srcs, dsts = [], []
        for p in parts:
            if p.n_edges == 0:
                continue
            live = np.ones(p.n_edges, bool) if p.dead is None else ~p.dead
            srcs.append(p.src[live])
            dsts.append(p.dst[live])
        src = np.concatenate(srcs) if srcs else np.empty(0, np.int64)
        dst = np.concatenate(dsts) if dsts else np.empty(0, np.int64)
        order = np.argsort(dst, kind="stable")
        self._src_sorted = src[order]
        n = self.iv.max_vertices
        counts = np.bincount(dst, minlength=n)
        self._ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self._rng = np.random.default_rng(seed)

    def sample(self, seeds: Sequence[int], fanouts: Sequence[int],
               pad_nodes: Optional[int] = None,
               pad_edges: Optional[int] = None) -> SampledSubgraph:
        seeds_orig = np.asarray(list(seeds), dtype=np.int64)
        seeds_int = np.asarray(self.iv.to_internal(seeds_orig))
        frontier = seeds_int
        all_nodes: List[np.ndarray] = [seeds_int]
        e_src: List[np.ndarray] = []
        e_dst: List[np.ndarray] = []
        for f in fanouts:
            deg = self._ptr[frontier + 1] - self._ptr[frontier]
            take = np.minimum(deg, f)
            tot = int(take.sum())
            s_hop = np.empty(tot, np.int64)
            d_hop = np.empty(tot, np.int64)
            o = 0
            for v, k, dg_ in zip(frontier, take, deg):
                if k == 0:
                    continue
                lo = self._ptr[v]
                if dg_ <= f:
                    picks = np.arange(lo, lo + dg_)
                else:
                    picks = lo + self._rng.choice(int(dg_), size=int(k), replace=False)
                s_hop[o:o + int(k)] = self._src_sorted[picks]
                d_hop[o:o + int(k)] = v
                o += int(k)
            e_src.append(s_hop)
            e_dst.append(d_hop)
            frontier = np.unique(s_hop)
            all_nodes.append(frontier)
        nodes_int, inv = np.unique(np.concatenate(all_nodes), return_inverse=True)
        # ensure seeds occupy the first n_seeds slots
        seed_pos = np.searchsorted(nodes_int, seeds_int)
        perm = np.concatenate([seed_pos, np.setdiff1d(np.arange(nodes_int.shape[0]), seed_pos)])
        nodes_int = nodes_int[perm]
        remap = np.empty(perm.shape[0], np.int64)
        remap[perm] = np.arange(perm.shape[0])

        lookup = {int(v): i for i, v in enumerate(nodes_int)}
        src_l = np.asarray([lookup[int(v)] for v in np.concatenate(e_src)] if e_src else [],
                           dtype=np.int64)
        dst_l = np.asarray([lookup[int(v)] for v in np.concatenate(e_dst)] if e_dst else [],
                           dtype=np.int64)

        n, e = nodes_int.shape[0], src_l.shape[0]
        n_pad = pad_nodes or (-(-max(n, 1) // 128) * 128)
        e_pad = pad_edges or (-(-max(e, 1) // 128) * 128)
        if n > n_pad or e > e_pad:
            raise ValueError(f"padding too small: nodes {n}>{n_pad} or edges {e}>{e_pad}")
        nodes = np.zeros(n_pad, np.int64)
        nodes[:n] = np.asarray(self.iv.to_original(nodes_int))
        node_mask = np.zeros(n_pad, bool)
        node_mask[:n] = True
        srcp = np.zeros(e_pad, np.int64)
        dstp = np.zeros(e_pad, np.int64)
        srcp[:e], dstp[:e] = src_l, dst_l
        edge_mask = np.zeros(e_pad, bool)
        edge_mask[:e] = True
        return SampledSubgraph(nodes, node_mask, srcp, dstp, edge_mask,
                               n_seeds=int(seeds_orig.shape[0]))
