"""Segment-reduction message-passing primitives.

JAX sparse is BCOO-only, so message passing is implemented directly as
edge-index gather → `jax.ops.segment_*` scatter (this IS part of the system,
per the assignment). All ops take `edge_index`-style (src, dst) int arrays
and are jit/vmap/grad-friendly. The PAL layout guarantees dst-sorted edges
per partition, which these ops exploit via `indices_are_sorted`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "gather_src",
    "scatter_sum",
    "scatter_mean",
    "scatter_max",
    "scatter_min",
    "scatter_std",
    "degree",
    "edge_softmax",
    "aggregate_multi",
]


def gather_src(x: jnp.ndarray, src: jnp.ndarray) -> jnp.ndarray:
    """Messages from source features: x[src]."""
    return jnp.take(x, src, axis=0)


def scatter_sum(msgs, dst, n_nodes: int, sorted_: bool = False):
    return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes,
                               indices_are_sorted=sorted_)


def scatter_mean(msgs, dst, n_nodes: int, sorted_: bool = False):
    s = scatter_sum(msgs, dst, n_nodes, sorted_)
    d = degree(dst, n_nodes).astype(s.dtype)
    return s / jnp.maximum(d, 1.0)[:, None] if s.ndim == 2 else s / jnp.maximum(d, 1.0)


def scatter_max(msgs, dst, n_nodes: int, sorted_: bool = False):
    return jax.ops.segment_max(msgs, dst, num_segments=n_nodes,
                               indices_are_sorted=sorted_)


def scatter_min(msgs, dst, n_nodes: int, sorted_: bool = False):
    return jax.ops.segment_min(msgs, dst, num_segments=n_nodes,
                               indices_are_sorted=sorted_)


def scatter_std(msgs, dst, n_nodes: int, eps: float = 1e-5,
                sorted_: bool = False):
    """Per-destination standard deviation (PNA aggregator)."""
    mean = scatter_mean(msgs, dst, n_nodes, sorted_)
    sq_mean = scatter_mean(msgs * msgs, dst, n_nodes, sorted_)
    var = jnp.maximum(sq_mean - mean * mean, 0.0)
    return jnp.sqrt(var + eps)


def degree(dst: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    return jax.ops.segment_sum(jnp.ones_like(dst, jnp.float32), dst,
                               num_segments=n_nodes)


def edge_softmax(scores: jnp.ndarray, dst: jnp.ndarray, n_nodes: int):
    """Numerically-stable softmax of edge scores grouped by destination."""
    m = jax.ops.segment_max(scores, dst, num_segments=n_nodes)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    ex = jnp.exp(scores - m[dst])
    z = jax.ops.segment_sum(ex, dst, num_segments=n_nodes)
    return ex / jnp.maximum(z[dst], 1e-16)


def aggregate_multi(msgs, dst, n_nodes: int,
                    aggregators=("mean", "max", "min", "std")):
    """Stacked multi-aggregator reduce (PNA). Returns (n_nodes, A*d)."""
    outs = []
    neg_inf = jnp.finfo(msgs.dtype).min
    for a in aggregators:
        if a == "mean":
            outs.append(scatter_mean(msgs, dst, n_nodes))
        elif a == "sum":
            outs.append(scatter_sum(msgs, dst, n_nodes))
        elif a == "max":
            o = scatter_max(msgs, dst, n_nodes)
            outs.append(jnp.where(o <= neg_inf, 0.0, o))
        elif a == "min":
            o = scatter_min(msgs, dst, n_nodes)
            outs.append(jnp.where(o >= jnp.finfo(msgs.dtype).max, 0.0, o))
        elif a == "std":
            outs.append(scatter_std(msgs, dst, n_nodes))
        else:
            raise ValueError(a)
    return jnp.concatenate(outs, axis=-1)
