"""Version tolerance for the jax APIs this repo leans on.

The sources target jax >= 0.8 (`jax.shard_map`, `jax.lax.pvary`, explicit
`AxisType` meshes). CI containers ship an older CPU-only jax (0.4.x) where
`shard_map` still lives in `jax.experimental`, `pvary` does not exist (the
varying-type system it belongs to was introduced later), and meshes take no
`axis_types`. Importing from here keeps one set of sources running on both.
"""
from __future__ import annotations

from typing import Tuple

import jax

__all__ = ["shard_map", "pvary", "mesh_axis_types"]


try:  # jax >= 0.8: shard_map is a top-level export
    from jax import shard_map as _shard_map_mod  # noqa: F401

    shard_map = jax.shard_map
except ImportError:  # jax 0.4.x: experimental API, needs check_rep=False
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
        kwargs.setdefault("check_rep", False)
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)


def _pvary_fallback(x, axis_names):
    """Old jax has no varying types — every value is already 'varying'."""
    return x


pvary = getattr(jax.lax, "pvary", _pvary_fallback)


def mesh_axis_types(n_axes: int) -> dict:
    """kwargs for Mesh()/jax.make_mesh(): explicit Auto axes when the
    installed jax has AxisType, nothing otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}
