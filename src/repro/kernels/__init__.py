"""Pallas TPU kernels (validated in interpret mode on CPU).

Each kernel package ships <name>.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd public wrapper), and ref.py (pure-jnp oracle).
"""
from . import embedding_bag, flash_attention, psw_spmm, segment_ell
