"""Shared kernel utilities."""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas kernels target TPU; on the CPU backend we validate with
    interpret=True (the kernel body executes as JAX ops)."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
