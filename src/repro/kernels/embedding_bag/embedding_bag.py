"""Pallas TPU kernel: EmbeddingBag (ragged gather + weighted segment-reduce).

JAX has no native EmbeddingBag; this is the recsys hot path (huge sparse
table, many small bags) implemented as a TPU kernel. Bags are padded to K
slots (multi-hot layout). The PAL reversible hash (paper §7.2) spreads hot
rows across table shards; within a shard this kernel does the positional
lookup — the paper's 'edge position is the attribute key' discipline.

Tiling: grid = (n_bag_blocks, n_dim_blocks). idx/weight tiles (Bb, K) are
VMEM-resident; the table stays in ANY/HBM and rows stream in with one DMA
per (bag, slot); weighted accumulation on the VPU. Padded slots carry
weight 0 and index 0 (row 0 fetched, multiplied by zero).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret

__all__ = ["embedding_bag_pallas"]


def _kernel(idx_ref, w_ref, table_ref, o_ref, *, k_slots: int):
    bb, db = o_ref.shape
    d0 = pl.program_id(1) * db

    def bag_body(b, acc):
        def slot_body(k, acc):
            r = idx_ref[b, k]
            w = w_ref[b, k]
            row = pl.load(table_ref, (pl.dslice(r, 1), pl.dslice(d0, db)))
            return acc.at[b].add(w.astype(jnp.float32)
                                 * row[0].astype(jnp.float32))

        return jax.lax.fori_loop(0, k_slots, slot_body, acc)

    acc0 = jnp.zeros((bb, db), jnp.float32)
    o_ref[...] = jax.lax.fori_loop(0, bb, bag_body, acc0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bag_block", "dim_block",
                                             "interpret"))
def embedding_bag_pallas(idx, weights, table, *, bag_block: int = 128,
                         dim_block: int = 128, interpret=None):
    """idx/weights: (B, K); table: (V, D). B % bag_block == 0,
    D % dim_block == 0. Returns (B, D) weighted sums."""
    if interpret is None:
        interpret = default_interpret()
    B, K = idx.shape
    V, D = table.shape
    assert B % bag_block == 0 and D % dim_block == 0

    return pl.pallas_call(
        functools.partial(_kernel, k_slots=K),
        grid=(B // bag_block, D // dim_block),
        in_specs=[
            pl.BlockSpec((bag_block, K), lambda b, d: (b, 0)),
            pl.BlockSpec((bag_block, K), lambda b, d: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),      # table stays in HBM
        ],
        out_specs=pl.BlockSpec((bag_block, dim_block), lambda b, d: (b, d)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(idx, weights, table)
