"""Jit'd wrapper for the EmbeddingBag kernel (sum/mean, padding-tolerant)."""
from __future__ import annotations

import jax.numpy as jnp

from ..common import round_up
from .embedding_bag import embedding_bag_pallas
from .ref import embedding_bag_ref

__all__ = ["embedding_bag"]


def embedding_bag(idx, weights, table, mode: str = "sum",
                  use_kernel: bool = True, interpret=None):
    B, K = idx.shape
    V, D = table.shape
    Bp, Dp = round_up(B, 128), round_up(D, 128)
    idx_p = jnp.pad(idx, ((0, Bp - B), (0, 0)))
    w_p = jnp.pad(weights, ((0, Bp - B), (0, 0)))
    t_p = jnp.pad(table, ((0, 0), (0, Dp - D)))
    if use_kernel:
        out = embedding_bag_pallas(idx_p, w_p, t_p, interpret=interpret)
    else:
        out = embedding_bag_ref(idx_p, w_p, t_p)
    out = out[:B, :D]
    if mode == "mean":
        denom = jnp.maximum(weights.sum(axis=1, keepdims=True), 1e-9)
        out = out / denom.astype(out.dtype)
    return out
