"""Pure-jnp oracle for the embedding-bag kernel."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["embedding_bag_ref"]


def embedding_bag_ref(idx, weights, table, mode: str = "sum"):
    """idx: (B, K) int32 rows; weights: (B, K) per-sample weights (0 = padded
    slot); table: (V, D). out[b] = reduce_k weights[b,k] * table[idx[b,k]]."""
    gathered = table[idx]                              # (B, K, D)
    w = weights[..., None].astype(table.dtype)
    s = (gathered * w).sum(axis=1)
    if mode == "sum":
        return s
    if mode == "mean":
        denom = jnp.maximum(weights.sum(axis=1, keepdims=True), 1e-9)
        return s / denom.astype(table.dtype)
    raise ValueError(mode)
