"""Pallas TPU kernel: causal GQA flash attention (FlashAttention-2 style).

Tiling: grid = (B, H, n_q_blocks, n_kv_blocks); the kv dimension iterates
fastest. Per (b, h, q-block): q tile (Bq, D) is VMEM-resident across the kv
sweep; k/v tiles (Bk, D) stream HBM→VMEM; the online-softmax state
(m: running max, l: running denominator, acc: unnormalized output) lives in
VMEM scratch and is written out, normalized, on the last kv step. GQA is
expressed in the k/v BlockSpec index map (h → h // group). Causal blocks
entirely above the diagonal are masked (computed-and-discarded; the
hillclimbed variant skips them — see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, scale: float, block_q: int, block_k: int,
            n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (Bq, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        q_idx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                        (block_q, block_k), 0)
        k_idx = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                        (block_q, block_k), 1)
        s = jnp.where(q_idx >= k_idx, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret=None):
    """q: (B, S, H, D); k, v: (B, T, Hkv, D). S % block_q == T % block_k == 0.
    Returns (B, S, H, D)."""
    if interpret is None:
        interpret = default_interpret()
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    n_q, n_kv = S // block_q, T // block_k

    # layout: (B, H, S, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, scale=D ** -0.5,
                          block_q=block_q, block_k=block_k, n_kv=n_kv),
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
