"""Jit'd wrapper: Pallas forward + exact-recompute XLA backward.

The kernel is the inference/serving hot path; for training we register a
custom VJP whose backward recomputes attention with the jnp oracle (XLA
flash-style chunking handles memory) — kernel-forward/XLA-backward is a
standard production split.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q, k, v, causal: bool = True):
    return flash_attention_pallas(q, k, v, causal=causal)


def _fwd(q, k, v, causal):
    return flash_attention_pallas(q, k, v, causal=causal), (q, k, v)


def _bwd(causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: attention_ref(q, k, v, causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
