"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, causal: bool = True):
    """q: (B, S, H, D); k, v: (B, T, Hkv, D); H % Hkv == 0.
    Exact softmax attention in fp32."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * D ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)
