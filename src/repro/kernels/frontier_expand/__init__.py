from .ops import (
    FrontierPlan,
    HAVE_PALLAS,
    build_frontier_plan,
    frontier_expand_counts,
)
from .ref import frontier_expand_np, frontier_expand_ref

__all__ = [
    "FrontierPlan",
    "HAVE_PALLAS",
    "build_frontier_plan",
    "frontier_expand_counts",
    "frontier_expand_np",
    "frontier_expand_ref",
]
