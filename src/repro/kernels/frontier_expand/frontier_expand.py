"""Pallas TPU kernel: virtual-row ELL frontier expansion.

The multi-hop dense path (core/multihop.py, DESIGN.md §10.3) lays the
store's deduplicated edge set out destination-grouped in rows of at most K
sources — a destination of degree d spans ceil(d/K) VIRTUAL rows, so the
layout is linear in |E| where `pad_to_ell`'s per-vertex padding explodes on
power-law degree tails. The kernel accumulates, per virtual row, the masked
sum of frontier-indicator rows; the per-destination reduction over virtual
rows happens outside (a sorted segment-sum keyed by the plan's `row_dst`).

Tiling: grid = (n_row_blocks, n_frontier_blocks). idx/mask tiles (Br, K)
sit in VMEM; the indicator panel x stays in ANY/HBM memory space and rows
are fetched with dynamic loads (row DMAs on real TPU — source locality
follows PAL's interval layout, same argument as segment_ell). The K slots
of one virtual row are an unrolled masked-load loop on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret

__all__ = ["frontier_expand_pallas"]


def _kernel(idx_ref, mask_ref, x_ref, o_ref, *, k_slots: int):
    br, fb = o_ref.shape
    f0 = pl.program_id(1) * fb

    def row_body(i, acc):
        # one row DMA per (virtual row, source) slot; masked slots add zero
        def slot_body(k, acc):
            r = idx_ref[i, k]
            v = mask_ref[i, k]
            row = pl.load(x_ref, (pl.dslice(r, 1), pl.dslice(f0, fb)))
            contrib = jnp.where(v, row[0], jnp.zeros((fb,), o_ref.dtype))
            return acc.at[i].add(contrib)

        return jax.lax.fori_loop(0, k_slots, slot_body, acc)

    acc0 = jnp.zeros(o_ref.shape, o_ref.dtype)
    o_ref[...] = jax.lax.fori_loop(0, br, row_body, acc0)


@functools.partial(jax.jit, static_argnames=("r_block", "b_block",
                                             "interpret"))
def frontier_expand_pallas(idx, mask, x, *, r_block: int = 128,
                           b_block: int = 128, interpret=None):
    """idx/mask: (R, K) virtual-row source slots; x: (M, B) frontier
    indicator panel. R % r_block == 0, B % b_block == 0. Returns (R, B)
    per-virtual-row masked sums (pre-reduction)."""
    if interpret is None:
        interpret = default_interpret()
    R, K = idx.shape
    B = x.shape[-1]
    assert R % r_block == 0 and B % b_block == 0

    grid = (R // r_block, B // b_block)
    return pl.pallas_call(
        functools.partial(_kernel, k_slots=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_block, K), lambda r, b: (r, 0)),
            pl.BlockSpec((r_block, K), lambda r, b: (r, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),     # x stays in HBM
        ],
        out_specs=pl.BlockSpec((r_block, b_block), lambda r, b: (r, b)),
        out_shape=jax.ShapeDtypeStruct((R, B), x.dtype),
        interpret=interpret,
    )(idx, mask, x)
