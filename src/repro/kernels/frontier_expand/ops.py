"""Plan builder + jit'd wrapper for the frontier-expansion kernel.

Virtual-row ELL: the deduplicated edge set, grouped by destination, is
split into rows of at most `k_slots` sources — a destination of degree d
occupies ceil(d/k) rows, so the plan is linear in |E|. Compare the two
existing device layouts at 1M+ edges: `psw_spmm`'s dense tiles materialize
O(n_blocks²·B²) memory, and `pad_to_ell` pads every vertex to the max
degree (quadratic-ish on power-law tails, and truncating). The virtual-row
plan is exact and costs (|E|/k + n_present_dsts) rows.

`row_dst` maps each virtual row to its destination, destination-sorted;
padding rows map to `n_dst` so one sorted segment-sum both reduces the
virtual rows and discards padding.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..common import round_up
from .ref import HAVE_JAX, frontier_expand_np, frontier_expand_ref

if HAVE_JAX:
    import jax
    import jax.numpy as jnp

    try:
        from .frontier_expand import frontier_expand_pallas

        HAVE_PALLAS = True
    except Exception:  # pragma: no cover - pallas missing from this jax
        frontier_expand_pallas = None
        HAVE_PALLAS = False
else:  # pragma: no cover - exercised only without jax
    frontier_expand_pallas = None
    HAVE_PALLAS = False

__all__ = ["FrontierPlan", "HAVE_PALLAS", "build_frontier_plan",
           "frontier_expand_counts"]


@dataclasses.dataclass(frozen=True)
class FrontierPlan:
    """Device layout of one store's deduplicated edge set (one direction)."""

    idx: np.ndarray       # (R, K) int32 source id per slot
    mask: np.ndarray      # (R, K) bool, True where a slot holds an edge
    row_dst: np.ndarray   # (R,) int32 destination per row; padding -> n_dst
    n_src: int
    n_dst: int
    n_edges: int          # deduplicated edge count packed into the plan
    k_slots: int


def build_frontier_plan(src, dst, n_src: int, n_dst: int,
                        k_slots: int = 32) -> FrontierPlan:
    """Host-side, fully vectorized: dedup + destination-major sort via one
    packed-key unique, ranks within destination groups via run-length
    arithmetic, then one scatter into the (R, K) slot grid."""
    src = np.asarray(src, np.int64).ravel()
    dst = np.asarray(dst, np.int64).ravel()
    keys = np.unique(dst * np.int64(n_src) + src)
    E = keys.shape[0]
    if E == 0:
        return FrontierPlan(np.zeros((128, k_slots), np.int32),
                            np.zeros((128, k_slots), bool),
                            np.full(128, n_dst, np.int32),
                            int(n_src), int(n_dst), 0, k_slots)
    d = keys // n_src
    s = keys % n_src
    newgrp = np.empty(E, bool)
    newgrp[0] = True
    newgrp[1:] = d[1:] != d[:-1]
    gstart = np.flatnonzero(newgrp)
    gid = np.cumsum(newgrp) - 1
    rank = np.arange(E) - gstart[gid]
    gcount = np.diff(np.append(gstart, E))
    vrows = -(-gcount // k_slots)                  # ceil: rows per group
    vbase = np.cumsum(vrows) - vrows
    row = vbase[gid] + rank // k_slots
    col = rank % k_slots
    R = int(vrows.sum())
    Rp = round_up(R, 128)
    idx = np.zeros((Rp, k_slots), np.int32)
    mask = np.zeros((Rp, k_slots), bool)
    idx[row, col] = s
    mask[row, col] = True
    row_dst = np.full(Rp, n_dst, np.int32)
    row_dst[:R] = np.repeat(d[gstart], vrows)
    return FrontierPlan(idx, mask, row_dst, int(n_src), int(n_dst), int(E),
                        k_slots)


def frontier_expand_counts(plan: FrontierPlan, x, use_kernel=None,
                           interpret=None) -> np.ndarray:
    """out (n_dst, B): out[d, j] = Σ_{(s,d) in plan} x[s, j]. With 0/1
    indicator columns this is each destination's count of DISTINCT frontier
    in-neighbors — expand + distinct + aggregate in one launch. float32
    accumulation is integer-exact below 2**24, far above any degree here."""
    x = np.ascontiguousarray(np.asarray(x, np.float32))
    B = x.shape[1]
    if use_kernel is None:
        # the Mosaic kernel is the TPU path; off-TPU it would run in
        # interpret mode (a correctness tool, ~1000x slow) — the jit'd ref
        # K-loop is the honest device-less default
        use_kernel = HAVE_PALLAS and jax.default_backend() == "tpu"
    if not HAVE_JAX:
        rows = frontier_expand_np(plan.idx, plan.mask, x)
        out = np.zeros((plan.n_dst + 1, B), np.float32)
        np.add.at(out, plan.row_dst, rows)
        return out[:plan.n_dst]
    Bp = round_up(B, 128)
    xp = jnp.asarray(np.pad(x, ((0, 0), (0, Bp - B))))
    if use_kernel and HAVE_PALLAS:
        rows = frontier_expand_pallas(jnp.asarray(plan.idx),
                                      jnp.asarray(plan.mask), xp,
                                      interpret=interpret)
    else:
        rows = frontier_expand_ref(jnp.asarray(plan.idx),
                                   jnp.asarray(plan.mask), xp)
    # virtual rows are destination-sorted; padding rows land in segment
    # n_dst and are sliced away
    seg = jax.ops.segment_sum(rows, jnp.asarray(plan.row_dst),
                              num_segments=plan.n_dst + 1,
                              indices_are_sorted=True)
    return np.asarray(seg[:plan.n_dst, :B])
