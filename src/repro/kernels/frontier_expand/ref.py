"""Oracle for the frontier-expansion kernel: jnp when available, else a
numpy K-loop — multihop's dense path degrades gracefully to the same
numbers without jax (the `jax_compat`-style fallback)."""
from __future__ import annotations

import numpy as np

try:
    import jax
    import jax.numpy as jnp

    HAVE_JAX = True
except ImportError:  # pragma: no cover - exercised only without jax
    jax = jnp = None
    HAVE_JAX = False

__all__ = ["HAVE_JAX", "frontier_expand_ref", "frontier_expand_np"]


def frontier_expand_np(idx, mask, x):
    """Numpy oracle, K-loop so peak memory stays (R, B) instead of the
    (R, K, B) a one-shot fancy-gather would allocate."""
    acc = np.zeros((idx.shape[0], x.shape[1]), x.dtype)
    for k in range(idx.shape[1]):
        acc += np.where(mask[:, k:k + 1], x[idx[:, k]], 0)
    return acc


if HAVE_JAX:

    @jax.jit
    def frontier_expand_ref(idx, mask, x):
        """idx/mask: (R, K); x: (M, B). out[r] = Σ_k mask[r,k]·x[idx[r,k]].
        Same K-loop shape as the kernel (bounded memory at 1M+ edges)."""
        def body(k, acc):
            rows = x[jax.lax.dynamic_index_in_dim(idx, k, 1, False)]
            m = jax.lax.dynamic_index_in_dim(mask, k, 1, False)
            return acc + jnp.where(m[:, None], rows, 0)

        acc0 = jnp.zeros((idx.shape[0], x.shape[1]), x.dtype)
        return jax.lax.fori_loop(0, idx.shape[1], body, acc0)

else:  # pragma: no cover - exercised only without jax
    frontier_expand_ref = frontier_expand_np
