from .ops import prepare_blocks, psw_spmm, psw_spmm_edges
from .psw_spmm import psw_spmm_pallas
from .ref import psw_spmm_ref, spmm_dense_ref
