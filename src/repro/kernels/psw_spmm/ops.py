"""Jit'd public wrapper for the PSW block-sparse SpMM kernel."""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ...graph.padding import bucket_edges_by_block
from ..common import cdiv, round_up
from .psw_spmm import psw_spmm_pallas
from .ref import psw_spmm_ref

__all__ = ["prepare_blocks", "psw_spmm", "psw_spmm_edges"]


def prepare_blocks(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                   block: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """Host-side: bucket an edge list into dense tiles + ensure every dst
    block appears (zero filler tiles) so the kernel initializes all rows.
    Returns (coords sorted by dst block, tiles, n_dst_blocks)."""
    coords, tiles = bucket_edges_by_block(src, dst, n_nodes, block)
    n_blocks = cdiv(n_nodes, block)
    present = np.zeros(n_blocks, bool)
    present[coords[:, 0]] = True
    missing = np.nonzero(~present)[0]
    if missing.size:
        fill_coords = np.stack([missing, np.zeros_like(missing)], 1).astype(np.int32)
        coords = np.concatenate([coords, fill_coords])
        tiles = np.concatenate([tiles, np.zeros((missing.size, block, block),
                                                tiles.dtype)])
    order = np.argsort(coords[:, 0], kind="stable")
    return coords[order], tiles[order], n_blocks


def psw_spmm(coords, tiles, x, n_dst_blocks: int, block: int,
             f_block: int = 128, use_kernel: bool = True, interpret=None):
    """Block-sparse A @ X over PAL tiles. Pads F to the feature block."""
    F = x.shape[-1]
    fb = min(f_block, round_up(F, 128))
    Fp = round_up(F, fb)
    if Fp != F:
        x = jnp.pad(x, ((0, 0), (0, Fp - F)))
    if use_kernel:
        out = psw_spmm_pallas(jnp.asarray(coords), jnp.asarray(tiles), x,
                              n_dst_blocks=n_dst_blocks, block=block,
                              f_block=fb, interpret=interpret)
    else:
        out = psw_spmm_ref(jnp.asarray(coords), jnp.asarray(tiles), x,
                           n_dst_blocks, block)
    return out[:, :F]


def psw_spmm_edges(src, dst, x, n_nodes: int, block: int = 128,
                   use_kernel: bool = True, interpret=None):
    """Convenience: edge list -> tiles -> kernel. Host-side prep; returns
    (n_dst_blocks*block, F) with rows beyond n_nodes zero."""
    coords, tiles, n_blocks = prepare_blocks(np.asarray(src), np.asarray(dst),
                                             n_nodes, block)
    n_src_pad = round_up(n_nodes, block)
    xp = jnp.pad(x, ((0, n_src_pad - x.shape[0]), (0, 0)))
    out = psw_spmm(coords, tiles, xp, n_blocks, block,
                   use_kernel=use_kernel, interpret=interpret)
    return out[:n_nodes]
