"""Pallas TPU kernel: PSW block-sparse SpMM (the PSW inner loop on the MXU).

A PAL edge partition, bucketed into (dst_block × src_block) adjacency tiles
(graph.padding.bucket_edges_by_block), is multiplied against node features.
Only ACTIVE tiles are enumerated — the power-law graph's empty blocks cost
nothing, mirroring the paper's 'only windows that contain edges are read'.

Tiling: grid = (n_feature_blocks, n_active_tiles); the active-tile dimension
iterates fastest so consecutive tiles hitting the same destination block
accumulate in the same VMEM output block (output revisiting). Tile coords
are scalar-prefetched (pltpu.PrefetchScalarGridSpec) so BlockSpec index_maps
can route x/out blocks by tile coordinate — data-dependent addressing
resolved at grid-index time, the TPU analogue of the paper's pointer-array
lookup. Tiles stream HBM→VMEM once each; x/out blocks stay VMEM-resident
across revisits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret

__all__ = ["psw_spmm_pallas"]


def _kernel(coords_ref, tiles_ref, x_ref, o_ref):
    t = pl.program_id(1)

    # zero the output block on its first visit (tiles are dst-sorted, so a
    # change of dst block == first visit)
    prev_dst = coords_ref[jnp.maximum(t, 1) - 1, 0]
    is_first = jnp.logical_or(t == 0, prev_dst != coords_ref[t, 0])

    @pl.when(is_first)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(tiles_ref[0], x_ref[...],
                          preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_dst_blocks", "block",
                                             "f_block", "interpret"))
def psw_spmm_pallas(coords, tiles, x, *, n_dst_blocks: int, block: int,
                    f_block: int = 128, interpret=None):
    """coords: (T, 2) int32 dst/src block ids, sorted by dst; tiles: (T,B,B);
    x: (n_src_blocks*B, F) with F % f_block == 0. Returns (n_dst_blocks*B, F).

    Every dst block must appear in coords at least once (ops.py pads with
    zero tiles) — otherwise its output rows are left uninitialized.
    """
    if interpret is None:
        interpret = default_interpret()
    T, B = tiles.shape[0], tiles.shape[1]
    F = x.shape[-1]
    assert B == block and F % f_block == 0

    grid = (F // f_block, T)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, B, B), lambda f, t, c: (t, 0, 0)),
                pl.BlockSpec((B, f_block), lambda f, t, c: (c[t, 1], f)),
            ],
            out_specs=pl.BlockSpec((B, f_block), lambda f, t, c: (c[t, 0], f)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_dst_blocks * B, F), x.dtype),
        interpret=interpret,
    )(coords, tiles, x)
    return out
