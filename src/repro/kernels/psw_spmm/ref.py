"""Pure-jnp oracle for the PSW block-sparse SpMM."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["psw_spmm_ref", "spmm_dense_ref"]


def psw_spmm_ref(coords, tiles, x, n_dst_blocks: int, block: int):
    """out[db*B:(db+1)*B] += tiles[t] @ x[sb*B:(sb+1)*B] for each active tile.

    coords: (T, 2) int32 (dst_block, src_block); tiles: (T, B, B);
    x: (n_src_blocks*B, F). Returns (n_dst_blocks*B, F).
    """
    B = block
    F = x.shape[-1]
    xb = x.reshape(-1, B, F)
    prods = jnp.einsum("tij,tjf->tif", tiles, xb[coords[:, 1]])
    out = jnp.zeros((n_dst_blocks, B, F), x.dtype)
    out = out.at[coords[:, 0]].add(prods)
    return out.reshape(n_dst_blocks * B, F)


def spmm_dense_ref(src, dst, x, n_dst: int):
    """Edge-list oracle: out[d] = sum_{(s,d) in E} x[s]."""
    msgs = x[src]
    out = jnp.zeros((n_dst, x.shape[-1]), x.dtype)
    return out.at[dst].add(msgs)
