from .ops import segment_ell, segment_ell_from_edges
from .ref import segment_ell_ref
from .segment_ell import segment_ell_pallas
