"""Jit'd wrapper for the ELL gather-reduce kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...graph.padding import pad_to_ell
from ..common import round_up
from .ref import segment_ell_ref
from .segment_ell import segment_ell_pallas

__all__ = ["segment_ell", "segment_ell_from_edges"]


def segment_ell(idx, mask, x, use_kernel: bool = True, interpret=None):
    """Padding-tolerant entry: pads N to 128 rows and F to 128 cols."""
    N, K = idx.shape
    F = x.shape[-1]
    Np, Fp = round_up(N, 128), round_up(F, 128)
    idx_p = jnp.pad(idx, ((0, Np - N), (0, 0)))
    mask_p = jnp.pad(mask, ((0, Np - N), (0, 0)))
    x_p = jnp.pad(x, ((0, 0), (0, Fp - F)))
    if use_kernel:
        out = segment_ell_pallas(idx_p, mask_p, x_p, interpret=interpret)
    else:
        out = segment_ell_ref(idx_p, mask_p, x_p)
    return out[:N, :F]


def segment_ell_from_edges(src, dst, x, n_nodes: int, max_degree: int,
                           use_kernel: bool = True, interpret=None):
    idx, mask = pad_to_ell(np.asarray(src), np.asarray(dst), n_nodes, max_degree)
    return segment_ell(jnp.asarray(idx), jnp.asarray(mask), x,
                       use_kernel=use_kernel, interpret=interpret)
