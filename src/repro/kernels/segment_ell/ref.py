"""Pure-jnp oracle for the ELL gather-reduce kernel."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["segment_ell_ref"]


def segment_ell_ref(idx, mask, x):
    """idx: (N, K) int32 source rows; mask: (N, K) valid; x: (M, F).
    out[n] = sum_k mask[n,k] * x[idx[n,k]]."""
    gathered = x[idx]                       # (N, K, F)
    return (gathered * mask[..., None].astype(x.dtype)).sum(axis=1)
