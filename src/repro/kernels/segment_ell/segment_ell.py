"""Pallas TPU kernel: ELL-padded neighbor gather-reduce.

The PAL layout bounds per-vertex in-degree by |E|/P (paper §4.1 constraint),
so a destination-node block's neighbor lists pad to a fixed K — the ELL
format. The kernel streams (node_block × K) index tiles and accumulates
masked gathered rows.

Tiling: grid = (n_node_blocks, n_feat_blocks). Per step: idx/mask tiles
(Bn, K) live in VMEM; the source-feature matrix stays in ANY/HBM memory
space and rows are fetched with dynamic loads (on real TPU this lowers to
row DMAs; PAL's window locality keeps the working set in a contiguous
region — see DESIGN.md §2). Accumulation is an unrolled K-loop of masked
row loads on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret

__all__ = ["segment_ell_pallas"]


def _kernel(idx_ref, mask_ref, x_ref, o_ref, *, k_neighbors: int):
    bn, fb = o_ref.shape
    f0 = pl.program_id(1) * fb

    def row_body(i, acc):
        # one row DMA per (node, neighbor) slot; masked slots add zero
        def slot_body(k, acc):
            r = idx_ref[i, k]
            v = mask_ref[i, k]
            row = pl.load(x_ref, (pl.dslice(r, 1), pl.dslice(f0, fb)))
            contrib = jnp.where(v, row[0], jnp.zeros((fb,), o_ref.dtype))
            return acc.at[i].add(contrib)

        return jax.lax.fori_loop(0, k_neighbors, slot_body, acc)

    acc0 = jnp.zeros(o_ref.shape, o_ref.dtype)
    o_ref[...] = jax.lax.fori_loop(0, bn, row_body, acc0)


@functools.partial(jax.jit, static_argnames=("n_block", "f_block", "interpret"))
def segment_ell_pallas(idx, mask, x, *, n_block: int = 128,
                       f_block: int = 128, interpret=None):
    """idx/mask: (N, K); x: (M, F). N % n_block == 0, F % f_block == 0.
    Returns (N, F) masked neighbor sums."""
    if interpret is None:
        interpret = default_interpret()
    N, K = idx.shape
    M, F = x.shape
    assert N % n_block == 0 and F % f_block == 0

    grid = (N // n_block, F // f_block)
    return pl.pallas_call(
        functools.partial(_kernel, k_neighbors=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_block, K), lambda n, f: (n, 0)),
            pl.BlockSpec((n_block, K), lambda n, f: (n, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),     # x stays in HBM
        ],
        out_specs=pl.BlockSpec((n_block, f_block), lambda n, f: (n, f)),
        out_shape=jax.ShapeDtypeStruct((N, F), x.dtype),
        interpret=interpret,
    )(idx, mask, x)
