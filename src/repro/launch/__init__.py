from .mesh import TPU_V5E, make_production_mesh
