import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and record roofline inputs.

The two lines above MUST run before any jax import (jax locks the device
count at first init); do not move them. 512 placeholder host devices back
both the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh.

Usage:
  python -m repro.launch.dryrun --arch granite-34b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both --workers 3   # orchestrator
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from collections import Counter
from concurrent.futures import ThreadPoolExecutor


DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/#_\.]*\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(\([^)]*\))?.*{\s*$")
WHILE_RE = re.compile(r"while\(.*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shapes_txt: str) -> int:
    nbytes = 0
    for dt, dims in SHAPE_RE.findall(shapes_txt):
        if dt not in DTYPE_BYTES:
            continue
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        nbytes += numel * DTYPE_BYTES[dt]
    return nbytes


def _split_computations(hlo_text: str):
    """computation name -> list of body lines (coarse HLO text parser)."""
    comps = {}
    entry = None
    cur, cur_lines = None, []
    for line in hlo_text.splitlines():
        if cur is None:
            m = COMP_START_RE.match(line)
            if m and ("->" in line or m.group(1)):
                cur = m.group(2)
                if m.group(1):  # ENTRY
                    entry = cur
                cur_lines = []
        else:
            if line.startswith("}"):
                comps[cur] = cur_lines
                cur = None
            else:
                cur_lines.append(line)
    return comps, entry


def parse_collective_bytes(hlo_text: str):
    """Collective bytes with while-loop trip-count multiplication.

    XLA's cost/collective accounting counts a while body ONCE; our train
    steps scan over layers and microbatches, so collectives inside scan
    bodies execute trip_count times. We walk the computation tree from
    ENTRY, multiply body contributions by the trip count (largest integer
    constant in the loop condition — exact for lax.scan's counter), and
    sum per kind. Returns (total, bytes-by-kind, op-counts, n_whiles)."""
    comps, entry = _split_computations(hlo_text)
    by_kind_bytes = Counter()
    by_kind_count = Counter()
    n_whiles = [0]

    def walk(comp_name: str, multiplier: float):
        lines = comps.get(comp_name, [])
        for line in lines:
            wm = WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = 1
                consts = [int(c) for ln in comps.get(cond, [])
                          for c in CONST_RE.findall(ln)]
                if consts:
                    trip = max(consts)
                n_whiles[0] += 1
                walk(body, multiplier * trip)
                continue
            m = COLLECTIVE_RE.search(line)
            if not m or "-done(" in line:
                continue
            shapes_txt, kind = m.group(1), m.group(2).lower()
            nb = _shape_bytes(shapes_txt)
            by_kind_bytes[kind] += int(nb * multiplier)
            by_kind_count[kind] += int(multiplier)

    if entry is not None:
        walk(entry, 1.0)
    else:  # fallback: flat scan, no multipliers
        for line in hlo_text.splitlines():
            m = COLLECTIVE_RE.search(line)
            if not m or "-done(" in line:
                continue
            by_kind_bytes[m.group(2).lower()] += _shape_bytes(m.group(1))
            by_kind_count[m.group(2).lower()] += 1
    total = sum(by_kind_bytes.values())
    return total, dict(by_kind_bytes), dict(by_kind_count), n_whiles[0]


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str) -> dict:
    import jax
    from ..configs import get_arch
    from ..sharding import DEFAULT_RULES, ShardingRules, use_rules
    from .mesh import make_production_mesh
    from .steps import build_cell

    t0 = time.time()
    spec = get_arch(arch)
    cell = spec.shapes[shape]
    result = {"arch": arch, "shape": shape, "mesh": mesh_kind,
              "kind": cell.kind, "dims": cell.dims}
    if cell.skip:
        result["status"] = "skipped"
        result["skip_reason"] = cell.skip
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_shards = mesh.devices.size
    rules = ShardingRules(rules=dict(DEFAULT_RULES), mesh=mesh)
    plan = build_cell(spec, shape, rules, n_shards)

    with mesh, use_rules(plan.rules):
        jitted = (jax.jit(plan.fn, out_shardings=plan.out_shardings)
                  if plan.out_shardings is not None else jax.jit(plan.fn))
        lowered = jitted.lower(*plan.args_sds)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll_bytes, coll_by_kind, coll_counts, n_whiles = parse_collective_bytes(hlo)

    result.update({
        "status": "ok",
        "n_devices": int(n_shards),
        "lower_s": round(t_lower - t0, 1),
        "compile_s": round(t_compile - t_lower, 1),
        "meta": plan.meta,
        # per-device numbers (cost/memory analysis run post-SPMD)
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
        "collective_bytes_per_device": int(coll_bytes),
        "collective_bytes_by_kind": coll_by_kind,
        "collective_op_counts": coll_counts,
        "n_while_loops": n_whiles,
        "hlo_size_chars": len(hlo),
    })
    return result


ALL_SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
                   "full_graph_sm", "minibatch_lg", "ogb_products", "molecule",
                   "train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]


def orchestrate(mesh_kinds, out_dir: str, workers: int, only_missing: bool,
                timeout: int):
    """Run each cell in its own subprocess (isolation: one bad compile can't
    take down the sweep; parallelism across CPU cores)."""
    from ..configs import ARCH_IDS, get_arch
    os.makedirs(out_dir, exist_ok=True)
    jobs = []
    for arch in ARCH_IDS:
        spec = get_arch(arch)
        for shape in spec.shapes:
            for mk in mesh_kinds:
                fname = f"{arch}__{shape}__{mk}.json".replace("/", "_")
                fpath = os.path.join(out_dir, fname)
                if only_missing and os.path.exists(fpath):
                    with open(fpath) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            continue
                jobs.append((arch, shape, mk, fpath))

    def run_one(job):
        arch, shape, mk, fpath = job
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mk, "--out", out_dir]
        t0 = time.time()
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=timeout,
                                  env={**os.environ,
                                       "PYTHONPATH": os.environ.get("PYTHONPATH", "src")})
            ok = proc.returncode == 0
            if not ok:
                with open(fpath, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mk,
                               "status": "error",
                               "stderr": proc.stderr[-4000:]}, f, indent=1)
        except subprocess.TimeoutExpired:
            with open(fpath, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mk,
                           "status": "timeout", "timeout_s": timeout}, f,
                          indent=1)
            ok = False
        print(f"[{'OK' if ok else 'FAIL'}] {arch} × {shape} × {mk} "
              f"({time.time() - t0:.0f}s)", flush=True)
        return ok

    with ThreadPoolExecutor(max_workers=workers) as ex:
        results = list(ex.map(run_one, jobs))
    print(f"done: {sum(results)}/{len(results)} ok")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        orchestrate(mesh_kinds, args.out, args.workers,
                    only_missing=not args.force, timeout=args.timeout)
        return

    os.makedirs(args.out, exist_ok=True)
    for mk in mesh_kinds:
        fname = f"{args.arch}__{args.shape}__{mk}.json".replace("/", "_")
        fpath = os.path.join(args.out, fname)
        try:
            result = run_cell(args.arch, args.shape, mk, args.out)
        except Exception:
            result = {"arch": args.arch, "shape": args.shape, "mesh": mk,
                      "status": "error", "traceback": traceback.format_exc()}
        with open(fpath, "w") as f:
            json.dump(result, f, indent=1)
        print(json.dumps({k: v for k, v in result.items()
                          if k not in ("traceback",)}, indent=1))
        if result["status"] == "error":
            print(result["traceback"], file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
