"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

from ..jax_compat import mesh_axis_types

__all__ = ["make_production_mesh", "TPU_V5E"]

# TPU v5e hardware constants (per chip) for the roofline model
TPU_V5E = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bytes_per_s": 819e9,    # HBM bandwidth
    "ici_bytes_per_s": 50e9,     # per ICI link
    "hbm_bytes": 16e9,
    "vmem_bytes": 128 * 2**20,
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_types(len(axes)))
