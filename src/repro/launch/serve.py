"""Batched decode server: prefill + decode loop with a continuous-batching
request queue (smoke-scale on CPU; the dry-run exercises production shapes).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
      --requests 8 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..models import transformer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke_config if args.smoke else spec.config
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    max_seq = args.prompt_len + args.gen

    @jax.jit
    def prefill(params, toks):
        return transformer.prefill(params, toks, cfg, max_seq=max_seq)

    @jax.jit
    def decode(params, cache, toks, pos):
        return transformer.decode_step(params, cache, toks, pos, cfg)

    rng = np.random.default_rng(0)
    pending = [rng.integers(1, cfg.vocab_size, args.prompt_len)
               for _ in range(args.requests)]
    done = 0
    lat = []
    while pending:
        batch = pending[: args.batch]
        pending = pending[args.batch:]
        toks = jnp.asarray(np.stack(batch), jnp.int32)
        t0 = time.time()
        logits, cache = prefill(params, toks)
        out = [jnp.argmax(logits, -1)]
        pos = jnp.int32(args.prompt_len)
        for _ in range(args.gen - 1):
            logits, cache = decode(params, cache, out[-1][:, None], pos)
            out.append(jnp.argmax(logits, -1))
            pos = pos + 1
        jax.block_until_ready(out[-1])
        dt = time.time() - t0
        lat.append(dt)
        done += len(batch)
        tokens = len(batch) * args.gen
        print(f"batch of {len(batch)}: {dt*1e3:.0f}ms "
              f"({tokens/dt:.1f} tok/s); total served {done}")
    print(f"served {done} requests; median batch latency "
          f"{np.median(lat)*1e3:.0f}ms")


if __name__ == "__main__":
    main()
