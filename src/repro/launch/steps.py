"""Per-family step builders: (arch × shape) cell → jit-able function +
ShapeDtypeStruct inputs + shardings.

This is the glue the dry-run, the trainer, and the server all share. Every
cell lowers a COMPLETE step: train cells include loss, backward, and the
AdamW update; serve cells include the full request path (e.g. chunked
top-k over the PAL-sharded item table, not just logits).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchSpec, ShapeCell
from ..models import bert4rec, transformer
from ..models.gnn import equiformer_v2, gin, meshgraphnet, pna
from ..optim import AdamWConfig, adamw_init, adamw_update
from ..sharding import ShardingRules

__all__ = ["CellPlan", "build_cell"]


@dataclasses.dataclass
class CellPlan:
    fn: Callable
    args_sds: Tuple[Any, ...]
    out_shardings: Any
    rules: ShardingRules
    meta: Dict[str, Any]


def _sh(rules: ShardingRules, *axes):
    return rules.sharding(*axes)


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _tree_sds(shape_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), shape_tree, sharding_tree)


def _param_shardings(axes_tree, rules: ShardingRules):
    return jax.tree.map(lambda ax: rules.sharding(*ax), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))


def _opt_shardings(param_sh):
    return {"m": param_sh, "v": param_sh, "step": None}


def _round_to(n: int, k: int) -> int:
    return -(-n // k) * k


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _lm_cell(spec: ArchSpec, cell: ShapeCell, rules: ShardingRules) -> CellPlan:
    cfg = spec.config
    B, S = cell.dims["batch"], cell.dims["seq"]
    if cell.kind in ("prefill", "decode"):
        # §Perf H3: inference has no optimizer state — replicate params over
        # the data axis (TP-only sharding) so serving never re-gathers them
        rules = ShardingRules(rules={**rules.rules, "fsdp": None},
                              mesh=rules.mesh)
    axes = transformer.param_logical_axes(cfg)
    param_sh = _param_shardings(axes, rules)
    params_shape = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    params_sds = _tree_sds(params_shape, param_sh)
    batch_sh = _sh(rules, "batch", None)

    if cell.kind == "train":
        opt_cfg = AdamWConfig()
        opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
        opt_sh = _opt_shardings(param_sh)
        opt_sds = _tree_sds(opt_shape, opt_sh)

        # gradient accumulation: pick microbatch count so per-device live
        # activations (L × d_model × 2B bf16 residual per token, scan+remat)
        # stay under ~5 GB, while the microbatch still spans every DP shard.
        mesh = rules.mesh
        dp = 1
        if mesh is not None:
            for ax in ("pod", "data"):
                if ax in mesh.axis_names:
                    dp *= mesh.shape[ax]
        tokens_per_dev = B * S // dp
        act_bytes = tokens_per_dev * cfg.n_layers * cfg.d_model * 2
        # MoE dispatch buffers scale with the microbatch too — halve the
        # activation budget for MoE configs
        budget = 2_500_000_000 if cfg.moe is not None else 5_000_000_000
        need = max(1, -(-act_bytes // budget))
        accum = 1
        while accum < need and (B // (accum * 2)) >= dp:
            accum *= 2

        def train_step(params, opt, batch):
            mb = jax.tree.map(
                lambda x: x.reshape(accum, B // accum, *x.shape[1:]), batch)

            def cast_and_loss(params, microbatch):
                # §Perf H1: cast params to bf16 while still SHARDED, so the
                # per-microbatch FSDP all-gathers move half the bytes; the
                # cast is differentiable (grads return in fp32)
                pc = jax.tree.map(
                    lambda p: p.astype(cfg.compute_dtype) if p.ndim >= 2
                    else p, params)
                return transformer.loss_fn(pc, microbatch, cfg)

            def micro(carry, microbatch):
                loss_sum, grads = carry
                l, g = jax.value_and_grad(cast_and_loss)(params, microbatch)
                grads = jax.tree.map(jnp.add, grads, g)
                return (loss_sum + l, grads), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (loss, grads), _ = jax.lax.scan(jax.checkpoint(micro),
                                            (0.0, zeros), mb)
            grads = jax.tree.map(lambda g: g / accum, grads)
            params, opt, metrics = adamw_update(grads, opt, params, opt_cfg)
            return params, opt, {"loss": loss / accum, **metrics}

        batch_sds = {
            "tokens": _sds((B, S), jnp.int32, batch_sh),
            "labels": _sds((B, S), jnp.int32, batch_sh),
        }
        return CellPlan(train_step, (params_sds, opt_sds, batch_sds),
                        (param_sh, _opt_shardings(param_sh), None), rules,
                        {"tokens_per_step": B * S, "grad_accum": accum})

    if cell.kind == "prefill":
        cache_sh = _sh(rules, None, "batch", "model", None, None)

        def prefill_step(params, tokens):
            return transformer.prefill(params, tokens, cfg, max_seq=S)

        tokens_sds = _sds((B, S), jnp.int32, batch_sh)
        out_sh = (None, {"k": cache_sh, "v": cache_sh})
        return CellPlan(prefill_step, (params_sds, tokens_sds), out_sh, rules,
                        {"tokens_per_step": B * S})

    if cell.kind == "decode":
        cache_sh = _sh(rules, None, "batch", "model", None, None)
        cache_shape = jax.eval_shape(
            lambda: transformer.init_cache(cfg, B, S))
        cache_sds = jax.tree.map(
            lambda s: _sds(s.shape, s.dtype, cache_sh), cache_shape)

        def decode(params, cache, tokens, pos):
            return transformer.decode_step(params, cache, tokens, pos, cfg)

        tokens_sds = _sds((B, 1), jnp.int32, batch_sh)
        pos_sds = _sds((), jnp.int32)
        out_sh = (None, {"k": cache_sh, "v": cache_sh})
        return CellPlan(decode, (params_sds, cache_sds, tokens_sds, pos_sds),
                        out_sh, rules, {"tokens_per_step": B})

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------
_GNN_MODULES = {
    "pna": pna, "gin-tu": gin, "equiformer-v2": equiformer_v2,
    "meshgraphnet": meshgraphnet,
}


def _adapt_gnn_config(arch: str, base, dims) -> Any:
    d_feat, n_cls = dims["d_feat"], dims["n_classes"]
    graph_level = dims["task"] == "graph_reg"
    E = dims["n_edges"]
    chunks = 16 if E >= 10_000_000 else (4 if E >= 1_000_000 else 1)
    if arch == "pna":
        return dataclasses.replace(base, d_in=d_feat, n_classes=n_cls,
                                   readout="graph" if graph_level else "node",
                                   edge_chunks=chunks)
    if arch == "gin-tu":
        return dataclasses.replace(base, d_in=d_feat, n_classes=n_cls,
                                   readout="graph" if graph_level else "node",
                                   edge_chunks=chunks)
    if arch == "meshgraphnet":
        return dataclasses.replace(base, d_node_in=d_feat, d_edge_in=4,
                                   d_out=n_cls, edge_chunks=chunks,
                                   remat_blocks=chunks > 1)
    if arch == "equiformer-v2":
        # huge partitions: PSW ring gather + per-layer remat (DESIGN.md §2);
        # remat is ALWAYS on — 12 unrematted layers of per-edge irreps state
        # exceed HBM even on small graphs
        echunks, mode = 1, "take"
        if E >= 10_000_000:
            echunks, mode = 16, "psw_ring"
        elif E >= 100_000:
            echunks, mode = 4, "psw_ring"
        return dataclasses.replace(base, d_out=n_cls, n_species=128,
                                   edge_chunks=echunks, gather_mode=mode,
                                   remat_layers=True)
    raise ValueError(arch)


def _gnn_batch_sds(arch: str, cfg, dims, rules: ShardingRules, shards: int):
    """ShapeDtypeStructs for one (possibly padded/sharded) graph batch."""
    batched = "batch" in dims
    N, E = dims["n_nodes"], dims["n_edges"]
    big = (not batched) and N >= max(shards, 4096)
    node_sh = _sh(rules, "nodes", None) if big else None
    node_sh1 = _sh(rules, "nodes") if big else None
    edge_sh = _sh(rules, "edges") if big else None
    edge_sh2 = _sh(rules, "edges", None) if big else None
    if big:
        # node padding: divisible by the shard count; edge padding: by
        # shards × max chunking (so per-chunk slices stay shardable)
        N = _round_to(N, 512)
        E = _round_to(E, 512 * 16)
    lead = ()
    b_sh = lambda *ax: None
    if batched:
        Bt = dims["batch"]
        lead = (Bt,)
        b_sh = lambda *ax: _sh(rules, "batch", *ax)
        node_sh = b_sh(None, None)
        node_sh1 = b_sh(None)
        edge_sh = b_sh(None)
        edge_sh2 = b_sh(None, None)

    batch = {
        "src": _sds((*lead, E), jnp.int32, edge_sh),
        "dst": _sds((*lead, E), jnp.int32, edge_sh),
        "edge_mask": _sds((*lead, E), jnp.bool_, edge_sh),
        "node_mask": _sds((*lead, N), jnp.bool_, node_sh1),
    }
    if arch == "equiformer-v2":
        batch["species"] = _sds((*lead, N), jnp.int32, node_sh1)
        batch["pos"] = _sds((*lead, N, 3), jnp.float32, node_sh)
    else:
        batch["x"] = _sds((*lead, N, dims["d_feat"]), jnp.float32, node_sh)
    if arch == "meshgraphnet":
        batch["edge_attr"] = _sds((*lead, E, 4), jnp.float32, edge_sh2)
    if dims["task"] == "graph_reg":
        batch["labels"] = _sds((dims["batch"],), jnp.float32, b_sh())
    else:
        batch["labels"] = _sds((*lead, N), jnp.int32, node_sh1)
    return batch, N, E


def _gnn_loss(module, cfg, dims):
    graph_level = dims["task"] == "graph_reg"
    batched = "batch" in dims

    def forward_one(params, b):
        return module.forward(params, b, cfg)

    def loss_fn(params, batch):
        if batched:
            labels = batch.pop("labels")
            out = jax.vmap(lambda b: forward_one(params, b))(batch)
            batch["labels"] = labels
            if graph_level:
                pred = out.reshape(out.shape[0], -1)[:, 0]  # (B,)
                return jnp.mean((pred - labels) ** 2)
            raise ValueError("batched node task unsupported")
        out = forward_one(params, batch)                    # (N, n_cls)
        labels = batch["labels"]
        mask = batch["node_mask"]
        logits = out.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        ce = (logz - gold) * mask
        return ce.sum() / jnp.maximum(mask.sum(), 1)

    return loss_fn


def _gnn_cell(spec: ArchSpec, cell: ShapeCell, rules: ShardingRules,
              shards: int) -> CellPlan:
    module = _GNN_MODULES[spec.name]
    cfg = _adapt_gnn_config(spec.name, spec.config, cell.dims)
    batched = "batch" in cell.dims
    big = (not batched) and cell.dims["n_nodes"] >= max(shards, 4096)
    if not big:
        # small/batched graphs: replicate graph arrays — null the node/edge
        # logical axes so in-model constraints don't force 512-way sharding
        rules = ShardingRules(rules={**rules.rules, "nodes": None,
                                     "edges": None}, mesh=rules.mesh)
    batch_sds, N, E = _gnn_batch_sds(spec.name, cfg, cell.dims, rules, shards)

    params_shape = jax.eval_shape(
        lambda: module.init_params(jax.random.PRNGKey(0), cfg))
    # GNN params are small: replicate
    params_sds = jax.tree.map(lambda s: _sds(s.shape, s.dtype), params_shape)
    opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
    opt_sds = jax.tree.map(lambda s: _sds(s.shape, s.dtype), opt_shape)

    loss_fn = _gnn_loss(module, cfg, cell.dims)
    opt_cfg = AdamWConfig()

    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt, metrics = adamw_update(grads, opt, params, opt_cfg)
        return params, opt, {"loss": loss, **metrics}

    return CellPlan(train_step, (params_sds, opt_sds, batch_sds),
                    None, rules, {"n_nodes": N, "n_edges": E,
                                  "edges_per_step": E})


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------
def _recsys_cell(spec: ArchSpec, cell: ShapeCell,
                 rules: ShardingRules) -> CellPlan:
    cfg = spec.config
    axes = bert4rec.param_logical_axes(cfg)
    param_sh = _param_shardings(axes, rules)
    params_shape = jax.eval_shape(
        lambda: bert4rec.init_params(jax.random.PRNGKey(0), cfg))
    params_sds = _tree_sds(params_shape, param_sh)
    B = cell.dims["batch"]
    batch_sh = _sh(rules, "batch", None) if B > 1 else None

    if cell.kind == "train":
        opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
        opt_sh = _opt_shardings(param_sh)
        opt_sds = _tree_sds(opt_shape, opt_sh)
        opt_cfg = AdamWConfig()
        n_masked = 40                       # ~20% of seq_len=200
        accum = 8 if B >= 16384 else 1

        def train_step(params, opt, batch):
            mb = jax.tree.map(
                lambda x: x.reshape(accum, B // accum, *x.shape[1:]), batch)

            def micro(carry, microbatch):
                loss_sum, grads = carry
                l, g = jax.value_and_grad(
                    functools.partial(bert4rec.masked_lm_loss,
                                      vocab_chunk=8192))(
                    params, microbatch, cfg)
                return (loss_sum + l, jax.tree.map(jnp.add, grads, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (loss, grads), _ = jax.lax.scan(jax.checkpoint(micro),
                                            (0.0, zeros), mb)
            grads = jax.tree.map(lambda g: g / accum, grads)
            params, opt, metrics = adamw_update(grads, opt, params, opt_cfg)
            return params, opt, {"loss": loss / accum, **metrics}

        batch_sds = {
            "item_seq": _sds((B, cfg.seq_len), jnp.int32, batch_sh),
            "masked_positions": _sds((B, n_masked), jnp.int32, batch_sh),
            "labels": _sds((B, n_masked), jnp.int32, batch_sh),
        }
        return CellPlan(train_step, (params_sds, opt_sds, batch_sds),
                        (param_sh, _opt_shardings(param_sh), None), rules,
                        {"sequences_per_step": B, "grad_accum": accum})

    if cell.kind == "serve":
        top_k = 100
        chunk = 65536
        req_chunk = 16384  # bulk requests stream through in chunks

        def _serve_chunk(params, item_seq):
            reps = bert4rec.encode(params, item_seq, cfg)
            last = reps[:, -1]                               # (B, d)
            vpad = _round_to(cfg.padded_vocab, chunk)
            n_chunks = vpad // chunk
            table = jnp.pad(params["item_embed"],
                            ((0, vpad - cfg.padded_vocab), (0, 0)))
            bias_all = jnp.pad(params["out_bias"],
                               (0, vpad - cfg.padded_vocab))

            def body(carry, ci):
                best_v, best_i = carry
                start = ci * chunk
                emb = jax.lax.dynamic_slice_in_dim(
                    table, start, chunk, 0).astype(last.dtype)
                bias = jax.lax.dynamic_slice_in_dim(
                    bias_all, start, chunk, 0).astype(last.dtype)
                s = last @ emb.T + bias[None, :]
                ids = start + jnp.arange(chunk)
                s = jnp.where(ids[None, :] < cfg.vocab, s, -jnp.inf)
                cat_v = jnp.concatenate([best_v, s], axis=1)
                cat_i = jnp.concatenate(
                    [best_i, jnp.broadcast_to(ids, s.shape)], axis=1)
                v, sel = jax.lax.top_k(cat_v, top_k)
                return (v, jnp.take_along_axis(cat_i, sel, axis=1)), None

            init = (jnp.full((last.shape[0], top_k), -jnp.inf, last.dtype),
                    jnp.zeros((last.shape[0], top_k), jnp.int32))
            (v, i), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
            return v, i

        def serve_step(params, item_seq):
            """Full-catalog top-k; bulk batches stream through in request
            chunks (offline scoring is embarrassingly parallel over users)."""
            Bn = item_seq.shape[0]
            if Bn <= req_chunk:
                return _serve_chunk(params, item_seq)
            nrc = Bn // req_chunk
            seqs = item_seq.reshape(nrc, req_chunk, -1)
            v, i = jax.lax.map(lambda s: _serve_chunk(params, s), seqs)
            return v.reshape(Bn, -1), i.reshape(Bn, -1)

        seq_sds = _sds((B, cfg.seq_len), jnp.int32, batch_sh)
        return CellPlan(serve_step, (params_sds, seq_sds), None, rules,
                        {"requests_per_step": B})

    if cell.kind == "retrieval":
        n_cand = cell.dims["n_candidates"]
        cand_sh = _sh(rules, "table")

        def retrieval_step(params, item_seq, candidates):
            scores = bert4rec.score_candidates(params, item_seq, candidates,
                                               cfg)
            return jax.lax.top_k(scores, 100)

        seq_sds = _sds((B, cfg.seq_len), jnp.int32)
        cand_sds = _sds((n_cand,), jnp.int32, cand_sh)
        return CellPlan(retrieval_step, (params_sds, seq_sds, cand_sds),
                        None, rules, {"candidates_per_step": n_cand})

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
def build_cell(spec: ArchSpec, shape_name: str, rules: ShardingRules,
               shards: int) -> CellPlan:
    cell = spec.shapes[shape_name]
    if cell.skip:
        raise ValueError(f"cell {spec.name}×{shape_name} is skipped: {cell.skip}")
    if spec.family == "lm":
        return _lm_cell(spec, cell, rules)
    if spec.family == "gnn":
        return _gnn_cell(spec, cell, rules, shards)
    if spec.family == "recsys":
        return _recsys_cell(spec, cell, rules)
    raise ValueError(spec.family)
