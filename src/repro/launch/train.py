"""End-to-end trainer: any --arch, checkpoint/restart fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --smoke --steps 200 --ckpt-dir /tmp/ckpt [--resume]

--smoke trains the arch's reduced config on CPU (the ~100M-class end-to-end
driver); without it the full config is used (real accelerators). The loop:
deterministic restart-safe data (TokenStream.batch_at(step)), async
checkpoints every --ckpt-every steps, auto-resume from the newest manifest,
straggler/step-time logging.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_arch
from ..data import TokenStream, TokenStreamConfig
from ..models import transformer
from ..optim import AdamWConfig, adamw_init, adamw_update, linear_warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    assert spec.family == "lm", "train.py drives LM archs; see examples/ for others"
    cfg = spec.smoke_config if args.smoke else spec.config

    stream = TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq))
    opt_cfg = AdamWConfig(lr=args.lr)
    sched = linear_warmup_cosine(min(20, args.steps // 10 + 1), args.steps)

    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    opt = adamw_init(params)
    start_step = 0

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    if args.resume and mgr.latest_step() is not None:
        restored, start_step = mgr.restore({"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from step {start_step}")

    @jax.jit
    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(transformer.loss_fn)(
            params, batch, cfg)
        params, opt, metrics = adamw_update(grads, opt, params, opt_cfg,
                                            schedule=sched)
        return params, opt, loss, metrics

    step_times = []
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = jax.tree.map(jnp.asarray, stream.batch_at(step))
        params, opt, loss, metrics = train_step(params, opt, batch)
        dt = time.time() - t0
        step_times.append(dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            med = float(np.median(step_times[-50:]))
            straggle = dt / max(med, 1e-9)
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"dt {dt*1e3:.0f}ms (x{straggle:.1f} of median)")
        if (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt}, blocking=False)
    mgr.save(args.steps, {"params": params, "opt": opt})
    mgr.wait()
    print(f"done; final loss {float(loss):.4f}; "
          f"median step {np.median(step_times)*1e3:.0f}ms")


if __name__ == "__main__":
    main()
