from . import bert4rec, transformer
from .gnn import equiformer_v2, gin, meshgraphnet, pna
