"""BERT4Rec (Sun et al., arXiv:1904.06690): bidirectional transformer over
item interaction sequences, masked-item training, with the item-embedding
table PAL-sharded (reversible-hash row partitioning over the `table`/model
mesh axis — the paper's §7.2 technique applied to a recsys table; see
DESIGN.md §4) and EmbeddingBag-style pooled lookups for bulk scoring.

Config (assigned): embed_dim=64, n_blocks=2, n_heads=2, seq_len=200.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding import constrain

__all__ = ["Bert4RecConfig", "init_params", "encode", "masked_lm_loss",
           "score_all_items", "score_candidates", "param_logical_axes"]

MASK_OFFSET = 1  # item ids are 1..n_items; 0 = padding; n_items+1 = [MASK]


@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    d_ff: Optional[int] = None          # default 4*d
    dropout: float = 0.0                # kept for config parity (eval mode)
    compute_dtype: object = jnp.float32

    @property
    def vocab(self) -> int:
        return self.n_items + 2          # + padding + [MASK]

    @property
    def padded_vocab(self) -> int:
        """Table rows rounded up so PAL row-sharding divides evenly over the
        model axis (padded rows are masked out of scores/losses)."""
        return -(-self.vocab // 256) * 256

    @property
    def ff(self) -> int:
        return self.d_ff or 4 * self.embed_dim


def init_params(key, cfg: Bert4RecConfig):
    d, h = cfg.embed_dim, cfg.n_heads
    keys = jax.random.split(key, cfg.n_blocks + 3)
    blocks = []
    for i in range(cfg.n_blocks):
        k = jax.random.split(keys[i], 6)
        blocks.append({
            "wq": jax.random.normal(k[0], (d, d)) * d ** -0.5,
            "wk": jax.random.normal(k[1], (d, d)) * d ** -0.5,
            "wv": jax.random.normal(k[2], (d, d)) * d ** -0.5,
            "wo": jax.random.normal(k[3], (d, d)) * d ** -0.5,
            "w1": jax.random.normal(k[4], (d, cfg.ff)) * d ** -0.5,
            "w2": jax.random.normal(k[5], (cfg.ff, d)) * cfg.ff ** -0.5,
            "ln1": jnp.ones((d,)), "ln2": jnp.ones((d,)),
            "b1": jnp.zeros((cfg.ff,)), "b2": jnp.zeros((d,)),
        })
    return {
        "item_embed": jax.random.normal(keys[-3], (cfg.padded_vocab, d)) * 0.02,
        "pos_embed": jax.random.normal(keys[-2], (cfg.seq_len, d)) * 0.02,
        "blocks": blocks,
        "out_bias": jnp.zeros((cfg.padded_vocab,)),
        "final_ln": jnp.ones((d,)),
    }


def param_logical_axes(cfg: Bert4RecConfig):
    blk = {
        "wq": ("fsdp", "model"), "wk": ("fsdp", "model"), "wv": ("fsdp", "model"),
        "wo": ("model", "fsdp"), "w1": ("fsdp", "model"), "w2": ("model", "fsdp"),
        "ln1": (None,), "ln2": (None,), "b1": ("model",), "b2": (None,),
    }
    return {
        "item_embed": ("table", None),   # PAL-hashed row sharding
        "pos_embed": (None, None),
        "blocks": [dict(blk) for _ in range(cfg.n_blocks)],
        "out_bias": ("table",),
        "final_ln": (None,),
    }


def _ln(x, scale, eps=1e-6):
    m = x.mean(-1, keepdims=True)
    v = jnp.var(x, axis=-1, keepdims=True)
    return (x - m) * lax.rsqrt(v + eps) * scale


def encode(params, item_seq, cfg: Bert4RecConfig):
    """item_seq: (B, S) int32 (0 = pad). Returns (B, S, d) representations.
    Bidirectional attention with padding mask (encoder-only; no causal mask,
    no decode step — see DESIGN.md §4)."""
    B, S = item_seq.shape
    d, H = cfg.embed_dim, cfg.n_heads
    dh = d // H
    cdt = cfg.compute_dtype
    pad = item_seq == 0

    # replicate the (row-sharded) table for the lookup — 10⁶×64 is ~256 MB,
    # vs SPMD's fallback of replicating the (B, S, d) gather OUTPUT
    table = constrain(params["item_embed"], None, None)
    x = jnp.take(table, item_seq, axis=0).astype(cdt)
    x = x + params["pos_embed"][None, :S].astype(cdt)
    x = constrain(x, "batch", None, None)

    for blk in params["blocks"]:
        h = _ln(x, blk["ln1"].astype(cdt))
        q = (h @ blk["wq"].astype(cdt)).reshape(B, S, H, dh)
        k = (h @ blk["wk"].astype(cdt)).reshape(B, S, H, dh)
        v = (h @ blk["wv"].astype(cdt)).reshape(B, S, H, dh)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * dh ** -0.5
        s = jnp.where(pad[:, None, None, :], -jnp.inf, s)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(B, S, d)
        x = x + o @ blk["wo"].astype(cdt)
        h = _ln(x, blk["ln2"].astype(cdt))
        f = jax.nn.gelu(h @ blk["w1"].astype(cdt) + blk["b1"].astype(cdt))
        f = constrain(f, "batch", None, "model")
        x = x + f @ blk["w2"].astype(cdt) + blk["b2"].astype(cdt)
        x = constrain(x, "batch", None, None)
    return _ln(x, params["final_ln"].astype(cdt))


def masked_lm_loss(params, batch, cfg: Bert4RecConfig,
                   vocab_chunk: int = 16384):
    """Masked-item CE computed ONLY at masked positions, with a streaming
    (chunked) logsumexp over the huge item table — never materializing
    (B, S, vocab) logits (at 1M items those would be petabytes).

    batch: item_seq (B, S) with [MASK] tokens placed; masked_positions
    (B, M) int32 slot indices (0-padded); labels (B, M) true items at those
    slots, 0 = unused slot.
    """
    reps = encode(params, batch["item_seq"], cfg)          # (B, S, d)
    pos = batch["masked_positions"]
    rows = jnp.take_along_axis(reps, pos[..., None], axis=1)  # (B, M, d)
    d = rows.shape[-1]
    flat = rows.reshape(-1, d).astype(jnp.float32)         # (R, d)
    lab = batch["labels"].reshape(-1)                      # (R,)
    valid = lab > 0

    table = params["item_embed"].astype(jnp.float32)
    bias = params["out_bias"].astype(jnp.float32)
    gold = (flat * jnp.take(table, lab, axis=0)).sum(-1) + jnp.take(bias, lab)

    vpad = -(-cfg.padded_vocab // vocab_chunk) * vocab_chunk
    tpad = jnp.pad(table, ((0, vpad - cfg.padded_vocab), (0, 0)))
    bpad = jnp.pad(bias, (0, vpad - cfg.padded_vocab))
    n_chunks = vpad // vocab_chunk

    def body(carry, ci):
        m, s = carry
        start = ci * vocab_chunk
        emb = jax.lax.dynamic_slice_in_dim(tpad, start, vocab_chunk, 0)
        bc = jax.lax.dynamic_slice_in_dim(bpad, start, vocab_chunk, 0)
        sc = flat @ emb.T + bc[None, :]                    # (R, chunk)
        ids = start + jnp.arange(vocab_chunk)
        sc = jnp.where(ids[None, :] < cfg.vocab, sc, -jnp.inf)
        m_new = jnp.maximum(m, sc.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(
            sc - m_new[:, None]).sum(-1)
        return (m_new, s), None

    m0 = jnp.full((flat.shape[0],), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((flat.shape[0],), jnp.float32)
    (m, s), _ = jax.lax.scan(jax.checkpoint(body), (m0, s0),
                             jnp.arange(n_chunks))
    logz = m + jnp.log(jnp.maximum(s, 1e-30))
    ce = (logz - gold) * valid
    return ce.sum() / jnp.maximum(valid.sum(), 1)


def score_all_items(params, item_seq, cfg: Bert4RecConfig):
    """Next-item scores over the FULL table from the last position:
    (B, vocab). Used by serve_p99 / serve_bulk (table stays row-sharded;
    logits vocab-sharded)."""
    reps = encode(params, item_seq, cfg)
    last = reps[:, -1]
    logits = last @ params["item_embed"].astype(reps.dtype).T
    logits = logits + params["out_bias"].astype(reps.dtype)
    return constrain(logits, "batch", "table")


def score_candidates(params, item_seq, candidate_ids, cfg: Bert4RecConfig):
    """retrieval_cand: score ONE query against a candidate set via a batched
    dot (gather rows of the PAL-sharded table, single matmul — not a loop).
    item_seq: (B, S); candidate_ids: (n_cand,). Returns (B, n_cand)."""
    reps = encode(params, item_seq, cfg)
    last = reps[:, -1]                                  # (B, d)
    cand = jnp.take(params["item_embed"], candidate_ids, axis=0)
    cand = cand.astype(last.dtype)                      # (n_cand, d)
    bias = jnp.take(params["out_bias"], candidate_ids).astype(last.dtype)
    return last @ cand.T + bias[None, :]
