from . import common, equiformer_v2, gin, meshgraphnet, pna, wigner
