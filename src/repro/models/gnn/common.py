"""Shared GNN building blocks (functional, pytree params)."""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = ["init_mlp", "mlp_apply", "init_linear", "linear", "layer_norm",
           "GraphBatch"]

# A graph minibatch is a plain dict:
#   x:         (N, d_in) node features
#   src, dst:  (E,) int32 local edge indices
#   edge_mask: (E,) bool
#   node_mask: (N,) bool
#   edge_attr: optional (E, d_e)
#   pos:       optional (N, 3) coordinates
#   labels:    optional (N,) or (B,) targets
GraphBatch = Dict[str, jnp.ndarray]


def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (d_in, d_out), dtype) * (d_in ** -0.5),
        "b": jnp.zeros((d_out,), dtype),
    }


def linear(p, x):
    return x @ p["w"] + p["b"]


def init_mlp(key, dims: Sequence[int], dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return [init_linear(k, a, b, dtype) for k, a, b in zip(keys, dims[:-1], dims[1:])]


def mlp_apply(layers, x, act=jax.nn.relu, final_act=False):
    for i, p in enumerate(layers):
        x = linear(p, x)
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def layer_norm(x, scale=None, bias=None, eps=1e-5):
    m = x.mean(-1, keepdims=True)
    v = jnp.var(x, axis=-1, keepdims=True)
    y = (x - m) * jax.lax.rsqrt(v + eps)
    if scale is not None:
        y = y * scale
    if bias is not None:
        y = y + bias
    return y
